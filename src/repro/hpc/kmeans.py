"""k-means (Rodinia analogue, data mining).

Two regions: assignment and centroid update.  The points are read-only; the
only main-loop data object is the centroid table — the paper's extreme case
("critical DO size: 20 B"): persisting a tiny object transforms
recomputability (+93 % in the paper) at essentially zero cost.

Acceptance verification: final inertia within a tolerance band of the golden
run (a fidelity-threshold acceptance per §2.2, not bitwise equality).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.regions import IterativeApp, Region, State, VerifyResult


@jax.jit
def _assign(points: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    d2 = jnp.sum((points[:, None, :] - centroids[None, :, :]) ** 2, axis=-1)
    return jnp.argmin(d2, axis=1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("k",))
def _update(points: jnp.ndarray, assign: jnp.ndarray, centroids: jnp.ndarray, k: int) -> jnp.ndarray:
    one_hot = jax.nn.one_hot(assign, k, dtype=points.dtype)          # (n, k)
    sums = one_hot.T @ points                                        # (k, d)
    counts = one_hot.sum(axis=0)[:, None]                            # (k, 1)
    return jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), centroids)


@jax.jit
def _inertia(points: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    d2 = jnp.sum((points[:, None, :] - centroids[None, :, :]) ** 2, axis=-1)
    return jnp.sum(jnp.min(d2, axis=1))


# Batched lane hooks for the vectorized campaign engine.  The assignment and
# inertia kernels are elementwise chains with per-lane reductions over
# *non-lane* axes (distance sum over dims, argmin/min over clusters), so
# vmapping them is bitwise-safe.  The centroid update contracts
# ``one_hot.T @ points`` — a matmul whose vmap would become a batched
# ``dot_general`` with a different reduction tiling — so lanes go through
# ``lax.map``: one dispatch, per-lane HLO identical to ``_update``.
def _step_core(points: jnp.ndarray, cent_b: jnp.ndarray, k: int):
    assign_b = jax.vmap(lambda c: _assign(points, c))(cent_b)
    cent_new = jax.lax.map(
        lambda ac: _update(points, ac[0], ac[1], k), (assign_b, cent_b)
    )
    return assign_b, cent_new


@partial(jax.jit, static_argnames=("k",))
def _step_batch(points: jnp.ndarray, cent_b: jnp.ndarray, k: int):
    return _step_core(points, cent_b, k)


@jax.jit
def _inertia_batch(points: jnp.ndarray, cent_b: jnp.ndarray) -> jnp.ndarray:
    return jax.vmap(lambda c: _inertia(points, c))(cent_b)


class KMeansApp(IterativeApp):
    name = "kmeans"
    candidates = ("centroids", "k")

    def __init__(self, n_points: int = 4000, n_dims: int = 8, n_clusters: int = 12,
                 n_iters: int = 40, seed: int = 0, inertia_tol: float = 1.01,
                 cluster_scale: float = 3.0):
        self.cluster_scale = cluster_scale
        self.n_points = n_points
        self.n_dims = n_dims
        self.n_clusters = n_clusters
        self.n_iters = n_iters
        self._seed = seed
        self.inertia_tol = inertia_tol
        self._golden_inertia: float | None = None

    def init(self, seed: int = 0) -> State:
        rng = np.random.default_rng(self._seed)
        # moderately-separated clusters: losing the centroids can strand the
        # restart in a different local optimum (strict inertia acceptance)
        true_c = rng.standard_normal((self.n_clusters, self.n_dims)).astype(np.float32) * self.cluster_scale
        labels = rng.integers(0, self.n_clusters, self.n_points)
        points = (true_c[labels] + rng.standard_normal((self.n_points, self.n_dims))).astype(np.float32)
        init_c = points[rng.choice(self.n_points, self.n_clusters, replace=False)].copy()
        return {
            "points": points,                       # read-only
            "centroids": init_c,
            "assign": np.zeros(self.n_points, np.int32),  # temporal
            "k": np.zeros(1, np.int64),
        }

    def _region_assign(self, s: State) -> State:
        s = dict(s)
        s["assign"] = np.asarray(_assign(jnp.asarray(s["points"]), jnp.asarray(s["centroids"])))
        return s

    def _region_update(self, s: State) -> State:
        s = dict(s)
        s["centroids"] = np.asarray(
            _update(jnp.asarray(s["points"]), jnp.asarray(s["assign"]),
                    jnp.asarray(s["centroids"]), self.n_clusters)
        )
        s["k"] = s["k"] + 1
        return s

    def regions(self) -> Tuple[Region, ...]:
        return (
            Region("assign", self._region_assign, writes=("assign",),
                   reads=("points", "centroids"), cost=4.0,
                   hot_reads=("centroids",)),
            Region("update", self._region_update, writes=("centroids", "k"),
                   reads=("points", "assign"), cost=1.0,
                   hot_reads=("centroids",)),
        )

    def _golden_target(self) -> float:
        if self._golden_inertia is None:
            s = self.init(self._seed)
            for _ in range(self.n_iters):
                s = self.run_iteration(s)
            self._golden_inertia = float(_inertia(jnp.asarray(s["points"]), jnp.asarray(s["centroids"])))
        return self._golden_inertia

    def verify(self, state: State) -> VerifyResult:
        inertia = float(_inertia(jnp.asarray(state["points"]), jnp.asarray(state["centroids"])))
        target = self._golden_target()
        ok = np.isfinite(inertia) and inertia <= target * self.inertia_tol
        return VerifyResult(bool(ok), inertia)

    def progress(self, state: State) -> float:
        return float(_inertia(jnp.asarray(state["points"]), jnp.asarray(state["centroids"])))

    # ------------------------------------------------------- batched recompute
    # ``points`` is read-only and never a candidate, so every restart lane
    # carries the identical init-rebuilt array; the hooks stack only the
    # centroid tables and close over lane 0's points.
    supports_batched_step = True
    supports_lane_driver = True

    def batched_kernels(self):
        from ..core.regions import BatchedKernel

        s = self.init(0)
        pts = jnp.asarray(s["points"])
        c3 = np.stack([s["centroids"]] * 3)
        k = self.n_clusters
        return (
            BatchedKernel("step_batch", lambda cb: _step_batch(pts, cb, k),
                          (c3,), {0: 0}),
            BatchedKernel("inertia_batch", lambda cb: _inertia_batch(pts, cb),
                          (c3,), {0: 0}),
        )

    def run_iteration_batch(self, states):
        pts = jnp.asarray(states[0]["points"])
        cent_b = np.stack([s["centroids"] for s in states])
        assign_b, cent_new = _step_batch(pts, jnp.asarray(cent_b), self.n_clusters)
        assign_b = np.asarray(assign_b)
        cent_new = np.asarray(cent_new)
        out = []
        for i, s in enumerate(states):
            s = dict(s)
            s["assign"] = assign_b[i]
            s["centroids"] = cent_new[i]
            s["k"] = s["k"] + 1
            out.append(s)
        return out

    # converged() is a pure iteration counter — the looping default is free

    def verify_batch(self, states):
        pts = jnp.asarray(states[0]["points"])
        cent_b = np.stack([s["centroids"] for s in states])
        inertias = np.asarray(_inertia_batch(pts, jnp.asarray(cent_b)))
        target = self._golden_target()
        out = []
        for v in inertias:
            v = float(v)
            out.append(VerifyResult(bool(np.isfinite(v) and v <= target * self.inertia_tol), v))
        return out

    def advance_lanes(self, states, its, stop):
        from ..core.lane_driver import LaneSpec, cached_driver

        n_iters, k = self.n_iters, self.n_clusters

        def step(consts, a):
            assign_b, cent_new = _step_core(consts["points"], a["centroids"], k)
            return {"centroids": cent_new, "assign": assign_b, "k": a["k"] + 1}

        def check(consts, a, it):
            conv = it >= n_iters  # counter-only converged(), never raises
            return conv, jnp.zeros_like(conv)

        key = ("kmeans", self.n_points, self.n_dims, k, self.n_iters,
               self._seed, self.cluster_scale)
        drv = cached_driver(key, lambda: LaneSpec(
            carry=("centroids", "assign", "k"),
            consts=lambda s0: {"points": s0["points"]},
            step=step, check=check,
        ))
        return drv.advance(states, its, stop)
