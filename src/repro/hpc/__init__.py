"""Region-structured HPC applications (the paper's benchmark spectrum)."""
from typing import Dict

from ..core.regions import IterativeApp
from .cg import CGApp
from .heat import HeatApp
from .kmeans import KMeansApp
from .mg import MGApp
from .montecarlo import MonteCarloApp
from .pagerank import PageRankApp
from .sor import SORApp

_REGISTRY = {
    "cg": CGApp,
    "mg": MGApp,
    "kmeans": KMeansApp,
    "montecarlo": MonteCarloApp,
    "heat": HeatApp,
    "sor": SORApp,
    "pagerank": PageRankApp,
}


def app_names():
    """Every registered app name — HPC suite plus the model stack.

    Delegates to :mod:`repro.hpc.suite`, the single registry (imported
    lazily: suite itself imports ``_REGISTRY`` from this module).
    """
    from . import suite

    return list(suite.app_names())


def get_app(name: str, **kwargs) -> IterativeApp:
    """Instantiate a registered app; kwargs override the default problem."""
    from . import suite

    return suite.get_app(name, **kwargs)


__all__ = [
    "get_app", "app_names", "CGApp", "MGApp", "KMeansApp", "MonteCarloApp",
    "HeatApp", "SORApp", "PageRankApp",
]
