"""Region-structured HPC applications (the paper's benchmark spectrum)."""
from typing import Dict

from ..core.regions import IterativeApp
from .cg import CGApp
from .heat import HeatApp
from .kmeans import KMeansApp
from .mg import MGApp
from .montecarlo import MonteCarloApp
from .pagerank import PageRankApp
from .sor import SORApp

_REGISTRY = {
    "cg": CGApp,
    "mg": MGApp,
    "kmeans": KMeansApp,
    "montecarlo": MonteCarloApp,
    "heat": HeatApp,
    "sor": SORApp,
    "pagerank": PageRankApp,
}


def app_names():
    return sorted(_REGISTRY.keys())


def get_app(name: str, **kwargs) -> IterativeApp:
    """Instantiate an app; kwargs override the default (CI-sized) problem."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown app {name!r}; have {app_names()}") from None
    return cls(**kwargs)


__all__ = [
    "get_app", "app_names", "CGApp", "MGApp", "KMeansApp", "MonteCarloApp",
    "HeatApp", "SORApp", "PageRankApp",
]
