"""SOR: red-black successive over-relaxation on the 2-D Poisson problem.

Analogue of a structured-grid smoother kernel (the SP/BT family's relaxation
loop).  Solves A u = b for the SPD 5-point Laplacian with an over-relaxed
red-black Gauss-Seidel sweep at the near-optimal ``omega = 2/(1+sin(pi/g))``.
Three regions per main-loop iteration: residual diagnostic, the red/black
sweep pair, and bookkeeping.

SOR sits between HEAT and CG on the paper's recomputability spectrum: the
sweep is a contraction (block-stale values are damped like any other error
component), but with over-relaxation the damping is far slower than HEAT's
parabolic smoothing, so late crashes leave too few remaining iterations and
spill into S2.

Acceptance verification: true relative residual ||b - A u|| / ||b|| below
tolerance (math-invariant check, §2.2).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.regions import IterativeApp, Region, State, VerifyResult
from .common import laplacian_apply, rel_residual


@partial(jax.jit, static_argnames=("g", "pairs"))
def _rb_sor(u_flat: jnp.ndarray, b_flat: jnp.ndarray, g: int, omega: float,
            pairs: int) -> jnp.ndarray:
    u = u_flat.reshape(g, g)
    b = b_flat.reshape(g, g)
    ii, jj = jnp.meshgrid(jnp.arange(g), jnp.arange(g), indexing="ij")
    red = ((ii + jj) % 2 == 0).astype(u.dtype)

    def half_sweep(u, mask):
        nb = (
            jnp.pad(u[1:, :], ((0, 1), (0, 0)))
            + jnp.pad(u[:-1, :], ((1, 0), (0, 0)))
            + jnp.pad(u[:, 1:], ((0, 0), (0, 1)))
            + jnp.pad(u[:, :-1], ((0, 0), (1, 0)))
        )
        gs = (b + nb) / 4.0
        return u + omega * mask * (gs - u)

    def body(_, u):
        u = half_sweep(u, red)
        return half_sweep(u, 1.0 - red)

    return jax.lax.fori_loop(0, pairs, body, u).reshape(-1)


# Batched lane hooks for the vectorized campaign engine.  The SOR update and
# the Laplacian are pure elementwise/stencil chains, so vmapping them is
# bitwise identical per lane to the serial kernels (no cross-lane reductions
# are introduced) — asserted by tests/test_campaign_vec.py.
@partial(jax.jit, static_argnames=("g",))
def _lap_batch(u_batch: jnp.ndarray, g: int) -> jnp.ndarray:
    return jax.vmap(lambda u: laplacian_apply(u, g))(u_batch)


@partial(jax.jit, static_argnames=("g", "pairs"))
def _rb_sor_batch(
    u_batch: jnp.ndarray, b_batch: jnp.ndarray, g: int, omega: float, pairs: int
) -> jnp.ndarray:
    return jax.vmap(lambda u, b: _rb_sor(u, b, g, omega, pairs))(u_batch, b_batch)


class SORApp(IterativeApp):
    name = "sor"
    candidates = ("u", "res", "k")
    #: campaign fault tuning: the red/black sweep is the heavy region and a
    #: contraction, so correlated failures should concentrate there
    #: (shape=4); torn half-sweep cachelines are the realistic tearing
    #: surface for a stencil smoother, so tear deeper into the store queue.
    fault_defaults = {
        "correlated-region": {"shape": 4.0},
        "torn-write": {"p_torn": 0.7, "depth": 16},
    }

    def __init__(self, grid: int = 32, tol: float = 1e-4, n_iters: int = 200,
                 seed: int = 0, omega: float | None = None, pairs_per_iter: int = 2):
        self.grid = grid
        self.tol = tol
        self.n_iters = n_iters
        self._seed = seed
        self.omega = float(omega) if omega is not None else 2.0 / (1.0 + np.sin(np.pi / grid))
        self.pairs_per_iter = pairs_per_iter

    def init(self, seed: int = 0) -> State:
        g = self.grid
        rng = np.random.default_rng(self._seed)
        # smooth source: a few Gaussian bumps (low-frequency content is the
        # slow-converging part, which keeps golden_iters comfortably > 1)
        ii, jj = np.meshgrid(np.arange(g), np.arange(g), indexing="ij")
        b = np.zeros((g, g), np.float32)
        for _ in range(3):
            ci, cj = rng.uniform(g * 0.2, g * 0.8, size=2)
            s = rng.uniform(g / 8, g / 4)
            b += rng.uniform(0.5, 1.5) * np.exp(-((ii - ci) ** 2 + (jj - cj) ** 2) / (2 * s * s))
        return {
            "u": np.zeros(g * g, np.float32),
            "res": np.zeros(g * g, np.float32),  # temporal diagnostic
            "k": np.zeros(1, np.int64),
            "b": b.reshape(-1).astype(np.float32),  # read-only
        }

    def _region_residual(self, s: State) -> State:
        s = dict(s)
        s["res"] = s["b"] - np.asarray(laplacian_apply(jnp.asarray(s["u"]), self.grid))
        return s

    def _region_sweep(self, s: State) -> State:
        s = dict(s)
        s["u"] = np.asarray(
            _rb_sor(jnp.asarray(s["u"]), jnp.asarray(s["b"]), self.grid,
                    self.omega, self.pairs_per_iter)
        )
        return s

    def _region_book(self, s: State) -> State:
        s = dict(s)
        s["k"] = s["k"] + 1
        return s

    def regions(self) -> Tuple[Region, ...]:
        return (
            Region("residual", self._region_residual, writes=("res",), reads=("u", "b"), cost=1.0),
            Region("sweep", self._region_sweep, writes=("u",), reads=("u", "b"), cost=2.0),
            Region("book", self._region_book, writes=("k",), cost=0.1),
        )

    def verify(self, state: State) -> VerifyResult:
        r = rel_residual(state["u"], state["b"], self.grid)
        return VerifyResult(bool(np.isfinite(r) and r < self.tol), r)

    def progress(self, state: State) -> float:
        return rel_residual(state["u"], state["b"], self.grid)

    def converged(self, state: State, it: int) -> bool:
        if it >= self.n_iters:
            return True
        r = rel_residual(state["u"], state["b"], self.grid)
        if not np.isfinite(r):
            raise FloatingPointError("SOR blow-up")
        # slim early-stop margin: a restart from block-stale state must claw
        # back most of the lost progress to pass acceptance, which is what
        # spreads SOR crashes across S1/S2 instead of trivially recomputing
        return r < self.tol * 0.95

    # ------------------------------------------------------- batched recompute
    supports_batched_step = True

    def batched_kernels(self):
        from ..core.regions import BatchedKernel

        s = self.init(0)
        u3 = np.stack([s["u"]] * 3)
        b3 = np.stack([s["b"]] * 3)
        g, om, pairs = self.grid, self.omega, self.pairs_per_iter
        return (
            BatchedKernel("lap_batch", lambda ub: _lap_batch(ub, g),
                          (u3,), {0: 0}),
            BatchedKernel("rb_sor_batch",
                          lambda ub, bb: _rb_sor_batch(ub, bb, g, om, pairs),
                          (u3, b3), {0: 0, 1: 0}),
        )

    def _residuals_batch(self, states) -> list:
        """rel_residual per lane with one batched Laplacian dispatch; the
        norms run in NumPy per contiguous row, exactly like the serial path."""
        u_rows = np.stack([s["u"] for s in states])
        b_rows = np.stack([s["b"] for s in states])
        lap = np.asarray(_lap_batch(jnp.asarray(u_rows), self.grid))
        out = []
        for i in range(len(states)):
            r = b_rows[i] - lap[i]
            nb = float(np.linalg.norm(b_rows[i]))
            out.append(float(np.linalg.norm(r)) / max(nb, 1e-30))
        return out

    def run_iteration_batch(self, states):
        u_rows = np.stack([s["u"] for s in states])
        b_rows = np.stack([s["b"] for s in states])
        # region order preserved: the residual diagnostic reads the pre-sweep u
        lap = np.asarray(_lap_batch(jnp.asarray(u_rows), self.grid))
        u_new = np.asarray(_rb_sor_batch(
            jnp.asarray(u_rows), jnp.asarray(b_rows), self.grid,
            self.omega, self.pairs_per_iter,
        ))
        out = []
        for i, s in enumerate(states):
            s = dict(s)
            s["res"] = b_rows[i] - lap[i]
            s["u"] = u_new[i]
            s["k"] = s["k"] + 1
            out.append(s)
        return out

    def converged_batch(self, states, its):
        out: list = [None] * len(states)
        need = []
        for i, it in enumerate(its):
            if it >= self.n_iters:
                out[i] = True  # serial converged() returns before the residual
            else:
                need.append(i)
        if need:
            rs = self._residuals_batch([states[i] for i in need])
            for i, r in zip(need, rs):
                if not np.isfinite(r):
                    out[i] = FloatingPointError("SOR blow-up")
                else:
                    out[i] = bool(r < self.tol * 0.95)
        return out

    def verify_batch(self, states):
        return [
            VerifyResult(bool(np.isfinite(r) and r < self.tol), r)
            for r in self._residuals_batch(states)
        ]
