"""HEAT: explicit 2-D heat diffusion to steady state (LULESH/SP stand-in:
structured-grid time stepping with strong smoothing dynamics).

A plate with implicit zero boundary and a few *pinned* (fixed-temperature)
source cells; explicit diffusion relaxes to the discrete harmonic solution.
Three regions: flux/diagnostic, explicit update (pins re-imposed inside the
step so equilibrium is exact), pin/bookkeeping.  The parabolic smoother damps
block-local perturbations exponentially, so this is the strongly-recomputable
end of the spectrum (the paper's SP at 88 %).

Acceptance verification: steady-state residual max|lap(u)| over non-source
cells below tolerance (physical-law check: harmonic balance).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.regions import IterativeApp, Region, State, VerifyResult


@partial(jax.jit, static_argnames=("g",))
def _laplace(u_flat: jnp.ndarray, g: int) -> jnp.ndarray:
    u = u_flat.reshape(g, g)
    lap = (
        jnp.pad(u[1:, :], ((0, 1), (0, 0)))
        + jnp.pad(u[:-1, :], ((1, 0), (0, 0)))
        + jnp.pad(u[:, 1:], ((0, 0), (0, 1)))
        + jnp.pad(u[:, :-1], ((0, 0), (1, 0)))
        - 4.0 * u
    )
    return lap.reshape(-1)


@partial(jax.jit, static_argnames=("g", "steps", "dt"))
def _diffuse(u_flat: jnp.ndarray, pin_idx: jnp.ndarray, g: int, steps: int, dt: float) -> jnp.ndarray:
    def body(_, u):
        u = u + dt * _laplace(u, g)
        return u.at[pin_idx].set(1.0)

    return jax.lax.fori_loop(0, steps, body, u_flat)


class HeatApp(IterativeApp):
    name = "heat"
    candidates = ("u", "k")

    def __init__(self, grid: int = 48, tol: float = 1e-4, n_iters: int = 600,
                 seed: int = 0, dt: float = 0.2, steps_per_iter: int = 8):
        self.grid = grid
        self.tol = tol
        self.n_iters = n_iters
        self._seed = seed
        self.dt = dt
        self.steps_per_iter = steps_per_iter

    def init(self, seed: int = 0) -> State:
        g = self.grid
        rng = np.random.default_rng(self._seed)
        idx = rng.choice(np.arange(g * g).reshape(g, g)[g // 4 : 3 * g // 4,
                                                        g // 4 : 3 * g // 4].reshape(-1),
                         size=4, replace=False).astype(np.int32)
        u = np.zeros(g * g, np.float32)
        u[idx] = 1.0
        return {
            "u": u,
            "flux": np.zeros(g * g, np.float32),  # temporal diagnostic
            "k": np.zeros(1, np.int64),
            "pins": idx,  # read-only
        }

    def _region_flux(self, s: State) -> State:
        s = dict(s)
        s["flux"] = np.asarray(_laplace(jnp.asarray(s["u"]), self.grid))
        return s

    def _region_update(self, s: State) -> State:
        s = dict(s)
        s["u"] = np.asarray(
            _diffuse(jnp.asarray(s["u"]), jnp.asarray(s["pins"]), self.grid,
                     self.steps_per_iter, self.dt)
        )
        return s

    def _region_pin(self, s: State) -> State:
        s = dict(s)
        u = s["u"].copy()
        u[s["pins"]] = 1.0
        s["u"] = u
        s["k"] = s["k"] + 1
        return s

    def regions(self) -> Tuple[Region, ...]:
        return (
            Region("flux", self._region_flux, writes=("flux",), reads=("u",), cost=1.0),
            Region("update", self._region_update, writes=("u",), reads=("u",), cost=2.0),
            Region("pin", self._region_pin, writes=("u", "k"), reads=("u",), cost=0.5),
        )

    def _residual(self, state: State) -> float:
        res = np.abs(np.asarray(_laplace(jnp.asarray(state["u"]), self.grid)))
        res[state["pins"]] = 0.0
        return float(res.max())

    def verify(self, state: State) -> VerifyResult:
        r = self._residual(state)
        return VerifyResult(bool(np.isfinite(r) and r < self.tol), r)

    def progress(self, state: State) -> float:
        return self._residual(state)

    def converged(self, state: State, it: int) -> bool:
        if it >= self.n_iters:
            return True
        r = self._residual(state)
        if not np.isfinite(r):
            raise FloatingPointError("heat blow-up")
        return r < self.tol * 0.5
