"""HEAT: explicit 2-D heat diffusion to steady state (LULESH/SP stand-in:
structured-grid time stepping with strong smoothing dynamics).

A plate with implicit zero boundary and a few *pinned* (fixed-temperature)
source cells; explicit diffusion relaxes to the discrete harmonic solution.
Three regions: flux/diagnostic, explicit update (pins re-imposed inside the
step so equilibrium is exact), pin/bookkeeping.  The parabolic smoother damps
block-local perturbations exponentially, so this is the strongly-recomputable
end of the spectrum (the paper's SP at 88 %).

Acceptance verification: steady-state residual max|lap(u)| over non-source
cells below tolerance (physical-law check: harmonic balance).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.regions import IterativeApp, Region, State, VerifyResult


@partial(jax.jit, static_argnames=("g",))
def _laplace(u_flat: jnp.ndarray, g: int) -> jnp.ndarray:
    u = u_flat.reshape(g, g)
    lap = (
        jnp.pad(u[1:, :], ((0, 1), (0, 0)))
        + jnp.pad(u[:-1, :], ((1, 0), (0, 0)))
        + jnp.pad(u[:, 1:], ((0, 0), (0, 1)))
        + jnp.pad(u[:, :-1], ((0, 0), (1, 0)))
        - 4.0 * u
    )
    return lap.reshape(-1)


@partial(jax.jit, static_argnames=("g", "steps", "dt"))
def _diffuse(u_flat: jnp.ndarray, pin_idx: jnp.ndarray, g: int, steps: int, dt: float) -> jnp.ndarray:
    def body(_, u):
        u = u + dt * _laplace(u, g)
        return u.at[pin_idx].set(1.0)

    return jax.lax.fori_loop(0, steps, body, u_flat)


# Batched lane hooks for the vectorized campaign engine.  The diffusion step
# is a pure elementwise/stencil chain, so vmapping is bitwise-safe; the only
# wrinkle is the pin scatter, which becomes a value-identical elementwise
# ``where(pin_mask, 1.0, u)`` (both write exactly 1.0f at the pins) so the
# batched kernel stays scatter-free and lane-structure-transparent to the
# determinism lint.
def _heat_step_core(u_b: jnp.ndarray, pin_mask: jnp.ndarray, g: int, steps: int, dt: float):
    """One main-loop iteration on stacked lanes: (flux, updated u)."""
    flux_b = jax.vmap(lambda u: _laplace(u, g))(u_b)

    def diffuse_one(u):
        def body(_, u):
            u = u + dt * _laplace(u, g)
            return jnp.where(pin_mask, 1.0, u)

        return jax.lax.fori_loop(0, steps, body, u)

    u_b = jax.vmap(diffuse_one)(u_b)
    u_b = jnp.where(pin_mask, 1.0, u_b)  # the pin region re-imposes sources
    return flux_b, u_b


@partial(jax.jit, static_argnames=("g", "steps", "dt"))
def _heat_step_batch(u_b, pin_mask, g: int, steps: int, dt: float):
    return _heat_step_core(u_b, pin_mask, g, steps, dt)


@partial(jax.jit, static_argnames=("g",))
def _lap_batch(u_b: jnp.ndarray, g: int) -> jnp.ndarray:
    return jax.vmap(lambda u: _laplace(u, g))(u_b)


class HeatApp(IterativeApp):
    name = "heat"
    candidates = ("u", "k")

    def __init__(self, grid: int = 48, tol: float = 1e-4, n_iters: int = 600,
                 seed: int = 0, dt: float = 0.2, steps_per_iter: int = 8):
        self.grid = grid
        self.tol = tol
        self.n_iters = n_iters
        self._seed = seed
        self.dt = dt
        self.steps_per_iter = steps_per_iter

    def init(self, seed: int = 0) -> State:
        g = self.grid
        rng = np.random.default_rng(self._seed)
        idx = rng.choice(np.arange(g * g).reshape(g, g)[g // 4 : 3 * g // 4,
                                                        g // 4 : 3 * g // 4].reshape(-1),
                         size=4, replace=False).astype(np.int32)
        u = np.zeros(g * g, np.float32)
        u[idx] = 1.0
        return {
            "u": u,
            "flux": np.zeros(g * g, np.float32),  # temporal diagnostic
            "k": np.zeros(1, np.int64),
            "pins": idx,  # read-only
        }

    def _region_flux(self, s: State) -> State:
        s = dict(s)
        s["flux"] = np.asarray(_laplace(jnp.asarray(s["u"]), self.grid))
        return s

    def _region_update(self, s: State) -> State:
        s = dict(s)
        s["u"] = np.asarray(
            _diffuse(jnp.asarray(s["u"]), jnp.asarray(s["pins"]), self.grid,
                     self.steps_per_iter, self.dt)
        )
        return s

    def _region_pin(self, s: State) -> State:
        s = dict(s)
        u = s["u"].copy()
        u[s["pins"]] = 1.0
        s["u"] = u
        s["k"] = s["k"] + 1
        return s

    def regions(self) -> Tuple[Region, ...]:
        return (
            Region("flux", self._region_flux, writes=("flux",), reads=("u",), cost=1.0),
            Region("update", self._region_update, writes=("u",), reads=("u",), cost=2.0),
            Region("pin", self._region_pin, writes=("u", "k"), reads=("u",), cost=0.5),
        )

    def _residual(self, state: State) -> float:
        res = np.abs(np.asarray(_laplace(jnp.asarray(state["u"]), self.grid)))
        res[state["pins"]] = 0.0
        return float(res.max())

    def verify(self, state: State) -> VerifyResult:
        r = self._residual(state)
        return VerifyResult(bool(np.isfinite(r) and r < self.tol), r)

    def progress(self, state: State) -> float:
        return self._residual(state)

    def converged(self, state: State, it: int) -> bool:
        if it >= self.n_iters:
            return True
        r = self._residual(state)
        if not np.isfinite(r):
            raise FloatingPointError("heat blow-up")
        return r < self.tol * 0.5

    # ------------------------------------------------------- batched recompute
    # ``pins`` is read-only (rebuilt identically by every restart), so the
    # hooks stack only the temperature fields and close over lane 0's pin
    # mask.  The convergence residual max|lap(u)| uses only exact ops (abs,
    # max, compare), so the driver decides it in-jit against an
    # f32_monotone_cutoff of the serial float64 threshold.
    supports_batched_step = True
    supports_lane_driver = True

    def _pin_mask(self, state: State) -> np.ndarray:
        mask = np.zeros(self.grid * self.grid, bool)
        mask[np.asarray(state["pins"])] = True
        return mask

    def batched_kernels(self):
        from ..core.regions import BatchedKernel

        s = self.init(0)
        u3 = np.stack([s["u"]] * 3)
        mask = self._pin_mask(s)
        g, steps, dt = self.grid, self.steps_per_iter, self.dt
        return (
            BatchedKernel("heat_step_batch",
                          lambda ub: _heat_step_batch(ub, mask, g, steps, dt),
                          (u3,), {0: 0}),
            BatchedKernel("lap_batch", lambda ub: _lap_batch(ub, g),
                          (u3,), {0: 0}),
        )

    def run_iteration_batch(self, states):
        u_b = np.stack([s["u"] for s in states])
        mask = self._pin_mask(states[0])
        flux_b, u_new = _heat_step_batch(
            jnp.asarray(u_b), jnp.asarray(mask), self.grid,
            self.steps_per_iter, self.dt,
        )
        flux_b = np.asarray(flux_b)
        u_new = np.asarray(u_new)
        out = []
        for i, s in enumerate(states):
            s = dict(s)
            s["flux"] = flux_b[i]
            s["u"] = u_new[i]
            s["k"] = s["k"] + 1
            out.append(s)
        return out

    def _residuals_batch(self, states) -> list:
        """max|lap(u)| per lane (pins zeroed) with one batched Laplacian
        dispatch; abs/max run in NumPy per row, exactly like the serial path
        (both are order-exact ops, so the values are bitwise the serial
        ones)."""
        lap = np.asarray(_lap_batch(jnp.asarray(np.stack([s["u"] for s in states])), self.grid))
        pins = states[0]["pins"]
        out = []
        for i in range(len(states)):
            res = np.abs(lap[i])
            res[pins] = 0.0
            out.append(float(res.max()))
        return out

    def converged_batch(self, states, its):
        out: list = [None] * len(states)
        need = []
        for i, it in enumerate(its):
            if it >= self.n_iters:
                out[i] = True  # serial converged() returns before the residual
            else:
                need.append(i)
        if need:
            rs = self._residuals_batch([states[i] for i in need])
            for i, r in zip(need, rs):
                if not np.isfinite(r):
                    out[i] = FloatingPointError("heat blow-up")
                else:
                    out[i] = bool(r < self.tol * 0.5)
        return out

    def verify_batch(self, states):
        return [
            VerifyResult(bool(np.isfinite(r) and r < self.tol), r)
            for r in self._residuals_batch(states)
        ]

    def advance_lanes(self, states, its, stop):
        from ..core.lane_driver import LaneSpec, cached_driver, f32_monotone_cutoff

        g, steps, dt, n_iters = self.grid, self.steps_per_iter, self.dt, self.n_iters
        cutoff = f32_monotone_cutoff(lambda v: v < self.tol * 0.5)

        def step(consts, a):
            flux_b, u_b = _heat_step_core(a["u"], consts["pin_mask"], g, steps, dt)
            return {"u": u_b, "flux": flux_b, "k": a["k"] + 1}

        def check(consts, a, it):
            lap = jax.vmap(lambda u: _laplace(u, g))(a["u"])
            r = jnp.max(jnp.abs(jnp.where(consts["pin_mask"], 0.0, lap)), axis=1)
            over = it >= n_iters
            fin = jnp.isfinite(r)
            conv = over | (fin & (r <= cutoff))
            suspect = ~over & ~fin  # serial converged() would raise
            return conv, suspect

        key = ("heat", g, self.tol, n_iters, self._seed, dt, steps)
        drv = cached_driver(key, lambda: LaneSpec(
            carry=("u", "flux", "k"),
            consts=lambda s0: {"pin_mask": self._pin_mask(s0)},
            step=step, check=check,
        ))
        return drv.advance(states, its, stop)
