"""MG: two-grid multigrid for the 2-D Poisson problem (NPB MG analogue).

Four first-level code regions per V-cycle — residual, coarse solve,
prolong+correct, fine smoothing — exactly the R1–R4 structure of the paper's
Fig 2a.  ``u`` and ``r`` are the big main-loop data objects (the paper's
critical-object study on MG uses u, r and an index object); the coarse-grid
correction is temporal and rebuilt every iteration.

Multigrid is strongly self-correcting: a block-stale ``u`` is just a worse
initial guess for the next V-cycle, so recomputability is high once ``u`` is
persisted (paper Fig 4a: persisting u lifts MG from 27 % to 63 %).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.regions import IterativeApp, Region, State, VerifyResult
from .common import jacobi_sweep, laplacian_apply, prolong, rel_residual, restrict


# Batched lane hooks for the vectorized campaign engine.  The V-cycle is
# stencils, grid-transfer reshapes and elementwise chains — no ``dot_general``
# — so vmapping is bitwise-safe.  Two serial host-side roundings must survive
# the move in-program: ``restrict`` materializes ``0.25 * sum`` as its own
# program root, and the coarse right-hand side ``4.0 * rc`` is an eager
# standalone multiply.  Inside one XLA program the first would reassociate
# with the second (``4 * (0.25 * s) -> s``) and the result would contract
# into the first Jacobi ``b + nb`` as an FMA; multiplying each by ``one`` — a
# *runtime* 1.0f the compiler cannot fold — pins both roundings exactly where
# the serial path takes them (see :func:`repro.hpc.cg._cg_step_core`).
def _mg_cycle_core(a: dict, b: jnp.ndarray, one: jnp.ndarray, g: int,
                   coarse_sweeps: int, fine_sweeps: int) -> dict:
    """One V-cycle (residual, coarse solve, prolong+correct, fine smoothing)
    on stacked lanes; mirrors the serial region chain value-for-value."""
    u = a["u"]
    r = b - jax.vmap(lambda v: laplacian_apply(v, g))(u)
    rc = jax.vmap(lambda v: restrict(v, g))(r) * one
    bc = (4.0 * rc) * one
    ec = jnp.zeros_like(rc)
    for _ in range(coarse_sweeps):
        ec = jax.vmap(lambda e, bb: jacobi_sweep(e, bb, g // 2))(ec, bc)
    u = u + jax.vmap(lambda e: prolong(e, g))(ec)
    for _ in range(fine_sweeps):
        u = jax.vmap(lambda v: jacobi_sweep(v, b, g))(u)
    return {"u": u, "r": r, "ec": ec, "k": a["k"] + 1}


@partial(jax.jit, static_argnames=("g", "coarse_sweeps", "fine_sweeps"))
def _mg_cycle_batch(u, r, ec, k, b, one, g: int, coarse_sweeps: int, fine_sweeps: int):
    out = _mg_cycle_core({"u": u, "r": r, "ec": ec, "k": k}, b, one, g,
                         coarse_sweeps, fine_sweeps)
    return (out["u"], out["r"], out["ec"], out["k"])


@partial(jax.jit, static_argnames=("g",))
def _lap_batch(u_b: jnp.ndarray, g: int) -> jnp.ndarray:
    return jax.vmap(lambda u: laplacian_apply(u, g))(u_b)


class MGApp(IterativeApp):
    name = "mg"
    candidates = ("u", "r", "k")

    def __init__(self, grid: int = 64, rel_eps: float = 1e-3, n_iters: int = 24, seed: int = 0,
                 coarse_sweeps: int = 8, fine_sweeps: int = 2):
        self.grid = grid
        # NPB-style verification: the final residual norm must match the
        # golden run's value to rel_eps (precise-numerical-integrity
        # acceptance, paper §2.2) — NPB MG compares norms against a reference
        # with a tight epsilon, on a *fixed* iteration schedule.
        self.rel_eps = rel_eps
        self.n_iters = n_iters
        self._seed = seed
        self.coarse_sweeps = coarse_sweeps
        self.fine_sweeps = fine_sweeps
        self._golden_res: float | None = None

    def init(self, seed: int = 0) -> State:
        g = self.grid
        rng = np.random.default_rng(self._seed)
        u_true = rng.standard_normal(g * g).astype(np.float32)
        b = np.asarray(laplacian_apply(jnp.asarray(u_true), g))
        return {
            "u": np.zeros(g * g, np.float32),
            "r": b.copy(),
            "ec": np.zeros((g // 2) * (g // 2), np.float32),  # temporal
            "k": np.zeros(1, np.int64),
            "b": b,  # read-only
        }

    # ---------------------------------------------------------------- regions
    def _residual(self, s: State) -> State:
        s = dict(s)
        s["r"] = s["b"] - np.asarray(laplacian_apply(jnp.asarray(s["u"]), self.grid))
        return s

    def _coarse(self, s: State) -> State:
        s = dict(s)
        g = self.grid
        rc = restrict(jnp.asarray(s["r"]), g)
        # scale: restriction halves h, so the coarse operator is 4x weaker
        ec = jnp.zeros_like(rc)
        for _ in range(self.coarse_sweeps):
            ec = jacobi_sweep(ec, 4.0 * rc, g // 2)
        s["ec"] = np.asarray(ec)
        return s

    def _correct(self, s: State) -> State:
        s = dict(s)
        s["u"] = s["u"] + np.asarray(prolong(jnp.asarray(s["ec"]), self.grid))
        return s

    def _smooth(self, s: State) -> State:
        s = dict(s)
        u = jnp.asarray(s["u"])
        for _ in range(self.fine_sweeps):
            u = jacobi_sweep(u, jnp.asarray(s["b"]), self.grid)
        s["u"] = np.asarray(u)
        s["k"] = s["k"] + 1
        return s

    def regions(self) -> Tuple[Region, ...]:
        return (
            Region("R1_residual", self._residual, writes=("r",), reads=("u", "b"), cost=1.0),
            Region("R2_coarse", self._coarse, writes=("ec",), reads=("r",), cost=2.0),
            Region("R3_correct", self._correct, writes=("u",), reads=("ec", "u"), cost=1.0),
            Region("R4_smooth", self._smooth, writes=("u", "k"), reads=("u", "b"), cost=2.0),
        )

    # ----------------------------------------------------------- verification
    def _golden_residual(self) -> float:
        if self._golden_res is None:
            s = self.init(self._seed)
            for _ in range(self.n_iters):
                s = self.run_iteration(s)
            self._golden_res = rel_residual(s["u"], s["b"], self.grid)
        return self._golden_res

    def verify(self, state: State) -> VerifyResult:
        res = rel_residual(state["u"], state["b"], self.grid)
        ref = self._golden_residual()
        ok = np.isfinite(res) and abs(res - ref) <= self.rel_eps * max(ref, 1e-30)
        return VerifyResult(bool(ok), res)

    def progress(self, state: State) -> float:
        return rel_residual(state["u"], state["b"], self.grid)

    def converged(self, state: State, it: int) -> bool:
        # fixed schedule (NPB MG runs exactly nit V-cycles)
        res = self.progress(state)
        if not np.isfinite(res):
            raise FloatingPointError("MG blow-up")
        return it >= self.n_iters

    # ------------------------------------------------------- batched recompute
    # ``b`` is read-only, so the hooks stack only the per-lane fields and
    # close over lane 0's right-hand side.
    supports_batched_step = True
    supports_lane_driver = True

    _CARRY = ("u", "r", "ec", "k")

    def batched_kernels(self):
        from ..core.regions import BatchedKernel

        s = self.init(0)
        b = jnp.asarray(s["b"])
        rows = {f: np.stack([s[f]] * 3) for f in self._CARRY}
        g, cs, fs = self.grid, self.coarse_sweeps, self.fine_sweeps
        args = tuple(rows[f] for f in self._CARRY)
        return (
            BatchedKernel("mg_cycle_batch",
                          lambda *vs: _mg_cycle_batch(*vs, b, np.float32(1.0), g, cs, fs),
                          args, {i: 0 for i in range(len(args))}),
            BatchedKernel("lap_batch", lambda ub: _lap_batch(ub, g),
                          (rows["u"],), {0: 0}),
        )

    def run_iteration_batch(self, states):
        b = jnp.asarray(states[0]["b"])
        stacked = [jnp.asarray(np.stack([s[f] for s in states])) for f in self._CARRY]
        new = _mg_cycle_batch(*stacked, b, np.float32(1.0), self.grid,
                              self.coarse_sweeps, self.fine_sweeps)
        new = [np.asarray(v) for v in new]
        out = []
        for i, s in enumerate(states):
            s = dict(s)
            for f, rows in zip(self._CARRY, new):
                s[f] = rows[i].astype(s[f].dtype, copy=False)
            out.append(s)
        return out

    def _rel_residuals_batch(self, states) -> list:
        """Per-lane true relative residual with one batched Laplacian
        dispatch; the subtraction and norms run in NumPy per contiguous row,
        exactly like the serial ``rel_residual``."""
        lap = np.asarray(_lap_batch(jnp.asarray(np.stack([s["u"] for s in states])), self.grid))
        out = []
        for i, s in enumerate(states):
            r = s["b"] - lap[i]
            nb = float(np.linalg.norm(s["b"]))
            out.append(float(np.linalg.norm(r)) / max(nb, 1e-30))
        return out

    def converged_batch(self, states, its):
        # the serial hook *always* computes the residual first (it raises on
        # blow-up even past the schedule), so no it-gated short-circuit here
        out: list = []
        for res, it in zip(self._rel_residuals_batch(states), its):
            if not np.isfinite(res):
                out.append(FloatingPointError("MG blow-up"))
            else:
                out.append(bool(it >= self.n_iters))
        return out

    def verify_batch(self, states):
        ref = self._golden_residual()
        return [
            VerifyResult(bool(np.isfinite(res) and abs(res - ref) <= self.rel_eps * max(ref, 1e-30)), res)
            for res in self._rel_residuals_batch(states)
        ]

    def advance_lanes(self, states, its, stop):
        from ..core.lane_driver import LaneSpec, cached_driver

        g, cs, fs, n_iters = self.grid, self.coarse_sweeps, self.fine_sweeps, self.n_iters
        # the fixed schedule makes convergence a pure counter; the only serial
        # host decision is the blow-up raise, which reads the float64 norm
        # ratio.  A lane whose residual max stays under this screen cannot
        # overflow any float32 summation order (g*g * screen^2 < f32 max), so
        # its serial residual is provably finite and the counter decision is
        # exact; anything else is handed back for serial reclassification.
        screen = np.float32(np.sqrt(3.0e38 / (g * g)))

        def step(consts, a):
            return _mg_cycle_core(a, consts["b"], consts["one"], g, cs, fs)

        def check(consts, a, it):
            lap = jax.vmap(lambda v: laplacian_apply(v, g))(a["u"])
            m = jnp.max(jnp.abs(consts["b"] - lap), axis=1)
            conv = it >= n_iters
            # NOT it-gated: the serial hook raises on blow-up even at the bound
            suspect = ~(jnp.isfinite(m) & (m <= screen))
            return conv, suspect

        key = ("mg", g, self.rel_eps, n_iters, self._seed, cs, fs)
        drv = cached_driver(key, lambda: LaneSpec(
            carry=self._CARRY,
            consts=lambda s0: {"b": s0["b"], "one": np.float32(1.0)},
            step=step, check=check,
        ))
        return drv.advance(states, its, stop)
