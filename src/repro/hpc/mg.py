"""MG: two-grid multigrid for the 2-D Poisson problem (NPB MG analogue).

Four first-level code regions per V-cycle — residual, coarse solve,
prolong+correct, fine smoothing — exactly the R1–R4 structure of the paper's
Fig 2a.  ``u`` and ``r`` are the big main-loop data objects (the paper's
critical-object study on MG uses u, r and an index object); the coarse-grid
correction is temporal and rebuilt every iteration.

Multigrid is strongly self-correcting: a block-stale ``u`` is just a worse
initial guess for the next V-cycle, so recomputability is high once ``u`` is
persisted (paper Fig 4a: persisting u lifts MG from 27 % to 63 %).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from ..core.regions import IterativeApp, Region, State, VerifyResult
from .common import jacobi_sweep, laplacian_apply, prolong, rel_residual, restrict


class MGApp(IterativeApp):
    name = "mg"
    candidates = ("u", "r", "k")

    def __init__(self, grid: int = 64, rel_eps: float = 1e-3, n_iters: int = 24, seed: int = 0,
                 coarse_sweeps: int = 8, fine_sweeps: int = 2):
        self.grid = grid
        # NPB-style verification: the final residual norm must match the
        # golden run's value to rel_eps (precise-numerical-integrity
        # acceptance, paper §2.2) — NPB MG compares norms against a reference
        # with a tight epsilon, on a *fixed* iteration schedule.
        self.rel_eps = rel_eps
        self.n_iters = n_iters
        self._seed = seed
        self.coarse_sweeps = coarse_sweeps
        self.fine_sweeps = fine_sweeps
        self._golden_res: float | None = None

    def init(self, seed: int = 0) -> State:
        g = self.grid
        rng = np.random.default_rng(self._seed)
        u_true = rng.standard_normal(g * g).astype(np.float32)
        b = np.asarray(laplacian_apply(jnp.asarray(u_true), g))
        return {
            "u": np.zeros(g * g, np.float32),
            "r": b.copy(),
            "ec": np.zeros((g // 2) * (g // 2), np.float32),  # temporal
            "k": np.zeros(1, np.int64),
            "b": b,  # read-only
        }

    # ---------------------------------------------------------------- regions
    def _residual(self, s: State) -> State:
        s = dict(s)
        s["r"] = s["b"] - np.asarray(laplacian_apply(jnp.asarray(s["u"]), self.grid))
        return s

    def _coarse(self, s: State) -> State:
        s = dict(s)
        g = self.grid
        rc = restrict(jnp.asarray(s["r"]), g)
        # scale: restriction halves h, so the coarse operator is 4x weaker
        ec = jnp.zeros_like(rc)
        for _ in range(self.coarse_sweeps):
            ec = jacobi_sweep(ec, 4.0 * rc, g // 2)
        s["ec"] = np.asarray(ec)
        return s

    def _correct(self, s: State) -> State:
        s = dict(s)
        s["u"] = s["u"] + np.asarray(prolong(jnp.asarray(s["ec"]), self.grid))
        return s

    def _smooth(self, s: State) -> State:
        s = dict(s)
        u = jnp.asarray(s["u"])
        for _ in range(self.fine_sweeps):
            u = jacobi_sweep(u, jnp.asarray(s["b"]), self.grid)
        s["u"] = np.asarray(u)
        s["k"] = s["k"] + 1
        return s

    def regions(self) -> Tuple[Region, ...]:
        return (
            Region("R1_residual", self._residual, writes=("r",), reads=("u", "b"), cost=1.0),
            Region("R2_coarse", self._coarse, writes=("ec",), reads=("r",), cost=2.0),
            Region("R3_correct", self._correct, writes=("u",), reads=("ec", "u"), cost=1.0),
            Region("R4_smooth", self._smooth, writes=("u", "k"), reads=("u", "b"), cost=2.0),
        )

    # ----------------------------------------------------------- verification
    def _golden_residual(self) -> float:
        if self._golden_res is None:
            s = self.init(self._seed)
            for _ in range(self.n_iters):
                s = self.run_iteration(s)
            self._golden_res = rel_residual(s["u"], s["b"], self.grid)
        return self._golden_res

    def verify(self, state: State) -> VerifyResult:
        res = rel_residual(state["u"], state["b"], self.grid)
        ref = self._golden_residual()
        ok = np.isfinite(res) and abs(res - ref) <= self.rel_eps * max(ref, 1e-30)
        return VerifyResult(bool(ok), res)

    def progress(self, state: State) -> float:
        return rel_residual(state["u"], state["b"], self.grid)

    def converged(self, state: State, it: int) -> bool:
        # fixed schedule (NPB MG runs exactly nit V-cycles)
        res = self.progress(state)
        if not np.isfinite(res):
            raise FloatingPointError("MG blow-up")
        return it >= self.n_iters
