"""Shared numerics for the HPC app suite (2-D Laplacian, smoothers, grids)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("g",))
def laplacian_apply(x_flat: jnp.ndarray, g: int) -> jnp.ndarray:
    """y = A x for the 2-D 5-point Laplacian (Dirichlet) on a g x g grid.

    A is SPD with stencil [4, -1, -1, -1, -1]; matrix-free.
    """
    x = x_flat.reshape(g, g)
    y = 4.0 * x
    y = y - jnp.pad(x[1:, :], ((0, 1), (0, 0)))
    y = y - jnp.pad(x[:-1, :], ((1, 0), (0, 0)))
    y = y - jnp.pad(x[:, 1:], ((0, 0), (0, 1)))
    y = y - jnp.pad(x[:, :-1], ((0, 0), (1, 0)))
    return y.reshape(-1)


@partial(jax.jit, static_argnames=("g",))
def jacobi_sweep(u_flat: jnp.ndarray, b_flat: jnp.ndarray, g: int, omega: float = 0.8) -> jnp.ndarray:
    """One weighted-Jacobi smoothing sweep for A u = b."""
    u = u_flat.reshape(g, g)
    b = b_flat.reshape(g, g)
    nb = (
        jnp.pad(u[1:, :], ((0, 1), (0, 0)))
        + jnp.pad(u[:-1, :], ((1, 0), (0, 0)))
        + jnp.pad(u[:, 1:], ((0, 0), (0, 1)))
        + jnp.pad(u[:, :-1], ((0, 0), (1, 0)))
    )
    u_new = (b + nb) / 4.0
    return (u + omega * (u_new - u)).reshape(-1)


@partial(jax.jit, static_argnames=("g",))
def restrict(r_flat: jnp.ndarray, g: int) -> jnp.ndarray:
    """Full-weighting restriction g x g -> g/2 x g/2 (g even)."""
    r = r_flat.reshape(g, g)
    gc = g // 2
    r = r[: gc * 2, : gc * 2].reshape(gc, 2, gc, 2)
    return r.mean(axis=(1, 3)).reshape(-1)


@partial(jax.jit, static_argnames=("g",))
def prolong(e_flat: jnp.ndarray, g: int) -> jnp.ndarray:
    """Piecewise-constant prolongation g/2 x g/2 -> g x g."""
    gc = g // 2
    e = e_flat.reshape(gc, gc)
    out = jnp.repeat(jnp.repeat(e, 2, axis=0), 2, axis=1)
    return out.reshape(-1)


def rel_residual(u: np.ndarray, b: np.ndarray, g: int) -> float:
    r = np.asarray(b) - np.asarray(laplacian_apply(jnp.asarray(u), g))
    nb = float(np.linalg.norm(np.asarray(b)))
    return float(np.linalg.norm(r)) / max(nb, 1e-30)


def to_np(x) -> np.ndarray:
    return np.asarray(x)
