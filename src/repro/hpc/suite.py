"""Suite-level helpers: canonical cache sizing + CI-sized app instances.

The cache-capacity : working-set ratio is the lever that controls how long
dirty blocks linger (and therefore how much EasyCrash's flushes matter).  The
paper chooses inputs whose footprint exceeds the LLC; we default to a cache
holding ~60 % of one iteration's working set, which reproduces the paper's
regime where natural write-backs keep *most* — but not all — of NVM
consistent.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np

from ..core.cache_sim import CacheConfig
from ..core.regions import IterativeApp, object_blocks
from . import _REGISTRY as _HPC_REGISTRY


# ------------------------------------------------------------- app registry
# One namespace for every campaign-characterizable workload: the HPC suite
# plus the model stack (LM training, autoregressive decode).  Model apps
# register lazy factories so importing the suite never pulls in jax's
# transformer stack.
_APP_FACTORIES: Dict[str, Callable[..., IterativeApp]] = dict(_HPC_REGISTRY)


def register_app(name: str, factory: Callable[..., IterativeApp]) -> None:
    """Register (or replace) an app factory under ``name``.

    ``factory(**params)`` must return an :class:`IterativeApp`; app classes
    themselves qualify.
    """
    if not callable(factory):
        raise TypeError(f"factory for {name!r} must be callable")
    _APP_FACTORIES[str(name)] = factory


def app_names() -> Tuple[str, ...]:
    return tuple(sorted(_APP_FACTORIES))


def get_app(name: str, **params) -> IterativeApp:
    """Instantiate a registered app by name (HPC suite + model stack)."""
    try:
        factory = _APP_FACTORIES[name]
    except KeyError:
        raise KeyError(f"unknown app {name!r}; have {list(app_names())}") from None
    return factory(**params)


def _lm_train_factory(**params) -> IterativeApp:
    from ..models.train_app import LMTrainApp

    return LMTrainApp(**params)


def _decode_factory(**params) -> IterativeApp:
    from ..models.serve_app import DecodeApp

    return DecodeApp(**params)


register_app("lm-train", _lm_train_factory)
register_app("decode", _decode_factory)


#: CI-sized problem instances (small enough for seconds-scale campaigns)
CI_SIZES: Dict[str, dict] = {
    "cg": dict(grid=24, n_iters=300),
    "mg": dict(grid=32, n_iters=24),
    "kmeans": dict(n_points=600, n_iters=8),
    "montecarlo": dict(batch=1024, n_iters=10),
    "heat": dict(grid=32, n_iters=300),
    "sor": dict(grid=24, n_iters=120),
    "pagerank": dict(n_nodes=192, n_iters=100),
    "lm-train": dict(n_iters=10, batch=2, seq=16, width=32),
    "decode": dict(n_iters=12, batch=2, prompt_len=8, width=32),
}

#: apps of the fault-model sweep (``bench_recomputability.py --fault-sweep``):
#: a spectrum pick — structured-grid smoothers (mg, sor), a hot-object
#: clustering code (kmeans) and an irregular graph workload (pagerank) — so
#: per-model S1–S4 shifts are visible across workload shapes.  Per-app fault
#: parameters live on each app class (``IterativeApp.fault_defaults``).
FAULT_SWEEP_APPS = ("mg", "kmeans", "sor", "pagerank")

#: benchmark-sized instances (paper-figure campaigns, minutes-scale)
BENCH_SIZES: Dict[str, dict] = {
    "cg": dict(grid=48, n_iters=600),
    "mg": dict(grid=48, n_iters=24),
    "kmeans": dict(n_points=4000, n_iters=10),
    "montecarlo": dict(batch=8192, n_iters=24),
    "heat": dict(grid=48, n_iters=600),
    "sor": dict(grid=48, n_iters=240),
    "pagerank": dict(n_nodes=512, n_iters=120),
    "lm-train": dict(n_iters=30, batch=4, seq=32, width=64),
    "decode": dict(n_iters=32, batch=4, prompt_len=16, width=64),
}


def working_set_blocks(app: IterativeApp, block_bytes: int = 64) -> int:
    state = app.init(0)
    names = set()
    for r in app.regions():
        names.update(r.reads)
        names.update(r.writes)
    blocks = object_blocks(state, [n for n in names if n in state], block_bytes)
    return sum(blocks.values())


def default_cache(app: IterativeApp, ratio: float = 0.45, block_bytes: int = 64) -> CacheConfig:
    ws = working_set_blocks(app, block_bytes)
    return CacheConfig(capacity_blocks=max(8, int(ws * ratio)), block_bytes=block_bytes)


def ci_app(name: str, **overrides) -> IterativeApp:
    kw = dict(CI_SIZES[name])
    kw.update(overrides)
    return get_app(name, **kw)


def bench_app(name: str, **overrides) -> IterativeApp:
    kw = dict(BENCH_SIZES[name])
    kw.update(overrides)
    return get_app(name, **kw)
