"""CG: preconditioner-free conjugate gradient on the 2-D Laplacian.

Analogue of NPB CG (sparse linear algebra).  Four first-level code regions
per main-loop iteration — matvec, x-update, r-update, p-update — matching
the paper's region abstraction.  Acceptance verification: true relative
residual ||b - A x|| / ||b|| below tolerance (a math-invariant check, §2.2).

CG is the paper's interesting case: its short-term recurrence is *fragile*
(stale p/r break conjugacy), so recomputation often needs extra iterations
(S2) — the paper reports 9.1 extra iterations on average and a 49 % gap to
best-achievable recomputability.
"""
from __future__ import annotations

from functools import partial
from typing import Mapping, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.regions import IterativeApp, Region, State, VerifyResult
from .common import laplacian_apply, rel_residual


@jax.jit
def _dot(a, b):
    return jnp.sum(a * b)


# Batched lane hooks for the vectorized campaign engine.  CG is matrix-free
# (the Laplacian is a stencil), so the whole iteration is elementwise chains
# plus per-lane reductions over the *data* axis — no ``dot_general`` — and
# vmapping is bitwise-safe.  The serial path's host-side float64 scalar math
# (``alpha = float(rho) / float(pq)`` then NumPy's value-based cast back to
# float32) is replicated by plain float32 division in-jit: for float32
# operands, dividing in float64 and rounding the quotient to float32 equals
# the direct float32 division (double rounding is innocuous at 53 >= 2*24+2,
# Figueroa 1995), so the two pipelines agree to the bit.
def _cg_step_core(a: dict, b: jnp.ndarray, one: jnp.ndarray, g: int, rr_every: int) -> dict:
    """One CG iteration (matvec, x-update, r-update, p-update) on stacked
    lanes; mirrors the serial region chain value-for-value.

    The axpy-style updates run in NumPy on the serial path (multiply, round,
    add, round); inside one XLA program the bare multiply-add contracts to an
    FMA at LLVM codegen (``llvm.fmuladd``, below HLO — optimization barriers
    and ``xla_allow_excess_precision=False`` do not reach it) and drifts by
    an ulp.  Multiplying each product by ``one`` — a *runtime* 1.0f operand
    the compiler cannot fold — forces the product to round first: the add
    then either stays separate or contracts to the exact ``fma(prod, 1, x)``,
    and both give the serial NumPy bits.
    """
    p, r, x = a["p"], a["r"], a["x"]
    q = jax.vmap(lambda v: laplacian_apply(v, g))(p)
    pq = jnp.sum(p * q, axis=1, keepdims=True)
    rho = a["rho"]
    alpha = jnp.where(pq != 0.0, rho / pq, 0.0)
    x = x + (alpha * p) * one
    kk = a["k"]
    use_rr = ((kk + 1) % rr_every) == 0 if rr_every else jnp.zeros_like(kk, bool)
    # both branches computed, selected per lane (exact select, no rounding)
    r_true = b - jax.vmap(lambda v: laplacian_apply(v, g))(x)
    r = jnp.where(use_rr, r_true, r - (alpha * q) * one)
    rho_prev = rho
    rho = jnp.sum(r * r, axis=1, keepdims=True)
    beta = jnp.where(rho_prev != 0.0, rho / rho_prev, 0.0)
    p = jnp.where(use_rr, r, r + (beta * p) * one)
    return {"x": x, "r": r, "p": p, "q": q, "rho": rho,
            "rho_prev": rho_prev, "alpha": alpha, "k": kk + 1}


@partial(jax.jit, static_argnames=("g", "rr_every"))
def _cg_step_batch(x, r, p, q, rho, rho_prev, alpha, k, b, one, g: int, rr_every: int):
    out = _cg_step_core(
        {"x": x, "r": r, "p": p, "q": q, "rho": rho, "rho_prev": rho_prev,
         "alpha": alpha, "k": k}, b, one, g, rr_every)
    return (out["x"], out["r"], out["p"], out["q"], out["rho"],
            out["rho_prev"], out["alpha"], out["k"])


@partial(jax.jit, static_argnames=("g",))
def _lap_batch(u_b: jnp.ndarray, g: int) -> jnp.ndarray:
    return jax.vmap(lambda u: laplacian_apply(u, g))(u_b)


class CGApp(IterativeApp):
    """CG with periodic residual replacement (van der Vorst/Ye), the standard
    HPC guard against recurrence drift — and the mechanism that lets CG
    absorb block-stale state after an EasyCrash restart."""

    name = "cg"
    candidates = ("x", "r", "p", "q", "rho", "rho_prev", "alpha", "k")

    def __init__(
        self,
        grid: int = 48,
        tol: float = 1e-4,
        n_iters: int = 600,
        seed: int = 0,
        residual_replace_every: int = 20,
    ):
        self.grid = grid
        self.tol = tol
        self.n_iters = n_iters
        self._seed = seed
        self.rr_every = residual_replace_every

    # ------------------------------------------------------------------ state
    def init(self, seed: int = 0) -> State:
        g = self.grid
        rng = np.random.default_rng(self._seed)
        x_true = rng.standard_normal(g * g).astype(np.float32)
        b = np.asarray(laplacian_apply(jnp.asarray(x_true), g))
        x = np.zeros(g * g, np.float32)
        r = b.copy()
        p = r.copy()
        rho = np.array([float(r @ r)], np.float32)
        return {
            "x": x, "r": r, "p": p, "q": np.zeros_like(x),
            "rho": rho, "rho_prev": rho.copy(), "alpha": np.zeros(1, np.float32),
            "k": np.zeros(1, np.int64),
            "b": b,  # read-only
        }

    # ---------------------------------------------------------------- regions
    def _matvec(self, s: State) -> State:
        s = dict(s)
        s["q"] = np.asarray(laplacian_apply(jnp.asarray(s["p"]), self.grid))
        return s

    def _x_update(self, s: State) -> State:
        s = dict(s)
        pq = float(_dot(jnp.asarray(s["p"]), jnp.asarray(s["q"])))
        alpha = float(s["rho"][0]) / pq if pq != 0.0 else 0.0
        s["alpha"] = np.array([alpha], np.float32)
        s["x"] = s["x"] + alpha * s["p"]
        return s

    def _r_update(self, s: State) -> State:
        s = dict(s)
        k = int(s["k"][0])
        if self.rr_every and (k + 1) % self.rr_every == 0:
            # residual replacement: recompute the *true* residual
            r = s["b"] - np.asarray(laplacian_apply(jnp.asarray(s["x"]), self.grid))
        else:
            r = s["r"] - s["alpha"][0] * s["q"]
        s["r"] = r.astype(np.float32)
        s["rho_prev"] = s["rho"].copy()
        s["rho"] = np.array([float(_dot(jnp.asarray(r), jnp.asarray(r)))], np.float32)
        return s

    def _p_update(self, s: State) -> State:
        s = dict(s)
        k = int(s["k"][0])
        if self.rr_every and (k + 1) % self.rr_every == 0:
            # restart direction after residual replacement
            s["p"] = s["r"].copy()
        else:
            denom = float(s["rho_prev"][0])
            beta = float(s["rho"][0]) / denom if denom != 0.0 else 0.0
            s["p"] = s["r"] + beta * s["p"]
        s["k"] = s["k"] + 1
        return s

    def regions(self) -> Tuple[Region, ...]:
        return (
            Region("matvec", self._matvec, writes=("q",), reads=("p",), cost=2.0),
            Region("x_update", self._x_update, writes=("alpha", "x"), reads=("p", "q", "rho", "x")),
            Region("r_update", self._r_update, writes=("r", "rho_prev", "rho"), reads=("alpha", "q", "r", "x", "b")),
            Region("p_update", self._p_update, writes=("p", "k"), reads=("r", "rho", "rho_prev", "p")),
        )

    # ----------------------------------------------------------- verification
    def verify(self, state: State) -> VerifyResult:
        res = rel_residual(state["x"], state["b"], self.grid)
        return VerifyResult(bool(np.isfinite(res) and res < self.tol), res)

    def progress(self, state: State) -> float:
        return rel_residual(state["x"], state["b"], self.grid)

    def converged(self, state: State, it: int) -> bool:
        if it >= self.n_iters:
            return True
        rho = float(state["rho"][0])
        if not np.isfinite(rho):
            raise FloatingPointError("CG blow-up")
        # cheap recurrence-residual check every iteration; the *true*
        # residual is only asserted by verify()
        nb = float(np.linalg.norm(state["b"]))
        return np.sqrt(max(rho, 0.0)) / max(nb, 1e-30) < self.tol * 0.5

    # ------------------------------------------------------- batched recompute
    # ``b`` is read-only, so the hooks stack only the per-lane vectors and
    # close over lane 0's right-hand side.
    supports_batched_step = True
    supports_lane_driver = True

    _CARRY = ("x", "r", "p", "q", "rho", "rho_prev", "alpha", "k")

    def batched_kernels(self):
        from ..core.regions import BatchedKernel

        s = self.init(0)
        b = jnp.asarray(s["b"])
        rows = {f: np.stack([s[f]] * 3) for f in self._CARRY}
        g, rr = self.grid, self.rr_every
        args = tuple(rows[f] for f in self._CARRY)
        return (
            BatchedKernel("cg_step_batch",
                          lambda *vs: _cg_step_batch(*vs, b, np.float32(1.0), g, rr),
                          args, {i: 0 for i in range(len(args))}),
            BatchedKernel("lap_batch", lambda ub: _lap_batch(ub, g),
                          (rows["x"],), {0: 0}),
        )

    def run_iteration_batch(self, states):
        b = jnp.asarray(states[0]["b"])
        stacked = [jnp.asarray(np.stack([s[f] for s in states])) for f in self._CARRY]
        new = _cg_step_batch(*stacked, b, np.float32(1.0), self.grid, self.rr_every)
        new = [np.asarray(v) for v in new]
        out = []
        for i, s in enumerate(states):
            s = dict(s)
            for f, rows in zip(self._CARRY, new):
                s[f] = rows[i].astype(s[f].dtype, copy=False)
            out.append(s)
        return out

    def converged_batch(self, states, its):
        # pure host scalar math on the carried rho — exactly the serial hook,
        # with the lane-constant ||b|| computed once
        out: list = []
        nb = float(np.linalg.norm(states[0]["b"]))
        for s, it in zip(states, its):
            if it >= self.n_iters:
                out.append(True)
                continue
            rho = float(s["rho"][0])
            if not np.isfinite(rho):
                out.append(FloatingPointError("CG blow-up"))
            else:
                out.append(bool(np.sqrt(max(rho, 0.0)) / max(nb, 1e-30) < self.tol * 0.5))
        return out

    def verify_batch(self, states):
        # one batched Laplacian dispatch; the norms run in NumPy per
        # contiguous row, exactly like the serial rel_residual
        x_rows = np.stack([s["x"] for s in states])
        b_rows = np.stack([s["b"] for s in states])
        lap = np.asarray(_lap_batch(jnp.asarray(x_rows), self.grid))
        out = []
        for i in range(len(states)):
            r = b_rows[i] - lap[i]
            nb = float(np.linalg.norm(b_rows[i]))
            res = float(np.linalg.norm(r)) / max(nb, 1e-30)
            out.append(VerifyResult(bool(np.isfinite(res) and res < self.tol), res))
        return out

    def advance_lanes(self, states, its, stop):
        from ..core.lane_driver import LaneSpec, cached_driver, f32_monotone_cutoff

        g, rr, n_iters = self.grid, self.rr_every, self.n_iters
        # the serial decision sqrt(max(rho,0))/max(||b||,eps) < tol/2 is a
        # monotone float64 predicate of the carried float32 rho; ||b|| is
        # lane-constant, so the whole decision folds to rho <= cutoff
        nb = float(np.linalg.norm(states[0]["b"]))
        tol = self.tol
        cutoff = f32_monotone_cutoff(
            lambda v: np.sqrt(max(v, 0.0)) / max(nb, 1e-30) < tol * 0.5
        )

        def step(consts, a):
            return _cg_step_core(a, consts["b"], consts["one"], g, rr)

        def check(consts, a, it):
            rho = a["rho"][:, 0]
            over = it >= n_iters
            fin = jnp.isfinite(rho)
            conv = over | (fin & (rho <= cutoff))
            suspect = ~over & ~fin  # serial converged() would raise
            return conv, suspect

        key = ("cg", g, tol, n_iters, self._seed, rr)
        drv = cached_driver(key, lambda: LaneSpec(
            carry=self._CARRY,
            consts=lambda s0: {"b": s0["b"], "one": np.float32(1.0)},
            step=step, check=check,
        ))
        return drv.advance(states, its, stop)
