"""CG: preconditioner-free conjugate gradient on the 2-D Laplacian.

Analogue of NPB CG (sparse linear algebra).  Four first-level code regions
per main-loop iteration — matvec, x-update, r-update, p-update — matching
the paper's region abstraction.  Acceptance verification: true relative
residual ||b - A x|| / ||b|| below tolerance (a math-invariant check, §2.2).

CG is the paper's interesting case: its short-term recurrence is *fragile*
(stale p/r break conjugacy), so recomputation often needs extra iterations
(S2) — the paper reports 9.1 extra iterations on average and a 49 % gap to
best-achievable recomputability.
"""
from __future__ import annotations

from typing import Mapping, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.regions import IterativeApp, Region, State, VerifyResult
from .common import laplacian_apply, rel_residual


@jax.jit
def _dot(a, b):
    return jnp.sum(a * b)


class CGApp(IterativeApp):
    """CG with periodic residual replacement (van der Vorst/Ye), the standard
    HPC guard against recurrence drift — and the mechanism that lets CG
    absorb block-stale state after an EasyCrash restart."""

    name = "cg"
    candidates = ("x", "r", "p", "q", "rho", "rho_prev", "alpha", "k")

    def __init__(
        self,
        grid: int = 48,
        tol: float = 1e-4,
        n_iters: int = 600,
        seed: int = 0,
        residual_replace_every: int = 20,
    ):
        self.grid = grid
        self.tol = tol
        self.n_iters = n_iters
        self._seed = seed
        self.rr_every = residual_replace_every

    # ------------------------------------------------------------------ state
    def init(self, seed: int = 0) -> State:
        g = self.grid
        rng = np.random.default_rng(self._seed)
        x_true = rng.standard_normal(g * g).astype(np.float32)
        b = np.asarray(laplacian_apply(jnp.asarray(x_true), g))
        x = np.zeros(g * g, np.float32)
        r = b.copy()
        p = r.copy()
        rho = np.array([float(r @ r)], np.float32)
        return {
            "x": x, "r": r, "p": p, "q": np.zeros_like(x),
            "rho": rho, "rho_prev": rho.copy(), "alpha": np.zeros(1, np.float32),
            "k": np.zeros(1, np.int64),
            "b": b,  # read-only
        }

    # ---------------------------------------------------------------- regions
    def _matvec(self, s: State) -> State:
        s = dict(s)
        s["q"] = np.asarray(laplacian_apply(jnp.asarray(s["p"]), self.grid))
        return s

    def _x_update(self, s: State) -> State:
        s = dict(s)
        pq = float(_dot(jnp.asarray(s["p"]), jnp.asarray(s["q"])))
        alpha = float(s["rho"][0]) / pq if pq != 0.0 else 0.0
        s["alpha"] = np.array([alpha], np.float32)
        s["x"] = s["x"] + alpha * s["p"]
        return s

    def _r_update(self, s: State) -> State:
        s = dict(s)
        k = int(s["k"][0])
        if self.rr_every and (k + 1) % self.rr_every == 0:
            # residual replacement: recompute the *true* residual
            r = s["b"] - np.asarray(laplacian_apply(jnp.asarray(s["x"]), self.grid))
        else:
            r = s["r"] - s["alpha"][0] * s["q"]
        s["r"] = r.astype(np.float32)
        s["rho_prev"] = s["rho"].copy()
        s["rho"] = np.array([float(_dot(jnp.asarray(r), jnp.asarray(r)))], np.float32)
        return s

    def _p_update(self, s: State) -> State:
        s = dict(s)
        k = int(s["k"][0])
        if self.rr_every and (k + 1) % self.rr_every == 0:
            # restart direction after residual replacement
            s["p"] = s["r"].copy()
        else:
            denom = float(s["rho_prev"][0])
            beta = float(s["rho"][0]) / denom if denom != 0.0 else 0.0
            s["p"] = s["r"] + beta * s["p"]
        s["k"] = s["k"] + 1
        return s

    def regions(self) -> Tuple[Region, ...]:
        return (
            Region("matvec", self._matvec, writes=("q",), reads=("p",), cost=2.0),
            Region("x_update", self._x_update, writes=("alpha", "x"), reads=("p", "q", "rho", "x")),
            Region("r_update", self._r_update, writes=("r", "rho_prev", "rho"), reads=("alpha", "q", "r", "x", "b")),
            Region("p_update", self._p_update, writes=("p", "k"), reads=("r", "rho", "rho_prev", "p")),
        )

    # ----------------------------------------------------------- verification
    def verify(self, state: State) -> VerifyResult:
        res = rel_residual(state["x"], state["b"], self.grid)
        return VerifyResult(bool(np.isfinite(res) and res < self.tol), res)

    def progress(self, state: State) -> float:
        return rel_residual(state["x"], state["b"], self.grid)

    def converged(self, state: State, it: int) -> bool:
        if it >= self.n_iters:
            return True
        rho = float(state["rho"][0])
        if not np.isfinite(rho):
            raise FloatingPointError("CG blow-up")
        # cheap recurrence-residual check every iteration; the *true*
        # residual is only asserted by verify()
        nb = float(np.linalg.norm(state["b"]))
        return np.sqrt(max(rho, 0.0)) / max(nb, 1e-30) < self.tol * 0.5
