"""PageRank: damped power iteration on a random directed graph.

Analogue of an irregular graph-analytics workload (the paper's spectrum
beyond the NPB kernels).  The link matrix is column-stochastic and dense at
suite sizes; one main-loop iteration is spmv -> damped apply -> bookkeeping.
The rank vector is re-read continuously while the matvec streams the link
matrix, so it is *hot* in the NVCT cache model — like the k-means centroid
table, it tends to stay chronically dirty and leave only ancient values in
NVM (paper §8), which is exactly what makes it a critical data object.

Power iteration contracts at the damping factor per step, so early crashes
recompute for free while late crashes lack the remaining iterations to
re-absorb a stale rank vector (S2 territory).

Acceptance verification: fixed-point residual ||G(rank) - rank||_1 below
tolerance, where G is the damped update (math-invariant check, §2.2).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.regions import IterativeApp, Region, State, VerifyResult


@jax.jit
def _spmv(links: jnp.ndarray, rank: jnp.ndarray) -> jnp.ndarray:
    return links @ rank


@jax.jit
def _damped(y: jnp.ndarray, rank: jnp.ndarray, damping: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n = rank.shape[0]
    new = damping * y + (1.0 - damping) / n
    return new, jnp.sum(jnp.abs(new - rank))


# Batched lane hooks for the vectorized campaign engine.  The spmv is a
# matmul whose vmap would become a matrix-matrix product with a *different*
# reduction tiling (not bitwise the serial matvec), so lanes go through
# ``lax.map`` — one dispatch, per-lane HLO identical to ``_spmv``.  The
# damped update is elementwise apart from a per-lane reduction of unchanged
# shape, where vmap is bitwise-safe (asserted by tests/test_campaign_vec.py).
@jax.jit
def _spmv_batch(links: jnp.ndarray, rank_batch: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.map(lambda r: links @ r, rank_batch)


@jax.jit
def _damped_batch(
    y_batch: jnp.ndarray, rank_batch: jnp.ndarray, damping: float
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return jax.vmap(lambda y, r: _damped(y, r, damping))(y_batch, rank_batch)


class PageRankApp(IterativeApp):
    name = "pagerank"
    candidates = ("rank", "y", "k")
    #: campaign fault tuning: the rank vector is chronically cached (hot in
    #: the spmv), so NVM holds ancient rank data — silent bit flips there are
    #: the interesting SDC surface, and correlated failures should strike the
    #: dominant spmv region.
    fault_defaults = {
        "bit-flip": {"n_bits": 16},
        "correlated-region": {"shape": 3.0},
    }

    def __init__(self, n_nodes: int = 256, out_degree: int = 3, damping: float = 0.9,
                 tol: float = 1e-5, n_iters: int = 100, seed: int = 0):
        self.n_nodes = n_nodes
        self.out_degree = out_degree
        self.damping = damping
        self.tol = tol
        self.n_iters = n_iters
        self._seed = seed

    def init(self, seed: int = 0) -> State:
        n = self.n_nodes
        rng = np.random.default_rng(self._seed)
        links = np.zeros((n, n), np.float32)
        for j in range(n):
            targets = rng.choice(n, size=self.out_degree, replace=False)
            links[targets, j] = 1.0 / self.out_degree
        return {
            "links": links,                          # read-only
            "rank": np.full(n, 1.0 / n, np.float32),
            "y": np.zeros(n, np.float32),            # temporal
            "delta": np.zeros(1, np.float32),        # temporal diagnostic
            "k": np.zeros(1, np.int64),
        }

    def _region_spmv(self, s: State) -> State:
        s = dict(s)
        s["y"] = np.asarray(_spmv(jnp.asarray(s["links"]), jnp.asarray(s["rank"])))
        return s

    def _region_apply(self, s: State) -> State:
        s = dict(s)
        new, delta = _damped(jnp.asarray(s["y"]), jnp.asarray(s["rank"]), self.damping)
        s["rank"] = np.asarray(new)
        s["delta"] = np.asarray(delta).reshape(1).astype(np.float32)
        return s

    def _region_book(self, s: State) -> State:
        s = dict(s)
        s["k"] = s["k"] + 1
        return s

    def regions(self) -> Tuple[Region, ...]:
        return (
            Region("spmv", self._region_spmv, writes=("y",),
                   reads=("links", "rank"), cost=4.0, hot_reads=("rank",)),
            Region("apply", self._region_apply, writes=("rank", "delta"),
                   reads=("y", "rank"), cost=1.0),
            Region("book", self._region_book, writes=("k",), cost=0.1),
        )

    def _fixed_point_residual(self, state: State) -> float:
        y = np.asarray(_spmv(jnp.asarray(state["links"]), jnp.asarray(state["rank"])))
        target = self.damping * y + (1.0 - self.damping) / self.n_nodes
        return float(np.abs(target - state["rank"]).sum())

    def verify(self, state: State) -> VerifyResult:
        r = self._fixed_point_residual(state)
        return VerifyResult(bool(np.isfinite(r) and r < self.tol), r)

    def progress(self, state: State) -> float:
        return self._fixed_point_residual(state)

    def converged(self, state: State, it: int) -> bool:
        if it >= self.n_iters:
            return True
        delta = float(state["delta"][0])
        if not np.isfinite(delta):
            raise FloatingPointError("pagerank blow-up")
        # delta is ||G(rank_prev) - rank_prev||_1's damped successor; the
        # true fixed-point residual is only asserted by verify()
        return 0 < delta < self.tol * 0.5

    # ------------------------------------------------------- batched recompute
    # ``links`` is read-only and never a selection candidate, so every
    # restart lane carries the identical init-rebuilt matrix — the batched
    # hooks stack only the per-lane vectors and close over lane 0's links.
    supports_batched_step = True
    supports_lane_driver = True

    def batched_kernels(self):
        from ..core.regions import BatchedKernel

        s = self.init(0)
        links = jnp.asarray(s["links"])
        r3 = np.stack([s["rank"]] * 3)
        y3 = np.stack([s["y"]] * 3)
        d = self.damping
        return (
            BatchedKernel("spmv_batch", lambda rb: _spmv_batch(links, rb),
                          (r3,), {0: 0}),
            BatchedKernel("damped_batch",
                          lambda yb, rb: _damped_batch(yb, rb, d),
                          (y3, r3), {0: 0, 1: 0}),
        )

    def run_iteration_batch(self, states):
        rank_rows = np.stack([s["rank"] for s in states])
        links = jnp.asarray(states[0]["links"])
        y_rows = np.asarray(_spmv_batch(links, jnp.asarray(rank_rows)))
        new_rows, deltas = _damped_batch(
            jnp.asarray(y_rows), jnp.asarray(rank_rows), self.damping
        )
        new_rows = np.asarray(new_rows)
        deltas = np.asarray(deltas)
        out = []
        for i, s in enumerate(states):
            s = dict(s)
            s["y"] = y_rows[i]
            s["rank"] = new_rows[i]
            s["delta"] = np.asarray(deltas[i]).reshape(1).astype(np.float32)
            s["k"] = s["k"] + 1
            out.append(s)
        return out

    # converged() only reads the scalar delta — the looping default is fine

    def verify_batch(self, states):
        rank_rows = np.stack([s["rank"] for s in states])
        links = jnp.asarray(states[0]["links"])
        y_rows = np.asarray(_spmv_batch(links, jnp.asarray(rank_rows)))
        out = []
        for i in range(len(states)):
            target = self.damping * y_rows[i] + (1.0 - self.damping) / self.n_nodes
            r = float(np.abs(target - rank_rows[i]).sum())
            out.append(VerifyResult(bool(np.isfinite(r) and r < self.tol), r))
        return out

    def advance_lanes(self, states, its, stop):
        from ..core.lane_driver import LaneSpec, cached_driver, f32_monotone_cutoff

        d, n_iters = self.damping, self.n_iters
        # the serial decision 0 < delta < tol/2 is a monotone float64
        # predicate of the carried float32 delta, so it folds to an exact
        # in-jit comparison against the cutoff
        cutoff = f32_monotone_cutoff(lambda v: v < self.tol * 0.5)

        def step(consts, a):
            y = jax.lax.map(lambda r: consts["links"] @ r, a["rank"])
            new, delta = jax.vmap(lambda yy, rr: _damped(yy, rr, d))(y, a["rank"])
            return {"rank": new, "y": y, "delta": delta[:, None], "k": a["k"] + 1}

        def check(consts, a, it):
            dl = a["delta"][:, 0]
            over = it >= n_iters
            fin = jnp.isfinite(dl)
            conv = over | (fin & (dl > 0) & (dl <= cutoff))
            suspect = ~over & ~fin  # serial converged() would raise
            return conv, suspect

        key = ("pagerank", self.n_nodes, self.out_degree, d, self.tol,
               n_iters, self._seed)
        drv = cached_driver(key, lambda: LaneSpec(
            carry=("rank", "y", "delta", "k"),
            consts=lambda s0: {"links": s0["links"]},
            step=step, check=check,
        ))
        return drv.advance(states, its, stop)
