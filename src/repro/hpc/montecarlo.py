"""EP analogue: embarrassingly-parallel Monte Carlo tally (NPB EP).

Each iteration generates a deterministic batch of Gaussian pairs (counter-
based RNG keyed by the iteration index) and *accumulates* annulus counts.
Acceptance verification demands an **exact** match with the golden tallies —
EP's verification in the paper is numerically precise, and accumulation is
not idempotent across a mid-iteration restart, so recomputability is ~0 even
with persistence (paper §6: "we do not present results for EP, because its
inherent recomputability is 0").  This app is the suite's negative control.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.regions import IterativeApp, Region, State, VerifyResult


@partial(jax.jit, static_argnames=("batch", "nbins"))
def _tally_batch(it: jnp.ndarray, batch: int, nbins: int) -> jnp.ndarray:
    key = jax.random.fold_in(jax.random.PRNGKey(1234), it)
    xy = jax.random.normal(key, (batch, 2))
    rad2 = jnp.sum(xy * xy, axis=-1)
    bins = jnp.clip(jnp.sqrt(rad2).astype(jnp.int32), 0, nbins - 1)
    return jnp.zeros(nbins, jnp.int32).at[bins].add(1)


class MonteCarloApp(IterativeApp):
    name = "montecarlo"
    candidates = ("counts", "sums", "k")

    def static_hints(self):
        # the tally regions are host-side (untraceable), but the algorithm
        # fact is declarative: verification is an exact golden match and the
        # tallies accumulate, so a replayed iteration double-counts
        return {"counts": "exact-accumulator", "sums": "exact-accumulator"}

    def __init__(self, batch: int = 8192, nbins: int = 10, n_iters: int = 24, seed: int = 0):
        self.batch = batch
        self.nbins = nbins
        self.n_iters = n_iters
        self._seed = seed
        self._golden_counts: np.ndarray | None = None

    def init(self, seed: int = 0) -> State:
        return {
            "counts": np.zeros(self.nbins, np.int64),
            "sums": np.zeros(2, np.float64),
            "scratch": np.zeros(self.batch, np.float32),  # temporal work array
            "k": np.zeros(1, np.int64),
        }

    def _generate(self, s: State) -> State:
        s = dict(s)
        key = jax.random.fold_in(jax.random.PRNGKey(1234), int(s["k"][0]))
        xy = jax.random.normal(key, (self.batch, 2))
        s["scratch"] = np.asarray(jnp.sum(xy * xy, axis=-1), np.float32)
        return s

    def _accumulate(self, s: State) -> State:
        s = dict(s)
        tal = np.asarray(_tally_batch(jnp.asarray(int(s["k"][0])), self.batch, self.nbins)).astype(np.int64)
        s["counts"] = s["counts"] + tal
        s["sums"] = s["sums"] + np.array([tal.sum(), float(np.sum(s["scratch"]))])
        s["k"] = s["k"] + 1
        return s

    def regions(self) -> Tuple[Region, ...]:
        return (
            Region("generate", self._generate, writes=("scratch",), reads=("k",), cost=3.0),
            Region("accumulate", self._accumulate, writes=("counts", "sums", "k"),
                   reads=("scratch", "counts", "sums"), cost=1.0),
        )

    def _golden(self) -> np.ndarray:
        if self._golden_counts is None:
            s = self.init(self._seed)
            for _ in range(self.n_iters):
                s = self.run_iteration(s)
            self._golden_counts = s["counts"].copy()
        return self._golden_counts

    def verify(self, state: State) -> VerifyResult:
        ok = np.array_equal(state["counts"], self._golden())
        return VerifyResult(bool(ok), float(np.abs(state["counts"] - self._golden()).sum()))

    def progress(self, state: State) -> float:
        return float(state["counts"].sum())
