"""EP analogue: embarrassingly-parallel Monte Carlo tally (NPB EP).

Each iteration generates a deterministic batch of Gaussian pairs (counter-
based RNG keyed by the iteration index) and *accumulates* annulus counts.
Acceptance verification demands an **exact** match with the golden tallies —
EP's verification in the paper is numerically precise, and accumulation is
not idempotent across a mid-iteration restart, so recomputability is ~0 even
with persistence (paper §6: "we do not present results for EP, because its
inherent recomputability is 0").  This app is the suite's negative control.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.regions import IterativeApp, Region, State, VerifyResult


@partial(jax.jit, static_argnames=("batch", "nbins"))
def _tally_batch(it: jnp.ndarray, batch: int, nbins: int) -> jnp.ndarray:
    key = jax.random.fold_in(jax.random.PRNGKey(1234), it)
    xy = jax.random.normal(key, (batch, 2))
    rad2 = jnp.sum(xy * xy, axis=-1)
    bins = jnp.clip(jnp.sqrt(rad2).astype(jnp.int32), 0, nbins - 1)
    return jnp.zeros(nbins, jnp.int32).at[bins].add(1)


# Batched hooks for the vectorized campaign engine.  The RNG is counter-based
# (key = fold_in(base, k)), so every round is a pure function of its index:
# one ``lax.map`` dispatch generates the tallies and radii for a whole range
# of rounds, and the host replays the int64/float64 accumulation per lane in
# exact serial order (the accumulators are ``exact-accumulator`` objects —
# their update order is the verification contract, so it never moves in-jit).
@partial(jax.jit, static_argnames=("batch", "nbins"))
def _mc_rounds(ks: jnp.ndarray, one: jnp.ndarray, batch: int, nbins: int):
    """Per-round (tally int32 (nbins,), rad2 float32 (batch,)) for each k in
    ``ks``; per round bitwise identical to ``_tally_batch`` / the generate
    region (``lax.map`` keeps each round's HLO the serial one).

    The serial paths round ``sum(xy*xy)`` two different ways: ``_tally_batch``
    computes it in-jit (the mul-add contracts to an FMA at LLVM codegen),
    while the generate region computes it *eagerly* (mul and sum are separate
    programs — separate roundings).  The tally path below keeps the bare
    single-use product so its contraction matches; the scratch path rebuilds
    the product from a ``one``-multiplied copy of ``xy`` (``one`` is a
    *runtime* 1.0f), which blocks both CSE with the tally product and FMA
    formation, reproducing the eager roundings.
    """

    def one_round(it):
        key = jax.random.fold_in(jax.random.PRNGKey(1234), it)
        xy = jax.random.normal(key, (batch, 2))
        rad2 = jnp.sum(xy * xy, axis=-1)
        bins = jnp.clip(jnp.sqrt(rad2).astype(jnp.int32), 0, nbins - 1)
        tal = jnp.zeros(nbins, jnp.int32).at[bins].add(1)
        xye = xy * one
        rad2_s = jnp.sum((xye * xye) * one, axis=-1)
        return tal, rad2_s.astype(jnp.float32)

    return jax.lax.map(one_round, ks)


def _pad_pow2(ks: np.ndarray) -> np.ndarray:
    b = 1
    while b < len(ks):
        b <<= 1
    return np.concatenate([ks, np.full(b - len(ks), ks[-1], ks.dtype)])


class MonteCarloApp(IterativeApp):
    name = "montecarlo"
    candidates = ("counts", "sums", "k")

    def static_hints(self):
        # the tally regions are host-side (untraceable), but the algorithm
        # fact is declarative: verification is an exact golden match and the
        # tallies accumulate, so a replayed iteration double-counts
        return {"counts": "exact-accumulator", "sums": "exact-accumulator"}

    def __init__(self, batch: int = 8192, nbins: int = 10, n_iters: int = 24, seed: int = 0):
        self.batch = batch
        self.nbins = nbins
        self.n_iters = n_iters
        self._seed = seed
        self._golden_counts: np.ndarray | None = None

    def init(self, seed: int = 0) -> State:
        return {
            "counts": np.zeros(self.nbins, np.int64),
            "sums": np.zeros(2, np.float64),
            "scratch": np.zeros(self.batch, np.float32),  # temporal work array
            "k": np.zeros(1, np.int64),
        }

    def _generate(self, s: State) -> State:
        s = dict(s)
        key = jax.random.fold_in(jax.random.PRNGKey(1234), int(s["k"][0]))
        xy = jax.random.normal(key, (self.batch, 2))
        s["scratch"] = np.asarray(jnp.sum(xy * xy, axis=-1), np.float32)
        return s

    def _accumulate(self, s: State) -> State:
        s = dict(s)
        tal = np.asarray(_tally_batch(jnp.asarray(int(s["k"][0])), self.batch, self.nbins)).astype(np.int64)
        s["counts"] = s["counts"] + tal
        s["sums"] = s["sums"] + np.array([tal.sum(), float(np.sum(s["scratch"]))])
        s["k"] = s["k"] + 1
        return s

    def regions(self) -> Tuple[Region, ...]:
        return (
            Region("generate", self._generate, writes=("scratch",), reads=("k",), cost=3.0),
            Region("accumulate", self._accumulate, writes=("counts", "sums", "k"),
                   reads=("scratch", "counts", "sums"), cost=1.0),
        )

    def _golden(self) -> np.ndarray:
        if self._golden_counts is None:
            s = self.init(self._seed)
            for _ in range(self.n_iters):
                s = self.run_iteration(s)
            self._golden_counts = s["counts"].copy()
        return self._golden_counts

    def verify(self, state: State) -> VerifyResult:
        ok = np.array_equal(state["counts"], self._golden())
        return VerifyResult(bool(ok), float(np.abs(state["counts"] - self._golden()).sum()))

    def progress(self, state: State) -> float:
        return float(state["counts"].sum())

    # ------------------------------------------------------- batched recompute
    # converged() is the counter default and verify() is a pure host compare,
    # so only the round generation is batched; accumulation stays host-side.
    supports_batched_step = True
    supports_lane_driver = True

    def batched_kernels(self):
        from ..core.regions import BatchedKernel

        batch, nbins = self.batch, self.nbins
        ks = np.arange(3, dtype=np.int32)
        return (
            BatchedKernel("mc_rounds", lambda kv: _mc_rounds(kv, np.float32(1.0), batch, nbins),
                          (ks,), {0: 0}),
        )

    def _apply_round(self, s: State, tal64: np.ndarray, rad2: np.ndarray) -> State:
        """One accumulate step from precomputed round data, in exact serial
        order: counts, then the float64 [n, sum(rad2)] pair, then k."""
        s = dict(s)
        s["scratch"] = rad2.copy()
        s["counts"] = s["counts"] + tal64
        s["sums"] = s["sums"] + np.array([tal64.sum(), float(np.sum(rad2))])
        s["k"] = s["k"] + 1
        return s

    def run_iteration_batch(self, states):
        ks = np.fromiter((int(s["k"][0]) for s in states), np.int32, len(states))
        tals, rads = _mc_rounds(jnp.asarray(_pad_pow2(ks)), np.float32(1.0), self.batch, self.nbins)
        tals = np.asarray(tals).astype(np.int64)
        rads = np.asarray(rads)
        return [self._apply_round(s, tals[i], rads[i]) for i, s in enumerate(states)]

    def advance_lanes(self, states, its, stop):
        """Bespoke jit-resident phase A: the loop has no data recurrence (the
        round stream depends only on k), so instead of a ``while_loop`` one
        ``lax.map`` generates every round in [min(its), stop) and the host
        replays each lane's accumulation bitwise."""
        stop = int(stop)
        # the generate region is keyed by the state's own k; the driver's
        # round stream assumes k == it (the campaign bookmarks the iterator
        # to the restart iteration, so this always holds — guard anyway)
        oks = [int(s["k"][0]) == int(it) for s, it in zip(states, its)]
        todo = [i for i, ok in enumerate(oks) if ok and its[i] < stop]
        out_states = list(states)
        out_its = [int(it) for it in its]
        if todo:
            lo = min(int(its[i]) for i in todo)
            ks = np.arange(lo, stop, dtype=np.int32)
            tals, rads = _mc_rounds(jnp.asarray(_pad_pow2(ks)), np.float32(1.0), self.batch, self.nbins)
            tals = np.asarray(tals).astype(np.int64)
            rads = np.asarray(rads)
            for i in todo:
                s = states[i]
                for t in range(int(its[i]), stop):
                    s = self._apply_round(s, tals[t - lo], rads[t - lo])
                out_states[i] = s
                out_its[i] = stop
        return out_states, out_its, oks
