from .pipeline import DataConfig, SyntheticLMStream, host_local_batch_specs

__all__ = ["DataConfig", "SyntheticLMStream", "host_local_batch_specs"]
