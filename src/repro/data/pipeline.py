"""Synthetic LM data pipeline: deterministic, host-sharded, prefetching.

Each host materializes only its shard of the global batch (process-local
slice along the batch axis), generated counter-based from (seed, step) so any
host can reproduce any step independently — restart after a crash needs no
data-loader state beyond the step counter (which EasyCrash persists).

A background thread prefetches ``prefetch`` batches ahead so host-side
generation overlaps device compute.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import jax
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0
    frontend_tokens: int = 0     # VLM patch embeddings prepended by the model
    d_model: int = 0             # needed when frontend_tokens > 0
    prefetch: int = 2


def _batch_for_step(cfg: DataConfig, step: int, lo: int, hi: int) -> Dict[str, np.ndarray]:
    """Rows [lo, hi) of the global batch for ``step`` (deterministic)."""
    n = hi - lo
    s_text = cfg.seq_len - cfg.frontend_tokens
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    # skip-ahead: draw the full batch lazily by row blocks for determinism
    tokens = rng.integers(0, cfg.vocab, size=(cfg.global_batch, s_text + 1), dtype=np.int32)
    # inject structure so the LM has something learnable: tokens repeat with
    # period 3 within a window (pure-noise streams can't show convergence)
    tokens[:, 2::3] = tokens[:, 1::3][:, : tokens[:, 2::3].shape[1]]
    out: Dict[str, np.ndarray] = {"tokens": tokens[lo:hi]}
    if cfg.frontend_tokens:
        patches = rng.standard_normal((n, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
        out["patches"] = patches
    return out


class SyntheticLMStream:
    """Iterator of host-local batches with background prefetch."""

    def __init__(self, cfg: DataConfig, process_index: Optional[int] = None,
                 process_count: Optional[int] = None, start_step: int = 0):
        self.cfg = cfg
        pi = jax.process_index() if process_index is None else process_index
        pc = jax.process_count() if process_count is None else process_count
        per = cfg.global_batch // pc
        assert per * pc == cfg.global_batch, "global batch must divide host count"
        self.lo, self.hi = pi * per, (pi + 1) * per
        self._lock = threading.Lock()
        self._next_out = start_step    # next step __next__ must return
        self._next_gen = start_step    # next step the producer generates
        self._q: "queue.Queue[Tuple[int, Dict[str, np.ndarray]]]" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                step = self._next_gen
                self._next_gen += 1
            batch = _batch_for_step(self.cfg, step, self.lo, self.hi)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
        return self

    def __next__(self) -> Tuple[int, Dict[str, np.ndarray]]:
        while True:
            step, batch = self._q.get()
            if step == self._next_out:   # drop anything stale after a seek
                self._next_out = step + 1
                return step, batch

    def seek(self, step: int) -> None:
        """Restart support: resume the stream at an arbitrary step."""
        with self._lock:
            self._next_out = step
            self._next_gen = step
        while not self._q.empty():
            try:
                self._q.get_nowait()
            except queue.Empty:
                break

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2)


def host_local_batch_specs(cfg: DataConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs of the *global* batch (dry-run stand-ins)."""
    s_text = cfg.seq_len - cfg.frontend_tokens
    out = {
        "tokens": jax.ShapeDtypeStruct((cfg.global_batch, s_text + 1), np.int32),
    }
    if cfg.frontend_tokens:
        out["patches"] = jax.ShapeDtypeStruct(
            (cfg.global_batch, cfg.frontend_tokens, cfg.d_model), np.float32
        )
    return out
