"""Logical-axis sharding rules (MaxText-style) for the production meshes.

Model code annotates tensors with *logical* axis names; the active
:class:`ShardingRules` maps them to mesh axes.  Baseline mapping:

  batch   -> ("pod", "data")     activations' batch dim
  seq     -> "model"             sequence-parallel activations between blocks
  vocab   -> "model"             embedding/logit vocab dim
  heads   -> "model"             attention-head tensor parallelism
  ff      -> "model"             MLP hidden tensor parallelism
  experts -> "model"             expert parallelism (MoE, when divisible)
  fsdp    -> ("pod", "data")     ZeRO-3 sharding of params/moments
  kv_seq  -> "model"             decode KV-cache sequence sharding (GQA<TP)

Anything unmapped is replicated.  ``with_logical`` is the model-side
constraint helper; it is a no-op outside a mesh context (single-device smoke
tests run the same code).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingRules:
    rules: Tuple[Tuple[str, Axis], ...] = (
        ("batch", ("pod", "data")),
        ("seq", "model"),
        ("vocab", "model"),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("ff", "model"),
        ("experts", "model"),
        ("expert_ff", "model"),
        ("fsdp", ("pod", "data")),
        ("kv_seq", "model"),
        ("rnn", "model"),
    )

    def resolve(self, mesh_axes: Sequence[str], *logical: Optional[str]) -> P:
        """Translate logical names to a PartitionSpec valid on this mesh."""
        table = dict(self.rules)
        out = []
        used: set = set()
        for name in logical:
            if name is None:
                out.append(None)
                continue
            ax = table.get(name)
            if ax is None:
                out.append(None)
                continue
            if isinstance(ax, str):
                ax = (ax,)
            ax = tuple(a for a in ax if a in mesh_axes and a not in used)
            used.update(ax)
            if not ax:
                out.append(None)
            elif len(ax) == 1:
                out.append(ax[0])
            else:
                out.append(ax)
        return P(*out)

    def replace(self, **kw: Axis) -> "ShardingRules":
        table = dict(self.rules)
        table.update(kw)
        return ShardingRules(tuple(table.items()))


DEFAULT_RULES = ShardingRules()

# A context-global rules object: launch code swaps it before lowering.
_active_rules = DEFAULT_RULES


def set_rules(rules: ShardingRules) -> None:
    global _active_rules
    _active_rules = rules


def get_rules() -> ShardingRules:
    return _active_rules


def _current_mesh() -> Optional[Mesh]:
    mesh = jax.sharding.get_abstract_mesh() if hasattr(jax.sharding, "get_abstract_mesh") else None
    try:
        from jax._src import mesh as mesh_lib

        env_mesh = mesh_lib.thread_resources.env.physical_mesh
        if env_mesh is not None and not env_mesh.empty:
            return env_mesh
    except Exception:
        pass
    return None


def with_logical(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Sharding constraint by logical axis names (no-op without a mesh)."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = get_rules().resolve(mesh.axis_names, *logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *logical: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, get_rules().resolve(mesh.axis_names, *logical))


def spec_for(mesh: Mesh, *logical: Optional[str]) -> P:
    return get_rules().resolve(mesh.axis_names, *logical)
