"""Blockwise online-softmax attention (Flash Attention) for TPU.

Grid: (batch, heads, q_blocks, kv_blocks) with the kv axis innermost and
``arbitrary`` semantics (sequential accumulation).  Per (b, h, iq) the kernel
keeps f32 scratch in VMEM: the running output accumulator (Bq x D), the row
max m and the row normalizer l.  kv blocks that lie entirely outside the
causal (or sliding-window) footprint of a q block are skipped via
``pl.when`` — the classic flash skip that makes causal attention ~2x cheaper
and windowed attention O(S·W).

Block shapes are MXU-aligned: Bq x D and Bk x D tiles with D ∈ {64,128,256}
and Bq=Bk=128 by default — (128, 128) matmuls on the MXU, working set
3 tiles + scratch ≈ 0.5 MB << 16 MB VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: Optional[int],
    block_q: int, block_k: int, kv_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # causal / window block-level skip: any overlap with the allowed band?
    live = jnp.asarray(True)
    if causal:
        live = k_start <= q_start + block_q - 1
    if window is not None:
        live = jnp.logical_and(live, k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (Bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (Bk, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (Bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # (Bq, Bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                           # (Bq,)
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, window: Optional[int] = None,
    block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """q, k, v: (B, H, S, D) -> (B, H, S, D)."""
    b, h, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    scale = d ** -0.5
    q_blocks = s // block_q
    kv_blocks = s // block_k

    kernel = functools.partial(
        _attn_kernel,
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_blocks=kv_blocks,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, q_blocks, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
            pltpu.VMEM((block_q,), jnp.float32),     # running row max m
            pltpu.VMEM((block_q,), jnp.float32),     # running normalizer l
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(q, k, v)
