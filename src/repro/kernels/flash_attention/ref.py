"""Pure-jnp oracle for flash attention (materializes the S x S scores)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_reference(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, window: Optional[int] = None,
) -> jax.Array:
    """q, k, v: (B, H, S, D) -> (B, H, S, D)."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (d ** -0.5)
    sq, sk = q.shape[2], k.shape[2]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
