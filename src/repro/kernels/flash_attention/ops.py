"""Public flash-attention op in the model's (B, S, H, D) layout."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k")
)
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, window: Optional[int] = None,
    block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """q, k, v: (B, S, H, D) (same head counts — repeat GQA upstream)."""
    bq = min(block_q, q.shape[1])
    bk = min(block_k, k.shape[1])
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=not _on_tpu(),
    )
    return jnp.swapaxes(out, 1, 2)
