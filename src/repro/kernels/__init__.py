"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package: ``kernel.py`` (pl.pallas_call + explicit BlockSpec VMEM
tiling), ``ops.py`` (jit'd public wrapper), ``ref.py`` (pure-jnp oracle).
Validated in interpret mode on CPU; compiled natively on TPU.

  flash_attention — blockwise online-softmax attention (causal + window)
  rwkv6_scan      — RWKV-6 data-dependent-decay recurrence, (64x64) state
  rglru_scan      — RG-LRU diagonal gated recurrence
  delta_snapshot  — dirty-block detection for EasyCrash delta flushes
"""
