"""Public RG-LRU scan op."""
from __future__ import annotations

import functools

import jax

from .kernel import DEFAULT_BLOCK_D, DEFAULT_BLOCK_T, rglru_scan_btd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_t", "block_d"))
def rglru_scan(a, b, *, block_t: int = DEFAULT_BLOCK_T, block_d: int = DEFAULT_BLOCK_D):
    """a, b: (B, T, D) gates/inputs -> hidden states (B, T, D) f32."""
    return rglru_scan_btd(a, b, block_t=block_t, block_d=block_d,
                          interpret=not _on_tpu())
