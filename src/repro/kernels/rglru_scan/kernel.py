"""RG-LRU diagonal recurrence kernel: h_t = a_t * h_{t-1} + b_t.

Grid: (batch, d_blocks, t_blocks), time innermost with ``arbitrary``
semantics.  Channels are independent, so the d axis tiles to 128-lane
multiples; the hidden state (one f32 lane-vector per channel block) lives in
VMEM scratch across time chunks and never round-trips to HBM — the win over
the XLA associative_scan, which materializes O(log T) intermediate
(B, T, D) tensors in HBM.  Inside a chunk the recurrence is a fori_loop of
fused multiply-adds on the VPU (one (1, bd) vector op per token).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 256
DEFAULT_BLOCK_D = 128


def rglru_scan_btd(
    a: jax.Array, b: jax.Array,
    *, block_t: int = DEFAULT_BLOCK_T, block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = True,
) -> jax.Array:
    """a, b: (B, T, D) -> h: (B, T, D) f32 with h_t = a_t h_{t-1} + b_t, h_0-1 = 0."""
    bsz, t, d = a.shape
    bt = min(block_t, t)
    bd = min(block_d, d)
    assert t % bt == 0 and d % bd == 0

    def kernel(a_ref, b_ref, o_ref, h_ref):
        it = pl.program_id(2)

        @pl.when(it == 0)
        def _init():
            h_ref[...] = jnp.zeros_like(h_ref)

        av = a_ref[0].astype(jnp.float32)   # (bt, bd)
        bv = b_ref[0].astype(jnp.float32)

        def body(tt, h):
            at = jax.lax.dynamic_slice_in_dim(av, tt, 1, 0)[0]
            btk = jax.lax.dynamic_slice_in_dim(bv, tt, 1, 0)[0]
            h = at * h + btk
            o_ref[0, tt, :] = h.astype(o_ref.dtype)
            return h

        h_ref[...] = jax.lax.fori_loop(0, bt, body, h_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(bsz, d // bd, t // bt),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda b, jd, it: (b, it, jd)),
            pl.BlockSpec((1, bt, bd), lambda b, jd, it: (b, it, jd)),
        ],
        out_specs=pl.BlockSpec((1, bt, bd), lambda b, jd, it: (b, it, jd)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(a, b)
