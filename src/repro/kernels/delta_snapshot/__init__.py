from .ops import dirty_block_mask

__all__ = ["dirty_block_mask"]
