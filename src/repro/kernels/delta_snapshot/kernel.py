"""Dirty-block detection kernel for EasyCrash delta flushes.

The paper's mechanism relies on CLWB being ~free for clean cache blocks; TPUs
have no dirty bit, so we *compute* it: compare the live shard against the
last-persisted snapshot at flush-block granularity and emit a per-block
changed mask.  The host then DMAs only dirty blocks (see
``repro.core.manager``).  Bandwidth-bound VPU compare + horizontal reduce:
one pass over 2x the shard bytes, no MXU.

Grid: 1-D over tiles of ``rows_per_tile`` blocks; each block is
``block_elems`` contiguous elements (default 256 elems = 1 KiB f32, the
production flush-block size).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ELEMS = 256
DEFAULT_ROWS_PER_TILE = 64


def _delta_kernel(x_ref, prev_ref, o_ref):
    x = x_ref[...]
    p = prev_ref[...]
    diff = (x != p).any(axis=1)
    o_ref[...] = diff.astype(jnp.int32)


def dirty_block_mask_blocks(
    x: jax.Array, prev: jax.Array,
    *, rows_per_tile: int = DEFAULT_ROWS_PER_TILE, interpret: bool = True,
) -> jax.Array:
    """x, prev: (n_blocks, block_elems) -> int32 (n_blocks,) changed mask."""
    n, e = x.shape
    rt = min(rows_per_tile, n)
    assert n % rt == 0
    return pl.pallas_call(
        _delta_kernel,
        grid=(n // rt,),
        in_specs=[
            pl.BlockSpec((rt, e), lambda i: (i, 0)),
            pl.BlockSpec((rt, e), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(x, prev)
