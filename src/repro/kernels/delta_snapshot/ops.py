"""Public dirty-block op: flat arrays in, per-block mask out."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_ELEMS, dirty_block_mask_blocks


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_elems",))
def dirty_block_mask(x, prev, *, block_elems: int = DEFAULT_BLOCK_ELEMS):
    """x, prev: same-shape arrays -> int32 (n_blocks,) changed mask.

    Arrays are flattened and zero-padded to a block multiple (zero-padding
    both sides identically, so padding never reads as dirty).
    """
    xf = x.reshape(-1)
    pf = prev.reshape(-1)
    n = xf.shape[0]
    nb = -(-n // block_elems)
    pad = nb * block_elems - n
    if pad:
        xf = jnp.pad(xf, (0, pad))
        pf = jnp.pad(pf, (0, pad))
    xb = xf.reshape(nb, block_elems)
    pb = pf.reshape(nb, block_elems)
    rt = 64
    while nb % rt != 0:
        rt //= 2
    return dirty_block_mask_blocks(xb, pb, rows_per_tile=max(rt, 1),
                                   interpret=not _on_tpu())
