"""numpy/jnp oracle for dirty-block detection."""
from __future__ import annotations

import jax.numpy as jnp


def dirty_block_mask_reference(x, prev):
    """x, prev: (n_blocks, block_elems) -> int32 (n_blocks,)."""
    return (x != prev).any(axis=1).astype(jnp.int32)
