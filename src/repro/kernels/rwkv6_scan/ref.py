"""Step-by-step jnp oracle for the RWKV-6 recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_reference(r, k, v, w, u):
    """r,k,v,w: (B, H, T, D); u: (H, D) -> (B, H, T, D) f32."""
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    w = w.astype(jnp.float32)
    b, h, t, d = r.shape

    def step(S, x):
        rt, kt, vt, wt = x                        # (B, H, D)
        kv = kt[..., :, None] * vt[..., None, :]  # (B, H, D, D)
        att = S + u[None, :, :, None] * kv
        yt = jnp.einsum("bhk,bhkv->bhv", rt, att)
        return wt[..., :, None] * S + kv, yt

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (r, k, v, w))
    S0 = jnp.zeros((b, h, d, d), jnp.float32)
    _, ys = jax.lax.scan(step, S0, xs)       # ys: (T, B, H, D)
    return jnp.moveaxis(ys, 0, 2)
