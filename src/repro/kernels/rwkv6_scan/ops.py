"""Public RWKV-6 scan op in the model's (B, S, H, D) layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_T, rwkv6_scan_bhtd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_t",))
def rwkv6_scan(r, k, v, w, u, *, block_t: int = DEFAULT_BLOCK_T):
    """r,k,v,w: (B, S, H, D); u: (H, D) -> (B, S, H, D) f32."""
    rt, kt, vt, wt = (jnp.swapaxes(x, 1, 2) for x in (r, k, v, w))
    y = rwkv6_scan_bhtd(rt, kt, vt, wt, u, block_t=block_t, interpret=not _on_tpu())
    return jnp.swapaxes(y, 1, 2)
