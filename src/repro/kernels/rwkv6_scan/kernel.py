"""RWKV-6 recurrence kernel: matrix-state scan with data-dependent decay.

Per (batch, head) grid cell the kernel holds the (D x D) f32 state in VMEM
scratch and walks the sequence in time-chunks of ``block_t`` tokens (the
chunk is the VMEM working set: 4 x block_t x D f32 inputs + D x D state;
block_t=256, D=64 -> ~0.5 MB).  Within a chunk the token loop is a
``fori_loop`` of rank-1 updates:

    y_t = r_t . (S + u * k_t^T v_t)
    S   = diag(w_t) S + k_t^T v_t

On TPU the outer products and the r.S contraction map to the VPU/MXU; the
HBM win over the pure-jnp scan is that S never round-trips to HBM (the
XLA scan carries it through memory every token).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 256


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *, block_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, 0].astype(jnp.float32)   # (T, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)      # (D,)

    def body(t, carry):
        S = carry                                        # (D, D)
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)[0]  # (D,)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)[0]
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)[0]
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)[0]
        kv = kt[:, None] * vt[None, :]                   # (D, D)
        att = S + u[:, None] * kv
        yt = rt @ att                                    # (D,)
        o_ref[0, 0, t, :] = yt.astype(o_ref.dtype)
        return wt[:, None] * S + kv

    state_ref[...] = jax.lax.fori_loop(0, block_t, body, state_ref[...])


def rwkv6_scan_bhtd(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
    *, block_t: int = DEFAULT_BLOCK_T, interpret: bool = True,
) -> jax.Array:
    """r,k,v,w: (B, H, T, D); u: (H, D) -> y (B, H, T, D) f32."""
    b, h, t, d = r.shape
    bt = min(block_t, t)
    assert t % bt == 0, (t, bt)
    kernel = functools.partial(_rwkv_kernel, block_t=bt)
    return pl.pallas_call(
        kernel,
        grid=(b, h, t // bt),
        in_specs=[
            pl.BlockSpec((1, 1, bt, d), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, bt, d), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, bt, d), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, bt, d), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, d), lambda b, h, it: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bt, d), lambda b, h, it: (b, h, it, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(r, k, v, w, u)
