"""Elastic restore: load a mesh-agnostic checkpoint onto any mesh.

Checkpoints store logical (unsharded) arrays, so resharding is just
``jax.device_put`` with the *target* mesh's NamedShardings.  This is the
elastic-scaling path: a run checkpointed on N hosts restores onto M hosts
with a different mesh shape, as long as the logical shapes still divide
(GSPMD pads when they don't).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from ..distributed.sharding import get_rules


def reshard_restore(tree: Any, spec_tree: Any, mesh: Mesh) -> Any:
    """Place a host-memory pytree onto ``mesh`` per logical spec tree.

    ``spec_tree`` mirrors ``tree`` with tuples of logical axis names (the
    same trees the model exposes via ``param_specs``/``cache_specs``).
    """
    rules = get_rules()

    def place(leaf, spec):
        if spec is None:
            spec = ()
        pspec = rules.resolve(mesh.axis_names, *spec)
        arr = np.asarray(leaf)
        return jax.device_put(arr, NamedSharding(mesh, pspec))

    return jax.tree.map(
        place, tree, spec_tree,
        is_leaf=lambda x: not isinstance(x, dict),
    )
