"""Multilevel asynchronous checkpoint manager.

Two tiers (paper §7 assumes exactly this):

* **local** — fast tier (node-local SSD / burst buffer): written
  synchronously-cheap via a background thread, committed atomically by
  directory rename;
* **remote** — slow tier (parallel FS): the local checkpoint is *drained*
  to the remote tier asynchronously, off the critical path.

Retention keeps the newest ``keep`` checkpoints per tier.  ``restore()``
prefers the newest complete local checkpoint and falls back to remote —
together with the EasyCrash arena this forms the three-level recovery
hierarchy: arena (NVM) -> local checkpoint -> remote checkpoint.

Commits go through the :mod:`repro.core.durable` replace path (data fsync,
atomic rename, directory fsync), so a checkpoint either exists completely or
not at all — even across ``kill -9`` mid-write or power loss.  Each local
write is also *timed*: :meth:`CheckpointManager.mean_save_seconds` and
:func:`measure_checkpoint_cost` turn the manager into the measurement
instrument that feeds :class:`~repro.core.efficiency.SystemConfig` a real
``T_chk`` (:func:`measured_system_config`) instead of an assumed one.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.durable import durable_replace, fsync_dir
from ..core.efficiency import SystemConfig
from .serialization import load_pytree, save_pytree, tree_nbytes


@dataclass(frozen=True)
class CheckpointConfig:
    local_dir: str
    remote_dir: Optional[str] = None
    keep: int = 2
    async_drain: bool = True


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.local_dir, exist_ok=True)
        if cfg.remote_dir:
            os.makedirs(cfg.remote_dir, exist_ok=True)
        self._drain_thread: Optional[threading.Thread] = None
        #: wall seconds of each completed local-tier write (oldest first)
        self.save_seconds: List[float] = []

    # ------------------------------------------------------------------ save
    def _step_dir(self, root: str, step: int) -> str:
        return os.path.join(root, f"step_{step:010d}")

    def save(self, step: int, tree: Any, block: bool = False) -> str:
        """Write a checkpoint to the local tier; drain to remote async."""
        t0 = time.perf_counter()
        final = self._step_dir(self.cfg.local_dir, step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_pytree(tree, tmp)
        durable_replace(tmp, final)  # atomic + power-loss-durable commit
        self.save_seconds.append(time.perf_counter() - t0)
        self._gc(self.cfg.local_dir)
        if self.cfg.remote_dir:
            if self.cfg.async_drain and not block:
                self._wait_drain()
                self._drain_thread = threading.Thread(
                    target=self._drain, args=(step,), daemon=True
                )
                self._drain_thread.start()
            else:
                self._drain(step)
        return final

    def _drain(self, step: int) -> None:
        src = self._step_dir(self.cfg.local_dir, step)
        dst = self._step_dir(self.cfg.remote_dir, step)  # type: ignore[arg-type]
        tmp = dst + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        if not os.path.exists(src):
            return
        shutil.copytree(src, tmp)
        # durable_replace requires the tmp contents to be fsynced already;
        # copytree does not fsync, so flush the copied leaves + manifest
        # before committing the rename (else the remote tier could surface a
        # manifest pointing at torn leaf data after power loss)
        for name in os.listdir(tmp):
            fd = os.open(os.path.join(tmp, name), os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
        fsync_dir(tmp)
        durable_replace(tmp, dst)
        self._gc(self.cfg.remote_dir)  # type: ignore[arg-type]

    def _wait_drain(self) -> None:
        if self._drain_thread is not None:
            self._drain_thread.join()
            self._drain_thread = None

    def _gc(self, root: str) -> None:
        steps = self.list_steps(root)
        for s in steps[: -self.cfg.keep]:
            shutil.rmtree(self._step_dir(root, s), ignore_errors=True)

    # --------------------------------------------------------------- restore
    @staticmethod
    def list_steps(root: str) -> List[int]:
        if not os.path.isdir(root):
            return []
        out = []
        for d in os.listdir(root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(root, d, "manifest.json")):
                    out.append(int(d[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        local = self.list_steps(self.cfg.local_dir)
        remote = self.list_steps(self.cfg.remote_dir) if self.cfg.remote_dir else []
        allsteps = sorted(set(local) | set(remote))
        return allsteps[-1] if allsteps else None

    def restore(self, step: Optional[int] = None) -> Optional[Tuple[int, Any]]:
        """Newest (or given) checkpoint; local tier preferred."""
        self._wait_drain()
        if step is None:
            step = self.latest_step()
            if step is None:
                return None
        for root in (self.cfg.local_dir, self.cfg.remote_dir):
            if not root:
                continue
            d = self._step_dir(root, step)
            if os.path.exists(os.path.join(d, "manifest.json")):
                return step, load_pytree(d)
        return None

    # ------------------------------------------------------------- measured
    def mean_save_seconds(self) -> float:
        """Mean measured local-tier write time (0.0 before the first save)."""
        if not self.save_seconds:
            return 0.0
        return sum(self.save_seconds) / len(self.save_seconds)

    def close(self) -> None:
        self._wait_drain()


# ----------------------------------------------------- measured SystemConfig
def measure_checkpoint_cost(
    tree: Any, repeats: int = 3
) -> Tuple[float, int]:
    """Measure the local-tier write cost of one checkpoint of ``tree``.

    Writes the tree ``repeats`` times to a throwaway directory through a
    :class:`CheckpointManager` (the same durable path production saves take)
    and returns ``(median seconds per write, checkpoint bytes)``.
    """
    if repeats < 1:
        raise ValueError("repeats must be >= 1")
    with tempfile.TemporaryDirectory(prefix="ckpt-measure-") as d:
        mgr = CheckpointManager(CheckpointConfig(local_dir=d, keep=1))
        for step in range(repeats):
            mgr.save(step, tree)
        mgr.close()
        secs = float(np.median(mgr.save_seconds))
    return secs, tree_nbytes(tree)


def system_config_from_measurement(
    seconds_per_write: float,
    checkpoint_bytes: int,
    mtbf: float,
    target_bytes: Optional[int] = None,
    **kwargs,
) -> SystemConfig:
    """Build a :class:`~repro.core.efficiency.SystemConfig` whose ``t_chk``
    comes from a measured write, optionally extrapolated (at the measured
    throughput) to a deployment-scale checkpoint of ``target_bytes``.

    Pure function of its inputs — the measurement itself lives in
    :func:`measure_checkpoint_cost` so this part stays deterministic and
    testable.
    """
    if seconds_per_write <= 0.0 or checkpoint_bytes <= 0:
        raise ValueError("need a positive measured write time and size")
    t_chk = seconds_per_write
    if target_bytes is not None:
        t_chk = seconds_per_write * (float(target_bytes) / float(checkpoint_bytes))
    return SystemConfig(mtbf=mtbf, t_chk=t_chk, **kwargs)


def measured_system_config(
    tree: Any,
    mtbf: float,
    target_bytes: Optional[int] = None,
    repeats: int = 3,
    **kwargs,
) -> SystemConfig:
    """Measure ``tree``'s checkpoint write cost and build the corresponding
    :class:`~repro.core.efficiency.SystemConfig` (paper §7's ``T_chk``,
    measured on this machine instead of assumed).

    ``target_bytes`` extrapolates the measured throughput to a deployment-
    scale checkpoint (CI-sized app states are kilobytes; a 100k-node
    system's coordinated checkpoint is not).
    """
    secs, nbytes = measure_checkpoint_cost(tree, repeats=repeats)
    return system_config_from_measurement(
        secs, nbytes, mtbf, target_bytes=target_bytes, **kwargs
    )
