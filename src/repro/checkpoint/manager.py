"""Multilevel asynchronous checkpoint manager.

Two tiers (paper §7 assumes exactly this):

* **local** — fast tier (node-local SSD / burst buffer): written
  synchronously-cheap via a background thread, committed atomically by
  directory rename;
* **remote** — slow tier (parallel FS): the local checkpoint is *drained*
  to the remote tier asynchronously, off the critical path.

Retention keeps the newest ``keep`` checkpoints per tier.  ``restore()``
prefers the newest complete local checkpoint and falls back to remote —
together with the EasyCrash arena this forms the three-level recovery
hierarchy: arena (NVM) -> local checkpoint -> remote checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from .serialization import load_pytree, save_pytree


@dataclass(frozen=True)
class CheckpointConfig:
    local_dir: str
    remote_dir: Optional[str] = None
    keep: int = 2
    async_drain: bool = True


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.local_dir, exist_ok=True)
        if cfg.remote_dir:
            os.makedirs(cfg.remote_dir, exist_ok=True)
        self._drain_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def _step_dir(self, root: str, step: int) -> str:
        return os.path.join(root, f"step_{step:010d}")

    def save(self, step: int, tree: Any, block: bool = False) -> str:
        """Write a checkpoint to the local tier; drain to remote async."""
        final = self._step_dir(self.cfg.local_dir, step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_pytree(tree, tmp)
        os.replace(tmp, final)  # atomic commit
        self._gc(self.cfg.local_dir)
        if self.cfg.remote_dir:
            if self.cfg.async_drain and not block:
                self._wait_drain()
                self._drain_thread = threading.Thread(
                    target=self._drain, args=(step,), daemon=True
                )
                self._drain_thread.start()
            else:
                self._drain(step)
        return final

    def _drain(self, step: int) -> None:
        src = self._step_dir(self.cfg.local_dir, step)
        dst = self._step_dir(self.cfg.remote_dir, step)  # type: ignore[arg-type]
        tmp = dst + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        if not os.path.exists(src):
            return
        shutil.copytree(src, tmp)
        os.replace(tmp, dst)
        self._gc(self.cfg.remote_dir)  # type: ignore[arg-type]

    def _wait_drain(self) -> None:
        if self._drain_thread is not None:
            self._drain_thread.join()
            self._drain_thread = None

    def _gc(self, root: str) -> None:
        steps = self.list_steps(root)
        for s in steps[: -self.cfg.keep]:
            shutil.rmtree(self._step_dir(root, s), ignore_errors=True)

    # --------------------------------------------------------------- restore
    @staticmethod
    def list_steps(root: str) -> List[int]:
        if not os.path.isdir(root):
            return []
        out = []
        for d in os.listdir(root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(root, d, "manifest.json")):
                    out.append(int(d[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        local = self.list_steps(self.cfg.local_dir)
        remote = self.list_steps(self.cfg.remote_dir) if self.cfg.remote_dir else []
        allsteps = sorted(set(local) | set(remote))
        return allsteps[-1] if allsteps else None

    def restore(self, step: Optional[int] = None) -> Optional[Tuple[int, Any]]:
        """Newest (or given) checkpoint; local tier preferred."""
        self._wait_drain()
        if step is None:
            step = self.latest_step()
            if step is None:
                return None
        for root in (self.cfg.local_dir, self.cfg.remote_dir):
            if not root:
                continue
            d = self._step_dir(root, step)
            if os.path.exists(os.path.join(d, "manifest.json")):
                return step, load_pytree(d)
        return None

    def close(self) -> None:
        self._wait_drain()
