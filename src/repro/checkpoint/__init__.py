from .manager import CheckpointConfig, CheckpointManager
from .serialization import load_pytree, save_pytree
from .reshard import reshard_restore

__all__ = [
    "CheckpointConfig", "CheckpointManager", "load_pytree", "save_pytree",
    "reshard_restore",
]
