from .manager import (
    CheckpointConfig,
    CheckpointManager,
    measure_checkpoint_cost,
    measured_system_config,
    system_config_from_measurement,
)
from .serialization import load_pytree, save_pytree, tree_nbytes
from .reshard import reshard_restore

__all__ = [
    "CheckpointConfig", "CheckpointManager", "load_pytree", "save_pytree",
    "tree_nbytes", "measure_checkpoint_cost", "measured_system_config",
    "system_config_from_measurement", "reshard_restore",
]
