"""Pytree (de)serialization: one .npy per leaf + a JSON manifest.

Leaves are saved in *logical* (unsharded) layout: every host writes its
addressable shards into the right slice of a per-leaf file region.  On one
host this degenerates to plain np.save; the format stays mesh-agnostic so a
checkpoint taken on any mesh restores onto any other (elastic scaling).

Writes are durable: every leaf file is flushed+fsynced and the manifest —
which is what marks a checkpoint *complete* — is committed last through the
:mod:`repro.core.durable` replace path.  A writer killed (or a node losing
power) mid-checkpoint therefore leaves either a manifest-less partial the
manager ignores, or a fully-landed checkpoint; never a manifest pointing at
torn leaf data.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np

from ..core.durable import durable_replace

_SEP = "/"


def flatten_tree(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            if isinstance(v, dict):
                out.update(flatten_tree(v, prefix + k + _SEP))
            else:
                out[prefix + k] = v
    else:
        out[prefix.rstrip(_SEP) or "value"] = tree
    return out


def unflatten_tree(flat: Dict[str, Any]) -> Any:
    out: Dict[str, Any] = {}
    for k, v in flat.items():
        parts = k.split(_SEP)
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return out


def tree_nbytes(tree: Any) -> int:
    """Total serialized payload size of a pytree's leaves, in bytes."""
    return sum(
        np.asarray(jax.device_get(leaf)).nbytes
        for leaf in flatten_tree(tree).values()
    )


def save_pytree(tree: Any, directory: str) -> None:
    os.makedirs(directory, exist_ok=True)
    flat = flatten_tree(tree)
    manifest = {}
    for name, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        safe = name.replace(_SEP, "__")
        with open(os.path.join(directory, safe + ".npy"), "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest[name] = {"file": safe + ".npy", "shape": list(arr.shape), "dtype": str(arr.dtype)}
    tmp = os.path.join(directory, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    durable_replace(tmp, os.path.join(directory, "manifest.json"))


def load_pytree(directory: str) -> Any:
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for name, meta in manifest.items():
        arr = np.load(os.path.join(directory, meta["file"]))
        want = np.dtype(meta["dtype"])
        if arr.dtype != want:
            # np.load round-trips extension dtypes (bfloat16) as void bytes
            if arr.dtype.kind == "V" and arr.dtype.itemsize == want.itemsize:
                arr = arr.view(want)
            else:
                arr = arr.astype(want)
        flat[name] = arr
    return unflatten_tree(flat)
