"""Gradient compression for the DP all-reduce: top-k + error feedback, int8.

Distributed-optimization trick for bandwidth-bound data parallelism: the
all-reduce moves top-k values+indices (or int8-quantized tensors) instead of
full bf16 gradients.  Error feedback accumulates the dropped residual so the
compression is unbiased over time (Stich et al., 2018).

These are pure-jnp and compile inside the train step; the launcher enables
them with ``--grad-compression topk:0.01`` / ``int8``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_topk(g: jax.Array, frac: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Keep the largest-|g| fraction.  Returns (values, indices, residual)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    residual = flat.at[idx].set(0.0).reshape(g.shape)
    return kept, idx, residual.astype(g.dtype)


def decompress_topk(vals: jax.Array, idx: jax.Array, shape, dtype) -> jax.Array:
    flat = jnp.zeros((int(jnp.prod(jnp.asarray(shape))),), jnp.float32)
    flat = flat.at[idx].set(vals)
    return flat.reshape(shape).astype(dtype)


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization.  Returns (q, scale)."""
    a = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(a / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)
