"""AdamW built from scratch (no optax on the box), pytree-functional.

Moments shard exactly like their parameters (the spec tree is reused), and
the moment dtype is a per-config knob — the 340B cell needs bf16 moments to
fit a single pod.  Global-norm clipping is fused into the update.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params: Params, moment_dtype: str = "float32") -> Dict:
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_spec_tree: Any) -> Dict:
    """Moments inherit their parameter's sharding; count is replicated."""
    return {
        "mu": param_spec_tree,
        "nu": param_spec_tree,
        "count": (),
    }


def _global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params: Params,
    grads: Params,
    state: Dict,
    lr: jax.Array,
    cfg: AdamWConfig = AdamWConfig(),
) -> Tuple[Params, Dict, Dict[str, jax.Array]]:
    count = state["count"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        nu32 = nu.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        mu_hat = mu32 / (1 - cfg.b1 ** count.astype(jnp.float32))
        nu_hat = nu32 / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    return (
        jax.tree.unflatten(treedef, new_p),
        {
            "mu": jax.tree.unflatten(treedef, new_mu),
            "nu": jax.tree.unflatten(treedef, new_nu),
            "count": count,
        },
        {"grad_norm": gnorm},
    )


class OptState(dict):
    """Marker type (opt state is a plain dict pytree)."""
