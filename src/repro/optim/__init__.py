from .adamw import OptState, adamw_init, adamw_update, opt_state_specs
from .schedule import cosine_schedule, linear_warmup
from .compression import compress_topk, decompress_topk, quantize_int8, dequantize_int8

__all__ = [
    "OptState", "adamw_init", "adamw_update", "opt_state_specs",
    "cosine_schedule", "linear_warmup",
    "compress_topk", "decompress_topk", "quantize_int8", "dequantize_int8",
]
