"""Batched decode server with EasyCrash KV/recurrent-state persistence.

Serves a (reduced-by-default) architecture: prefill a batch of prompts,
decode greedily, and — the EasyCrash extension for inference — persist the
decode cache incrementally so a crashed server resumes sessions without
re-running prefill.  ``--inject-failure-at`` kills the server mid-stream to
demonstrate the recovery path: the restart reloads params + cache from the
arena, verifies by re-decoding the last committed token, and continues.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --prompts 4 --decode-steps 64 --inject-failure-at 32
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..core.arena import NVMArena
from ..core.manager import EasyCrashManager, FlushPolicy, flatten_state
from ..models import init_cache, init_params, scaled_down
from .steps import make_decode_fn, make_prefill_step


class SimulatedFailure(RuntimeError):
    pass


def run(args) -> Dict[str, float]:
    cfg = get_arch(args.arch)
    if not args.full_size:
        cfg = scaled_down(cfg, width=args.width)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    prefill_fn = jax.jit(make_prefill_step(cfg))
    decode_fn = jax.jit(make_decode_fn(cfg), donate_argnums=(1,))

    os.makedirs(args.workdir, exist_ok=True)
    arena_dir = os.path.join(args.workdir, "serve_arena")
    try:
        arena = NVMArena.reattach(arena_dir)
        resumed = True
    except Exception:
        arena = NVMArena(backing_dir=arena_dir)
        resumed = False
    policy = FlushPolicy(leaves=("cache", "tokens"), every_steps=args.flush_every,
                         async_flush=False, persist_mode=args.persist_mode)
    mgr = EasyCrashManager(arena, policy)

    max_len = args.prompt_len + args.decode_steps + 1
    prompts = jax.random.randint(
        jax.random.PRNGKey(7), (args.prompts, args.prompt_len), 0, cfg.vocab
    )

    if resumed and "__step__" in arena:
        start = int(arena.get("__step__"))
        print(f"[restore] resuming decode at step {start} from arena")
        flat = {n: arena.get(n) for n in arena.names() if not n.startswith("__")}
        from ..core.manager import unflatten_state

        state = unflatten_state(flat)
        cache = jax.tree.map(jnp.asarray, state["cache"])
        all_tokens = [jnp.asarray(state["tokens"])]
        token = all_tokens[-1][:, -1:]
    else:
        start = 0
        logits, cache = prefill_fn(params, {"tokens": prompts})
        # right-size the cache for continued decoding
        full_cache = init_cache(cfg, args.prompts, max_len)
        cache = _splice_cache(cfg, full_cache, cache, args.prompt_len)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        all_tokens = [prompts, token]

    t0 = time.time()
    for step in range(start, args.decode_steps):
        token, cache = decode_fn(params, cache, token)
        all_tokens.append(token)
        host = {
            "cache": jax.tree.map(np.asarray, cache),
            "tokens": np.asarray(jnp.concatenate(all_tokens, axis=1)),
        }
        mgr.maybe_flush(step + 1, host)
        if args.inject_failure_at and step + 1 == args.inject_failure_at:
            raise SimulatedFailure(f"injected failure at decode step {step + 1}")
    dt = time.time() - t0
    out = np.asarray(jnp.concatenate(all_tokens, axis=1))
    stats = {
        "decode_steps": args.decode_steps - start,
        "tokens_per_s": (args.decode_steps - start) * args.prompts / max(dt, 1e-9),
        "blocks_written": mgr.stats.blocks_written,
        "bytes_written": mgr.stats.bytes_written,
        "resumed": resumed,
        "output_shape": list(out.shape),
    }
    print("[done]", stats)
    mgr.close()
    return stats


def fleet_report(stats: Dict[str, float], args) -> Dict[str, dict]:
    """Project this server's *measured* serving process onto a replica fleet.

    The single-process run measures the two quantities the fleet simulator
    needs from the real system: the per-step decode time (service rate) and
    the delta-flush traffic (``bytes_written`` -> ``t_s`` via
    :func:`~repro.core.efficiency.persist_overhead_fraction`).  Everything
    else — arrivals, failures, recovery policy — is simulated, so the same
    binary answers "what would this server's goodput/p99 look like across N
    replicas under paper-like failure rates?".
    """
    from ..core import (
        POLICIES,
        ArrivalProcess,
        FleetConfig,
        PoissonTrace,
        RecomputeProfile,
        ServiceModel,
        SystemConfig,
        fleet_frontier,
        persist_overhead_fraction,
    )

    steps = max(int(stats["decode_steps"]), 1)
    step_time = args.prompts / max(stats["tokens_per_s"], 1e-9)
    t_s = persist_overhead_fraction(stats["bytes_written"] / steps, step_time)
    # decode sessions are S1-dominant (the KV cache is the session and it is
    # what we persist); the tail mirrors the decode campaign's shape
    profile = RecomputeProfile.from_fractions(
        "serve", {"S1": 0.9, "S2": 0.06, "S3": 0.02, "S4": 0.02},
        extra_iters_hist=((2, 3), (8, 1)),
    )
    service_s = args.decode_steps * step_time
    rate = args.fleet_rate
    if rate <= 0:  # auto: offer ~80% of fleet capacity at the measured speed
        rate = 0.8 * args.fleet_replicas / max(service_s, 1e-3)
    cfg = FleetConfig(
        n_replicas=args.fleet_replicas,
        arrival=ArrivalProcess(rate=rate, amplitude=0.3),
        service=ServiceModel(mean_s=max(service_s, 1e-3), sigma=0.6,
                             prefill_s=max(args.prompt_len * step_time, 1e-3)),
        trace=PoissonTrace(mtbf=args.fleet_mtbf),
        system=SystemConfig(mtbf=args.fleet_mtbf, t_chk=30.0,
                            nvm_restore_time=2.0),
        slo_latency=4.0 * max(service_s, 1e-3),
        queue_cap=48,
        horizon=args.fleet_horizon,
        t_s=t_s,
        t_iter=step_time,
        seed=args.seed,
    )
    print(f"[fleet] measured t_s={t_s:.4f} step={step_time*1e3:.2f}ms "
          f"service={service_s:.2f}s; {cfg.n_replicas} replicas, "
          f"mtbf={cfg.trace.mtbf:.0f}s, horizon={cfg.horizon:.0f}s")
    doc = fleet_frontier(cfg, profile)
    for policy in POLICIES:
        p = doc["policies"][policy]
        print(f"[fleet] {policy:10s} goodput={p['goodput']:.3f}rps "
              f"loss={p['dropped']/max(p['arrived'],1):.3f} "
              f"slo={p['slo_violation_frac']:.3f} "
              f"p99={p['latency_p99']:.2f}s fails={p['n_failures']}")
    return doc["policies"]


def _splice_cache(cfg, full_cache, prefill_cache, prompt_len: int):
    """Install prefill K/V into the right-sized decode cache."""
    def splice(dst, src):
        if dst.ndim >= 3 and src.ndim == dst.ndim and dst.shape != src.shape:
            # KV caches: (L, B, S, H, D) — copy the prefix
            n = min(src.shape[2], dst.shape[2])
            return jax.lax.dynamic_update_slice_in_dim(dst, src[:, :, :n], 0, axis=2)
        return src.astype(dst.dtype) if src.shape == dst.shape else dst

    out = jax.tree.map(splice, full_cache, prefill_cache)
    out["t"] = jnp.asarray(prompt_len, jnp.int32)
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=64)
    ap.add_argument("--flush-every", type=int, default=8)
    ap.add_argument("--persist-mode", default="delta",
                    choices=("auto", "delta", "full"),
                    help="flush granularity: arena byte diff / delta_snapshot "
                         "kernel (changed blocks only) / whole-object rewrite")
    ap.add_argument("--workdir", default="/tmp/repro_serve")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--inject-failure-at", type=int, default=0)
    ap.add_argument("--fleet", action="store_true",
                    help="after serving, project the measured step time and "
                         "persist traffic onto a replica fleet under "
                         "failures (repro.core.fleetsim policy comparison)")
    ap.add_argument("--fleet-replicas", type=int, default=4)
    ap.add_argument("--fleet-rate", type=float, default=0.0,
                    help="fleet offered load, requests/s "
                         "(<= 0: auto, ~80%% of measured fleet capacity)")
    ap.add_argument("--fleet-mtbf", type=float, default=900.0,
                    help="per-replica MTBF, seconds")
    ap.add_argument("--fleet-horizon", type=float, default=1800.0)
    args = ap.parse_args(argv)
    try:
        stats = run(args)
    except SimulatedFailure as e:
        print(f"[failure] {e}; restarting...")
        args.inject_failure_at = 0
        stats = run(args)
    if args.fleet:
        fleet_report(stats, args)


if __name__ == "__main__":
    main()
