"""Trip-count-aware cost extraction from optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body exactly ONCE,
so for scan-over-layers models (all of ours — layer stacks and gradient
accumulation compile to whiles) its FLOPs/bytes understate the true step
cost by the trip counts.  This module parses the post-partitioning HLO:

  1. split the module into computations;
  2. find ``while`` ops; their trip counts come straight from
     ``backend_config={"known_trip_count":{"n":...}}`` (fallback: the
     comparison constant in the condition computation);
  3. propagate nesting multipliers through the call graph (a layer scan
     inside a grad-accum scan runs trips_outer x trips_inner times);
  4. accumulate per-computation costs x multiplier:
       - FLOPs from ``dot`` / ``convolution`` ops (2 x |out| x K),
       - memory traffic as operand+output bytes per op (the cost_analysis
         convention, post-fusion; fusion bodies are counted at the fusion
         boundary, not per internal op),
       - collective wire bytes (ring-algorithm-weighted) per op kind.

Validated against cost_analysis on while-free modules and against known
config trip counts in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\()?\s*([a-z0-9]+)\[([\d,]*)\]"
)
# opcode = first `word(` after the '=' (type tuples contain no parens)
_OPCODE_RE = re.compile(r"\s([a-z][a-z0-9\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_WHILE_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_WHILE_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_INT_RE = re.compile(r"=\s*s(?:32|64)\[\]\s*constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%[\w\.\-]+")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS_RE = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "while",
    "conditional", "call", "domain", "opt-barrier", "optimization-barrier",
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: List[int]) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    return b * (math.prod(dims) if dims else 1)


@dataclass
class OpLine:
    name: str
    dtype: str                   # "" for tuple-typed
    dims: List[int]
    op: str
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    ops: List[OpLine] = field(default_factory=list)
    shapes: Dict[str, Tuple[str, List[int]]] = field(default_factory=dict)


def _parse_op_line(line: str) -> Optional[OpLine]:
    d = _DEF_RE.match(line)
    if not d:
        return None
    name, tuple_open, dtype, dims_s = d.groups()
    dims = [int(x) for x in dims_s.split(",") if x]
    is_tuple = tuple_open == "("
    eq = line.index("=")
    rest = line[eq + 1:]
    m = _OPCODE_RE.search(rest)
    if not m:
        return None
    op = m.group(1)
    args = rest[m.end():]
    depth = 1
    end = 0
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = [o[1:] for o in _OPERAND_RE.findall(args[:end])]
    return OpLine(
        name=name,
        dtype="" if is_tuple else dtype,
        dims=[] if is_tuple else dims,
        op=op,
        operands=operands,
        line=line,
    )


def split_computations(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if "->" in line and line.rstrip().endswith("{"):
            hdr = _COMP_HDR_RE.match(line)
            if hdr:
                cur = Computation(hdr.group(1))
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        ol = _parse_op_line(line)
        if ol:
            cur.ops.append(ol)
            if ol.dtype:
                cur.shapes[ol.name] = (ol.dtype, ol.dims)
    if not entry and comps:
        entry = max(comps, key=lambda c: len(comps[c].ops))
    return comps, entry


def _trip_count(op: OpLine, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(op.line)
    if m:
        return max(1, int(m.group(1)))
    mc = _WHILE_COND_RE.search(op.line)
    if mc and mc.group(1) in comps:
        ints = [int(x) for ol in comps[mc.group(1)].ops
                for x in _CONST_INT_RE.findall(ol.line)]
        if ints:
            return max(1, max(ints))
    return 1


def region_multipliers(
    comps: Dict[str, Computation], entry: str
) -> Tuple[Dict[str, float], List[int], Set[str]]:
    """(multiplier per computation, trip counts found, fusion-body names)."""
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry in mult:
        mult[entry] = 1.0
    fusion_bodies: Set[str] = set()
    trips: List[int] = []
    for comp in comps.values():
        for op in comp.ops:
            if op.op == "fusion":
                for callee in _CALLS_RE.findall(op.line):
                    fusion_bodies.add(callee)
            if op.op == "while":
                trips.append(_trip_count(op, comps))
    for _ in range(16):
        changed = False
        for cname, comp in comps.items():
            base = mult.get(cname, 0.0)
            if base == 0.0:
                continue
            for op in comp.ops:
                callees: List[Tuple[str, float]] = []
                if op.op == "while":
                    trip = _trip_count(op, comps)
                    mb = _WHILE_BODY_RE.search(op.line)
                    if mb:
                        callees.append((mb.group(1), base * trip))
                    mc = _WHILE_COND_RE.search(op.line)
                    if mc:
                        callees.append((mc.group(1), base))
                else:
                    for callee in _CALLS_RE.findall(op.line):
                        callees.append((callee, base))
                for callee, new in callees:
                    if callee in mult and new > mult[callee]:
                        mult[callee] = new
                        changed = True
        if not changed:
            break
    return mult, sorted(trips), fusion_bodies


@dataclass
class HLOCost:
    flops: float
    bytes_accessed: float
    collective_bytes: float          # ring-weighted, per device
    collective_breakdown: Dict[str, float]
    n_collectives: float
    trip_counts: List[int]

    def as_dict(self):
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "collective_breakdown": self.collective_breakdown,
            "n_collectives": self.n_collectives,
            "trip_counts": self.trip_counts,
        }


def _collective_wire_bytes(kind: str, nbytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * nbytes
    if kind in ("all-gather", "reduce-scatter", "all-to-all"):
        return (g - 1) / g * nbytes
    return float(nbytes)


def analyze_hlo(text: str) -> HLOCost:
    comps, entry = split_computations(text)
    mult, trips, fusion_bodies = region_multipliers(comps, entry)

    flops = 0.0
    bytes_acc = 0.0
    coll_bytes = 0.0
    coll_break: Dict[str, float] = {}
    n_coll = 0.0

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0.0:
            continue
        in_fusion = cname in fusion_bodies
        for op in comp.ops:
            base = op.op[:-6] if op.op.endswith("-start") else op.op
            # ---------------- flops: dot / convolution
            if base in ("dot", "convolution"):
                k = 1
                cm = _CONTRACT_RE.search(op.line)
                lhs = op.operands[0] if op.operands else None
                if cm and lhs and lhs in comp.shapes:
                    _, ldims = comp.shapes[lhs]
                    for ci in [int(x) for x in cm.group(1).split(",") if x]:
                        if ci < len(ldims):
                            k *= ldims[ci]
                elif base == "convolution" and lhs and lhs in comp.shapes:
                    _, ldims = comp.shapes[lhs]
                    k = max(1, math.prod(ldims) // max(1, math.prod(op.dims)))
                out = math.prod(op.dims) if op.dims else 1
                flops += m * 2.0 * out * k
            # ---------------- collectives
            if base in _COLL_KINDS:
                nbytes = _shape_bytes(op.dtype, op.dims) if op.dtype else 0
                if not nbytes and op.operands:
                    sh = comp.shapes.get(op.operands[0])
                    if sh:
                        nbytes = _shape_bytes(*sh)
                g = 1
                mi = _GROUPS_IOTA_RE.search(op.line)
                if mi:
                    g = int(mi.group(2))
                else:
                    ml = _GROUPS_LIST_RE.search(op.line)
                    if ml:
                        g = len([x for x in ml.group(1).split(",") if x.strip()])
                wb = m * _collective_wire_bytes(base, nbytes, g)
                coll_bytes += wb
                coll_break[base] = coll_break.get(base, 0.0) + wb
                n_coll += m
            # ---------------- memory traffic (fusion internals: boundary only)
            if in_fusion or base in _SKIP_BYTES_OPS or op.op.endswith("-done"):
                continue
            out_b = _shape_bytes(op.dtype, op.dims) if op.dtype else 0
            operand_b = 0
            for on in op.operands:
                sh = comp.shapes.get(on)
                if sh:
                    operand_b += _shape_bytes(*sh)
            bytes_acc += m * (out_b + operand_b)

    return HLOCost(
        flops=flops,
        bytes_accessed=bytes_acc,
        collective_bytes=coll_bytes,
        collective_breakdown=coll_break,
        n_collectives=n_coll,
        trip_counts=trips,
    )
