"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e):
    peak bf16 compute   197 TFLOP/s / chip
    HBM bandwidth       819 GB/s   / chip
    ICI link bandwidth  ~50 GB/s   / link

Terms per (arch x shape x mesh) cell — all in seconds-per-step, per chip:

    compute    = HLO_FLOPs / peak            (cost_analysis is per-device)
    memory     = HLO_bytes / HBM_bw
    collective = sum over collective ops of algo-weighted shard bytes / link_bw

cost_analysis does not expose collective traffic, so we parse the
post-partitioning HLO: every ``all-reduce|all-gather|reduce-scatter|
all-to-all|collective-permute`` line contributes its shard bytes times the
ring-algorithm factor for its replica-group size.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclass
class CollectiveOp:
    kind: str
    shape_bytes: int
    group_size: int

    @property
    def wire_bytes(self) -> float:
        """Ring-algorithm bytes through one device's link."""
        g = max(self.group_size, 1)
        if g == 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * (g - 1) / g * self.shape_bytes
        if self.kind in ("all-gather", "reduce-scatter", "all-to-all"):
            return (g - 1) / g * self.shape_bytes
        return float(self.shape_bytes)  # collective-permute


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims_s, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        dims = [int(d) for d in dims_s.split(",") if d] or [1]
        size = nbytes * math.prod(dims)
        g = 1
        mi = _GROUPS_IOTA_RE.search(line)
        if mi:
            g = int(mi.group(2))
        else:
            ml = _GROUPS_LIST_RE.search(line)
            if ml:
                g = len([x for x in ml.group(1).split(",") if x.strip() != ""])
        ops.append(CollectiveOp(kind, size, g))
    return ops


@dataclass
class RooflineTerms:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    n_collectives: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    memory_stats: Dict[str, float] = field(default_factory=dict)
    collective_breakdown: Dict[str, float] = field(default_factory=dict)
    trip_counts: List[int] = field(default_factory=list)

    def as_dict(self) -> Dict[str, Any]:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes": self.collective_bytes,
            "n_collectives": self.n_collectives,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "memory_stats": self.memory_stats,
            "collective_breakdown": self.collective_breakdown,
            "trip_counts": self.trip_counts,
        }


def roofline_from_compiled(
    compiled,
    n_devices: int,
    model_flops: float,
) -> RooflineTerms:
    """Roofline terms from the compiled module.

    XLA's cost_analysis counts ``while`` bodies once, so scan-over-layers
    models understate by the trip counts; :mod:`repro.launch.hlo_cost`
    re-derives FLOPs / bytes / collective traffic from the partitioned HLO
    with nesting-aware trip multipliers.  cost_analysis raw values are kept
    as ``*_raw`` cross-checks.
    """
    from .hlo_cost import analyze_hlo

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jaxlib wraps the dict in a list
        ca = ca[0] if ca else {}
    flops_raw = float(ca.get("flops", 0.0))
    bytes_raw = float(ca.get("bytes accessed", 0.0))
    hc = analyze_hlo(compiled.as_text())
    flops = max(hc.flops, flops_raw)
    bytes_acc = max(hc.bytes_accessed, bytes_raw)
    coll_bytes = hc.collective_bytes
    breakdown = dict(hc.collective_breakdown)
    n_colls = hc.n_collectives

    mem_stats: Dict[str, float] = {}
    try:
        ms = compiled.memory_analysis()
        mem_stats = {
            "argument_bytes": float(ms.argument_size_in_bytes),
            "output_bytes": float(ms.output_size_in_bytes),
            "temp_bytes": float(ms.temp_size_in_bytes),
            "alias_bytes": float(ms.alias_size_in_bytes),
        }
        mem_stats["peak_hbm_bytes"] = (
            mem_stats["argument_bytes"] + mem_stats["output_bytes"]
            + mem_stats["temp_bytes"] - mem_stats["alias_bytes"]
        )
    except Exception:
        pass

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = coll_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)  # type: ignore[arg-type]
    per_dev_model = model_flops / n_devices
    useful = per_dev_model / flops if flops else 0.0
    mem_stats["flops_raw_scan_once"] = flops_raw
    mem_stats["bytes_raw_scan_once"] = bytes_raw
    return RooflineTerms(
        flops_per_device=flops,
        bytes_per_device=bytes_acc,
        collective_bytes=coll_bytes,
        n_collectives=int(n_colls),
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=useful,
        memory_stats=mem_stats,
        collective_breakdown=breakdown,
        trip_counts=hc.trip_counts,
    )


# --------------------------------------------------------- model FLOP counts
def param_counts(cfg) -> Dict[str, float]:
    """Analytic parameter counts: total / active (MoE top-k) / embeddings."""
    d, ff, V = cfg.d_model, cfg.d_ff, cfg.vocab
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    embed = V * d * (1 if cfg.tie_embeddings else 2)
    total = 0.0
    active = 0.0
    for pattern, rep in cfg.groups:
        for kind in pattern:
            if kind == "attn":
                mix = d * hq * dh + 2 * d * hkv * dh + hq * dh * d
            elif kind == "rec":
                dr = cfg.rec.d_rnn
                mix = 2 * d * dr + 2 * dr * dr + dr * d + cfg.rec.conv_width * dr
            elif kind == "rwkv":
                lora = max(32, d // 32)
                mix = 5 * d * d + d * lora + lora * d
            else:
                mix = 0.0
            if cfg.moe is not None and kind == "attn":
                m = cfg.moe
                expert = 3 * d * m.d_ff_expert
                routed_total = m.num_experts * expert
                routed_active = m.top_k * expert
                shared = 3 * d * m.d_ff_shared if m.d_ff_shared else 0.0
                router = d * m.num_experts
                ffn_total = routed_total + shared + router
                ffn_active = routed_active + shared + router
            else:
                ffn_total = ffn_active = 3 * d * ff
            total += rep * (mix + ffn_total)
            active += rep * (mix + ffn_active)
    return {"total": total, "active": active, "embed": float(embed)}


def model_flops_for(cfg, shape) -> float:
    """6*N_active*D for a train step; 2*N*D for prefill; 2*N*B for decode."""
    counts = param_counts(cfg)
    n = counts["active"]
    if shape.mode == "train":
        tokens = shape.global_batch * (shape.seq_len - cfg.frontend_tokens)
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * (shape.seq_len - cfg.frontend_tokens)
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
