"""Production training driver: EasyCrash + multilevel C/R + failure injection.

Runs a (reduced-by-default) architecture for N steps on the local device(s),
wiring together every fault-tolerance layer this framework provides:

  * EasyCrash flushes of the *critical* state subset (params + step — the
    selection the crash campaigns find; Adam moments re-warm) to a
    host-local NVM arena, asynchronously, every ``--flush-every`` steps;
  * multilevel checkpoints at the Young interval stretched by measured
    recomputability (MTBF' = MTBF / (1 - R));
  * deterministic, seekable data (restart needs only the step counter);
  * ``--inject-failure-every K`` kills the loop mid-step every K steps; the
    driver then restores via EasyCrash -> checkpoint -> fresh, with a
    loss-based acceptance verification guarding the EasyCrash path.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --steps 200 --inject-failure-every 60 --workdir /tmp/ec_train
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointConfig, CheckpointManager
from ..configs import get_arch
from ..core.arena import NVMArena
from ..core.manager import EasyCrashManager, FlushPolicy, flatten_state, unflatten_state
from ..data import DataConfig, SyntheticLMStream
from ..models import scaled_down
from .steps import init_train_state, make_train_step


class SimulatedFailure(RuntimeError):
    pass


def _to_host(tree):
    return jax.tree.map(lambda x: np.asarray(x), tree)


def build(args):
    cfg = get_arch(args.arch)
    if not args.full_size:
        cfg = scaled_down(cfg, width=args.width)
    data_cfg = DataConfig(
        seq_len=args.seq, global_batch=args.batch, vocab=cfg.vocab,
        frontend_tokens=cfg.frontend_tokens, d_model=cfg.d_model,
    )
    step_fn = jax.jit(
        make_train_step(cfg, peak_lr=args.lr, total_steps=args.steps),
        donate_argnums=(0,),
    )
    return cfg, data_cfg, step_fn


def run(args) -> Dict[str, float]:
    cfg, data_cfg, step_fn = build(args)
    os.makedirs(args.workdir, exist_ok=True)
    arena_dir = os.path.join(args.workdir, "arena")
    ckpt = CheckpointManager(CheckpointConfig(
        local_dir=os.path.join(args.workdir, "ckpt_local"),
        remote_dir=os.path.join(args.workdir, "ckpt_remote"),
    ))

    def checkpoint_save(step: int, state) -> None:
        ckpt.save(step, _to_host(state))

    def checkpoint_restore():
        got = ckpt.restore()
        if got is None:
            return None
        return got[0], got[1]

    try:
        arena = NVMArena.reattach(arena_dir)
        print(f"[restore] reattached arena with {len(list(arena.names()))} objects")
    except Exception:
        arena = NVMArena(backing_dir=arena_dir)

    policy = FlushPolicy(
        leaves=("params", "step"), every_steps=args.flush_every,
        async_flush=not args.sync_flush,
        persist_mode=args.persist_mode,
    )
    mgr = EasyCrashManager(
        arena, policy,
        checkpoint_save=checkpoint_save,
        checkpoint_restore=checkpoint_restore,
        mtbf=args.mtbf, t_chk=args.t_chk,
        recomputability=args.recomputability, step_time=1.0,
    )

    init_state = init_train_state(cfg, jax.random.PRNGKey(args.seed))

    def verify(candidate, step) -> bool:
        """Acceptance verification: one forward loss must be finite and sane."""
        try:
            stream0 = SyntheticLMStream(data_cfg, 0, 1, start_step=step)
            _, batch = next(stream0)
            stream0.close()
            from ..models import loss_and_aux

            loss, _ = loss_and_aux(
                cfg, jax.tree.map(jnp.asarray, candidate["params"]),
                {k: jnp.asarray(v) for k, v in batch.items()},
            )
            ok = bool(np.isfinite(float(loss)) and float(loss) < args.verify_loss_max)
            print(f"[verify] step={step} loss={float(loss):.3f} -> {'ACCEPT' if ok else 'REJECT'}")
            return ok
        except Exception as e:  # noqa: BLE001
            print(f"[verify] failed: {e}")
            return False

    state_host, start_step, source = mgr.restore(_to_host(init_state), verify=verify)
    print(f"[restore] source={source} step={start_step}")
    state = jax.tree.map(jnp.asarray, state_host)
    state["step"] = jnp.asarray(start_step, jnp.int32)

    stream = SyntheticLMStream(data_cfg, 0, 1, start_step=start_step)
    losses = []
    t0 = time.time()
    step = start_step
    try:
        while step < args.steps:
            _, batch = next(stream)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = step_fn(state, batch)
            step += 1
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({(time.time()-t0)/max(1,step-start_step):.2f}s/step)")
            host_state = _to_host(state)
            mgr.maybe_flush(step, host_state)
            mgr.maybe_checkpoint(step, host_state)
            if args.inject_failure_every and step % args.inject_failure_every == 0 \
                    and step < args.steps:
                mgr.barrier()  # crash strikes after in-flight flushes land
                raise SimulatedFailure(f"injected failure at step {step}")
    finally:
        stream.close()

    mgr.barrier()
    mgr.close()
    ckpt.close()
    stats = {
        "final_step": step,
        "final_loss": losses[-1] if losses else float("nan"),
        "flushes": mgr.stats.flushes_issued,
        "flushes_skipped": mgr.stats.flushes_skipped,
        "blocks_written": mgr.stats.blocks_written,
        "bytes_written": mgr.stats.bytes_written,
        "checkpoints": mgr.stats.checkpoints_taken,
        "easycrash_restores": mgr.stats.easycrash_restores,
        "checkpoint_restores": mgr.stats.checkpoint_restores,
        "restore_source": source,
    }
    print("[done]", stats)
    return stats


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (TPU pods); default reduced")
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--flush-every", type=int, default=1)
    ap.add_argument("--sync-flush", action="store_true")
    ap.add_argument("--persist-mode", default="auto",
                    choices=("auto", "delta", "full"),
                    help="flush granularity: arena byte diff / delta_snapshot "
                         "kernel (changed blocks only) / whole-object rewrite")
    ap.add_argument("--mtbf", type=float, default=300.0)
    ap.add_argument("--t-chk", type=float, default=5.0)
    ap.add_argument("--recomputability", type=float, default=0.82)
    ap.add_argument("--verify-loss-max", type=float, default=20.0)
    ap.add_argument("--inject-failure-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--max-restarts", type=int, default=10)
    args = ap.parse_args(argv)

    restarts = 0
    while True:
        try:
            run(args)
            return
        except SimulatedFailure as e:
            restarts += 1
            print(f"[failure] {e} (restart {restarts})")
            if restarts > args.max_restarts:
                raise


if __name__ == "__main__":
    main()
