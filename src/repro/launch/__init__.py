"""Launch layer: meshes, step builders, dry-run, trainer, server."""
