import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("DRYRUN_DEVICES", "512")
    + " " + os.environ.get("XLA_FLAGS_EXTRA", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import (jax locks the device count
on first init).  For each cell this driver:

  1. builds the production mesh (16x16 single pod / 2x16x16 multi-pod);
  2. resolves the model's logical shard specs against it;
  3. ``jit(step).lower(**ShapeDtypeStructs).compile()`` — no allocation;
  4. records memory_analysis / cost_analysis / collective traffic and the
     three roofline terms into one JSON per cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single,multi --out benchmarks/results/dryrun
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding

from ..configs import ARCHS, get_arch
from ..distributed.sharding import get_rules, named_sharding
from ..models import SHAPES, get_shape, shape_applicable
from ..models.config import ModelConfig, ShapeConfig
from .analysis import model_flops_for, param_counts, roofline_from_compiled
from .mesh import mesh_for_name
from .steps import (
    abstract_cache,
    abstract_params,
    abstract_train_state,
    input_spec_names,
    input_specs,
    make_decode_fn,
    make_prefill_step,
    make_train_step,
    train_state_specs,
)
from ..models import cache_specs as model_cache_specs
from ..models import param_specs as model_param_specs


def _resolve_tree(mesh, spec_tree, abstract_tree=None):
    """Logical specs -> NamedShardings, pruning axes that don't divide.

    Argument shardings (unlike in-function constraints) must divide the
    dimension exactly; dims like batch=1 or head counts not divisible by the
    TP degree fall back to replication on that dim.
    """
    rules = get_rules()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def leaf(spec, aval=None):
        pspec = rules.resolve(mesh.axis_names, *spec)
        if aval is not None:
            pruned = []
            for dim, ax in zip(aval.shape, tuple(pspec) + (None,) * (len(aval.shape) - len(pspec))):
                if ax is None:
                    pruned.append(None)
                    continue
                axes = (ax,) if isinstance(ax, str) else tuple(ax)
                total = 1
                for a in axes:
                    total *= sizes.get(a, 1)
                pruned.append(ax if dim % total == 0 else None)
            pspec = jax.sharding.PartitionSpec(*pruned)
        return NamedSharding(mesh, pspec)

    if abstract_tree is None:
        return jax.tree.map(leaf, spec_tree, is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(
        lambda s, a: leaf(s, a), spec_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def run_cell(arch: str, shape_name: str, mesh_name: str, impl: str = "reference",
             moe_groups: int = 1, grad_accum: Optional[int] = None) -> Dict[str, Any]:
    import dataclasses

    cfg = get_arch(arch)
    if moe_groups > 1 and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=moe_groups)
        )
    if grad_accum is not None:
        cfg = dataclasses.replace(cfg, grad_accum=grad_accum)
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(cfg, shape)
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mode": shape.mode, "status": "skipped", "reason": reason,
    }
    if not ok:
        return result

    mesh = mesh_for_name(mesh_name)
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    n_dev = mesh.devices.size
    t0 = time.time()
    try:
        with mesh:
            if shape.mode == "train":
                step = make_train_step(cfg, impl=impl)
                state = abstract_train_state(cfg)
                state_sh = _resolve_tree(mesh, train_state_specs(cfg, tp), state)
                batch = input_specs(cfg, shape)
                batch_sh = _resolve_tree(mesh, input_spec_names(cfg, shape), batch)
                lowered = jax.jit(
                    step,
                    in_shardings=(state_sh, batch_sh),
                    donate_argnums=(0,),
                ).lower(state, batch)
            elif shape.mode == "prefill":
                step = make_prefill_step(cfg, impl=impl)
                params = abstract_params(cfg)
                params_sh = _resolve_tree(mesh, model_param_specs(cfg, tp), params)
                batch = input_specs(cfg, shape)
                batch_sh = _resolve_tree(mesh, input_spec_names(cfg, shape), batch)
                lowered = jax.jit(
                    step, in_shardings=(params_sh, batch_sh)
                ).lower(params, batch)
            else:  # decode
                step = make_decode_fn(cfg)
                params = abstract_params(cfg)
                params_sh = _resolve_tree(mesh, model_param_specs(cfg, tp), params)
                cache = abstract_cache(cfg, shape)
                cache_sh = _resolve_tree(mesh, model_cache_specs(cfg, tp), cache)
                tok = input_specs(cfg, shape)["token"]
                tok_sh = _resolve_tree(
                    mesh, {"token": ("batch", None)}, {"token": tok}
                )["token"]
                lowered = jax.jit(
                    step,
                    in_shardings=(params_sh, cache_sh, tok_sh),
                    donate_argnums=(1,),
                ).lower(params, cache, tok)

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mf = model_flops_for(cfg, shape)
        terms = roofline_from_compiled(compiled, n_dev, mf)
        result.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            n_devices=n_dev,
            param_counts=param_counts(cfg),
            roofline=terms.as_dict(),
        )
        ms = terms.memory_stats
        if ms:
            result["bytes_per_device"] = ms.get("peak_hbm_bytes")
            result["fits_16gb_hbm"] = bool(ms.get("peak_hbm_bytes", 0) <= 16e9)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--impl", default="reference")
    ap.add_argument("--moe-groups", type=int, default=1)
    ap.add_argument("--grad-accum", type=int, default=0)
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = [s.name for s in SHAPES] if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")
    os.makedirs(args.out, exist_ok=True)

    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                tag = f"{arch}_{shape}_{mesh}".replace("/", "-")
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                res = run_cell(arch, shape, mesh, impl=args.impl,
                               moe_groups=args.moe_groups,
                               grad_accum=args.grad_accum or None)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                if res["status"] == "ok":
                    r = res["roofline"]
                    print(
                        f"[ok]   {tag}: compile={res['compile_s']}s "
                        f"dominant={r['dominant']} compute={r['compute_s']:.3e}s "
                        f"mem={r['memory_s']:.3e}s coll={r['collective_s']:.3e}s "
                        f"useful={r['useful_ratio']:.2f}"
                    )
                elif res["status"] == "skipped":
                    print(f"[skip] {tag}: {res['reason']}")
                else:
                    print(f"[ERR]  {tag}: {res['error']}")


if __name__ == "__main__":
    main()
