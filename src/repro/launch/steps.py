"""Step-function builders shared by the trainer, server, and dry-run.

Everything here is mesh-agnostic: functions return (step_fn, state_spec_tree,
input_spec_tree) where spec trees hold *logical* axis-name tuples; the caller
resolves them against a concrete mesh (``repro.distributed.sharding``).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import (
    ModelConfig,
    ShapeConfig,
    cache_specs,
    decode_step,
    init_cache,
    init_params,
    loss_and_aux,
    param_specs,
    prefill,
)
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update, opt_state_specs
from ..optim.schedule import cosine_schedule

Tree = Any


# ----------------------------------------------------------------- abstract
def abstract_params(cfg: ModelConfig) -> Tree:
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def abstract_train_state(cfg: ModelConfig) -> Tree:
    params = abstract_params(cfg)
    opt = jax.eval_shape(lambda: adamw_init(params, cfg.moment_dtype))
    return {"params": params, "opt": opt, "step": jax.ShapeDtypeStruct((), jnp.int32)}


def train_state_specs(cfg: ModelConfig, tp: int = 16) -> Tree:
    pspecs = param_specs(cfg, tp)
    return {"params": pspecs, "opt": opt_state_specs(pspecs), "step": ()}


def init_train_state(cfg: ModelConfig, key) -> Tree:
    params = init_params(cfg, key)
    return {
        "params": params,
        "opt": adamw_init(params, cfg.moment_dtype),
        "step": jnp.zeros((), jnp.int32),
    }


# -------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Modality frontends are stubs: the audio arch takes EnCodec code ids
    (ordinary tokens), the VLM takes pre-projected patch embeddings.
    """
    if shape.mode == "train":
        s_text = shape.seq_len - cfg.frontend_tokens
        out = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, s_text + 1), jnp.int32)}
        if cfg.frontend_tokens:
            out["patches"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.frontend_tokens, cfg.d_model), jnp.float32
            )
        return out
    if shape.mode == "prefill":
        s_text = shape.seq_len - cfg.frontend_tokens
        out = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, s_text), jnp.int32)}
        if cfg.frontend_tokens:
            out["patches"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.frontend_tokens, cfg.d_model), jnp.float32
            )
        return out
    # decode: one new token + the KV/recurrent cache at seq_len
    return {"token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}


def input_spec_names(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Tuple]:
    if shape.mode in ("train", "prefill"):
        out = {"tokens": ("batch", None)}
        if cfg.frontend_tokens:
            out["patches"] = ("batch", None, None)
        return out
    return {"token": ("batch", None)}


# ------------------------------------------------------------------- train
def make_train_step(
    cfg: ModelConfig,
    adamw: AdamWConfig = AdamWConfig(),
    peak_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    impl: str = "reference",
    grad_compression: Optional[str] = None,
) -> Callable[[Tree, Dict[str, jax.Array]], Tuple[Tree, Dict[str, jax.Array]]]:
    """``grad_compression``: None | "int8" | "topk:<frac>" — compresses the
    gradient before the DP all-reduce (bandwidth trick; int8 is unbiased-ish
    per-tensor symmetric quantization, top-k keeps an error-feedback residual
    in the optimizer state is future work — here the residual folds into the
    same step, making it a one-step-delayed correction)."""
    accum = max(1, cfg.grad_accum)

    def loss_fn(params, batch):
        loss, parts = loss_and_aux(cfg, params, batch, impl=impl)
        return loss, parts

    def train_step(state, batch):
        params = state["params"]
        grad_dt = jnp.float32 if cfg.moment_dtype == "float32" else jnp.bfloat16

        if accum > 1:
            def reshape_mb(x):
                b = x.shape[0]
                return x.reshape(accum, b // accum, *x.shape[1:])

            micro = jax.tree.map(reshape_mb, batch)

            def body(carry, mb):
                g_acc, loss_acc = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(grad_dt), g_acc, g)
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, grad_dt), params)
            (grads, loss_sum), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

        if grad_compression == "int8":
            from ..optim.compression import dequantize_int8, quantize_int8

            def qdq(g):
                q, s = quantize_int8(g)
                return dequantize_int8(q, s, g.dtype)

            grads = jax.tree.map(qdq, grads)
        elif grad_compression and grad_compression.startswith("topk:"):
            frac = float(grad_compression.split(":", 1)[1])
            from ..optim.compression import compress_topk, decompress_topk

            def topk(g):
                vals, idx, _ = compress_topk(g, frac)
                return decompress_topk(vals, idx, g.shape, g.dtype)

            grads = jax.tree.map(topk, grads)

        lr = cosine_schedule(state["step"], warmup, total_steps, peak_lr)
        new_params, new_opt, stats = adamw_update(params, grads, state["opt"], lr, adamw)
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        metrics = {"loss": loss, "lr": lr, **stats}
        return new_state, metrics

    return train_step


# ------------------------------------------------------------------- serve
def make_prefill_step(cfg: ModelConfig, impl: str = "reference"):
    def prefill_step(params, batch):
        return prefill(cfg, params, batch["tokens"], batch.get("patches"), impl=impl)

    return prefill_step


def make_decode_fn(cfg: ModelConfig):
    def serve_step(params, cache, token):
        logits, new_cache = decode_step(cfg, params, token, cache)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return next_token, new_cache

    return serve_step


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig) -> Tree:
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, max_len=shape.seq_len)
    )
