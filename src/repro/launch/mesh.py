"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The dry-run entry point sets
``--xla_force_host_platform_device_count`` *before* importing jax; everything
else (smoke tests, benches) sees the real single CPU device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def _mk(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    except (TypeError, AttributeError):  # older jax: no axis_types kwarg /
        return jax.make_mesh(shape, axes)  # no jax.sharding.AxisType at all


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 v5e pod (256 chips) or 2 pods = 512 chips with a "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_tiny_mesh(*, multi_pod: bool = False) -> Mesh:
    """CI-scale stand-in (8 host devices): same axis structure, tiny extents."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def mesh_for_name(name: str) -> Mesh:
    if name == "single":
        return make_production_mesh(multi_pod=False)
    if name == "multi":
        return make_production_mesh(multi_pod=True)
    if name == "tiny":
        return make_tiny_mesh(multi_pod=False)
    if name == "tiny-multi":
        return make_tiny_mesh(multi_pod=True)
    raise KeyError(f"unknown mesh {name!r}")


MESH_DEVICE_COUNT = {"single": 256, "multi": 512, "tiny": 8, "tiny-multi": 8}
