"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]:
MoE 16 experts top-1 + shared expert, every layer MoE; early-fusion backbone.

16 experts divide the 16-way model axis exactly -> expert parallelism.
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=202_048,
    activation="silu",
    moe=MoEConfig(
        num_experts=16, top_k=1, d_ff_expert=8192, d_ff_shared=8192,
        expert_parallel=True, dispatch_groups=32,  # §Perf: shard-local dispatch
    ),
    grad_accum=8,
)
