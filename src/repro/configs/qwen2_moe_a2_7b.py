"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B]: 60 routed experts top-4 +
4 shared experts (shared MLP width 4x1408 = 5632).

60 experts do not divide 16 -> expert weights stay replicated across "model"
and the expert FF dim (1408 = 88 x 16) is tensor-parallel instead.
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab=151_936,
    activation="silu",
    moe=MoEConfig(
        num_experts=60, top_k=4, d_ff_expert=1408, d_ff_shared=5632,
        expert_parallel=False, dispatch_groups=32,  # §Perf: shard-local dispatch
    ),
    grad_accum=4,
)
