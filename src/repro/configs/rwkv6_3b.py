"""RWKV-6 "Finch" 3B [arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b]: attention-free,
data-dependent decay, matrix-valued state per head (head_dim 64)."""
from ..models.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,           # d_model / rwkv.head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab=65_536,
    activation="silu",
    rwkv=RWKVConfig(head_dim=64),
    layer_groups=((("rwkv",), 32),),
    grad_accum=2,
)
