"""Granite-8B-Code: llama-arch code model [arXiv:2405.04324; hf:ibm-granite/granite-8b-code-base]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=49_152,
    activation="silu",
    grad_accum=4,
)
