"""MusicGen-medium: decoder-only transformer over EnCodec audio tokens
[arXiv:2306.05284; hf:facebook/musicgen-medium].

Backbone only: the EnCodec frontend is a stub — inputs are code-book token
ids (vocab 2048).  24 heads = MHA (kv == q heads).  24 heads do not divide a
16-way TP axis: baseline takes GSPMD padding on the head dim (flagged in
EXPERIMENTS.md §Perf as a hillclimb target).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_head=64,
    d_ff=6144,
    vocab=2048,
    activation="gelu",
    grad_accum=1,
)
