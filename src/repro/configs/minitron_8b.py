"""Minitron-8B: width-pruned Nemotron-4 [arXiv:2407.14679; hf:nvidia/Minitron-8B-Base]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab=256_000,
    activation="relu2",     # squared ReLU, inherited from Nemotron-4
    grad_accum=8,           # 256k vocab: bound microbatch logits
)
