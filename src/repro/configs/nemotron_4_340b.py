"""Nemotron-4-340B [arXiv:2402.16819; unverified]: GQA kv=8, squared ReLU.

The heavyweight cell: params+moments only fit a 256-chip v5e pod with
bf16 Adam moments and full FSDPxTP sharding; activations need microbatched
gradient accumulation (grad_accum=16 -> 16 sequences per microbatch).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_head=192,
    d_ff=73728,
    vocab=256_000,
    activation="relu2",
    moment_dtype="bfloat16",
    grad_accum=16,
)
