"""InternVL2-Llama3-76B [arXiv:2404.16821; unverified]: InternViT-6B vision
frontend (STUB: input_specs supplies 256 pre-projected patch embeddings per
image) + Llama-3-70B-class language backbone."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab=128_256,
    activation="silu",
    frontend_tokens=256,
    moment_dtype="bfloat16",
    grad_accum=16,
)
