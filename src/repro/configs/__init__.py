"""Assigned architecture configs (one module per arch) + registry."""
from typing import Dict

from ..models.config import ModelConfig
from .musicgen_medium import CONFIG as musicgen_medium
from .minitron_8b import CONFIG as minitron_8b
from .granite_8b import CONFIG as granite_8b
from .stablelm_1_6b import CONFIG as stablelm_1_6b
from .nemotron_4_340b import CONFIG as nemotron_4_340b
from .recurrentgemma_9b import CONFIG as recurrentgemma_9b
from .rwkv6_3b import CONFIG as rwkv6_3b
from .llama4_scout_17b_a16e import CONFIG as llama4_scout_17b_a16e
from .qwen2_moe_a2_7b import CONFIG as qwen2_moe_a2_7b
from .internvl2_76b import CONFIG as internvl2_76b

ARCHS: Dict[str, ModelConfig] = {
    c.name: c.validate()
    for c in (
        musicgen_medium,
        minitron_8b,
        granite_8b,
        stablelm_1_6b,
        nemotron_4_340b,
        recurrentgemma_9b,
        rwkv6_3b,
        llama4_scout_17b_a16e,
        qwen2_moe_a2_7b,
        internvl2_76b,
    )
}


def get_arch(name: str) -> ModelConfig:
    key = name.replace("_", "-")
    for k, v in ARCHS.items():
        if k == key or k.replace("-", "_") == name:
            return v
    raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")


def arch_names():
    return sorted(ARCHS)
