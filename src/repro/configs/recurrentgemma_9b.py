"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427; unverified]: RG-LRU + local
attention, pattern (rec, rec, attn) with a trailing (rec, rec); window 2048.

38 layers = 12 x (rec, rec, attn) + 1 x (rec, rec).
"""
from ..models.config import ModelConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_head=256,
    d_ff=12288,
    vocab=256_000,
    activation="gelu",
    rec=RecurrentConfig(d_rnn=4096, conv_width=4, window=2048),
    layer_groups=((("rec", "rec", "attn"), 12), (("rec", "rec"), 1)),
    attn_window=2048,
    grad_accum=8,
)
