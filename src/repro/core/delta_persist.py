"""Incremental ("delta") persistence: Pallas dirty-block masks for the arena.

Bridges :mod:`repro.kernels.delta_snapshot` to :class:`repro.core.arena.NVMArena`.
The arena reasons in *bytes* (cache blocks of ``block_bytes``); the kernel
compares element streams.  We therefore run the kernel over flat ``uint8``
views with ``block_elems = block_bytes``, which makes the kernel's block
boundary coincide exactly with the arena's — the resulting mask is
bit-for-bit the mask :func:`repro.core.blocks.block_diff_mask` computes, so a
delta flush writes a byte-identical NVM image to a whole-object flush
(asserted by the differential test in ``tests/test_kernel_differential.py``).

On hosts without the Pallas toolchain the CPU reference is used; the contract
(and therefore the persisted image) is unchanged — only the bandwidth story
differs.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .blocks import DEFAULT_BLOCK_BYTES, _as_byte_view, block_diff_mask

_KERNEL = None
_KERNEL_FAILED = False


def _kernel():
    """Lazily import the Pallas op; cache the failure so hosts without the
    toolchain pay the import cost once."""
    global _KERNEL, _KERNEL_FAILED
    if _KERNEL is None and not _KERNEL_FAILED:
        try:
            from ..kernels.delta_snapshot import dirty_block_mask

            _KERNEL = dirty_block_mask
        except Exception:
            _KERNEL_FAILED = True
    return _KERNEL


def kernel_available() -> bool:
    return _kernel() is not None


def delta_block_mask(
    cur: np.ndarray,
    live: np.ndarray,
    block_bytes: int = DEFAULT_BLOCK_BYTES,
    use_kernel: bool = True,
) -> np.ndarray:
    """Per-block "changed" mask between the NVM image and the live value.

    Same contract as :func:`repro.core.blocks.block_diff_mask` (bool
    ``(n_blocks,)``, final partial block is a real block, padding never reads
    as dirty) — computed by the ``delta_snapshot`` kernel when available.
    """
    k = _kernel() if use_kernel else None
    if k is None:
        return block_diff_mask(cur, live, block_bytes)
    av = _as_byte_view(np.asarray(cur))
    bv = _as_byte_view(np.asarray(live))
    if av.size != bv.size:
        raise ValueError("size mismatch")
    if av.size == 0:
        return np.zeros((0,), dtype=bool)
    mask = np.asarray(k(bv, av, block_elems=int(block_bytes)))
    return mask.astype(bool)


def persist_mask_for(
    mode: str,
    cur: Optional[np.ndarray],
    live: np.ndarray,
    block_bytes: int = DEFAULT_BLOCK_BYTES,
) -> Optional[np.ndarray]:
    """Resolve a :class:`FlushPolicy.persist_mode` to an arena flush mask.

    ``None`` means "let the arena decide" (its own byte diff — the cache-model
    superset behaviour).  ``cur`` is the current NVM image (``arena.peek``),
    or ``None`` when the object has never been persisted / was reallocated,
    in which case the arena full-writes regardless of any mask.
    """
    if mode == "auto":
        return None
    live = np.asarray(live)
    if cur is None or cur.nbytes != live.nbytes:
        return None  # first flush / reallocation: arena full-writes
    if mode == "full":
        from .blocks import obj_num_blocks

        return np.ones(obj_num_blocks(live, block_bytes), dtype=bool)
    if mode == "delta":
        return delta_block_mask(cur, live, block_bytes)
    raise ValueError(f"unknown persist_mode {mode!r}; use 'auto', 'full' or 'delta'")
