"""EasyCrash core: the paper's contribution as a composable library.

Emulation/characterization layer (paper §3–5):
  blocks, arena, cache_sim, regions, crash_tester, selection, workflow
Production layer (paper §5.3 step 4 + §7):
  manager (flush runtime), efficiency (system model)
"""
from .adaptive import (
    AdaptiveReport,
    RegionEvidence,
    SequentialConfig,
    StaticPriorSampler,
    effective_sample_size,
    final_rate_interval,
    selection_invariant,
    shard_rounds,
    weighted_outcome_stats,
    wilson_interval,
)
from .arena import NVMArena, WriteStats
from .blocks import (
    DEFAULT_BLOCK_BYTES,
    block_diff_mask,
    inconsistent_rate,
    mix_blocks,
    num_blocks,
)
from .cache_sim import (
    ENGINES,
    CacheConfig,
    Flush,
    RegionEvents,
    Sweep,
    TornBlock,
    resolve_window_images,
    simulate_window,
    simulate_window_vec,
)
from .campaign_store import CampaignStore, CampaignStoreError, WorkflowStore
from .crash_tester import (
    CampaignResult,
    CrashRecord,
    CrashTester,
    PersistPlan,
    PlannedTest,
    default_engine,
)
from .trace_cache import WindowTraceCache, shared_trace_cache
from .faults import (
    FAULT_MODELS,
    BitFlip,
    CorrelatedRegion,
    FaultModel,
    MultiCrash,
    PowerFail,
    TornWrite,
    all_fault_models,
    fault_model_from_spec,
    get_fault_model,
)
from .artifacts import (
    STATIC_PLAN_KIND,
    ArtifactError,
    PlanArtifact,
    ProfileArtifact,
    StaticPlanArtifact,
    WorkflowArtifact,
    load_plan,
    load_profile,
    load_static_plan,
    load_workflow,
    profile_from_workflow,
    replay_plan,
    save_plan,
    save_profile,
    save_static_plan,
    save_workflow,
)
from .delta_persist import delta_block_mask, persist_mask_for
from .efficiency import (
    SystemConfig,
    efficiency_with,
    efficiency_without,
    expected_overhead,
    persist_overhead_fraction,
    scale_mtbf,
    tau_threshold,
    young_interval,
)
from .sysim import (
    POLICIES,
    FailureTrace,
    PoissonTrace,
    RecomputeProfile,
    SimResult,
    WeibullTrace,
    efficiency_frontier,
    optimize_interval,
    scaled_trace,
    simulate_policy,
    trace_from_spec,
)
from .fleetsim import (
    ArrivalProcess,
    FleetConfig,
    FleetResult,
    ServiceModel,
    fleet_frontier,
    simulate_fleet,
)
from .manager import EasyCrashManager, FlushPolicy, flatten_state, unflatten_state
from .regions import BatchedKernel, IterativeApp, Region, State, VerifyResult
from .selection import select_objects, select_regions, spearman
from .workflow import (
    CampaignSpec,
    RoundsResult,
    WorkflowConfig,
    WorkflowOrchestrator,
    WorkflowResult,
    run_workflow,
)

__all__ = [
    "NVMArena", "WriteStats", "DEFAULT_BLOCK_BYTES", "block_diff_mask",
    "inconsistent_rate", "mix_blocks", "num_blocks", "CacheConfig", "Flush",
    "RegionEvents", "Sweep", "TornBlock", "resolve_window_images",
    "simulate_window", "simulate_window_vec", "ENGINES",
    "CampaignStore", "CampaignStoreError", "WorkflowStore",
    "CampaignResult",
    "CrashRecord", "CrashTester", "PersistPlan", "PlannedTest",
    "default_engine", "WindowTraceCache", "shared_trace_cache",
    "FAULT_MODELS", "BitFlip", "CorrelatedRegion", "FaultModel", "MultiCrash",
    "PowerFail", "TornWrite", "all_fault_models", "fault_model_from_spec",
    "get_fault_model",
    "ArtifactError", "PlanArtifact", "ProfileArtifact", "StaticPlanArtifact",
    "WorkflowArtifact", "STATIC_PLAN_KIND",
    "load_plan", "load_profile", "load_static_plan", "load_workflow",
    "profile_from_workflow", "replay_plan", "save_plan", "save_profile",
    "save_static_plan", "save_workflow",
    "SystemConfig", "delta_block_mask", "persist_mask_for",
    "efficiency_with", "efficiency_without", "expected_overhead",
    "persist_overhead_fraction", "scale_mtbf", "tau_threshold",
    "POLICIES", "FailureTrace", "PoissonTrace", "RecomputeProfile",
    "SimResult", "WeibullTrace", "efficiency_frontier", "optimize_interval",
    "scaled_trace", "simulate_policy", "trace_from_spec",
    "ArrivalProcess", "FleetConfig", "FleetResult", "ServiceModel",
    "fleet_frontier", "simulate_fleet",
    "young_interval", "EasyCrashManager", "FlushPolicy", "flatten_state",
    "unflatten_state", "BatchedKernel", "IterativeApp", "Region", "State",
    "VerifyResult",
    "select_objects", "select_regions", "spearman",
    "CampaignSpec", "RoundsResult", "WorkflowConfig", "WorkflowOrchestrator",
    "WorkflowResult", "run_workflow",
    "AdaptiveReport", "RegionEvidence", "SequentialConfig", "StaticPriorSampler",
    "effective_sample_size", "final_rate_interval", "selection_invariant",
    "shard_rounds", "weighted_outcome_stats", "wilson_interval",
]
