"""EasyCrash core: the paper's contribution as a composable library.

Emulation/characterization layer (paper §3–5):
  blocks, arena, cache_sim, regions, crash_tester, selection, workflow
Production layer (paper §5.3 step 4 + §7):
  manager (flush runtime), efficiency (system model)
"""
from .arena import NVMArena, WriteStats
from .blocks import (
    DEFAULT_BLOCK_BYTES,
    block_diff_mask,
    inconsistent_rate,
    mix_blocks,
    num_blocks,
)
from .cache_sim import (
    CacheConfig,
    Flush,
    RegionEvents,
    Sweep,
    TornBlock,
    resolve_window_images,
    simulate_window,
)
from .campaign_store import CampaignStore, CampaignStoreError, WorkflowStore
from .crash_tester import (
    CampaignResult,
    CrashRecord,
    CrashTester,
    PersistPlan,
    PlannedTest,
)
from .faults import (
    FAULT_MODELS,
    BitFlip,
    CorrelatedRegion,
    FaultModel,
    MultiCrash,
    PowerFail,
    TornWrite,
    all_fault_models,
    fault_model_from_spec,
    get_fault_model,
)
from .artifacts import (
    ArtifactError,
    PlanArtifact,
    WorkflowArtifact,
    load_plan,
    load_workflow,
    replay_plan,
    save_plan,
    save_workflow,
)
from .efficiency import (
    SystemConfig,
    efficiency_with,
    efficiency_without,
    scale_mtbf,
    tau_threshold,
    young_interval,
)
from .manager import EasyCrashManager, FlushPolicy, flatten_state, unflatten_state
from .regions import IterativeApp, Region, State, VerifyResult
from .selection import select_objects, select_regions, spearman
from .workflow import (
    CampaignSpec,
    WorkflowOrchestrator,
    WorkflowResult,
    run_workflow,
)

__all__ = [
    "NVMArena", "WriteStats", "DEFAULT_BLOCK_BYTES", "block_diff_mask",
    "inconsistent_rate", "mix_blocks", "num_blocks", "CacheConfig", "Flush",
    "RegionEvents", "Sweep", "TornBlock", "resolve_window_images",
    "simulate_window", "CampaignStore", "CampaignStoreError", "WorkflowStore",
    "CampaignResult",
    "CrashRecord", "CrashTester", "PersistPlan", "PlannedTest",
    "FAULT_MODELS", "BitFlip", "CorrelatedRegion", "FaultModel", "MultiCrash",
    "PowerFail", "TornWrite", "all_fault_models", "fault_model_from_spec",
    "get_fault_model",
    "ArtifactError", "PlanArtifact", "WorkflowArtifact", "load_plan",
    "load_workflow", "replay_plan", "save_plan", "save_workflow",
    "SystemConfig",
    "efficiency_with", "efficiency_without", "scale_mtbf", "tau_threshold",
    "young_interval", "EasyCrashManager", "FlushPolicy", "flatten_state",
    "unflatten_state", "IterativeApp", "Region", "State", "VerifyResult",
    "select_objects", "select_regions", "spearman",
    "CampaignSpec", "WorkflowOrchestrator", "WorkflowResult", "run_workflow",
]
