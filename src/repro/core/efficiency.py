"""System-efficiency model for large-scale C/R with and without EasyCrash.

Implements paper §7 (Eqs. 6–9): synchronous coordinated checkpointing at the
Young-formula interval, crashes at Poisson rate 1/MTBF, and — with EasyCrash —
a split of crashes into M'' (recompute from the NVM image, cheap) and
M' (fall back to the last checkpoint).  Efficiency is useful computation time
over total wall time.  ``tau_threshold`` inverts the model to the minimum
recomputability at which EasyCrash beats plain C/R (the Eq. 4 threshold).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional

SECONDS_PER_HOUR = 3600.0
TEN_YEARS = 10 * 365.25 * 24 * SECONDS_PER_HOUR


def young_interval(t_chk: float, mtbf: float) -> float:
    """Young's first-order optimal checkpoint interval."""
    return math.sqrt(2.0 * t_chk * mtbf)


def expected_overhead(interval: float, t_chk: float, mtbf: float) -> float:
    """The first-order overhead rate Young's interval minimizes: checkpoint
    cost amortized over the interval plus expected rework per crash,
    ``t_chk/T + T/(2*MTBF)``.  Exactly minimized at :func:`young_interval`;
    the *full* bookkeeping model (and the discrete-event simulator in
    :mod:`repro.core.sysim`) have their optimum slightly below it, because
    Young ignores crashes during checkpoint writes and recovery time."""
    return t_chk / interval + interval / (2.0 * mtbf)


@dataclass(frozen=True)
class SystemConfig:
    mtbf: float                      # seconds, whole-system MTBF
    t_chk: float                     # checkpoint write time (local tier)
    total_time: float = TEN_YEARS    # simulated wall time
    t_sync_frac: float = 0.5         # T_sync = frac * T_chk (paper's constant)
    nvm_restore_time: float = 30.0   # T_r': load data objects from local NVM

    @property
    def t_sync(self) -> float:
        return self.t_sync_frac * self.t_chk

    @property
    def t_r(self) -> float:
        return self.t_chk  # T_r = T_chk (paper assumption, after [7])

    def spec(self) -> Dict[str, object]:
        return {
            "mtbf": float(self.mtbf),
            "t_chk": float(self.t_chk),
            "total_time": float(self.total_time),
            "t_sync_frac": float(self.t_sync_frac),
            "nvm_restore_time": float(self.nvm_restore_time),
        }


@dataclass(frozen=True)
class EfficiencyResult:
    efficiency: float
    n_checkpoints: float
    n_crashes: float
    interval: float
    useful_time: float
    breakdown: Dict[str, float]


def efficiency_without(
    cfg: SystemConfig, interval: Optional[float] = None
) -> EfficiencyResult:
    """Eq. 6/7: plain C/R.  ``interval`` overrides the Young checkpoint
    interval (interval-sweep experiments); ``None`` is the paper's choice."""
    T = young_interval(cfg.t_chk, cfg.mtbf) if interval is None else float(interval)
    M = cfg.total_time / cfg.mtbf
    t_vain = 0.5 * T
    recovery = M * (t_vain + cfg.t_r + cfg.t_sync)
    # Total = N*(T + T_chk) + recovery  =>  N
    N = max(0.0, (cfg.total_time - recovery) / (T + cfg.t_chk))
    useful = N * T
    return EfficiencyResult(
        efficiency=useful / cfg.total_time,
        n_checkpoints=N,
        n_crashes=M,
        interval=T,
        useful_time=useful,
        breakdown={
            "checkpoint": N * cfg.t_chk,
            "recovery": recovery,
            "useful": useful,
        },
    )


def efficiency_with(
    cfg: SystemConfig,
    recomputability: float,
    t_s: float = 0.03,
    interval: Optional[float] = None,
) -> EfficiencyResult:
    """Eq. 8/9: EasyCrash in front of C/R.

    ``recomputability`` is R_EasyCrash; the crash stream splits into
    M'' = M*R (NVM restart, cost T_r' + T_sync) and M' = M*(1-R)
    (checkpoint rollback).  The checkpoint interval stretches via
    MTBF' = MTBF / (1 - R) — only non-recomputable crashes force rollbacks.
    EasyCrash's own flush overhead taxes useful time by (1 - t_s).
    ``interval`` overrides the stretched Young interval.
    """
    R = min(max(recomputability, 0.0), 0.999999)
    mtbf_ec = cfg.mtbf / (1.0 - R)
    T = young_interval(cfg.t_chk, mtbf_ec) if interval is None else float(interval)
    M = cfg.total_time / cfg.mtbf
    M_fallback = M * (1.0 - R)
    M_recompute = M * R
    t_vain = 0.5 * T
    recovery = (
        M_fallback * (t_vain + cfg.t_r + cfg.t_sync)
        + M_recompute * (cfg.nvm_restore_time + cfg.t_sync)
    )
    N = max(0.0, (cfg.total_time - recovery) / (T + cfg.t_chk))
    useful = N * T * (1.0 - t_s)
    return EfficiencyResult(
        efficiency=useful / cfg.total_time,
        n_checkpoints=N,
        n_crashes=M,
        interval=T,
        useful_time=useful,
        breakdown={
            "checkpoint": N * cfg.t_chk,
            "recovery_fallback": M_fallback * (t_vain + cfg.t_r + cfg.t_sync),
            "recovery_easycrash": M_recompute * (cfg.nvm_restore_time + cfg.t_sync),
            "flush_overhead": N * T * t_s,
            "useful": useful,
        },
    )


def tau_threshold(cfg: SystemConfig, t_s: float = 0.03, tol: float = 1e-5) -> float:
    """Minimum recomputability for which EasyCrash beats plain C/R (Eq. 4)."""
    base = efficiency_without(cfg).efficiency
    lo, hi = 0.0, 1.0
    if efficiency_with(cfg, hi, t_s).efficiency <= base:
        return float("inf")  # EasyCrash can never win under these parameters
    for _ in range(64):
        mid = 0.5 * (lo + hi)
        if efficiency_with(cfg, mid, t_s).efficiency > base:
            hi = mid
        else:
            lo = mid
        if hi - lo < tol:
            break
    return hi


def scale_mtbf(base_mtbf: float, base_nodes: int, nodes: int) -> float:
    """MTBF scales inversely with node count (paper's 100k→400k scaling)."""
    return base_mtbf * base_nodes / nodes


#: Optane-class sustained NVM write bandwidth, bytes/s (paper's device tier).
DEFAULT_NVM_WRITE_BW = 2e9


def persist_overhead_fraction(
    bytes_per_flush: float,
    flush_interval_s: float,
    nvm_write_bw: float = DEFAULT_NVM_WRITE_BW,
) -> float:
    """Measured ``t_s``: fraction of wall time spent writing flush traffic.

    Turns the *measured* delta-flush write volume (``ManagerStats.bytes_written``
    per flush, which delta mode shrinks to the changed blocks only) into the
    EasyCrash overhead knob that :func:`efficiency_with` taxes useful time by.
    Clamped to 1.0 — a flush that cannot keep up with the interval saturates.
    """
    if flush_interval_s <= 0:
        raise ValueError("flush_interval_s must be positive")
    if nvm_write_bw <= 0:
        raise ValueError("nvm_write_bw must be positive")
    return min(1.0, (float(bytes_per_flush) / nvm_write_bw) / flush_interval_s)
