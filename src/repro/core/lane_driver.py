"""Jit-resident multi-window lane driver for the vectorized campaign engine.

PR 5 batched the *dispatches* of lane recompute (one ``run_iteration_batch``
call advances every lane one iteration) but the loop itself stayed on the
host: every iteration round-trips device -> host -> device and re-dispatches,
so short-iteration apps (kmeans) pay more in dispatch overhead than the
batching saves.  This module moves the whole phase-A run-to-completion loop
into a single jitted program per lane bucket:

* the per-lane carried state is stacked into struct-of-arrays buffers
  (padded to the next power of two so the jit cache stays bounded, exactly
  like :meth:`CrashTester._call_padded`) and **donated** to the program;
* a ``lax.while_loop`` advances all lanes together with per-lane ``active``
  masks replicating the serial control flow (step, increment, converged
  check, iteration bound), lanes freezing in place as they finish;
* convergence decisions that the serial path takes on the host move in-jit
  only where they are *provably identical*: exact-op predicates (max / abs /
  compare / isfinite) and scalar thresholds precomputed with
  :func:`f32_monotone_cutoff`;
* any lane whose decision the program cannot make bit-exactly (non-finite
  decision scalars, conservative overflow screens) raises a sticky ``bad``
  flag instead, and the caller re-runs that lane through the untouched
  serial classifier — over-flagging costs speed, never correctness.

The ref engine remains the bitwise oracle: every driver result is asserted
identical to the serial path by the engine differentials in
``tests/test_campaign_vec.py`` and the per-engine golden campaign pins.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Carry = Dict[str, jnp.ndarray]


def f32_monotone_cutoff(pred: Callable[[float], bool]) -> np.float32:
    """Largest non-negative float32 ``v`` with ``pred(float(v))`` true.

    ``pred`` must be monotone over the non-negative float32 range: true on
    an initial segment ``[0, v*]`` and false beyond.  This turns a host-side
    float64 convergence predicate of a single float32 scalar (``sqrt(rho)/nb
    < tol`` and friends) into the bit-exact in-jit comparison ``x <= cutoff``
    — every float32 is exactly representable in float64, so the decision
    boundary between adjacent float32 values is exact.

    Returns ``-inf`` when even ``pred(0.0)`` is false (no value converges).
    """
    def val(bits: int) -> float:
        return float(np.array([bits], np.uint32).view(np.float32)[0])

    if not pred(0.0):
        return np.float32(-np.inf)
    lo, hi = 0, 0x7F7F_FFFF  # bit patterns of +0.0 and float32 max
    if pred(val(hi)):
        return np.float32(val(hi))
    # positive float32 bit patterns are ordered like their values, so a
    # 31-step bisection over the bit space finds the exact boundary
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if pred(val(mid)):
            lo = mid
        else:
            hi = mid
    return np.float32(val(lo))


@dataclass(frozen=True)
class LaneSpec:
    """One app's jit-resident phase-A loop.

    ``carry``
        State fields stacked per lane (axis 0 = lane).  Everything else in
        the state dict is lane-constant or recomputed before read and is
        left untouched in the returned states.
    ``consts``
        Builds the lane-constant device operands (read-only objects such as
        ``b`` / ``links`` / ``points``) from one lane's restart state —
        ``restart_init`` rebuilds them identically for every lane.
    ``step``
        ``step(consts, carry) -> carry``: one main-loop iteration on the
        stacked arrays, bitwise identical per lane to ``run_iteration``.
    ``check``
        ``check(consts, carry, it) -> (conv, suspect)``: the serial
        ``converged(state, it)`` decision *after* a step, as two boolean
        lane vectors.  ``conv`` mirrors the early-exit (including the
        ``it >= n_iters`` bound); ``suspect`` marks lanes where the serial
        hook would raise or where bit-exactness cannot be guaranteed in-jit
        — those lanes are handed back for serial reclassification.
    """

    carry: Tuple[str, ...]
    consts: Callable[[Mapping[str, np.ndarray]], Dict[str, jnp.ndarray]]
    step: Callable[[Dict[str, jnp.ndarray], Carry], Carry]
    check: Callable[[Dict[str, jnp.ndarray], Carry, jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]]


class JitLaneDriver:
    """Runs a :class:`LaneSpec` as one donated-buffer jitted ``while_loop``.

    One instance per app configuration (cache with :func:`cached_driver` so
    app objects stay picklable for the campaign process pool); the jit cache
    inside is keyed by the padded bucket shape, which padding keeps to
    ``O(log lanes)`` entries.
    """

    def __init__(self, spec: LaneSpec):
        self.spec = spec
        self._consts: Dict[str, jnp.ndarray] | None = None
        # donate the stacked lane buffers (args 1-3: carry, it, active) —
        # phase A re-steps the same buffers hundreds of times, so in-place
        # reuse is what keeps the driver memory-flat at large lane counts
        self._drive = jax.jit(self._drive_impl, donate_argnums=(1, 2, 3))

    def _drive_impl(self, consts, carry, it, active, stop):
        spec = self.spec

        def cond(loop):
            _, _, act, _ = loop
            return jnp.any(act)

        def body(loop):
            carry, it, act, bad = loop
            new = spec.step(consts, carry)

            def sel(nv, ov):
                mask = act.reshape(act.shape + (1,) * (nv.ndim - 1))
                return jnp.where(mask, nv, ov)

            carry2 = {k: sel(new[k], carry[k]) for k in carry}
            it2 = it + act.astype(it.dtype)
            conv, suspect = spec.check(consts, carry2, it2)
            bad2 = bad | (act & suspect)
            act2 = act & ~suspect & ~conv & (it2 < stop)
            return carry2, it2, act2, bad2

        bad0 = jnp.zeros_like(active)
        return jax.lax.while_loop(cond, body, (carry, it, active, bad0))

    def advance(
        self,
        states: Sequence[Mapping[str, np.ndarray]],
        its: Sequence[int],
        stop: int,
    ) -> Tuple[List[Mapping[str, np.ndarray]], List[int], List[bool]]:
        """Advance every lane through the run-to-completion loop.

        Replicates ``run_to_completion(state, it, stop)`` per lane: step,
        increment, break on ``converged`` or the iteration bound.  Returns
        ``(states, its, oks)``; ``oks[i]`` false means lane ``i`` tripped
        the suspect mask and is returned *unmodified* — the caller must
        reclassify it through the serial path.

        Lanes enter at scattered restart iterations, and a single bucket
        convoys everyone behind the lane with the most remaining work (every
        padded lane computes every step until the last one exits).  Lanes
        are therefore sorted by remaining iterations and split into a few
        power-of-two buckets when the padded lane-iterations saved clearly
        outweigh an extra dispatch; per-lane results are independent, so the
        regrouping cannot change any value.
        """
        n = len(states)
        rem = [max(0, int(stop) - int(it)) for it in its]
        out_states: List[Mapping[str, np.ndarray]] = [None] * n  # type: ignore[list-item]
        out_its: List[int] = [0] * n
        oks: List[bool] = [False] * n
        todo = []
        for i in range(n):
            if rem[i] == 0:  # run_to_completion would execute nothing
                out_states[i], out_its[i], oks[i] = states[i], int(its[i]), True
            else:
                todo.append(i)
        todo.sort(key=lambda i: -rem[i])
        pos = 0
        for size in _plan_buckets([rem[i] for i in todo]):
            idx = todo[pos:pos + size]
            pos += size
            ss, ii, oo = self._advance_bucket(
                [states[i] for i in idx], [its[i] for i in idx], stop
            )
            for j, i in enumerate(idx):
                out_states[i], out_its[i], oks[i] = ss[j], ii[j], oo[j]
        return out_states, out_its, oks

    def _advance_bucket(
        self,
        states: Sequence[Mapping[str, np.ndarray]],
        its: Sequence[int],
        stop: int,
    ) -> Tuple[List[Mapping[str, np.ndarray]], List[int], List[bool]]:
        spec = self.spec
        n = len(states)
        if self._consts is None:
            self._consts = {k: jnp.asarray(v) for k, v in spec.consts(states[0]).items()}
        b = 1
        while b < n:
            b <<= 1
        pad = b - n
        carry = {}
        for f in spec.carry:
            rows = [np.asarray(s[f]) for s in states]
            carry[f] = jnp.asarray(np.stack(rows + [rows[0]] * pad))
        it0 = np.fromiter(its, np.int32, n)
        it0 = np.concatenate([it0, np.full(pad, int(stop), np.int32)])
        active0 = it0 < int(stop)
        carry, itv, _, bad = self._drive(
            self._consts, carry, jnp.asarray(it0), jnp.asarray(active0),
            jnp.int32(int(stop)),
        )
        carry = {k: np.asarray(v) for k, v in carry.items()}
        itv = np.asarray(itv)
        bad = np.asarray(bad)
        out_states: List[Mapping[str, np.ndarray]] = []
        out_its: List[int] = []
        oks: List[bool] = []
        for i, s in enumerate(states):
            if bad[i]:
                out_states.append(s)
                out_its.append(int(its[i]))
                oks.append(False)
                continue
            s2 = dict(s)
            for f in spec.carry:
                ref = np.asarray(s[f])
                # x64-disabled jit downcast int64 counters to int32; values
                # are tiny iteration counts, so the round trip is lossless
                s2[f] = carry[f][i].astype(ref.dtype, copy=False)
            out_states.append(s2)
            out_its.append(int(itv[i]))
            oks.append(True)
        return out_states, out_its, oks


def _pow2(n: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return b


def _plan_buckets(rem_desc: Sequence[int]) -> List[int]:
    """Split lanes (sorted by remaining iterations, descending) into bucket
    sizes minimizing padded lane-iterations: a bucket of ``k`` lanes costs
    ``pow2(k) * rem_desc[first]`` while-loop iterations.  An extra bucket is
    an extra dispatch, charged at an eighth of the single-bucket cost so the
    split only happens when it clearly pays."""
    n = len(rem_desc)
    if n == 0:
        return []
    overhead = max(1, (_pow2(n) * rem_desc[0]) // 8)
    best = [0] * (n + 1)  # best[i]: min cost of lanes i..n-1
    cut = [n] * (n + 1)
    for i in range(n - 1, -1, -1):
        best[i] = float("inf")  # type: ignore[assignment]
        for j in range(i + 1, n + 1):
            c = _pow2(j - i) * rem_desc[i] + overhead + best[j]
            if c < best[i]:
                best[i], cut[i] = c, j
    sizes = []
    i = 0
    while i < n:
        sizes.append(cut[i] - i)
        i = cut[i]
    return sizes


_DRIVER_CACHE: Dict[tuple, JitLaneDriver] = {}


def cached_driver(key: tuple, factory: Callable[[], JitLaneDriver]) -> JitLaneDriver:
    """Process-level driver cache keyed by app configuration.

    Apps must not hold driver instances as attributes — the jitted closures
    are unpicklable and would silently knock the app out of the campaign
    process pool.  Worker processes repopulate their own cache on first use.
    """
    drv = _DRIVER_CACHE.get(key)
    if drv is None:
        drv = _DRIVER_CACHE[key] = JitLaneDriver(factory())
    return drv
