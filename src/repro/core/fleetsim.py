"""Fleet-scale serving-under-failure simulator (the ROADMAP's "millions of
users" story, built on the §7 single-job machinery in :mod:`repro.core.sysim`).

:func:`~repro.core.sysim.simulate_policy` scores one HPC job's *efficiency*
under a failure trace.  A serving deployment is a different animal: N
replicas answer an open-loop request stream, and a crash does not cost
abstract "useful time" — it costs *requests*: queues back up behind the dead
replica, tail latency explodes, and a cold restart forces every interrupted
session to re-run prefill because the KV cache died with the process.
EasyCrash's claim translates directly: an NVM-recovered replica warm-starts
with its KV/recurrent caches intact (sessions resume mid-decode), while a
checkpoint restore or bare restart comes back cold.

This module plays that tape.  :func:`simulate_fleet` is a seeded
discrete-event simulation of a replica fleet:

* **arrivals** — open-loop nonhomogeneous Poisson (:class:`ArrivalProcess`),
  diurnally modulated (Lewis thinning, so the stream is seeded and exact);
* **service** — heavy-tail lognormal per-request work
  (:class:`ServiceModel`); requests join the shortest backlog among live
  replicas, bounded queues drop on overflow, arrivals with no live replica
  are lost;
* **failures** — each replica fails independently per a
  :class:`~repro.core.sysim.FailureTrace` (Poisson/Weibull/
  :func:`~repro.core.sysim.scaled_trace`, shared with ``sysim``);
* **recovery** — per the protection policy under test (same four names as
  ``sysim``): ``none`` restarts cold; ``checkpoint`` restores from the last
  checkpoint (cold); ``easycrash`` draws the outcome from a campaign-measured
  :class:`~repro.core.sysim.RecomputeProfile` — S1/S2 warm-start from the
  NVM image (S2 pays recompute iterations drawn from the measured
  extra-iteration histogram), S3/S4 restart cold; ``hybrid`` falls back to
  the checkpoint instead of restarting.  Failures that strike *during*
  recovery restart the recovery with a fresh outcome draw, exactly like
  ``sysim``;
* **persistence cost** — the checkpointing policies pause serving for
  ``t_chk`` at the (Young/stretched-Young) interval between requests, and
  the EasyCrash policies inflate every service time by ``1 / (1 - t_s)``
  where ``t_s`` is the measured delta-flush overhead
  (:func:`~repro.core.efficiency.persist_overhead_fraction` of
  ``ManagerStats.bytes_written``) — persist traffic is charged against
  serving capacity, per Huang et al.'s persistence-cost analysis.

**Warm vs cold** is the mechanism under study: a warm recovery resumes the
preempted request with its remaining work and keeps the queue intact; a cold
recovery keeps the queue (sessions retry) but marks every queued request
``needs_prefill`` — each pays :attr:`ServiceModel.prefill_s` again before
decoding resumes, and the interrupted request starts its service over.

The simulator reports goodput, request loss, SLO-violation fraction, and
p50/p95/p99 latency (:class:`FleetResult`), plus an availability/breakdown
accounting that reduces to ``sysim``'s single-job buckets when the fleet is
one replica with no traffic (the differential oracle in
``tests/test_fleetsim.py``).

Everything is seeded and single-threaded: the same
``(policy, FleetConfig, profile)`` reproduces the same :class:`FleetResult`
bit for bit.  Arrival, service, per-replica failure, and recovery-outcome
draws come from *independent* spawned streams, so changing the failure trace
never perturbs the offered load — policy comparisons run against the same
request tape.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .efficiency import SystemConfig
from .sysim import (
    POLICIES,
    SECONDS_PER_DAY,
    FailureTrace,
    PoissonTrace,
    RecomputeProfile,
    default_interval,
)

FLEET_VERSION = 1

#: event kinds, in deterministic tie-break order (heap entries carry a
#: monotone sequence number, so same-time events process in push order)
_ARRIVAL, _DEPART, _FAIL, _RECOVER, _CKPT_START, _CKPT_END = range(6)


# ------------------------------------------------------------- load models
@dataclass(frozen=True)
class ArrivalProcess:
    """Open-loop nonhomogeneous Poisson arrivals with diurnal modulation.

    The instantaneous rate is ``rate * (1 + amplitude * sin(2*pi*t/period +
    phase))`` requests/second fleet-wide; draws use Lewis thinning against
    the peak rate so the stream is exact and consumes a deterministic,
    trace-independent RNG stream.  ``rate=0`` produces no arrivals (the
    no-traffic reduction used by the ``sysim`` differential test).
    """

    rate: float
    amplitude: float = 0.0
    period: float = SECONDS_PER_DAY
    phase: float = 0.0

    def __post_init__(self):
        if self.rate < 0:
            raise ValueError(f"rate must be >= 0, got {self.rate}")
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError(f"amplitude must be in [0, 1), got {self.amplitude}")
        if self.period <= 0:
            raise ValueError(f"period must be positive, got {self.period}")

    def rate_at(self, t: float) -> float:
        return self.rate * (1.0 + self.amplitude
                            * math.sin(2.0 * math.pi * t / self.period + self.phase))

    def next_arrival(self, rng: np.random.Generator, t: float) -> float:
        """The first arrival after ``t`` (Lewis thinning); inf if rate=0."""
        peak = self.rate * (1.0 + self.amplitude)
        if peak <= 0.0:
            return math.inf
        while True:
            t += float(rng.exponential(1.0 / peak))
            if float(rng.random()) * peak <= self.rate_at(t):
                return t

    def spec(self) -> Dict[str, object]:
        return {"rate": float(self.rate), "amplitude": float(self.amplitude),
                "period": float(self.period), "phase": float(self.phase)}


@dataclass(frozen=True)
class ServiceModel:
    """Heavy-tail (lognormal) per-request service times.

    ``mean_s`` is the *mean* service time (``mu`` is derived so the lognormal
    mean lands there); ``sigma`` is the lognormal shape — 0 degenerates to
    deterministic service.  ``prefill_s`` is the extra work a request pays
    when its session's KV cache is gone (cold recovery re-prefill); the
    steady-state cost of its own prefill is already inside ``mean_s``.
    """

    mean_s: float = 0.5
    sigma: float = 0.6
    prefill_s: float = 1.0

    def __post_init__(self):
        if self.mean_s <= 0:
            raise ValueError(f"mean_s must be positive, got {self.mean_s}")
        if self.sigma < 0:
            raise ValueError(f"sigma must be >= 0, got {self.sigma}")
        if self.prefill_s < 0:
            raise ValueError(f"prefill_s must be >= 0, got {self.prefill_s}")

    def draw(self, rng: np.random.Generator) -> float:
        mu = math.log(self.mean_s) - 0.5 * self.sigma * self.sigma
        return float(rng.lognormal(mu, self.sigma))

    def spec(self) -> Dict[str, object]:
        return {"mean_s": float(self.mean_s), "sigma": float(self.sigma),
                "prefill_s": float(self.prefill_s)}


# ------------------------------------------------------------ fleet config
@dataclass(frozen=True)
class FleetConfig:
    """Everything :func:`simulate_fleet` needs besides the policy and the
    profile, in one frozen, validated object (mirroring
    :class:`~repro.core.workflow.WorkflowConfig`): :meth:`spec` is the single
    serialization point and :meth:`fingerprint` the artifact identity.

    ``t_s`` is the EasyCrash flush-overhead fraction charged against the
    serving rate of the ``easycrash``/``hybrid`` policies (measure it with
    :func:`~repro.core.efficiency.persist_overhead_fraction` from delta-mode
    ``bytes_written``); ``t_iter`` converts the profile's S2
    extra-recompute-iteration draws into downtime seconds (a serving
    "iteration" is one decode step, so it is orders of magnitude below the
    HPC default).  ``interval`` overrides the Young/stretched-Young
    checkpoint interval; ``None`` uses
    :func:`~repro.core.sysim.default_interval` at the replica trace's MTBF.
    """

    n_replicas: int = 4
    arrival: ArrivalProcess = ArrivalProcess(rate=4.0, amplitude=0.3)
    service: ServiceModel = ServiceModel()
    trace: FailureTrace = PoissonTrace(mtbf=2 * 3600.0)
    system: SystemConfig = SystemConfig(mtbf=2 * 3600.0, t_chk=20.0,
                                        nvm_restore_time=2.0)
    slo_latency: float = 2.0
    queue_cap: int = 64
    horizon: float = 4 * 3600.0
    interval: Optional[float] = None
    t_s: float = 0.0
    t_iter: float = 0.05
    seed: int = 0

    def __post_init__(self):
        if self.n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {self.n_replicas}")
        if self.horizon <= 0:
            raise ValueError(f"horizon must be positive, got {self.horizon}")
        if self.queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1, got {self.queue_cap}")
        if self.slo_latency <= 0:
            raise ValueError(f"slo_latency must be positive, got {self.slo_latency}")
        if not 0.0 <= self.t_s < 1.0:
            raise ValueError(f"t_s must be in [0, 1), got {self.t_s}")
        if self.t_iter < 0:
            raise ValueError(f"t_iter must be >= 0, got {self.t_iter}")
        if self.interval is not None and self.interval <= 0:
            raise ValueError(f"interval must be positive, got {self.interval}")

    def replace(self, **overrides) -> "FleetConfig":
        """A copy with the given fields overridden (re-validated)."""
        return dataclasses.replace(self, **overrides)

    def spec(self) -> Dict[str, object]:
        """Fleet identity (JSON-round-trip safe) for artifacts and goldens."""
        return {
            "fleet_version": FLEET_VERSION,
            "n_replicas": int(self.n_replicas),
            "arrival": self.arrival.spec(),
            "service": self.service.spec(),
            "trace": self.trace.spec(),
            "system": {
                "mtbf": float(self.system.mtbf),
                "t_chk": float(self.system.t_chk),
                "t_sync": float(self.system.t_sync),
                "t_r": float(self.system.t_r),
                "nvm_restore_time": float(self.system.nvm_restore_time),
            },
            "slo_latency": float(self.slo_latency),
            "queue_cap": int(self.queue_cap),
            "horizon": float(self.horizon),
            "interval": None if self.interval is None else float(self.interval),
            "t_s": float(self.t_s),
            "t_iter": float(self.t_iter),
            "seed": int(self.seed),
        }

    def fingerprint(self) -> str:
        from .artifacts import payload_fingerprint

        return payload_fingerprint(self.spec())


# ------------------------------------------------------------ fleet result
@dataclass(frozen=True)
class FleetResult:
    """One policy's serving record over the horizon.

    ``arrived == served + dropped + in_flight`` holds exactly (request
    conservation); ``breakdown`` buckets replica-seconds by state (``up`` /
    ``checkpoint`` / ``down``) and sums to ``n_replicas * horizon``.
    Latency percentiles are 0 when nothing was served (strict-JSON safe).
    """

    policy: str
    goodput: float               # served requests / second of horizon
    offered_rate: float          # arrived requests / second of horizon
    arrived: int
    served: int
    dropped: int                 # queue overflow + no-live-replica losses
    dropped_down: int            # the no-live-replica share of ``dropped``
    in_flight: int               # queued or in service when the tape ends
    slo_violations: int          # served with latency > slo_latency
    slo_violation_frac: float    # ... as a fraction of served (0 if none)
    latency_p50: float
    latency_p95: float
    latency_p99: float
    latency_mean: float
    latency_max: float
    availability: float          # up replica-seconds / total replica-seconds
    interval: float              # checkpoint interval used (0 if none)
    n_failures: int
    n_checkpoints: int
    n_nvm_recoveries: int        # warm recoveries from the NVM image (S1/S2)
    n_fallbacks: int             # recoveries via checkpoint restore
    n_cold_restarts: int         # recoveries with nothing to restore
    breakdown: Dict[str, float]  # replica-seconds per state bucket

    def payload(self) -> Dict[str, object]:
        """Strict-JSON dict (the frontier/golden/bench serialization)."""
        d = dataclasses.asdict(self)
        d["breakdown"] = {k: float(v) for k, v in sorted(d["breakdown"].items())}
        return d

    def spec(self) -> Dict[str, object]:
        return self.payload()


# --------------------------------------------------------------- internals
class _Request:
    __slots__ = ("arr", "work", "needs_prefill", "work_left")

    def __init__(self, arr: float, work: float):
        self.arr = arr
        self.work = work
        self.needs_prefill = False   # cold recovery: pay prefill_s again
        self.work_left: Optional[float] = None  # warm preemption: resume here


class _Replica:
    __slots__ = ("idx", "up", "queue", "current", "epoch", "ckpt_active",
                 "next_ckpt_due", "service_end", "state_label", "state_since")

    def __init__(self, idx: int):
        self.idx = idx
        self.up = True
        self.queue: deque = deque()
        self.current: Optional[_Request] = None
        self.epoch = 0               # bumped on failure: stale events ignored
        self.ckpt_active = False
        self.next_ckpt_due = math.inf
        self.service_end = 0.0       # when the in-service request departs
        self.state_label = "up"
        self.state_since = 0.0

    def backlog(self) -> int:
        return len(self.queue) + (1 if self.current is not None else 0)


@dataclass
class _Tally:
    arrived: int = 0
    served: int = 0
    dropped_queue: int = 0
    dropped_down: int = 0
    n_failures: int = 0
    n_checkpoints: int = 0
    n_nvm: int = 0
    n_fallbacks: int = 0
    n_cold: int = 0
    latencies: List[float] = field(default_factory=list)
    buckets: Dict[str, float] = field(default_factory=dict)


def _percentile(lat: np.ndarray, q: float) -> float:
    return float(np.percentile(lat, q)) if lat.size else 0.0


# ------------------------------------------------------------ the simulator
def simulate_fleet(
    policy: str,
    config: FleetConfig,
    profile: Optional[RecomputeProfile] = None,
) -> FleetResult:
    """Play the request tape against a failing fleet under one policy.

    ``profile`` (required for ``easycrash``/``hybrid``) supplies the
    campaign-measured S1–S4 outcome draw and the S2 extra-iteration
    histogram; build it from the ``decode`` app's campaign
    (:meth:`RecomputeProfile.from_campaign`) for the serving story the
    ROADMAP asks for.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r} (one of {POLICIES})")
    if policy in ("easycrash", "hybrid") and profile is None:
        raise ValueError(f"policy {policy!r} needs a RecomputeProfile")

    system, trace, horizon = config.system, config.trace, config.horizon
    checkpointing = policy in ("checkpoint", "hybrid")
    interval = 0.0
    if checkpointing:
        interval = (config.interval if config.interval is not None
                    else default_interval(policy, system, trace, profile))
    inflate = 1.0 / (1.0 - config.t_s) if policy in ("easycrash", "hybrid") else 1.0

    # independent streams: the offered load never shifts with the trace
    ss = np.random.SeedSequence(config.seed)
    children = ss.spawn(3 + config.n_replicas)
    rng_arrival = np.random.default_rng(children[0])
    rng_service = np.random.default_rng(children[1])
    rng_outcome = np.random.default_rng(children[2])
    rng_fail = [np.random.default_rng(c) for c in children[3:]]

    replicas = [_Replica(i) for i in range(config.n_replicas)]
    tally = _Tally()
    heap: List[Tuple[float, int, int, int, int]] = []  # (t, seq, kind, replica, epoch)
    seq = 0

    def push(t: float, kind: int, ridx: int, epoch: int) -> None:
        nonlocal seq
        heapq.heappush(heap, (t, seq, kind, ridx, epoch))
        seq += 1

    def set_state(r: _Replica, label: str, now: float) -> None:
        tally.buckets[r.state_label] = (
            tally.buckets.get(r.state_label, 0.0) + now - r.state_since
        )
        r.state_label, r.state_since = label, now

    def start_service(r: _Replica, now: float) -> None:
        req = r.queue.popleft()
        r.current = req
        if req.work_left is not None:        # warm-resumed preemption
            remaining = req.work_left
            req.work_left = None
        else:
            extra = config.service.prefill_s if req.needs_prefill else 0.0
            req.needs_prefill = False
            remaining = (req.work + extra) * inflate
        r.service_end = now + remaining
        push(r.service_end, _DEPART, r.idx, r.epoch)

    def begin_checkpoint(r: _Replica, now: float) -> None:
        r.ckpt_active = True
        set_state(r, "checkpoint", now)
        push(now + system.t_chk, _CKPT_END, r.idx, r.epoch)

    def next_step(r: _Replica, now: float) -> None:
        """Replica is up with no request in service: checkpoint if due,
        serve if backlogged, else idle (with a wake-up at the due time)."""
        if checkpointing and now >= r.next_ckpt_due and not r.ckpt_active:
            begin_checkpoint(r, now)
        elif r.queue:
            start_service(r, now)
        elif checkpointing and math.isfinite(r.next_ckpt_due):
            push(r.next_ckpt_due, _CKPT_START, r.idx, r.epoch)

    def begin_recovery(r: _Replica, now: float) -> None:
        """Draw this attempt's recovery path; a failure mid-recovery lands
        back here with a fresh draw (same semantics as ``sysim``)."""
        if policy == "checkpoint":
            tally.n_fallbacks += 1
            duration, warm = system.t_r + system.t_sync, False
        elif policy == "none":
            tally.n_cold += 1
            duration, warm = system.t_sync, False
        else:
            outcome = profile.draw_outcome(rng_outcome)
            if outcome in ("S1", "S2"):
                tally.n_nvm += 1
                duration, warm = system.nvm_restore_time + system.t_sync, True
                if outcome == "S2":
                    duration += profile.draw_extra_iters(rng_outcome) * config.t_iter
            elif policy == "hybrid":
                tally.n_fallbacks += 1
                duration, warm = system.t_r + system.t_sync, False
            else:
                tally.n_cold += 1
                duration, warm = system.t_sync, False
        if not warm:
            # the KV caches died with the process: every queued session must
            # re-prefill, and the interrupted request starts its service over
            for req in r.queue:
                req.needs_prefill = True
                req.work_left = None
        push(now + duration, _RECOVER, r.idx, r.epoch)

    # initial events
    first = config.arrival.next_arrival(rng_arrival, 0.0)
    if math.isfinite(first):
        push(first, _ARRIVAL, -1, 0)
    for r in replicas:
        push(trace.interarrival(rng_fail[r.idx]), _FAIL, r.idx, 0)
        if checkpointing:
            r.next_ckpt_due = interval
            push(r.next_ckpt_due, _CKPT_START, r.idx, r.epoch)

    now = 0.0
    while heap:
        t, _, kind, ridx, epoch = heapq.heappop(heap)
        if t >= horizon:
            break
        now = t
        if kind == _ARRIVAL:
            tally.arrived += 1
            work = config.service.draw(rng_service)  # stream-stable draw
            live = [r for r in replicas if r.up]
            if not live:
                tally.dropped_down += 1
            else:
                r = min(live, key=lambda x: (x.backlog(), x.idx))
                if r.backlog() >= config.queue_cap:
                    tally.dropped_queue += 1
                else:
                    r.queue.append(_Request(now, work))
                    if r.current is None and not r.ckpt_active:
                        next_step(r, now)
            nxt = config.arrival.next_arrival(rng_arrival, now)
            if math.isfinite(nxt):
                push(nxt, _ARRIVAL, -1, 0)
            continue

        r = replicas[ridx]
        if kind == _FAIL:
            tally.n_failures += 1
            push(now + trace.interarrival(rng_fail[ridx]), _FAIL, ridx, 0)
            r.epoch += 1          # invalidate depart/ckpt/recover in flight
            if r.up:
                r.up = False
                r.ckpt_active = False
                set_state(r, "down", now)
                if r.current is not None:
                    # preempt: park at the queue head with its remaining work
                    # (resumed as-is on a warm recovery; a cold recovery
                    # resets it to a full redo below, in begin_recovery)
                    req = r.current
                    r.current = None
                    req.work_left = max(0.0, r.service_end - now)
                    r.queue.appendleft(req)
            begin_recovery(r, now)
            continue
        if epoch != r.epoch:
            continue  # stale event from before this replica's last failure

        if kind == _DEPART:
            req = r.current
            r.current = None
            tally.served += 1
            lat = now - req.arr
            tally.latencies.append(lat)
            next_step(r, now)
        elif kind == _RECOVER:
            r.up = True
            set_state(r, "up", now)
            if checkpointing:
                r.next_ckpt_due = now + interval
            next_step(r, now)
        elif kind == _CKPT_START:
            if r.up and r.current is None and not r.ckpt_active \
                    and now >= r.next_ckpt_due:
                begin_checkpoint(r, now)
        elif kind == _CKPT_END:
            r.ckpt_active = False
            tally.n_checkpoints += 1
            r.next_ckpt_due = now + interval
            set_state(r, "up", now)
            next_step(r, now)

    # close the books at the horizon
    for r in replicas:
        set_state(r, r.state_label, horizon)
    in_flight = sum(r.backlog() for r in replicas)
    dropped = tally.dropped_queue + tally.dropped_down
    lat = np.asarray(sorted(tally.latencies), dtype=np.float64)
    n_slo = int(np.count_nonzero(lat > config.slo_latency))
    total_rs = config.n_replicas * horizon
    return FleetResult(
        policy=policy,
        goodput=tally.served / horizon,
        offered_rate=tally.arrived / horizon,
        arrived=tally.arrived,
        served=tally.served,
        dropped=dropped,
        dropped_down=tally.dropped_down,
        in_flight=in_flight,
        slo_violations=n_slo,
        slo_violation_frac=n_slo / tally.served if tally.served else 0.0,
        latency_p50=_percentile(lat, 50),
        latency_p95=_percentile(lat, 95),
        latency_p99=_percentile(lat, 99),
        latency_mean=float(lat.mean()) if lat.size else 0.0,
        latency_max=float(lat.max()) if lat.size else 0.0,
        availability=tally.buckets.get("up", 0.0) / total_rs,
        interval=interval,
        n_failures=tally.n_failures,
        n_checkpoints=tally.n_checkpoints,
        n_nvm_recoveries=tally.n_nvm,
        n_fallbacks=tally.n_fallbacks,
        n_cold_restarts=tally.n_cold,
        breakdown=dict(tally.buckets),
    )


# ---------------------------------------------------------- policy frontier
def fleet_frontier(
    config: FleetConfig,
    profile: RecomputeProfile,
    *,
    policies: Sequence[str] = POLICIES,
) -> Dict[str, object]:
    """All policies against the same request tape, as one JSON-serializable
    policy-frontier document (the fleet analogue of
    :func:`~repro.core.sysim.efficiency_frontier`)."""
    doc: Dict[str, object] = {
        "config": config.spec(),
        "fingerprint": config.fingerprint(),
        "profile": {
            "app": profile.app_name,
            "fault": dict(profile.fault_spec),
            "fractions": {c: float(profile.fractions.get(c, 0.0))
                          for c in ("S1", "S2", "S3", "S4")},
            "success_rate": profile.success_rate,
            "mean_extra_iters": profile.mean_extra_iters(),
            "n_records": profile.n_records,
        },
        "policies": {},
    }
    for policy in policies:
        prof = profile if policy in ("easycrash", "hybrid") else None
        doc["policies"][policy] = simulate_fleet(policy, config, prof).payload()
    return doc


__all__ = [
    "FLEET_VERSION",
    "ArrivalProcess",
    "ServiceModel",
    "FleetConfig",
    "FleetResult",
    "simulate_fleet",
    "fleet_frontier",
]
