"""Block-granularity utilities.

EasyCrash reasons about persistence at *cache-block* granularity (64 B on
x86).  On TPU the analogous unit is the flush block used by the
``delta_snapshot`` kernel.  Everything in :mod:`repro.core` that mixes old and
new values, computes inconsistency rates or counts NVM writes does so in
units of blocks via these helpers.

Arrays are treated as flat byte streams; the final (possibly partial) block
is a real block (the paper's objects are not block-aligned either).
"""
from __future__ import annotations

import numpy as np

DEFAULT_BLOCK_BYTES = 64


def num_blocks(nbytes: int, block_bytes: int = DEFAULT_BLOCK_BYTES) -> int:
    """Number of cache blocks spanned by an object of ``nbytes`` bytes."""
    if nbytes <= 0:
        return 0
    return -(-nbytes // block_bytes)


def obj_nbytes(arr: np.ndarray) -> int:
    return int(np.asarray(arr).nbytes)


def obj_num_blocks(arr: np.ndarray, block_bytes: int = DEFAULT_BLOCK_BYTES) -> int:
    return num_blocks(obj_nbytes(arr), block_bytes)


def _as_byte_view(arr: np.ndarray) -> np.ndarray:
    """Flat uint8 view of an array (no copy)."""
    a = np.ascontiguousarray(arr)
    return a.view(np.uint8).reshape(-1)


def mix_blocks(
    old: np.ndarray,
    new: np.ndarray,
    new_block_mask: np.ndarray,
    block_bytes: int = DEFAULT_BLOCK_BYTES,
) -> np.ndarray:
    """Blockwise select: where ``new_block_mask[b]`` take ``new``, else ``old``.

    This is the post-crash NVM image constructor: persisted blocks carry the
    new value, lost (dirty-in-cache) blocks retain the stale one.
    """
    old = np.asarray(old)
    new = np.asarray(new)
    if old.shape != new.shape or old.dtype != new.dtype:
        raise ValueError(f"mix_blocks shape/dtype mismatch: {old.shape}/{old.dtype} vs {new.shape}/{new.dtype}")
    nb = obj_num_blocks(old, block_bytes)
    mask = np.asarray(new_block_mask, dtype=bool)
    if mask.shape != (nb,):
        raise ValueError(f"mask must have {nb} blocks, got {mask.shape}")
    if nb == 0:
        return old.copy()
    ob = _as_byte_view(old).copy()
    nbv = _as_byte_view(new)
    byte_mask = np.repeat(mask, block_bytes)[: ob.size]
    ob[byte_mask] = nbv[byte_mask]
    return ob.view(old.dtype).reshape(old.shape)


def inconsistent_rate(
    image: np.ndarray,
    truth: np.ndarray,
) -> float:
    """Fraction of *bytes* in ``image`` that differ from ``truth``.

    Matches NVCT's "data inconsistent rate": dirty (lost) bytes divided by
    the object size.
    """
    a = _as_byte_view(np.asarray(image))
    b = _as_byte_view(np.asarray(truth))
    if a.size != b.size:
        raise ValueError("size mismatch")
    if a.size == 0:
        return 0.0
    return float(np.count_nonzero(a != b)) / a.size


def block_diff_mask(
    a: np.ndarray,
    b: np.ndarray,
    block_bytes: int = DEFAULT_BLOCK_BYTES,
) -> np.ndarray:
    """Per-block "changed" mask between two same-shaped arrays.

    CPU reference for the ``delta_snapshot`` Pallas kernel: a block is dirty
    iff any byte within it differs.
    """
    av = _as_byte_view(np.asarray(a))
    bv = _as_byte_view(np.asarray(b))
    if av.size != bv.size:
        raise ValueError("size mismatch")
    nb = num_blocks(av.size, block_bytes)
    if nb == 0:
        return np.zeros((0,), dtype=bool)
    diff = av != bv
    pad = nb * block_bytes - av.size
    if pad:
        diff = np.concatenate([diff, np.zeros(pad, dtype=bool)])
    return diff.reshape(nb, block_bytes).any(axis=1)
