"""Append-only JSONL result stores for crash campaigns and workflows.

Two stores share one file discipline:

* :class:`CampaignStore` — one campaign per file: a header line with the
  campaign fingerprint, then one line per completed *shard* (all crash tests
  whose crash point falls in the same crash window).
* :class:`WorkflowStore` — one §5.3 workflow per file: a workflow header,
  one ``campaign`` line per member campaign (baseline, persist-everywhere
  "best", and the per-region isolated campaigns) carrying that campaign's
  fingerprint, and shard lines tagged with their campaign key.  This is what
  lets a killed ``run_workflow`` resume executing only the shards that never
  landed — across *all* of its campaigns, not just the one that was running.

Durability contract: every append is flushed **and fsynced** before the call
returns (a shard reported "completed" has reached the device, not just the
page cache), and the directory entry is fsynced when the file is first
created.  The file is only ever appended to, so the worst a crash can leave
behind is one torn *trailing* line — the loader tolerates exactly that and
nothing else.  An undecodable line in the middle of the file is not a torn
append, it is corruption, and silently dropping it would silently drop a
shard's results from a resumed campaign; the loader raises
:class:`CampaignStoreError` instead.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
from typing import Dict, List, Mapping, Optional, Tuple

from .crash_tester import CrashRecord
from .durable import fsync_dir

#: bump when the shard record layout changes; mismatching stores are rejected
STORE_VERSION = 1


class CampaignStoreError(RuntimeError):
    """Raised when a store exists but belongs to a different campaign, or
    when its contents are corrupt beyond the tolerated torn trailing line."""


def record_to_dict(record: CrashRecord) -> dict:
    d = dataclasses.asdict(record)
    # unit importance weight is the (historical) default: elide it, so every
    # uniform campaign's stored lines are byte-identical to pre-weight stores
    if d.get("weight") == 1.0:
        d.pop("weight")
    return d


def record_from_dict(d: Mapping[str, object]) -> CrashRecord:
    return CrashRecord(
        iter_idx=int(d["iter_idx"]),
        region_idx=int(d["region_idx"]),
        frac=float(d["frac"]),
        inconsistency={k: float(v) for k, v in dict(d["inconsistency"]).items()},
        outcome=str(d["outcome"]),
        extra_iters=int(d["extra_iters"]),
        verify_metric=float(d["verify_metric"]),
        weight=float(d.get("weight", 1.0)),
    )


def _json_roundtrip(obj: dict) -> dict:
    """The stored header went through JSON; compare live dicts in JSON space
    (tuples become lists, int keys become strings, ...)."""
    return json.loads(json.dumps(obj))


class _JsonlStore:
    """Shared JSONL plumbing: strict reads, torn-tail repair, fsynced appends."""

    def __init__(self, path: str):
        self.path = path
        # parsed-line cache keyed by (mtime_ns, size): a resumed workflow
        # consults the store several times (header validation, one batch
        # registration per stage, progress accounting) and each would
        # otherwise re-decode the full file.  Appends go through _append,
        # which changes the stat signature and so invalidates naturally.
        self._cache: Optional[Tuple[Tuple[int, int], List[dict]]] = None

    # ------------------------------------------------------------------ read
    def _stat_sig(self) -> Optional[Tuple[int, int]]:
        try:
            st = os.stat(self.path)
        except FileNotFoundError:
            return None
        return (st.st_mtime_ns, st.st_size)

    def _read_lines(self) -> List[dict]:
        """Decode every line of the store (cached per file state).

        Callers must treat the returned list and dicts as read-only.

        Tolerates exactly one undecodable *trailing* line (a crash mid-append
        tears at most the final line; the torn shard simply re-executes).  An
        undecodable line followed by more data cannot be a torn append —
        appends are fsynced in order — so it is treated as corruption and
        raised, never silently dropped.
        """
        sig = self._stat_sig()
        if sig is None:
            return []
        if self._cache is not None and self._cache[0] == sig:
            return self._cache[1]
        out: List[dict] = []
        # bytes, decoded per line: a torn append can cut a multi-byte UTF-8
        # character, which must be handled like any other torn tail rather
        # than crash the reader with UnicodeDecodeError
        with io.open(self.path, "rb") as f:
            raw = [ln.strip() for ln in f.read().split(b"\n")]
        # trailing blank lines are not data
        while raw and not raw[-1]:
            raw.pop()
        for i, line in enumerate(raw):
            if not line:
                continue
            try:
                obj = json.loads(line.decode("utf-8"))
            except (json.JSONDecodeError, UnicodeDecodeError) as e:
                if i == len(raw) - 1:
                    continue  # torn trailing line: discard, shard re-executes
                raise CampaignStoreError(
                    f"{self.path}: undecodable line {i + 1} of {len(raw)} — "
                    f"mid-file corruption, refusing to silently drop a shard "
                    f"({e})"
                ) from None
            if not isinstance(obj, dict):
                # our appends only ever write objects; a decodable non-dict
                # line cannot be a torn prefix of one (prefixes never decode)
                raise CampaignStoreError(
                    f"{self.path}: line {i + 1} of {len(raw)} is not a JSON "
                    f"object — foreign or corrupt store content"
                )
            out.append(obj)
        self._cache = (sig, out)
        return out

    # ----------------------------------------------------------------- write
    def _repair_torn_tail(self) -> None:
        """Repair an unterminated final line left by a crash mid-append.

        Two cases, matching exactly what :meth:`_read_lines` accepts:

        * the tail *decodes* — every byte of the line landed except the
          newline (a proper prefix of a serialized JSON object can never
          itself decode, so a decodable tail is necessarily complete): the
          reader already treats it as valid data, so terminate it;
        * the tail does not decode — torn: truncate it.  Truncating — not
          newline-terminating — matters here: terminated garbage would be
          buried mid-file by the next append and poison every later read.
        """
        if os.path.getsize(self.path) == 0:
            return
        with io.open(self.path, "rb+") as f:
            f.seek(-1, os.SEEK_END)
            if f.read(1) == b"\n":
                return
            f.seek(0)
            data = f.read()
            cut = data.rfind(b"\n") + 1
            try:
                complete = isinstance(json.loads(data[cut:].decode("utf-8")), dict)
            except (json.JSONDecodeError, UnicodeDecodeError):
                complete = False
            if complete:
                f.write(b"\n")  # complete line, only the newline was lost
            else:
                f.truncate(cut)
            f.flush()
            os.fsync(f.fileno())

    def _append(self, obj: dict) -> None:
        created = not os.path.exists(self.path)
        if not created:
            self._repair_torn_tail()
        with io.open(self.path, "a", encoding="utf-8") as f:
            f.write(json.dumps(obj) + "\n")
            f.flush()
            os.fsync(f.fileno())
        if created:
            # the file's directory entry must survive the crash too
            fsync_dir(os.path.dirname(os.path.abspath(self.path)))


class CampaignStore(_JsonlStore):
    """JSONL store bound to one campaign.

    Typical use is through ``CrashTester.run_campaign(store_path=...)``; the
    class is public so benchmarks can inspect partial campaigns.
    """

    def header(self) -> Optional[dict]:
        lines = self._read_lines()
        if lines and lines[0].get("type") == "header":
            return lines[0]
        return None

    def completed_shards(self) -> Dict[int, List[Tuple[int, CrashRecord]]]:
        """shard_id -> [(original test index, record)], later lines win."""
        shards: Dict[int, List[Tuple[int, CrashRecord]]] = {}
        for line in self._read_lines():
            if line.get("type") != "shard":
                continue
            shards[int(line["shard"])] = [
                (int(i), record_from_dict(r)) for i, r in line["records"]
            ]
        return shards

    def load_or_create(self, fingerprint: dict) -> Dict[int, List[Tuple[int, CrashRecord]]]:
        """Validate/initialise the store; return already-completed shards.

        * no file (or empty file): write the header, return ``{}``;
        * matching header: return the completed shards to skip;
        * mismatching header: raise :class:`CampaignStoreError` — a store is
          bound to exactly one campaign, silently mixing results would
          corrupt the resumed ``CampaignResult``.
        """
        existing = self.header()
        if existing is None:
            if self._read_lines():
                raise CampaignStoreError(
                    f"{self.path}: not a campaign store (no header line)"
                )
            d = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(d, exist_ok=True)
            self._append({"type": "header", **fingerprint})
            return {}
        found = {k: existing.get(k) for k in fingerprint}
        # legacy headers predate pluggable fault models; those campaigns ran
        # under the clean-power-fail semantics, so a missing "fault" key
        # means exactly that — old stores stay resumable with the default
        # model (and still refuse any other)
        if "fault" in fingerprint and found.get("fault") is None:
            found["fault"] = {"model": "power-fail"}
        if found != _json_roundtrip(dict(fingerprint)):
            raise CampaignStoreError(
                f"{self.path}: store belongs to a different campaign\n"
                f"  store:    {found}\n  campaign: {fingerprint}"
            )
        return self.completed_shards()

    def append_shard(self, shard_id: int, records: List[Tuple[int, CrashRecord]]) -> None:
        self._append({
            "type": "shard",
            "shard": int(shard_id),
            "records": [(int(i), record_to_dict(r)) for i, r in records],
        })


class WorkflowStore(_JsonlStore):
    """JSONL store for a whole §5.3 workflow: many campaigns, one file.

    Line taxonomy:

    * ``{"type": "workflow-header", **workflow_fingerprint}`` — first line;
      binds the file to one ``run_workflow`` invocation (app, problem data,
      seed, test count, cache, fault model, selection parameters);
    * ``{"type": "campaign", "key": K, "fingerprint": {...}}`` — registers
      member campaign ``K`` (``"baseline"``, ``"best"``, ``"region:3"``)
      with its full campaign fingerprint.  A resumed workflow whose
      recomputed campaign fingerprint differs (e.g. the critical-object set
      changed because the code changed) refuses the store rather than mixing
      incompatible shard results;
    * ``{"type": "shard", "campaign": K, "shard": S, "records": [...]}`` —
      one completed shard of campaign ``K``.
    """

    def header(self) -> Optional[dict]:
        lines = self._read_lines()
        if lines and lines[0].get("type") == "workflow-header":
            return lines[0]
        return None

    def load_or_create(self, fingerprint: dict) -> None:
        """Validate the workflow header (write it if the store is new)."""
        existing = self.header()
        if existing is None:
            if self._read_lines():
                raise CampaignStoreError(
                    f"{self.path}: not a workflow store (no workflow-header)"
                )
            d = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(d, exist_ok=True)
            self._append({"type": "workflow-header", **fingerprint})
            return
        found = {k: existing.get(k) for k in fingerprint}
        if found != _json_roundtrip(dict(fingerprint)):
            raise CampaignStoreError(
                f"{self.path}: store belongs to a different workflow\n"
                f"  store:    {found}\n  workflow: {fingerprint}"
            )

    def campaign_fingerprints(self) -> Dict[str, dict]:
        out: Dict[str, dict] = {}
        for line in self._read_lines():
            if line.get("type") == "campaign":
                out[str(line["key"])] = dict(line["fingerprint"])
        return out

    def register_campaigns(
        self, fingerprints: Mapping[str, dict]
    ) -> Dict[str, Dict[int, List[Tuple[int, CrashRecord]]]]:
        """Bind every campaign in ``fingerprints`` to the store; return each
        campaign's completed shards (empty for fresh campaigns, raising on
        any fingerprint clash).

        One pass over the file for the whole batch: a resumed isolated-mode
        workflow registers W+2 campaigns against a store holding every crash
        record, so decoding the file once per *registration* would cost
        O(campaigns x store size) before any shard executes.
        """
        existing_fp: Dict[str, dict] = {}
        shards: Dict[str, Dict[int, List[Tuple[int, CrashRecord]]]] = {}
        for line in self._read_lines():
            t = line.get("type")
            if t == "campaign":
                existing_fp[str(line["key"])] = dict(line["fingerprint"])
            elif t == "shard":
                shards.setdefault(str(line["campaign"]), {})[int(line["shard"])] = [
                    (int(i), record_from_dict(r)) for i, r in line["records"]
                ]
        out: Dict[str, Dict[int, List[Tuple[int, CrashRecord]]]] = {}
        for key, fingerprint in fingerprints.items():
            existing = existing_fp.get(str(key))
            if existing is None:
                self._append({
                    "type": "campaign", "key": str(key),
                    "fingerprint": dict(fingerprint),
                })
                out[str(key)] = {}
            elif existing != _json_roundtrip(dict(fingerprint)):
                raise CampaignStoreError(
                    f"{self.path}: campaign {key!r} in store does not match "
                    f"the resumed workflow\n  store:    {existing}\n"
                    f"  campaign: {fingerprint}"
                )
            else:
                out[str(key)] = shards.get(str(key), {})
        return out

    def register_campaign(
        self, key: str, fingerprint: dict
    ) -> Dict[int, List[Tuple[int, CrashRecord]]]:
        """Single-campaign convenience wrapper over :meth:`register_campaigns`."""
        return self.register_campaigns({key: fingerprint})[str(key)]

    def completed_shards(self, key: str) -> Dict[int, List[Tuple[int, CrashRecord]]]:
        """shard_id -> [(original test index, record)] for campaign ``key``."""
        return self.completed_shards_by_campaign().get(key, {})

    def completed_shards_by_campaign(
        self,
    ) -> Dict[str, Dict[int, List[Tuple[int, CrashRecord]]]]:
        """campaign key -> {shard_id -> records}, in one pass over the file."""
        out: Dict[str, Dict[int, List[Tuple[int, CrashRecord]]]] = {}
        for line in self._read_lines():
            if line.get("type") != "shard":
                continue
            out.setdefault(str(line["campaign"]), {})[int(line["shard"])] = [
                (int(i), record_from_dict(r)) for i, r in line["records"]
            ]
        return out

    def append_shard(
        self, key: str, shard_id: int, records: List[Tuple[int, CrashRecord]]
    ) -> None:
        self._append({
            "type": "shard",
            "campaign": str(key),
            "shard": int(shard_id),
            "records": [(int(i), record_to_dict(r)) for i, r in records],
        })
