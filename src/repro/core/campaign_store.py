"""Append-only JSONL result store for crash campaigns (resume support).

A campaign writes one header line describing the campaign fingerprint (app,
plan, cache, seed, test count, engine version), then one line per completed
*shard* — all crash tests whose crash point falls in the same crash window.
Shards are the unit of work of the parallel engine and the unit of resume:
a campaign killed mid-run (fittingly, for this paper) restarts, replays the
store, and executes only the shards that never landed.

The file is only ever appended to, with a flush per shard, so the worst a
crash can leave behind is one torn trailing line — the loader tolerates
exactly that (and nothing else) by discarding undecodable trailing data.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
from typing import Dict, List, Mapping, Optional, Tuple

from .crash_tester import CrashRecord

#: bump when the shard record layout changes; mismatching stores are rejected
STORE_VERSION = 1


class CampaignStoreError(RuntimeError):
    """Raised when a store exists but belongs to a different campaign."""


def record_to_dict(record: CrashRecord) -> dict:
    return dataclasses.asdict(record)


def record_from_dict(d: Mapping[str, object]) -> CrashRecord:
    return CrashRecord(
        iter_idx=int(d["iter_idx"]),
        region_idx=int(d["region_idx"]),
        frac=float(d["frac"]),
        inconsistency={k: float(v) for k, v in dict(d["inconsistency"]).items()},
        outcome=str(d["outcome"]),
        extra_iters=int(d["extra_iters"]),
        verify_metric=float(d["verify_metric"]),
    )


class CampaignStore:
    """JSONL store bound to one file path.

    Typical use is through ``CrashTester.run_campaign(store_path=...)``; the
    class is public so benchmarks can inspect partial campaigns.
    """

    def __init__(self, path: str):
        self.path = path

    # ------------------------------------------------------------------ read
    def _read_lines(self) -> List[dict]:
        if not os.path.exists(self.path):
            return []
        out: List[dict] = []
        with io.open(self.path, "r", encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    # torn line from a crash mid-append: skip it — shard
                    # lines are self-contained, so the rest of the file is
                    # still usable (the torn shard just re-executes)
                    continue
        return out

    def header(self) -> Optional[dict]:
        lines = self._read_lines()
        if lines and lines[0].get("type") == "header":
            return lines[0]
        return None

    def completed_shards(self) -> Dict[int, List[Tuple[int, CrashRecord]]]:
        """shard_id -> [(original test index, record)], later lines win."""
        shards: Dict[int, List[Tuple[int, CrashRecord]]] = {}
        for line in self._read_lines():
            if line.get("type") != "shard":
                continue
            shards[int(line["shard"])] = [
                (int(i), record_from_dict(r)) for i, r in line["records"]
            ]
        return shards

    # ----------------------------------------------------------------- write
    def load_or_create(self, fingerprint: dict) -> Dict[int, List[Tuple[int, CrashRecord]]]:
        """Validate/initialise the store; return already-completed shards.

        * no file (or empty file): write the header, return ``{}``;
        * matching header: return the completed shards to skip;
        * mismatching header: raise :class:`CampaignStoreError` — a store is
          bound to exactly one campaign, silently mixing results would
          corrupt the resumed ``CampaignResult``.
        """
        existing = self.header()
        if existing is None:
            if self._read_lines():
                raise CampaignStoreError(
                    f"{self.path}: not a campaign store (no header line)"
                )
            d = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(d, exist_ok=True)
            self._append({"type": "header", **fingerprint})
            return {}
        found = {k: existing.get(k) for k in fingerprint}
        # legacy headers predate pluggable fault models; those campaigns ran
        # under the clean-power-fail semantics, so a missing "fault" key
        # means exactly that — old stores stay resumable with the default
        # model (and still refuse any other)
        if "fault" in fingerprint and found.get("fault") is None:
            found["fault"] = {"model": "power-fail"}
        # compare in JSON space: the header went through a JSON round-trip,
        # so the live fingerprint must too (tuples become lists, etc.)
        if found != json.loads(json.dumps(dict(fingerprint))):
            raise CampaignStoreError(
                f"{self.path}: store belongs to a different campaign\n"
                f"  store:    {found}\n  campaign: {fingerprint}"
            )
        return self.completed_shards()

    def append_shard(self, shard_id: int, records: List[Tuple[int, CrashRecord]]) -> None:
        self._append({
            "type": "shard",
            "shard": int(shard_id),
            "records": [(int(i), record_to_dict(r)) for i, r in records],
        })

    def _append(self, obj: dict) -> None:
        # a previous crash may have left a torn, unterminated line at EOF —
        # terminate it first so this append starts a fresh line
        needs_newline = False
        if os.path.exists(self.path) and os.path.getsize(self.path) > 0:
            with io.open(self.path, "rb") as rf:
                rf.seek(-1, os.SEEK_END)
                needs_newline = rf.read(1) != b"\n"
        with io.open(self.path, "a", encoding="utf-8") as f:
            if needs_newline:
                f.write("\n")
            f.write(json.dumps(obj) + "\n")
            f.flush()
            os.fsync(f.fileno())
