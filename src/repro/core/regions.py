"""Application abstraction: iterative apps as chains of code regions.

The paper models an HPC application as a main computation loop containing
first-level inner loops; a *code region* is one inner loop or the straight-
line code between two of them (§5.2).  Here an app declares its regions
explicitly: each region is a pure, jittable transition on the app state that
also declares which data objects it reads and writes (in sweep order), which
is what drives the NVCT cache model.

State is a flat ``dict[str, np.ndarray]``.  Heap/global data objects whose
lifetime is the main loop and which are not read-only are the *candidates*
for critical-object selection (§5.1); everything else is rebuilt by
``restart_init`` on recovery.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

State = Dict[str, np.ndarray]


@dataclass(frozen=True)
class Region:
    """One code region of the main loop."""

    name: str
    fn: Callable[[State], State]
    writes: Tuple[str, ...]              # objects written, in sweep order
    reads: Tuple[str, ...] = ()
    cost: float = 1.0                    # relative execution-time weight (a_k)
    loop: bool = True                    # has loop structure (flush freq x applies)
    hot_reads: Tuple[str, ...] = ()      # small objects re-read continuously


@dataclass(frozen=True)
class VerifyResult:
    passed: bool
    metric: float
    detail: str = ""


class IterativeApp:
    """Base class for region-structured iterative applications."""

    name: str = "app"
    n_iters: int = 10
    #: candidates of critical data objects (non-read-only, main-loop lifetime)
    candidates: Tuple[str, ...] = ()
    #: the loop iterator object; always persisted at iteration end (paper
    #: footnote 3: "we always persist a loop iterator to bookmark where the
    #: crash happens ... almost zero impact on performance")
    iterator_object: Optional[str] = "k"
    #: per-app fault-model parameter overrides for crash campaigns:
    #: ``{model_name: {param: value}}``, consumed by
    #: :func:`repro.core.faults.get_fault_model` (and the benchmark fault
    #: sweep).  Apps whose structure makes a failure mode unusually punishing
    #: (or trivial) tune the model here instead of at every call site.
    fault_defaults: Mapping[str, Mapping[str, object]] = {}

    def regions(self) -> Tuple[Region, ...]:
        raise NotImplementedError

    def init(self, seed: int = 0) -> State:
        raise NotImplementedError

    def restart_init(self, seed: int, persisted: Mapping[str, np.ndarray]) -> State:
        """Rebuild a runnable state from the (possibly inconsistent) NVM image.

        Default: re-run ``init`` (restores temporaries / read-only objects)
        then overwrite candidates with their persisted images.
        """
        state = self.init(seed)
        for k, v in persisted.items():
            if k in state:
                state[k] = np.array(v, copy=True).astype(state[k].dtype, copy=False)
        return state

    def verify(self, state: State) -> VerifyResult:
        """Application-specific acceptance verification."""
        raise NotImplementedError

    def progress(self, state: State) -> float:
        """Convergence metric (residual / loss); used for early-stop checks."""
        return float("nan")

    # ------------------------------------------------------------------ runner
    def run_iteration(self, state: State) -> State:
        for region in self.regions():
            state = region.fn(state)
        return state

    def run_region(self, state: State, region_idx: int) -> State:
        return self.regions()[region_idx].fn(state)

    def run_to_completion(self, state: State, first_iter: int, max_iters: int) -> Tuple[State, int]:
        """Run the main loop from ``first_iter`` for up to ``max_iters`` total
        iterations (counted across the whole execution).  Returns final state
        and the number of iterations executed in this call."""
        executed = 0
        it = first_iter
        while it < max_iters:
            state = self.run_iteration(state)
            it += 1
            executed += 1
            if self.converged(state, it):
                break
        return state, executed

    def converged(self, state: State, it: int) -> bool:
        """Early termination hook: by default run the fixed iteration count."""
        return it >= self.n_iters

    def run_golden(self, seed: int = 0) -> Tuple[State, int]:
        state = self.init(seed)
        state, executed = self.run_to_completion(state, 0, self.n_iters)
        return state, executed


def object_blocks(state: State, names: Sequence[str], block_bytes: int) -> Dict[str, int]:
    out = {}
    for n in names:
        arr = np.asarray(state[n])
        out[n] = max(1, -(-arr.nbytes // block_bytes))
    return out
