"""Application abstraction: iterative apps as chains of code regions.

The paper models an HPC application as a main computation loop containing
first-level inner loops; a *code region* is one inner loop or the straight-
line code between two of them (§5.2).  Here an app declares its regions
explicitly: each region is a pure, jittable transition on the app state that
also declares which data objects it reads and writes (in sweep order), which
is what drives the NVCT cache model.

State is a flat ``dict[str, np.ndarray]``.  Heap/global data objects whose
lifetime is the main loop and which are not read-only are the *candidates*
for critical-object selection (§5.1); everything else is rebuilt by
``restart_init`` on recovery.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

State = Dict[str, np.ndarray]


@dataclass(frozen=True)
class Region:
    """One code region of the main loop."""

    name: str
    fn: Callable[[State], State]
    writes: Tuple[str, ...]              # objects written, in sweep order
    reads: Tuple[str, ...] = ()
    cost: float = 1.0                    # relative execution-time weight (a_k)
    loop: bool = True                    # has loop structure (flush freq x applies)
    hot_reads: Tuple[str, ...] = ()      # small objects re-read continuously


@dataclass(frozen=True)
class VerifyResult:
    passed: bool
    metric: float
    detail: str = ""

    def spec(self) -> Dict[str, object]:
        """JSON-round-trip-safe identity (fingerprint input)."""
        m = float(self.metric)
        return {
            "passed": bool(self.passed),
            "metric": m if m == m and abs(m) != float("inf") else None,
            "detail": str(self.detail),
        }


@dataclass(frozen=True)
class BatchedKernel:
    """One batched-lane kernel of a ``supports_batched_step`` app, exposed
    for the bitwise-batchability lint (:mod:`repro.analysis.determinism_lint`).

    ``fn(*args)`` must be traceable by :func:`jax.make_jaxpr`; ``batched``
    maps argument positions to the lane axis (axis 0 by convention).  Static
    configuration (grid size, loop counts) is closed over, not passed.
    """

    name: str
    fn: Callable
    args: Tuple
    batched: Mapping[int, int]


class IterativeApp:
    """Base class for region-structured iterative applications."""

    name: str = "app"
    n_iters: int = 10
    #: candidates of critical data objects (non-read-only, main-loop lifetime)
    candidates: Tuple[str, ...] = ()
    #: the loop iterator object; always persisted at iteration end (paper
    #: footnote 3: "we always persist a loop iterator to bookmark where the
    #: crash happens ... almost zero impact on performance")
    iterator_object: Optional[str] = "k"
    #: per-app fault-model parameter overrides for crash campaigns:
    #: ``{model_name: {param: value}}``, consumed by
    #: :func:`repro.core.faults.get_fault_model` (and the benchmark fault
    #: sweep).  Apps whose structure makes a failure mode unusually punishing
    #: (or trivial) tune the model here instead of at every call site.
    fault_defaults: Mapping[str, Mapping[str, object]] = {}
    #: opt-in for the vectorized campaign engine: the crash tester may stack
    #: this app's restart lanes and advance them through the ``*_batch``
    #: hooks below.  An app must only set this when its batched hooks are
    #: **bitwise identical** per lane to the serial ones (vmapped elementwise
    #: jax ops are; batched matmuls generally are not — use ``lax.map``).
    supports_batched_step: bool = False
    #: opt-in for the jit-resident lane driver: the crash tester may hand the
    #: whole phase-A run-to-completion loop to :meth:`advance_lanes` (one
    #: jitted ``lax.while_loop`` dispatch per lane bucket instead of one
    #: ``run_iteration_batch`` dispatch per iteration).  Same contract as
    #: ``supports_batched_step``, strengthened: the *convergence decision*
    #: must also be bit-exact in-jit, or the lane must come back flagged
    #: (``ok=False``) for serial reclassification.
    supports_lane_driver: bool = False

    def regions(self) -> Tuple[Region, ...]:
        raise NotImplementedError

    def init(self, seed: int = 0) -> State:
        raise NotImplementedError

    # --------------------------------------------------- static-analysis hooks
    def static_hints(self) -> Mapping[str, str]:
        """Algorithm knowledge the dataflow walker cannot derive, as
        ``{object: hint}``.  Recognized hints: ``"exact-accumulator"`` — the
        object is an exact (bitwise-verified) accumulation, so re-executing a
        crashed iteration double-counts and the object is crash-critical
        regardless of any contraction argument."""
        return {}

    def batched_kernels(self) -> Tuple["BatchedKernel", ...]:
        """The jax kernels behind ``run_iteration_batch``, for the
        bitwise-batchability lint.  Apps setting ``supports_batched_step``
        should expose every batched dispatch here; the lint (and CI) walks
        each kernel's jaxpr for cross-lane reductions."""
        return ()

    def restart_init(self, seed: int, persisted: Mapping[str, np.ndarray]) -> State:
        """Rebuild a runnable state from the (possibly inconsistent) NVM image.

        Default: re-run ``init`` (restores temporaries / read-only objects)
        then overwrite candidates with their persisted images.
        """
        state = self.init(seed)
        for k, v in persisted.items():
            if k in state:
                state[k] = np.array(v, copy=True).astype(state[k].dtype, copy=False)
        return state

    def verify(self, state: State) -> VerifyResult:
        """Application-specific acceptance verification."""
        raise NotImplementedError

    def progress(self, state: State) -> float:
        """Convergence metric (residual / loss); used for early-stop checks."""
        return float("nan")

    # ------------------------------------------------------------------ runner
    def run_iteration(self, state: State) -> State:
        for region in self.regions():
            state = region.fn(state)
        return state

    def run_region(self, state: State, region_idx: int) -> State:
        return self.regions()[region_idx].fn(state)

    def run_to_completion(self, state: State, first_iter: int, max_iters: int) -> Tuple[State, int]:
        """Run the main loop from ``first_iter`` for up to ``max_iters`` total
        iterations (counted across the whole execution).  Returns final state
        and the number of iterations executed in this call."""
        executed = 0
        it = first_iter
        while it < max_iters:
            state = self.run_iteration(state)
            it += 1
            executed += 1
            if self.converged(state, it):
                break
        return state, executed

    def converged(self, state: State, it: int) -> bool:
        """Early termination hook: by default run the fixed iteration count."""
        return it >= self.n_iters

    # ------------------------------------------------------- batched recompute
    # The vectorized campaign engine advances many independent restart lanes
    # at once.  The default implementations loop the serial hooks (always
    # correct); apps that set ``supports_batched_step`` override them with
    # stacked array ops so a whole lane batch costs one dispatch.  Contract
    # for every override: lane i's result is bitwise identical to the serial
    # hook on lane i alone, and exceptions are captured per lane (a blown-up
    # lane classifies as S3 without tearing down its batch-mates).

    def run_iteration_batch(self, states: Sequence[State]) -> "List[State]":
        """Advance each state one main-loop iteration; pure per lane."""
        return [self.run_iteration(s) for s in states]

    def advance_lanes(
        self, states: Sequence[State], its: Sequence[int], stop: int
    ) -> Tuple["List[State]", "List[int]", "List[bool]"]:
        """Jit-resident phase A: run every lane's run-to-completion loop
        (``run_to_completion(state, it, stop)`` — step, increment, break on
        ``converged`` or ``it >= stop``) in as few device dispatches as the
        app can manage, typically one donated-buffer ``lax.while_loop`` via
        :class:`repro.core.lane_driver.JitLaneDriver`.

        Returns ``(states, its, oks)``.  ``oks[i]`` false means the driver
        could not decide lane ``i`` bit-exactly (blow-up, overflow screen);
        the lane comes back **unmodified** and the caller reclassifies it
        through the serial path.  Only consulted when
        ``supports_lane_driver`` is set.
        """
        raise NotImplementedError

    def converged_batch(self, states: Sequence[State], its: Sequence[int]) -> "List[object]":
        """Element i is ``converged(states[i], its[i])`` — a bool, or the
        exception instance the serial hook would have raised (blow-ups)."""
        out: "List[object]" = []
        for s, it in zip(states, its):
            try:
                out.append(bool(self.converged(s, it)))
            except Exception as e:  # noqa: BLE001 - captured per lane
                out.append(e)
        return out

    def verify_batch(self, states: Sequence[State]) -> "List[object]":
        """Element i is ``verify(states[i])`` — a :class:`VerifyResult`, or
        the exception instance the serial hook would have raised."""
        out: "List[object]" = []
        for s in states:
            try:
                out.append(self.verify(s))
            except Exception as e:  # noqa: BLE001 - captured per lane
                out.append(e)
        return out

    def run_golden(self, seed: int = 0) -> Tuple[State, int]:
        state = self.init(seed)
        state, executed = self.run_to_completion(state, 0, self.n_iters)
        return state, executed


def object_blocks(state: State, names: Sequence[str], block_bytes: int) -> Dict[str, int]:
    out = {}
    for n in names:
        arr = np.asarray(state[n])
        out[n] = max(1, -(-arr.nbytes // block_bytes))
    return out
