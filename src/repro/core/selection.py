"""Critical data-object and code-region selection (paper §5).

Data objects: Spearman rank correlation between per-object data-inconsistency
rate and recompute success across a crash campaign.  An object is *critical*
iff R_s < 0 (more inconsistency => less recomputable) and p < 0.01.

Code regions: a multiple-choice 0/1 knapsack.  For each region k and flush
frequency x, the item has weight l_k / x (persistence overhead) and value
a_k * (c_k^x - c_k), with the Eq. 5 interpolation
``c_k^x = (c_k^max - c_k)/x + c_k``.  The DP maximises recomputability gain
under the runtime budget t_s, and the result is checked against the system
efficiency threshold tau (Eq. 4).

No scipy on the box: Spearman's p-value uses the exact t-distribution via a
regularised-incomplete-beta continued fraction (Numerical Recipes 6.4).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

# --------------------------------------------------------------------- stats

def _rankdata(x: np.ndarray) -> np.ndarray:
    """Average ranks (ties share the mean rank), 1-based."""
    x = np.asarray(x, dtype=float)
    order = np.argsort(x, kind="stable")
    ranks = np.empty(x.size, dtype=float)
    sx = x[order]
    i = 0
    while i < x.size:
        j = i
        while j + 1 < x.size and sx[j + 1] == sx[i]:
            j += 1
        ranks[order[i : j + 1]] = 0.5 * (i + j) + 1.0
        i = j + 1
    return ranks


def _betacf(a: float, b: float, x: float) -> float:
    """Continued fraction for the incomplete beta function."""
    MAXIT, EPS, FPMIN = 200, 3e-14, 1e-300
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c = 1.0
    d = 1.0 - qab * x / qap
    if abs(d) < FPMIN:
        d = FPMIN
    d = 1.0 / d
    h = d
    for m in range(1, MAXIT + 1):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        if abs(d) < FPMIN:
            d = FPMIN
        c = 1.0 + aa / c
        if abs(c) < FPMIN:
            c = FPMIN
        d = 1.0 / d
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        if abs(d) < FPMIN:
            d = FPMIN
        c = 1.0 + aa / c
        if abs(c) < FPMIN:
            c = FPMIN
        d = 1.0 / d
        de = d * c
        h *= de
        if abs(de - 1.0) < EPS:
            break
    return h


def _betainc_reg(a: float, b: float, x: float) -> float:
    """Regularised incomplete beta I_x(a, b)."""
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    ln_bt = (
        math.lgamma(a + b) - math.lgamma(a) - math.lgamma(b)
        + a * math.log(x) + b * math.log1p(-x)
    )
    bt = math.exp(ln_bt)
    if x < (a + 1.0) / (a + b + 2.0):
        return bt * _betacf(a, b, x) / a
    return 1.0 - bt * _betacf(b, a, 1.0 - x) / b


def t_sf(t: float, df: float) -> float:
    """Student-t survival function P(T > t)."""
    x = df / (df + t * t)
    p = 0.5 * _betainc_reg(df / 2.0, 0.5, x)
    return p if t >= 0 else 1.0 - p


def spearman(x: Sequence[float], y: Sequence[float]) -> Tuple[float, float]:
    """Spearman's rank correlation R_s and two-sided p-value.

    Returns (nan, 1.0) for degenerate inputs (constant vectors / n < 4).
    """
    x = np.asarray(x, dtype=float)
    y = np.asarray(y, dtype=float)
    n = x.size
    if n != y.size:
        raise ValueError("length mismatch")
    if n < 4 or np.all(x == x[0]) or np.all(y == y[0]):
        return float("nan"), 1.0
    rx, ry = _rankdata(x), _rankdata(y)
    rx = rx - rx.mean()
    ry = ry - ry.mean()
    denom = math.sqrt(float(rx @ rx) * float(ry @ ry))
    if denom == 0.0:
        return float("nan"), 1.0
    rs = float(rx @ ry) / denom
    rs = max(-1.0, min(1.0, rs))
    if abs(rs) >= 1.0:
        return rs, 0.0
    t = rs * math.sqrt((n - 2) / (1.0 - rs * rs))
    p = 2.0 * t_sf(abs(t), n - 2)
    return rs, min(1.0, p)


# ---------------------------------------------------------- object selection
# Result/value dataclasses in core/ are frozen: several (CacheConfig,
# PersistPlan) appear as shared default parameter values, and the rest are
# outputs whose silent in-place mutation would desynchronise stores,
# fingerprints and artifacts.  Mutable-by-design counters (WriteStats,
# ManagerStats) stay unfrozen.
@dataclass(frozen=True)
class ObjectScore:
    name: str
    rs: float
    p_value: float
    critical: bool


def select_objects(
    campaign,
    candidates: Sequence[str],
    p_threshold: float = 0.01,
) -> List[ObjectScore]:
    """Paper §5.1: critical objects have R_s < 0 with p below threshold."""
    scores = []
    for obj in candidates:
        x, y = campaign.vectors_for_selection(obj)
        rs, p = spearman(x, y)
        critical = (not math.isnan(rs)) and rs < 0.0 and p < p_threshold
        scores.append(ObjectScore(obj, rs, p, critical))
    return scores


def critical_objects(scores: Sequence[ObjectScore]) -> Tuple[str, ...]:
    return tuple(s.name for s in scores if s.critical)


# ---------------------------------------------------------- region selection
@dataclass(frozen=True)
class RegionChoice:
    region_idx: int
    freq: int            # flush every `freq` iterations
    gain: float          # a_k * (c_k^x - c_k)
    overhead: float      # l_k / freq


@dataclass(frozen=True)
class RegionSelection:
    choices: List[RegionChoice]
    expected_recomputability: float   # Y' of Eq. 2
    total_overhead: float
    meets_tau: bool

    def plan_freqs(self) -> Dict[int, int]:
        return {c.region_idx: c.freq for c in self.choices}


def interpolate_ckx(c_max: float, c_base: float, x: int) -> float:
    """Eq. 5 linear interpolation between every-iteration and never."""
    return (c_max - c_base) / x + c_base


def select_regions_from_gains(
    gains: Mapping[int, float],
    overheads: Mapping[int, float],
    y_base: float,
    t_s: float,
    tau: float,
    freq_options: Sequence[int] = (1, 2, 4, 8),
    resolution: int = 2000,
) -> RegionSelection:
    """Multiple-choice knapsack core.

    ``gains[k]``: recomputability gain of flushing at region k every
    iteration (x = 1); frequency x scales the gain by 1/x (Eq. 5) and the
    overhead ``overheads[k]`` by 1/x.  Budget t_s; target tau (Eq. 3/4).
    """
    region_ids = sorted(gains.keys())
    W = len(region_ids)
    scale = resolution / max(t_s, 1e-12)

    def wt(ov: float) -> int:
        return int(math.ceil(ov * scale - 1e-9))

    NEG = -1.0
    dp = [0.0] + [NEG] * resolution
    choice: List[List[Optional[Tuple[int, int]]]] = [
        [None] * (resolution + 1) for _ in range(W)
    ]
    for ki, k in enumerate(region_ids):
        new_dp = dp[:]  # "skip region k" keeps previous
        for x in freq_options:
            gain = gains[k] / x
            if gain <= 0:
                continue
            w = wt(overheads[k] / x)
            if w > resolution:
                continue
            for j in range(resolution, w - 1, -1):
                if dp[j - w] >= 0.0 and dp[j - w] + gain > new_dp[j]:
                    new_dp[j] = dp[j - w] + gain
                    choice[ki][j] = (x, j - w)
        dp = new_dp

    j_best = max(range(resolution + 1), key=lambda j: dp[j])
    choices: List[RegionChoice] = []
    j = j_best
    for ki in range(W - 1, -1, -1):
        ch = choice[ki][j]
        if ch is not None:
            x, j_prev = ch
            k = region_ids[ki]
            choices.append(RegionChoice(k, x, gains[k] / x, overheads[k] / x))
            j = j_prev
    choices.reverse()

    y_prime = y_base + sum(c.gain for c in choices)
    total_overhead = sum(c.overhead for c in choices)
    return RegionSelection(
        choices=choices,
        expected_recomputability=y_prime,
        total_overhead=total_overhead,
        meets_tau=y_prime > tau,
    )


def select_regions(
    a: Sequence[float],
    c_base: Sequence[float],
    c_max: Sequence[float],
    l: Sequence[float],
    t_s: float,
    tau: float,
    freq_options: Sequence[int] = (1, 2, 4, 8),
    resolution: int = 2000,
) -> RegionSelection:
    """Paper-faithful wrapper: per-region gains a_k * (c_k^max - c_k) from a
    single persist-everywhere campaign (§5.2's shortcut)."""
    W = len(a)
    if not (len(c_base) == len(c_max) == len(l) == W):
        raise ValueError("length mismatch")
    gains = {k: a[k] * (c_max[k] - c_base[k]) for k in range(W)}
    overheads = {k: l[k] for k in range(W)}
    y_base = float(sum(ak * ck for ak, ck in zip(a, c_base)))
    return select_regions_from_gains(
        gains, overheads, y_base, t_s, tau, freq_options, resolution
    )
