"""Durable file-write primitives shared by every persistence layer.

One protocol, three users (:mod:`~repro.core.arena` backing files,
:mod:`~repro.core.campaign_store` JSONL stores,
:mod:`~repro.core.artifacts` plan artifacts): write the new content to a
temp file, flush+fsync the *data*, atomically rename over the target, then
fsync the *directory* so the rename itself survives power loss.  A rename
without the two fsyncs is only atomic against process crashes: the journal
may commit the rename before the data blocks land, leaving an empty or torn
file behind — unacceptable in a repo whose premise is NVM durability.
"""
from __future__ import annotations

import os


def fsync_dir(path: str) -> None:
    """Persist a directory entry (create/rename durability)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def durable_replace(tmp: str, path: str) -> None:
    """``os.replace(tmp, path)`` whose rename survives power loss.

    The caller must already have flushed+fsynced ``tmp``'s contents.
    """
    os.replace(tmp, path)
    fsync_dir(os.path.dirname(os.path.abspath(path)))
