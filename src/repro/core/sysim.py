"""Failure-trace system-efficiency simulator (paper §7, measured end-to-end).

The analytic model in :mod:`repro.core.efficiency` answers "what does
EasyCrash buy a running system?" with a first-order closed form and an
*assumed* recomputability.  This module answers it by *playing the tape*: a
seeded discrete-event simulation of a month- (or decade-) scale execution
under a failure trace, for four protection policies:

* ``"none"``        — no protection: a crash restarts the run from scratch;
* ``"checkpoint"``  — coordinated C/R at the Young/Daly interval
  (:func:`~repro.core.efficiency.young_interval`), crashes roll back to the
  last complete checkpoint;
* ``"easycrash"``   — EasyCrash only: a crash first attempts recomputation
  from the NVM image; if recomputation fails there is nothing to fall back
  to and the run restarts from scratch;
* ``"hybrid"``      — EasyCrash in front of C/R (the paper's deployment):
  recompute from NVM when the crash-campaign-measured outcome says so, fall
  back to the checkpoint otherwise.  The checkpoint interval stretches to
  ``young(T_chk, MTBF / (1 - success))`` because only non-recomputable
  crashes force rollbacks.

What makes this a *reproduction* rather than another Daly calculator is the
input: recovery success is drawn from the S1–S4 outcome fractions a real
crash campaign measured (:class:`RecomputeProfile`), and the cost of an
S2 recovery is drawn from the campaign's measured extra-recompute-iteration
histogram — the simulator consumes exactly what
:meth:`~repro.core.crash_tester.CrashTester.run_campaign` produces.

Failure interarrivals come from a :class:`FailureTrace` — exponential
(:class:`PoissonTrace`) or Weibull (:class:`WeibullTrace`, the standard HPC
failure-log fit with shape < 1 for infant mortality); traces scale to larger
machines via :func:`scaled_trace` (the paper's 100k -> 400k node scaling).
Failures keep arriving during recovery: a crash that strikes mid-restore
restarts the recovery (with a fresh outcome draw for the NVM policies).

Everything is seeded and single-threaded: the same
``(policy, system, trace, profile, seed)`` tuple reproduces the same
:class:`SimResult` bit for bit, regardless of environment.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

from .efficiency import SystemConfig, young_interval

OUTCOMES = ("S1", "S2", "S3", "S4")
POLICIES = ("none", "checkpoint", "easycrash", "hybrid")

SECONDS_PER_DAY = 24 * 3600.0
MONTH = 30 * SECONDS_PER_DAY


# ------------------------------------------------------------ failure traces
class FailureTrace:
    """A seeded stream of failure interarrival times (seconds)."""

    mtbf: float  # mean interarrival, seconds

    def interarrival(self, rng: np.random.Generator) -> float:
        raise NotImplementedError

    def spec(self) -> Dict[str, object]:
        """JSON-round-trip-safe identity (for artifacts and frontier files)."""
        raise NotImplementedError


@dataclass(frozen=True)
class PoissonTrace(FailureTrace):
    """Exponential interarrivals — the analytic model's assumption."""

    mtbf: float

    def interarrival(self, rng: np.random.Generator) -> float:
        return float(rng.exponential(self.mtbf))

    def spec(self) -> Dict[str, object]:
        return {"trace": "poisson", "mtbf": float(self.mtbf)}


@dataclass(frozen=True)
class WeibullTrace(FailureTrace):
    """Weibull interarrivals with mean ``mtbf``.

    ``shape < 1`` reproduces the burstiness of real HPC failure logs (many
    short gaps, a heavy tail of long ones); ``shape = 1`` degenerates to
    :class:`PoissonTrace`.  The scale is derived so the mean stays ``mtbf``:
    ``scale = mtbf / gamma(1 + 1/shape)``.
    """

    mtbf: float
    shape: float = 0.7

    @property
    def scale(self) -> float:
        return self.mtbf / math.gamma(1.0 + 1.0 / self.shape)

    def interarrival(self, rng: np.random.Generator) -> float:
        return float(self.scale * rng.weibull(self.shape))

    def spec(self) -> Dict[str, object]:
        return {"trace": "weibull", "mtbf": float(self.mtbf), "shape": float(self.shape)}


def scaled_trace(trace: FailureTrace, base_nodes: int, nodes: int) -> FailureTrace:
    """The trace of a ``nodes``-node machine, given one measured at
    ``base_nodes`` (MTBF scales inversely with node count)."""
    from .efficiency import scale_mtbf

    return dataclasses.replace(trace, mtbf=scale_mtbf(trace.mtbf, base_nodes, nodes))


def trace_from_spec(spec: Mapping[str, object]) -> FailureTrace:
    """Rehydrate a :class:`FailureTrace` from its :meth:`~FailureTrace.spec`
    (the inverse used when frontier/fleet artifacts are read back)."""
    kind = spec.get("trace")
    if kind == "poisson":
        return PoissonTrace(mtbf=float(spec["mtbf"]))
    if kind == "weibull":
        return WeibullTrace(mtbf=float(spec["mtbf"]),
                            shape=float(spec.get("shape", 0.7)))
    raise ValueError(f"unknown trace spec {dict(spec)!r}")


# --------------------------------------------------------- recompute profile
@dataclass(frozen=True)
class RecomputeProfile:
    """Campaign-measured recovery behaviour of one (app, fault model) pair.

    ``fractions`` are the S1–S4 outcome fractions of a crash campaign
    (S1: recompute succeeds outright; S2: succeeds after extra iterations;
    S3/S4: recompute fails — interruption or budget exhaustion).
    ``extra_iters_hist`` is the measured histogram of extra recompute
    iterations over the campaign's S2 records, as sorted
    ``(extra_iters, count)`` pairs; the simulator draws S2 recompute costs
    from it.  ``golden_iters`` and ``n_records`` carry the measurement's
    provenance (how long the app runs, how many crash tests back the rates).
    """

    app_name: str
    fault_spec: Mapping[str, object]
    fractions: Mapping[str, float]
    extra_iters_hist: Tuple[Tuple[int, int], ...] = ()
    golden_iters: int = 0
    n_records: int = 0

    def __post_init__(self):
        unknown = set(self.fractions) - set(OUTCOMES)
        if unknown:
            raise ValueError(f"unknown outcome classes {sorted(unknown)}")
        total = sum(float(self.fractions.get(c, 0.0)) for c in OUTCOMES)
        if not math.isclose(total, 1.0, abs_tol=1e-6):
            raise ValueError(f"outcome fractions sum to {total}, expected 1")
        if any(float(v) < 0.0 for v in self.fractions.values()):
            raise ValueError("outcome fractions must be non-negative")

    # ------------------------------------------------------------- measures
    @property
    def recomputability(self) -> float:
        """The paper's R: fraction of crashes recomputed with no extra work."""
        return float(self.fractions.get("S1", 0.0))

    @property
    def success_rate(self) -> float:
        """Fraction of crashes the NVM image recovers at all (S1 + S2)."""
        return float(self.fractions.get("S1", 0.0)) + float(self.fractions.get("S2", 0.0))

    def mean_extra_iters(self) -> float:
        """Mean extra recompute iterations over the S2 histogram (0 if empty)."""
        total = sum(c for _, c in self.extra_iters_hist)
        if not total:
            return 0.0
        return sum(i * c for i, c in self.extra_iters_hist) / total

    # ---------------------------------------------------------------- draws
    def draw_outcome(self, rng: np.random.Generator) -> str:
        u = float(rng.random())
        acc = 0.0
        for c in OUTCOMES:
            acc += float(self.fractions.get(c, 0.0))
            if u < acc:
                return c
        return "S4"

    def draw_extra_iters(self, rng: np.random.Generator) -> int:
        if not self.extra_iters_hist:
            return 0
        total = sum(c for _, c in self.extra_iters_hist)
        u = float(rng.random()) * total
        acc = 0
        for iters, count in self.extra_iters_hist:
            acc += count
            if u < acc:
                return int(iters)
        return int(self.extra_iters_hist[-1][0])

    # --------------------------------------------------------- construction
    @staticmethod
    def from_campaign(campaign, fault=None) -> "RecomputeProfile":
        """Measure a profile from a finished
        :class:`~repro.core.crash_tester.CampaignResult`.

        ``fault`` is the :class:`~repro.core.faults.FaultModel` the campaign
        ran under (``None`` = the default clean power failure): campaign
        results do not carry their fault model, but a profile must — rates
        measured under torn writes are not rates under power failures.
        """
        if fault is None:
            from .faults import PowerFail

            fault = PowerFail()
        hist: Dict[int, int] = {}
        for r in campaign.records:
            if r.outcome == "S2":
                hist[int(r.extra_iters)] = hist.get(int(r.extra_iters), 0) + 1
        return RecomputeProfile(
            app_name=campaign.app_name,
            fault_spec=dict(fault.spec()),
            fractions=campaign.class_fractions(),
            extra_iters_hist=tuple(sorted(hist.items())),
            golden_iters=int(campaign.golden_iters),
            n_records=int(campaign.n),
        )

    @staticmethod
    def from_fractions(
        app_name: str,
        fractions: Mapping[str, float],
        fault_spec: Optional[Mapping[str, object]] = None,
        extra_iters_hist: Sequence[Tuple[int, int]] = (),
        golden_iters: int = 0,
        n_records: int = 0,
    ) -> "RecomputeProfile":
        """A synthetic profile (parity tests, smoke runs, what-if sweeps)."""
        full = {c: float(fractions.get(c, 0.0)) for c in OUTCOMES}
        return RecomputeProfile(
            app_name=app_name,
            fault_spec=dict(fault_spec or {"model": "synthetic"}),
            fractions=full,
            extra_iters_hist=tuple((int(i), int(c)) for i, c in extra_iters_hist),
            golden_iters=int(golden_iters),
            n_records=int(n_records),
        )


# --------------------------------------------------------------- sim result
@dataclass(frozen=True)
class SimResult:
    policy: str
    efficiency: float          # useful computation / total wall time
    useful_time: float
    total_time: float
    interval: float            # checkpoint interval used (0 for none/easycrash)
    n_failures: int
    n_checkpoints: int
    n_nvm_recoveries: int      # crashes recovered from the NVM image (S1/S2)
    n_fallbacks: int           # crashes rolled back to a checkpoint
    n_restarts: int            # crashes that restarted the run from scratch
    lost_work: float           # work wiped by rollbacks/restarts
    breakdown: Dict[str, float]  # wall time per phase bucket

    def spec(self) -> Dict[str, object]:
        """Strict-JSON dict of the full result (sorted breakdown)."""
        return {
            "policy": self.policy,
            "efficiency": float(self.efficiency),
            "useful_time": float(self.useful_time),
            "total_time": float(self.total_time),
            "interval": float(self.interval),
            "n_failures": int(self.n_failures),
            "n_checkpoints": int(self.n_checkpoints),
            "n_nvm_recoveries": int(self.n_nvm_recoveries),
            "n_fallbacks": int(self.n_fallbacks),
            "n_restarts": int(self.n_restarts),
            "lost_work": float(self.lost_work),
            "breakdown": {k: float(v) for k, v in sorted(self.breakdown.items())},
        }


class _Clock:
    """Wall clock + failure stream.  Advancing through a phase either
    completes it or stops at the next failure; the simulation ends the
    instant the failure budget or the horizon is reached (a budget-boundary
    failure is counted but not processed — at 10k events the truncation is
    far below the parity tolerance)."""

    def __init__(self, trace: FailureTrace, rng: np.random.Generator,
                 n_failures: Optional[int], horizon: Optional[float]):
        self.trace = trace
        self.rng = rng
        self.limit = n_failures  # None: horizon-only run, no failure budget
        self.horizon = horizon
        self.now = 0.0
        self.failures = 0
        self.next_fail = trace.interarrival(rng)
        self.done = False
        self.buckets: Dict[str, float] = {}

    def advance(self, duration: float, bucket: str) -> Tuple[float, bool]:
        """Advance up to ``duration`` seconds of ``bucket`` time.

        Returns ``(elapsed, failed)``; checks :attr:`done` after every call.
        """
        end = self.now + duration
        cut, event = end, None
        if self.next_fail < cut:
            cut, event = self.next_fail, "fail"
        if self.horizon is not None and self.horizon <= cut:
            cut, event = self.horizon, "horizon"
        elapsed = cut - self.now
        self.buckets[bucket] = self.buckets.get(bucket, 0.0) + elapsed
        self.now = cut
        if event == "horizon":
            self.done = True
            return elapsed, False
        if event == "fail":
            self.failures += 1
            if self.limit is not None and self.failures >= self.limit:
                self.done = True
            else:
                self.next_fail = self.now + self.trace.interarrival(self.rng)
            return elapsed, True
        return elapsed, False


class _SimState:
    """Mutable per-run counters (the frozen :class:`SimResult` is built from
    these at the end)."""

    def __init__(self):
        self.since_ckpt = 0.0   # live work not yet retained by a checkpoint
        self.committed = 0.0    # work safely behind a complete checkpoint
        self.lost = 0.0
        self.n_checkpoints = 0
        self.n_nvm = 0
        self.n_fallbacks = 0
        self.n_restarts = 0


def default_interval(policy: str, system: SystemConfig, trace: FailureTrace,
                     profile: Optional[RecomputeProfile] = None) -> float:
    """The policy's Young/Daly checkpoint interval.

    ``"hybrid"`` stretches the MTBF by ``1 / (1 - success_rate)``: only
    crashes the NVM image cannot recover force a rollback, so the effective
    failure rate the checkpoint scheme must absorb is that much lower.
    """
    if policy == "checkpoint":
        return young_interval(system.t_chk, trace.mtbf)
    if policy == "hybrid":
        if profile is None:
            raise ValueError("hybrid interval needs a RecomputeProfile")
        s = min(profile.success_rate, 0.999999)
        return young_interval(system.t_chk, trace.mtbf / (1.0 - s))
    return 0.0


def _handle_failure(policy: str, clock: _Clock, state: _SimState,
                    system: SystemConfig, profile: Optional[RecomputeProfile],
                    rng: np.random.Generator, t_iter: float) -> None:
    """Process one failure, and any failures that strike during its own
    recovery (each re-enters as a fresh failure with a fresh outcome draw)."""
    pending = True
    while pending and not clock.done:
        pending = False
        if policy == "checkpoint":
            state.n_fallbacks += 1
            state.lost += state.since_ckpt
            state.since_ckpt = 0.0
            phases = [(system.t_r, "restore"), (system.t_sync, "sync")]
        elif policy == "none":
            state.n_restarts += 1
            state.lost += state.since_ckpt
            state.since_ckpt = 0.0
            phases = [(system.t_sync, "sync")]
        else:  # easycrash / hybrid: try the NVM image first
            outcome = profile.draw_outcome(rng)
            if outcome in ("S1", "S2"):
                state.n_nvm += 1
                phases = [(system.nvm_restore_time, "nvm_restore")]
                if outcome == "S2":
                    extra = profile.draw_extra_iters(rng)
                    if extra:
                        phases.append((extra * t_iter, "recompute"))
                phases.append((system.t_sync, "sync"))
            elif policy == "hybrid":
                state.n_fallbacks += 1
                state.lost += state.since_ckpt
                state.since_ckpt = 0.0
                phases = [(system.t_r, "restore"), (system.t_sync, "sync")]
            else:
                state.n_restarts += 1
                state.lost += state.since_ckpt
                state.since_ckpt = 0.0
                phases = [(system.t_sync, "sync")]
        for dur, bucket in phases:
            _, failed = clock.advance(dur, bucket)
            if failed:
                pending = not clock.done  # recovery interrupted: handle anew
                break
            if clock.done:
                break


def simulate_policy(
    policy: str,
    system: SystemConfig,
    trace: FailureTrace,
    profile: Optional[RecomputeProfile] = None,
    *,
    n_failures: int = 10_000,
    horizon: Optional[float] = None,
    interval: Optional[float] = None,
    t_s: float = 0.03,
    t_iter: float = 1.0,
    seed: int = 0,
) -> SimResult:
    """Play one execution under a failure trace and score its efficiency.

    * ``n_failures`` — stop after this many failure events (the estimator's
      sample size); ``horizon`` — or after this much wall time, whichever
      comes first (e.g. :data:`MONTH`).
    * ``interval`` — checkpoint interval for the checkpointing policies;
      ``None`` uses :func:`default_interval` (Young at the policy's
      effective MTBF).
    * ``t_s`` — EasyCrash's flush-overhead fraction: useful work of the
      ``easycrash``/``hybrid`` policies is taxed by ``(1 - t_s)`` exactly as
      in :func:`~repro.core.efficiency.efficiency_with`.
    * ``t_iter`` — wall seconds one application iteration costs at
      deployment scale; converts the profile's measured extra-recompute-
      iteration draws (S2 recoveries) into downtime.

    Efficiency counts *retained* useful work: work behind a complete
    checkpoint, plus whatever is live when the tape ends (a crash-free
    shutdown keeps in-flight progress; without this boundary convention a
    near-perfect profile's stretched interval would misread end-of-horizon
    work as lost).  For ``easycrash``/``none`` the live progress since the
    last unrecovered crash is all there is.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r} (one of {POLICIES})")
    if policy in ("easycrash", "hybrid") and profile is None:
        raise ValueError(f"policy {policy!r} needs a RecomputeProfile")
    if n_failures < 1 and horizon is None:
        raise ValueError("need a failure budget or a horizon to terminate")
    if interval is not None and interval <= 0:
        raise ValueError("interval must be positive")

    checkpointing = policy in ("checkpoint", "hybrid")
    T = (interval if interval is not None
         else default_interval(policy, system, trace, profile))
    tax = t_s if policy in ("easycrash", "hybrid") else 0.0

    rng = np.random.default_rng(seed)
    clock = _Clock(trace, rng, n_failures if n_failures >= 1 else None, horizon)
    state = _SimState()

    while not clock.done:
        if checkpointing:
            elapsed, failed = clock.advance(T - state.since_ckpt, "work")
            state.since_ckpt += elapsed
            if clock.done:
                break
            if failed:
                _handle_failure(policy, clock, state, system, profile, rng, t_iter)
                continue
            _, failed = clock.advance(system.t_chk, "checkpoint")
            if clock.done:
                break
            if failed:
                # the torn checkpoint is discarded; the previous one stands
                _handle_failure(policy, clock, state, system, profile, rng, t_iter)
                continue
            state.committed += state.since_ckpt
            state.since_ckpt = 0.0
            state.n_checkpoints += 1
        else:
            # work straight through to the next failure (or the horizon)
            chunk = clock.next_fail - clock.now + 1.0
            elapsed, failed = clock.advance(chunk, "work")
            state.since_ckpt += elapsed
            if clock.done:
                break
            if failed:
                _handle_failure(policy, clock, state, system, profile, rng, t_iter)

    retained = state.committed + state.since_ckpt
    useful = retained * (1.0 - tax)
    total = clock.now
    return SimResult(
        policy=policy,
        efficiency=useful / total if total > 0 else 0.0,
        useful_time=useful,
        total_time=total,
        interval=T,
        n_failures=clock.failures,
        n_checkpoints=state.n_checkpoints,
        n_nvm_recoveries=state.n_nvm,
        n_fallbacks=state.n_fallbacks,
        n_restarts=state.n_restarts,
        lost_work=state.lost,
        breakdown=dict(clock.buckets),
    )


# --------------------------------------------------------- interval sweeps
@dataclass(frozen=True)
class IntervalPoint:
    interval: float
    efficiency: float


@dataclass(frozen=True)
class IntervalSweep:
    policy: str
    young: float                       # the Young/Daly anchor interval
    points: Tuple[IntervalPoint, ...]  # sorted by interval
    best: IntervalPoint


DEFAULT_SWEEP_FACTORS = (0.25, 0.4, 0.6, 0.8, 1.0, 1.25, 1.6, 2.0, 3.0)


def optimize_interval(
    policy: str,
    system: SystemConfig,
    trace: FailureTrace,
    profile: Optional[RecomputeProfile] = None,
    *,
    factors: Sequence[float] = DEFAULT_SWEEP_FACTORS,
    n_failures: int = 2_000,
    t_s: float = 0.03,
    t_iter: float = 1.0,
    seed: int = 0,
) -> IntervalSweep:
    """Sweep checkpoint intervals around the Young anchor and report the
    simulated optimum.

    Young's formula is first-order — it ignores work lost to crashes during
    checkpoint writes and the recovery costs themselves — so on harsh
    configurations (large ``t_chk`` relative to MTBF) the simulated optimum
    sits *below* the anchor.  Every interval is simulated with the same
    seed, so the sweep compares policies on identical failure traces.
    """
    if policy not in ("checkpoint", "hybrid"):
        raise ValueError(f"policy {policy!r} takes no checkpoint interval")
    anchor = default_interval(policy, system, trace, profile)
    points = []
    for f in sorted(set(float(x) for x in factors)):
        r = simulate_policy(
            policy, system, trace, profile, n_failures=n_failures,
            interval=anchor * f, t_s=t_s, t_iter=t_iter, seed=seed,
        )
        points.append(IntervalPoint(interval=anchor * f, efficiency=r.efficiency))
    best = max(points, key=lambda p: p.efficiency)
    return IntervalSweep(policy=policy, young=anchor,
                         points=tuple(points), best=best)


def efficiency_frontier(
    system: SystemConfig,
    trace: FailureTrace,
    profile: RecomputeProfile,
    *,
    policies: Sequence[str] = POLICIES,
    factors: Sequence[float] = DEFAULT_SWEEP_FACTORS,
    n_failures: int = 2_000,
    t_s: float = 0.03,
    t_iter: float = 1.0,
    seed: int = 0,
) -> Dict[str, object]:
    """Per-policy efficiency (with interval sweeps where applicable), as one
    JSON-serializable document — the artifact the scheduled CI job uploads
    next to the robustness matrix."""
    doc: Dict[str, object] = {
        "app": profile.app_name,
        "fault": dict(profile.fault_spec),
        "profile": {
            "fractions": {c: float(profile.fractions.get(c, 0.0)) for c in OUTCOMES},
            "success_rate": profile.success_rate,
            "mean_extra_iters": profile.mean_extra_iters(),
            "n_records": profile.n_records,
        },
        "system": {
            "mtbf": float(system.mtbf),
            "t_chk": float(system.t_chk),
            "t_sync": float(system.t_sync),
            "t_r": float(system.t_r),
            "nvm_restore_time": float(system.nvm_restore_time),
        },
        "trace": trace.spec(),
        "t_s": float(t_s),
        "t_iter": float(t_iter),
        "n_failures": int(n_failures),
        "seed": int(seed),
        "policies": {},
    }
    pols: Dict[str, object] = doc["policies"]  # type: ignore[assignment]
    for policy in policies:
        if policy in ("checkpoint", "hybrid"):
            sweep = optimize_interval(
                policy, system, trace, profile, factors=factors,
                n_failures=n_failures, t_s=t_s, t_iter=t_iter, seed=seed,
            )
            pols[policy] = {
                "young_interval": sweep.young,
                "sweep": [
                    {"interval": p.interval, "efficiency": p.efficiency}
                    for p in sweep.points
                ],
                "best": {"interval": sweep.best.interval,
                         "efficiency": sweep.best.efficiency},
            }
        else:
            r = simulate_policy(
                policy, system, trace, profile, n_failures=n_failures,
                t_s=t_s, t_iter=t_iter, seed=seed,
            )
            pols[policy] = {"efficiency": r.efficiency}
    return doc


__all__ = [
    "MONTH",
    "OUTCOMES",
    "POLICIES",
    "DEFAULT_SWEEP_FACTORS",
    "FailureTrace",
    "PoissonTrace",
    "WeibullTrace",
    "scaled_trace",
    "trace_from_spec",
    "RecomputeProfile",
    "SimResult",
    "IntervalPoint",
    "IntervalSweep",
    "default_interval",
    "simulate_policy",
    "optimize_interval",
    "efficiency_frontier",
]
