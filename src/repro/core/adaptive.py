"""Adaptive crash campaigns: sequential early stopping + importance sampling.

The W+2 workflow (paper §5.3) brute-forces every pre-drawn crash point of
every per-region campaign even when the downstream decision — the knapsack's
region/frequency selection in :mod:`repro.core.selection` — was already
determined by the first handful of outcomes.  This module supplies the two
halves of the sample-efficient replacement:

* **Batch-sequential early stopping.**  Region campaigns execute in
  deterministic *rounds* (whole crash-window shards, in planned-test order).
  After each round, every campaigned region gets an interval on its final S1
  rate — the intersection of a Wilson score interval with the *hard reachable
  bound* (remaining tests are pre-drawn, so the final self-normalized
  estimate is bracketed by "every remaining test fails" / "every remaining
  test passes").  The campaigns stop as soon as the knapsack decision is
  invariant over the whole gain box (:func:`selection_invariant`).  Because
  the round partition and the stopping check are pure functions of the
  completed-round prefix, worker count and kill/resume cannot change the
  executed set — bit-for-bit.

* **Static-prior importance sampling.**  :class:`StaticPriorSampler` biases
  the per-test crash-*region* draw toward regions whose static-plan
  confidence (PR 8's jaxpr dataflow walk) is low, carrying the likelihood
  ratio in :attr:`~repro.core.crash_tester.PlannedTest.weight`.  The
  self-normalized estimator (:func:`weighted_outcome_stats`) recovers
  unbiased S1–S4 rates; with uniform weights it degrades exactly to the
  empirical fractions.

Soundness of the stop rule: the knapsack objective is linear in the gain
vector for any fixed choice set, so over a box of gains the optimal choice
is corner-determined — if every corner (and the point estimate) yields the
same ``plan_freqs()``, so does every interior point.  When the interval is
the hard reachable bound alone, a fired stop is therefore a *theorem*: the
truncated campaign's final plan equals the full campaign's.  The Wilson
intersection trades that certainty for earlier stopping at the interval's
coverage level; ``tests/test_adaptive.py`` pins the resulting plans against
the brute-force workflow on the whole suite.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .crash_tester import PlannedTest
from .selection import select_regions_from_gains


# ------------------------------------------------------------------ estimator
def wilson_interval(successes: float, n: float, z: float = 1.96) -> Tuple[float, float]:
    """Wilson score interval for a Bernoulli rate.

    Accepts *effective* (possibly fractional) counts so weighted campaigns
    can reuse it with the Kish sample size.  ``n <= 0`` returns the vacuous
    ``(0, 1)`` — no evidence constrains nothing.
    """
    if n <= 0:
        return 0.0, 1.0
    p = min(1.0, max(0.0, successes / n))
    z2 = z * z
    denom = 1.0 + z2 / n
    center = (p + z2 / (2.0 * n)) / denom
    half = (z / denom) * math.sqrt(p * (1.0 - p) / n + z2 / (4.0 * n * n))
    return max(0.0, center - half), min(1.0, center + half)


def effective_sample_size(weights: Sequence[float]) -> float:
    """Kish effective n: ``(sum w)^2 / sum w^2`` (== len for uniform weights)."""
    w = np.asarray(weights, dtype=float)
    if w.size == 0:
        return 0.0
    s2 = float(np.sum(w * w))
    if s2 <= 0.0:
        return 0.0
    return float(np.sum(w)) ** 2 / s2


def weighted_outcome_stats(
    values: Sequence[float], weights: Sequence[float]
) -> Tuple[float, float]:
    """Self-normalized IS estimate of a rate: ``(sum w*x / sum w, n_eff)``.

    ``values`` are 0/1 outcome indicators; with uniform weights the estimate
    is the plain empirical fraction and ``n_eff == len(values)``.
    """
    w = np.asarray(weights, dtype=float)
    x = np.asarray(values, dtype=float)
    tot = float(np.sum(w))
    if tot <= 0.0:
        return float("nan"), 0.0
    return float(np.sum(w * x)) / tot, effective_sample_size(w)


# --------------------------------------------------------------------- config
@dataclass(frozen=True)
class SequentialConfig:
    """Knobs of the adaptive scheduler, one frozen object.

    ``round_tests`` sets the per-campaign round size: whole crash-window
    shards accumulate (in planned-test order) until a round holds at least
    this many tests, so rounds align with the store's shard durability
    granularity.  ``z`` is the Wilson interval's critical value.  The default
    1.645 is the one-sided 95% point: every comparison the stopping rule
    makes is directional (is this gain still positive?  still below the
    budget cut?), and the interval is always intersected with the hard
    reachable bound, so a huge ``z`` degrades to the provably-safe rule
    rather than to "never stop".  ``sampler_bias`` scales the
    importance-sampling tilt toward
    low-confidence regions (0 disables IS: uniform draws, unit weights).
    ``max_corners`` caps the invariance sweep — above it the round never
    claims invariance (no silent unsoundness on very wide apps).

    Equivalence fine print: early stopping alone is *provably* decision-
    invariant (the plan equals what full execution of the same campaigns
    would produce).  ``sampler_bias=0`` additionally makes the draws
    bit-identical to the brute-force workflow's, so the final plan provably
    equals brute force.  With bias > 0 the IS estimator is unbiased for the
    same rates but sees different finite-sample draws, so a knife-edge
    knapsack decision (per-region gains within sampling noise of a budget or
    sign boundary) can resolve differently; the differential suite pins the
    per-app agreement at the defaults.
    """

    z: float = 1.645
    round_tests: int = 4
    min_rounds: int = 1
    sampler_bias: float = 1.0
    max_corners: int = 4096

    def __post_init__(self):
        if self.round_tests < 1:
            raise ValueError(f"round_tests must be >= 1, got {self.round_tests}")
        if self.min_rounds < 1:
            raise ValueError(f"min_rounds must be >= 1, got {self.min_rounds}")
        if self.z <= 0:
            raise ValueError(f"z must be > 0, got {self.z}")
        if self.sampler_bias < 0:
            raise ValueError(f"sampler_bias must be >= 0, got {self.sampler_bias}")

    def spec(self) -> Dict[str, object]:
        """JSON-round-trip-safe identity (store fingerprints, artifacts)."""
        return {
            "z": float(self.z),
            "round_tests": int(self.round_tests),
            "min_rounds": int(self.min_rounds),
            "sampler_bias": float(self.sampler_bias),
            "max_corners": int(self.max_corners),
        }


# -------------------------------------------------------------------- sampler
@dataclass(frozen=True)
class StaticPriorSampler:
    """Importance sampler over crash points, tilted by static-plan confidence.

    The historical draw is (uniform crash iteration, uniform time in the
    window) — the time draw makes the crash *region* proportional to its
    span length.  This sampler keeps the iteration draw and reweights the
    region draw:  ``q_k ∝ span_k * (1 + bias * (1 - confidence_k))`` — a
    region the static analysis is sure about keeps roughly its uniform mass,
    an uncertain one gets up to ``1 + bias`` times more.  Each test carries
    ``weight = p_k / q_k`` (uniform over proposal likelihood ratio) so the
    self-normalized estimator stays unbiased for the uniform-draw rates.

    ``confidences`` is indexed by region (from
    :meth:`repro.analysis.classify.StaticPlan.window_confidences`), rounded
    to 6 decimals so the sampler spec — and every store fingerprint built
    from it — is stable across float formatting.
    """

    confidences: Tuple[float, ...]
    bias: float = 3.0

    def __post_init__(self):
        object.__setattr__(
            self,
            "confidences",
            tuple(round(min(1.0, max(0.0, float(c))), 6) for c in self.confidences),
        )
        if self.bias < 0:
            raise ValueError(f"bias must be >= 0, got {self.bias}")

    def spec(self) -> Dict[str, object]:
        return {
            "kind": "static-prior",
            "bias": round(float(self.bias), 6),
            "confidences": [float(c) for c in self.confidences],
        }

    def _distributions(self, planner) -> Tuple[np.ndarray, np.ndarray, List[Tuple[int, int]]]:
        """(uniform p, proposal q, spans) over this planner's regions."""
        spans = planner.region_time_spans()
        if len(spans) != len(self.confidences):
            raise ValueError(
                f"sampler has {len(self.confidences)} region confidences but "
                f"{planner.app.name} has {len(spans)} regions"
            )
        lengths = np.array([max(0, t1 - t0) for t0, t1 in spans], dtype=float)
        if lengths.sum() <= 0:
            raise ValueError(f"{planner.app.name}: no positive region spans")
        p = lengths / lengths.sum()
        tilt = lengths * (1.0 + self.bias * (1.0 - np.asarray(self.confidences)))
        q = tilt / tilt.sum()
        return p, q, spans

    def draw(self, rng: np.random.Generator, planner) -> Tuple[int, int, float]:
        """One importance-sampled ``(crash_iter, crash_t, weight)``.

        Draw order is fixed (iteration, region, time-in-region) so a planned
        campaign is a pure function of the seed, exactly like the uniform
        planner.
        """
        p, q, spans = self._distributions(planner)
        crash_iter = int(rng.integers(0, planner.golden_iters))
        k = int(rng.choice(len(spans), p=q))
        t0, t1 = spans[k]
        t_lo, _ = planner.window_bounds(crash_iter)
        crash_t = t_lo + t0 + int(rng.integers(0, max(1, t1 - t0)))
        return crash_iter, crash_t, float(p[k] / q[k])


# ---------------------------------------------------------- decision analysis
def selection_invariant(
    point_gains: Mapping[int, float],
    gain_boxes: Mapping[int, Tuple[float, float]],
    overheads: Mapping[int, float],
    y_base: float,
    t_s: float,
    tau: float,
    freq_options: Sequence[int] = (1, 2, 4, 8),
    max_corners: int = 4096,
) -> Optional[Dict[int, int]]:
    """The knapsack's ``plan_freqs()`` if it is invariant over the gain box.

    ``point_gains`` holds every region's current point estimate;
    ``gain_boxes`` the (lo, hi) interval of each still-uncertain region
    (regions absent from it are held fixed at their point gain).  For a
    fixed choice set the knapsack objective is linear in the gain vector, so
    its optimum over a box is attained at a corner: if the DP returns the
    same plan at *every* corner and at the point estimate, the decision is
    settled — return it.  Any disagreement (or more than ``max_corners``
    corners) returns ``None``: keep sampling.
    """
    varying = sorted(k for k, (lo, hi) in gain_boxes.items() if hi - lo > 1e-12)
    if len(varying) > 0 and 2 ** len(varying) > max_corners:
        return None

    def decide(gains: Mapping[int, float]) -> Dict[int, int]:
        return select_regions_from_gains(
            gains, overheads, y_base, t_s=t_s, tau=tau, freq_options=freq_options
        ).plan_freqs()

    base = dict(point_gains)
    for k, (lo, hi) in gain_boxes.items():
        if k not in varying:
            base[k] = lo  # degenerate box: pin to its single value
    decision = decide(base)
    for corner in itertools.product(*[(gain_boxes[k][0], gain_boxes[k][1]) for k in varying]):
        gains = dict(base)
        gains.update(zip(varying, corner))
        if decide(gains) != decision:
            return None
    return decision


# --------------------------------------------------------------------- report
@dataclass(frozen=True)
class RegionEvidence:
    """Per-region adaptive evidence at the stop point."""

    region: int
    executed: int
    planned: int
    rate: float                    # self-normalized S1 estimate
    interval: Tuple[float, float]  # final-rate interval the stop was taken on
    n_eff: float

    def to_payload(self) -> Dict[str, object]:
        def _f(x: float):
            x = float(x)
            return None if x != x else round(x, 9)

        return {
            "region": int(self.region),
            "executed": int(self.executed),
            "planned": int(self.planned),
            "rate": _f(self.rate),
            "interval": [_f(self.interval[0]), _f(self.interval[1])],
            "n_eff": _f(self.n_eff),
        }

    @classmethod
    def from_payload(cls, d: Mapping[str, object]) -> "RegionEvidence":
        nan = float("nan")

        def _f(x):
            return nan if x is None else float(x)

        lo, hi = d["interval"]
        return cls(
            region=int(d["region"]), executed=int(d["executed"]),
            planned=int(d["planned"]), rate=_f(d["rate"]),
            interval=(_f(lo), _f(hi)), n_eff=_f(d["n_eff"]),
        )


@dataclass(frozen=True)
class AdaptiveReport:
    """What the adaptive scheduler did: the stopping decision and its evidence.

    Saved into workflow artifacts (only when the workflow actually ran
    adaptively, so historical artifact fingerprints are untouched).
    """

    rounds_executed: int
    rounds_total: int
    stopped_early: bool
    tests_executed: int            # sequential-campaign tests actually run
    tests_planned: int             # sequential-campaign tests brute force runs
    regions: Tuple[RegionEvidence, ...]
    stopping: Dict[str, object]    # SequentialConfig.spec()
    sampler: Optional[Dict[str, object]]  # StaticPriorSampler.spec() or None
    # evidence for the persist-everything reference campaign when it rode the
    # rounds (pure adaptive mode; ``region`` is -1).  None when the reference
    # ran in full (static+verify composition, where fixed gains consume it).
    reference: Optional[RegionEvidence] = None

    @property
    def tests_skipped(self) -> int:
        return self.tests_planned - self.tests_executed

    def to_payload(self) -> Dict[str, object]:
        return {
            "rounds_executed": int(self.rounds_executed),
            "rounds_total": int(self.rounds_total),
            "stopped_early": bool(self.stopped_early),
            "tests_executed": int(self.tests_executed),
            "tests_planned": int(self.tests_planned),
            "regions": [r.to_payload() for r in self.regions],
            "stopping": dict(self.stopping),
            "sampler": None if self.sampler is None else dict(self.sampler),
            **(
                {"reference": self.reference.to_payload()}
                if self.reference is not None else {}
            ),
        }

    @classmethod
    def from_payload(cls, d: Mapping[str, object]) -> "AdaptiveReport":
        return cls(
            rounds_executed=int(d["rounds_executed"]),
            rounds_total=int(d["rounds_total"]),
            stopped_early=bool(d["stopped_early"]),
            tests_executed=int(d["tests_executed"]),
            tests_planned=int(d["tests_planned"]),
            regions=tuple(RegionEvidence.from_payload(r) for r in d["regions"]),
            stopping=dict(d["stopping"]),
            sampler=None if d.get("sampler") is None else dict(d["sampler"]),
            reference=(
                None if d.get("reference") is None
                else RegionEvidence.from_payload(d["reference"])
            ),
        )


# ------------------------------------------------------------- round geometry
def shard_rounds(
    tests: Sequence[PlannedTest],
    shards: Mapping[int, Sequence[PlannedTest]],
    round_tests: int,
) -> List[List[int]]:
    """Partition one campaign's shards into deterministic rounds.

    Whole shards (never split — a shard is the store's durability unit), in
    order of each shard's first appearance in the planned-test sequence,
    greedily packed until a round holds at least ``round_tests`` tests.  A
    pure function of the plan, so every worker count and every resume
    computes the identical partition.
    """
    order: List[int] = []
    seen = set()
    for t in tests:
        if t.crash_iter not in seen:
            seen.add(t.crash_iter)
            order.append(t.crash_iter)
    rounds: List[List[int]] = []
    current: List[int] = []
    count = 0
    for ci in order:
        current.append(ci)
        count += len(shards[ci])
        if count >= round_tests:
            rounds.append(current)
            current, count = [], 0
    if current:
        rounds.append(current)
    return rounds


def final_rate_interval(
    executed_values: Sequence[float],
    executed_weights: Sequence[float],
    remaining_weights: Sequence[float],
    z: float,
) -> Tuple[float, float, float, float]:
    """(lo, hi, point rate, n_eff) bounding the campaign's *final* S1 estimate.

    Two constraints intersected:

    * the hard reachable bound — remaining tests are pre-drawn with known
      weights, so the final self-normalized estimate lies between "every
      remaining test fails" and "every remaining test passes" (exact, not
      statistical);
    * the Wilson score interval at ``z``, on the Kish effective sample size.

    The point estimate lies in both, so the intersection is never empty.
    """
    w_exec = float(np.sum(np.asarray(executed_weights, dtype=float))) if len(executed_weights) else 0.0
    if w_exec <= 0.0:
        return 0.0, 1.0, float("nan"), 0.0
    s = float(np.sum(np.asarray(executed_values, dtype=float)
                     * np.asarray(executed_weights, dtype=float)))
    w_rem = float(np.sum(np.asarray(remaining_weights, dtype=float))) if len(remaining_weights) else 0.0
    w_tot = w_exec + w_rem
    hard_lo, hard_hi = s / w_tot, (s + w_rem) / w_tot
    rate, n_eff = weighted_outcome_stats(executed_values, executed_weights)
    wil_lo, wil_hi = wilson_interval(rate * n_eff, n_eff, z)
    # the current estimate lies in both intervals mathematically; widen to
    # it so float rounding (Wilson hi at p_hat=1 computes to 1-1e-16) can
    # never produce an interval excluding the point
    lo = min(max(hard_lo, wil_lo), rate)
    hi = max(min(hard_hi, wil_hi), rate)
    return lo, hi, rate, n_eff
