"""Pluggable fault models for crash campaigns.

The paper's NVCT draws exactly one failure flavor: a clean power failure at a
uniformly random crash point, with every cacheline image perfectly atomic —
a block either reached NVM in full or not at all.  The S1–S4 outcome taxonomy
(§3–4), however, absorbs a much wider family of failures, and the
recomputability numbers shift materially with the failure model.  This module
makes the failure model a first-class, pluggable campaign parameter.

Models and the paper scenario each stresses:

========================  ====================================================
model                     scenario / outcome classes stressed
========================  ====================================================
``PowerFail``             the paper's §3 baseline: clean power-fail, atomic
                          cachelines, uniform crash point.  Default; campaigns
                          reproduce the historical engine bit-for-bit.
``TornWrite``             the in-flight write sweep's recently stored
                          cachelines land *partially* in NVM (per-block
                          Bernoulli tearing of the store queue).  Stresses the
                          §4 data-inconsistency analysis: images mix bytes of
                          two versions inside one block, pushing records
                          toward S2/S3.
``MultiCrash``            a second crash strikes while the recomputation is
                          still running, forcing recovery-from-recovery (the
                          paper's §7 efficiency model assumes recovery always
                          completes; this measures what happens when it does
                          not).  Stresses S2 (extra iterations compound) and
                          S4 (budget exhaustion).
``BitFlip``               silent data corruption: after the NVM image is
                          formed, k bits flip in non-persisted objects,
                          modeling undetected media/controller corruption.
                          The §3 taxonomy absorbs this as S3 (blow-up /
                          interruption) or S4 (acceptance never reached) —
                          or, for contraction-dominated solvers, S1/S2.
``CorrelatedRegion``      crash points are not uniform: failures concentrate
                          in the *heaviest* code region (utilization-
                          correlated failure, Weibull-ish weighting of region
                          residency).  Stresses the §5.2 per-region
                          recomputability c_k estimates, which the uniform
                          draw samples evenly.
========================  ====================================================

Determinism contract (all models): every random decision is derived either
from the campaign RNG at *planning* time (crash points) or from the per-test
``fault_seed`` pre-drawn at planning time (tearing, bit flips, recovery
crashes).  Nothing depends on execution order, so campaigns are bit-for-bit
identical across ``n_workers`` and across a kill/resume through
:class:`~repro.core.campaign_store.CampaignStore`.  The store fingerprint
includes :meth:`FaultModel.spec`, so a resumed store refuses a different
fault model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, List, Mapping, Optional, Sequence, Tuple, Type

import numpy as np

from .cache_sim import TornBlock, WindowTrace

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .crash_tester import CrashTester, PlannedTest

#: stream-splitting salt so per-test fault RNG never collides with the
#: campaign planning RNG (which is seeded with the bare campaign seed)
_FAULT_STREAM = 0xEC_FA17

#: salts for the independent per-test decision streams
_SALT_TEAR = 1
_SALT_FLIP = 2
_SALT_RECOVERY = 3


def _test_rng(test: "PlannedTest", salt: int) -> np.random.Generator:
    """Per-test decision stream: depends only on the pre-drawn fault seed
    (and the decision kind), never on execution order."""
    return np.random.default_rng((_FAULT_STREAM, int(test.fault_seed), salt))


@dataclass(frozen=True)
class FaultModel:
    """Base fault model == the paper's clean power failure.

    Subclasses override one or more hooks; every hook must be a pure function
    of its arguments (plus frozen model parameters), with randomness drawn
    only from the planning RNG or the per-test ``fault_seed`` stream.
    """

    #: registry key; also the ``--fault-model`` spelling in CLIs
    model_name = "power-fail"
    #: whether :meth:`CrashTester.plan_campaign` pre-draws a per-test fault
    #: seed.  False for the default model keeps the historical campaign RNG
    #: stream untouched (PowerFail is bit-for-bit the PR-1 engine).
    uses_test_entropy = False

    # ----------------------------------------------------------- fingerprint
    def spec(self) -> Dict[str, object]:
        """JSON-round-trippable identity, stored in campaign fingerprints."""
        out: Dict[str, object] = {"model": self.model_name}
        for f in getattr(self, "__dataclass_fields__", {}):
            v = getattr(self, f)
            out[f] = float(v) if isinstance(v, float) else int(v) if isinstance(v, (int, np.integer)) else v
        return out

    # -------------------------------------------------------- planning hook
    def draw_crash_point(self, rng: np.random.Generator, planner: "CrashTester") -> Tuple[int, int]:
        """Draw ``(crash_iter, crash_t)`` with the campaign RNG.

        The default performs exactly the historical two draws (uniform crash
        iteration, then uniform time inside the iteration's window), in the
        historical order — this is what keeps ``PowerFail`` campaigns
        bit-for-bit identical to the pre-fault-model engine.
        """
        crash_iter = int(rng.integers(0, planner.golden_iters))
        t_lo, t_end = planner.window_bounds(crash_iter)
        return crash_iter, int(rng.integers(t_lo, t_end))

    # ------------------------------------------------------ resolution hook
    def torn_blocks(
        self, test: "PlannedTest", trace: WindowTrace, block_bytes: int
    ) -> Optional[List[TornBlock]]:
        """Cachelines of the in-flight sweep that land partially in NVM
        (``None`` == atomic cachelines, the default)."""
        return None

    # ----------------------------------------------------------- image hook
    def corrupt_image(
        self,
        test: "PlannedTest",
        image: Dict[str, np.ndarray],
        protected: Sequence[str],
    ) -> Dict[str, np.ndarray]:
        """Post-process the resolved NVM image (SDC injection point).

        ``protected`` lists objects the model must not touch (the persist
        plan's flushed objects and the bookmarked loop iterator).
        """
        return image

    # -------------------------------------------------------- recovery hook
    def recovery_plan(
        self, test: "PlannedTest", restart_iter: int, golden_iters: int
    ) -> Optional[Tuple[int, float]]:
        """Second crash during recompute: ``(recrash_iter, u)`` where
        ``recrash_iter`` is the iteration the second crash strikes in and
        ``u`` in [0, 1) places the crash time inside that iteration's window.
        ``None`` == recovery runs undisturbed (the default)."""
        return None


@dataclass(frozen=True)
class PowerFail(FaultModel):
    """The paper's baseline: clean power-fail, atomic cachelines, uniform
    crash point.  All hooks are the base-class defaults."""

    model_name = "power-fail"


@dataclass(frozen=True)
class TornWrite(FaultModel):
    """Torn cacheline writes at the crash point.

    The cache model treats a crash as atomic at block granularity; real
    persistence domains drain a store queue, and a power cut mid-drain leaves
    *partial* cachelines.  For the sweep in flight at the crash, each of its
    last ``depth`` stored blocks independently tears with probability
    ``p_torn``: a prefix of 1..block_bytes-1 bytes of the new version lands
    in NVM, the suffix keeps whatever NVM held.
    """

    model_name = "torn-write"
    uses_test_entropy = True

    p_torn: float = 0.5
    depth: int = 8

    def torn_blocks(self, test, trace, block_bytes):
        # Sweeps are time-disjoint and ordered, so at most one — the last
        # with t_start < crash_t — can be in flight; find it with one binary
        # search over the trace's SoA sweep arrays instead of a Python scan.
        # Only in-flight sweeps ever consumed tearing entropy, so the rng
        # stream is bit-for-bit the historical per-sweep loop's.
        ct = int(test.crash_t)
        t_starts, _ = trace.sweep_soa()
        idx = int(np.searchsorted(t_starts, ct, side="left")) - 1
        if idx < 0:
            return None
        sw = trace.sweeps[idx]
        done = ct - sw.t_start
        if done >= sw.n_blocks:
            return None  # sweep completed before the crash: stores drained
        rng = _test_rng(test, _SALT_TEAR)
        out: List[TornBlock] = []
        for blk in range(max(0, done - self.depth), done):
            if rng.random() < self.p_torn:
                cut = int(rng.integers(1, block_bytes))
                out.append(TornBlock(sw.obj, blk, cut, sw.seq))
        return out or None


@dataclass(frozen=True)
class MultiCrash(FaultModel):
    """A second crash strikes during recomputation.

    With probability ``p_recrash`` the recompute run from the first crash's
    image is itself crashed, at a uniformly drawn iteration of the remaining
    recompute span; the engine simulates a fresh crash window on the *live
    recompute trajectory*, resolves its NVM image, and restarts again
    (recovery-from-recovery).  The second window starts cache-consistent and
    carries no chronic base — the recompute trajectory is not in the
    steady-state regime the chronic adjustment models.
    """

    model_name = "multi-crash"
    uses_test_entropy = True

    p_recrash: float = 1.0

    def recovery_plan(self, test, restart_iter, golden_iters):
        rng = _test_rng(test, _SALT_RECOVERY)
        if rng.random() >= self.p_recrash:
            return None
        if restart_iter >= golden_iters:
            return None
        recrash_iter = int(rng.integers(restart_iter, golden_iters))
        return recrash_iter, float(rng.random())


@dataclass(frozen=True)
class BitFlip(FaultModel):
    """Silent data corruption in the NVM image.

    After the crash image is resolved (and before restart), ``n_bits``
    distinct bits flip across the *non-persisted* objects — corruption the
    flush path never scrubbed and no checksum catches.  Flushed objects and
    the bookmarked loop iterator are protected; if every candidate is
    flushed, the image is returned untouched (the model has nothing
    unprotected to corrupt).
    """

    model_name = "bit-flip"
    uses_test_entropy = True

    n_bits: int = 8

    def corrupt_image(self, test, image, protected):
        targets = [o for o in sorted(image) if o not in protected]
        sizes = [int(np.asarray(image[o]).nbytes) for o in targets]
        total_bits = 8 * sum(sizes)
        if total_bits == 0:
            return image
        rng = _test_rng(test, _SALT_FLIP)
        k = min(self.n_bits, total_bits)
        positions = rng.choice(total_bits, size=k, replace=False)
        out = dict(image)
        flat: Dict[str, np.ndarray] = {}
        offsets = np.cumsum([0] + [8 * s for s in sizes])
        for pos in sorted(int(p) for p in positions):
            oi = int(np.searchsorted(offsets, pos, side="right")) - 1
            obj = targets[oi]
            if obj not in flat:
                arr = np.ascontiguousarray(np.asarray(out[obj])).copy()
                flat[obj] = arr.view(np.uint8).reshape(-1)
                out[obj] = flat[obj].view(arr.dtype).reshape(arr.shape)
            local = pos - int(offsets[oi])
            flat[obj][local // 8] ^= np.uint8(1 << (local % 8))
        return out


@dataclass(frozen=True)
class CorrelatedRegion(FaultModel):
    """Utilization-correlated crash points.

    The crash iteration stays uniform, but within the iteration the crash
    region is drawn with probability proportional to (region access time)
    ** ``shape`` — a Weibull-ish concentration on the heaviest region
    (``shape=1`` recovers residency-proportional sampling, which is what the
    uniform time draw already does; larger shapes model failures that strike
    under peak load).  The crash time is then uniform inside the chosen
    region's span.
    """

    model_name = "correlated-region"
    uses_test_entropy = False

    shape: float = 3.0

    def draw_crash_point(self, rng, planner):
        crash_iter = int(rng.integers(0, planner.golden_iters))
        t_lo, _ = planner.window_bounds(crash_iter)
        spans = planner.region_time_spans()
        w = np.array([max(t1 - t0, 0) for t0, t1 in spans], dtype=np.float64)
        w = np.where(w > 0, w, 1e-9) ** self.shape
        ridx = int(rng.choice(len(spans), p=w / w.sum()))
        t0, t1 = spans[ridx]
        if t1 <= t0:
            return crash_iter, int(t_lo + t0)
        return crash_iter, int(t_lo + rng.integers(t0, t1))


#: registry, keyed by the CLI spelling
FAULT_MODELS: Dict[str, Type[FaultModel]] = {
    cls.model_name: cls
    for cls in (PowerFail, TornWrite, MultiCrash, BitFlip, CorrelatedRegion)
}


def get_fault_model(name: str, app=None, **overrides) -> FaultModel:
    """Instantiate a registered model, layering parameters as
    model defaults < ``app.fault_defaults[name]`` < explicit ``overrides``."""
    try:
        cls = FAULT_MODELS[name]
    except KeyError:
        raise KeyError(
            f"unknown fault model {name!r}; have {sorted(FAULT_MODELS)}"
        ) from None
    params: Dict[str, object] = {}
    if app is not None:
        params.update(getattr(app, "fault_defaults", {}).get(name, {}))
    params.update(overrides)
    return cls(**params)


def all_fault_models(app=None) -> Dict[str, FaultModel]:
    """Every registered model, instantiated with ``app``'s
    ``fault_defaults`` applied — the sweep and robustness-matrix benchmarks'
    canonical way to enumerate failure flavors."""
    return {name: get_fault_model(name, app=app) for name in sorted(FAULT_MODELS)}


def fault_model_from_spec(spec: Mapping[str, object]) -> FaultModel:
    """Inverse of :meth:`FaultModel.spec` (e.g. to rehydrate from a store
    header or a plan artifact)."""
    d = dict(spec)
    name = str(d.pop("model"))
    return get_fault_model(name, **d)
