"""Portable, fingerprinted workflow artifacts (paper §5.3 step 4).

The product EasyCrash ships is not a campaign log — it is the *persist plan*:
which data objects to flush, at which code regions, how often.  This module
makes that product a portable file:

* :func:`save_plan` / :func:`load_plan` — a :class:`PersistPlan` plus the
  context it was characterized in (app, fault model, tau, expected
  recomputability), serialized to JSON with a content fingerprint;
* :func:`save_workflow` / :func:`load_workflow` — the full
  :class:`~repro.core.workflow.WorkflowResult` summary (object scores,
  region choices, campaign outcome fractions) in the same envelope;
* :func:`replay_plan` — re-run a crash campaign under a loaded plan, by
  default under the fault model the plan was characterized with, or under
  any other (the cross-fault robustness question: does a plan characterized
  under clean power failures survive deployment under torn writes?).

Envelope: ``{"kind": ..., "version": ..., "fingerprint": sha256(payload),
"payload": {...}}``.  The fingerprint is over the canonical (sorted-key,
no-whitespace) JSON payload; loading verifies it and raises
:class:`ArtifactError` on any mismatch — a truncated download or a hand-
edited plan must never silently steer a production run.
"""
from __future__ import annotations

import hashlib
import io
import json
import math
import os
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Tuple, Union

from .cache_sim import CacheConfig
from .crash_tester import CampaignResult, CrashTester, PersistPlan
from .durable import durable_replace
from .faults import FaultModel, fault_model_from_spec
from .regions import IterativeApp

from .sysim import RecomputeProfile

ARTIFACT_VERSION = 1
PLAN_KIND = "easycrash-persist-plan"
WORKFLOW_KIND = "easycrash-workflow-result"
PROFILE_KIND = "easycrash-recompute-profile"
STATIC_PLAN_KIND = "easycrash-static-plan"


class ArtifactError(RuntimeError):
    """Raised for corrupt, tampered, or mismatched artifact files."""


# ------------------------------------------------------------------ envelope
def _canonical(payload: Mapping[str, object]) -> str:
    # allow_nan=False: artifacts are *portable* — a NaN token parses in
    # Python but is rejected by strict JSON consumers (jq, JSON.parse).
    # Non-finite values must be mapped to null by the codecs before here.
    return json.dumps(payload, sort_keys=True, separators=(",", ":"),
                      allow_nan=False)


def _finite_or_none(x: float) -> Optional[float]:
    """Strict-JSON stand-in for possibly-non-finite statistics (Spearman rs
    of a constant vector is NaN by contract; ``tau_threshold`` returns inf
    when EasyCrash can never win)."""
    x = float(x)
    return x if math.isfinite(x) else None


def _nan_if_none(x: Optional[object]) -> float:
    """Loader inverse of :func:`_finite_or_none` (null -> nan)."""
    return float("nan") if x is None else float(x)


def _sanitize_meta(meta: Mapping[str, object]) -> Dict[str, object]:
    """Map non-finite float values in caller-supplied metadata to null so
    the strict-JSON encoder never rejects a finished workflow's artifact."""
    return {
        k: _finite_or_none(v) if isinstance(v, float) else v
        for k, v in meta.items()
    }


def payload_fingerprint(payload: Mapping[str, object]) -> str:
    return hashlib.sha256(_canonical(payload).encode()).hexdigest()


def _write_envelope(path: str, kind: str, payload: Mapping[str, object]) -> str:
    """Atomically write an artifact file; returns its fingerprint."""
    fp = payload_fingerprint(payload)
    doc = {
        "kind": kind,
        "version": ARTIFACT_VERSION,
        "fingerprint": fp,
        "payload": payload,
    }
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with io.open(tmp, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2, sort_keys=True, allow_nan=False)
        f.write("\n")
        f.flush()
        os.fsync(f.fileno())
    durable_replace(tmp, path)
    return fp


def _read_envelope(path: str, kind: str) -> Tuple[Dict[str, object], str]:
    try:
        with io.open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    # ValueError covers JSONDecodeError and UnicodeDecodeError (binary
    # garbage over the file) alike — all corruption surfaces as ArtifactError
    except (OSError, ValueError) as e:
        raise ArtifactError(f"{path}: unreadable artifact ({e})") from None
    if not isinstance(doc, dict) or doc.get("kind") != kind:
        raise ArtifactError(
            f"{path}: not a {kind!r} artifact (kind={doc.get('kind')!r})"
        )
    version = doc.get("version")
    if not isinstance(version, int) or version != ARTIFACT_VERSION:
        raise ArtifactError(
            f"{path}: artifact version {version!r} unsupported "
            f"(want {ARTIFACT_VERSION})"
        )
    payload = doc.get("payload")
    if not isinstance(payload, dict):
        raise ArtifactError(f"{path}: artifact has no payload object")
    want = doc.get("fingerprint")
    got = payload_fingerprint(payload)
    if want != got:
        raise ArtifactError(
            f"{path}: fingerprint mismatch — the payload was modified after "
            f"the artifact was written (stored {want!r}, computed {got!r})"
        )
    return payload, got


# ---------------------------------------------------------------- plan codec
def cache_to_payload(cache: Optional[CacheConfig]) -> Optional[Dict[str, int]]:
    if cache is None:
        return None
    return {
        "capacity_blocks": int(cache.capacity_blocks),
        "block_bytes": int(cache.block_bytes),
    }


def cache_from_payload(d: Optional[Mapping[str, object]]) -> Optional[CacheConfig]:
    if d is None:
        return None
    return CacheConfig(
        capacity_blocks=int(d["capacity_blocks"]),
        block_bytes=int(d["block_bytes"]),
    )


def plan_to_payload(plan: PersistPlan) -> Dict[str, object]:
    return {
        "objects": list(plan.objects),
        "region_freq": sorted([int(k), int(v)] for k, v in plan.region_freq.items()),
    }


def plan_from_payload(d: Mapping[str, object]) -> PersistPlan:
    return PersistPlan(
        objects=tuple(str(o) for o in d["objects"]),
        region_freq={int(k): int(v) for k, v in d["region_freq"]},
    )


@dataclass(frozen=True)
class PlanArtifact:
    """A loaded persist-plan artifact (verified fingerprint)."""

    app_name: str
    plan: PersistPlan
    fault_spec: Dict[str, object]
    cache: Optional[CacheConfig]
    meta: Dict[str, object]
    fingerprint: str

    @property
    def fault(self) -> FaultModel:
        """The fault model the plan was characterized under."""
        return fault_model_from_spec(self.fault_spec)


def save_plan(
    path: str,
    plan: PersistPlan,
    app_name: str,
    fault: Optional[FaultModel] = None,
    cache: Optional[CacheConfig] = None,
    meta: Optional[Mapping[str, object]] = None,
) -> str:
    """Write a persist-plan artifact; returns its fingerprint.

    ``cache`` records the cache geometry the plan was characterized under —
    replaying under a different geometry yields S1–S4 numbers that are not
    comparable to the characterization, so :func:`replay_plan` defaults to
    the recorded one.
    """
    from .faults import PowerFail

    payload: Dict[str, object] = {
        "app": str(app_name),
        "plan": plan_to_payload(plan),
        "fault": (fault if fault is not None else PowerFail()).spec(),
        "cache": cache_to_payload(cache),
        "meta": _sanitize_meta(meta or {}),
    }
    return _write_envelope(path, PLAN_KIND, payload)


def load_plan(path: str) -> PlanArtifact:
    payload, fp = _read_envelope(path, PLAN_KIND)
    return PlanArtifact(
        app_name=str(payload["app"]),
        plan=plan_from_payload(payload["plan"]),
        fault_spec=dict(payload["fault"]),
        cache=cache_from_payload(payload.get("cache")),
        meta=dict(payload.get("meta", {})),
        fingerprint=fp,
    )


# ------------------------------------------------------------ workflow codec
@dataclass(frozen=True)
class WorkflowArtifact:
    """A loaded workflow-result summary artifact (verified fingerprint)."""

    app_name: str
    plan: PersistPlan
    critical: Tuple[str, ...]
    object_scores: List[Dict[str, object]]
    region_choices: List[Dict[str, object]]
    campaign_fractions: Dict[str, Dict[str, float]]
    summary: Dict[str, float]
    tau: float
    t_s: float
    fault_spec: Dict[str, object]
    cache: Optional[CacheConfig]
    fingerprint: str
    plan_source: str = "measured"
    #: the sequential scheduler's stopping decision + per-region evidence
    #: (an :meth:`repro.core.adaptive.AdaptiveReport.to_payload` document),
    #: present only for adaptively-run workflows
    adaptive: Optional[Dict[str, object]] = None

    @property
    def fault(self) -> FaultModel:
        return fault_model_from_spec(self.fault_spec)

    def adaptive_report(self):
        """Rehydrated :class:`~repro.core.adaptive.AdaptiveReport` (or None)."""
        if self.adaptive is None:
            return None
        from .adaptive import AdaptiveReport

        return AdaptiveReport.from_payload(self.adaptive)


def save_workflow(
    path: str,
    wf,  # WorkflowResult (not imported to avoid a cycle)
    fault: Optional[FaultModel] = None,
    cache: Optional[CacheConfig] = None,
) -> str:
    """Write a workflow-result summary artifact; returns its fingerprint.

    Carries everything step 4 (production) and the paper's figures need —
    the plan, the Spearman scores, the knapsack choices, per-campaign
    S1–S4 fractions — but not the raw crash records (those live in the
    :class:`~repro.core.campaign_store.WorkflowStore`, if one was attached).
    """
    from .faults import PowerFail

    payload: Dict[str, object] = {
        "app": str(wf.app_name),
        "plan": plan_to_payload(wf.plan),
        "critical": list(wf.critical),
        "object_scores": [
            {"name": s.name, "rs": _finite_or_none(s.rs),
             "p_value": _finite_or_none(s.p_value),
             "critical": bool(s.critical)}
            for s in wf.object_scores
        ],
        "region_choices": [
            {"region_idx": int(c.region_idx), "freq": int(c.freq),
             "gain": _finite_or_none(c.gain),
             "overhead": _finite_or_none(c.overhead)}
            for c in wf.region_selection.choices
        ],
        "campaign_fractions": (
            {
                "baseline": wf.baseline_campaign.class_fractions(),
                "best": wf.best_campaign.class_fractions(),
            }
            # a static-plan workflow measured no campaigns at all
            if wf.baseline_campaign is not None and wf.best_campaign is not None
            else {}
        ),
        "summary": {k: _finite_or_none(v) for k, v in wf.summary().items()},
        "tau": _finite_or_none(wf.tau),
        "t_s": _finite_or_none(wf.t_s),
        "fault": (fault if fault is not None else PowerFail()).spec(),
        "cache": cache_to_payload(cache),
    }
    # only when non-default, so historical artifact fingerprints are unchanged
    plan_source = getattr(wf, "plan_source", "measured")
    if plan_source != "measured":
        payload["plan_source"] = str(plan_source)
    adaptive = getattr(wf, "adaptive", None)
    if adaptive is not None:
        # stopping decision, weights/evidence, sampler spec — the envelope
        # records *why* the adaptive plan is trustworthy, not just the plan
        payload["adaptive"] = adaptive.to_payload()
    return _write_envelope(path, WORKFLOW_KIND, payload)


def load_workflow(path: str) -> WorkflowArtifact:
    payload, fp = _read_envelope(path, WORKFLOW_KIND)
    return WorkflowArtifact(
        app_name=str(payload["app"]),
        plan=plan_from_payload(payload["plan"]),
        critical=tuple(str(o) for o in payload["critical"]),
        object_scores=list(payload["object_scores"]),
        region_choices=list(payload["region_choices"]),
        campaign_fractions={
            k: {c: float(x) for c, x in v.items()}
            for k, v in dict(payload["campaign_fractions"]).items()
        },
        summary={k: _nan_if_none(v) for k, v in dict(payload["summary"]).items()},
        tau=_nan_if_none(payload["tau"]),
        t_s=_nan_if_none(payload["t_s"]),
        fault_spec=dict(payload["fault"]),
        cache=cache_from_payload(payload.get("cache")),
        fingerprint=fp,
        plan_source=str(payload.get("plan_source", "measured")),
        adaptive=(
            dict(payload["adaptive"]) if payload.get("adaptive") is not None
            else None
        ),
    )


# ---------------------------------------------------------- static-plan codec
@dataclass(frozen=True)
class StaticPlanArtifact:
    """A loaded static persist-plan prediction (verified fingerprint).

    The payload is the :meth:`repro.analysis.classify.StaticPlan.to_payload`
    document: per-object classification + confidence, per-region decision +
    estimated write traffic.  :meth:`static_plan` rehydrates the dataclass
    (imported lazily — core does not depend on the analysis package).
    """

    app_name: str
    payload: Dict[str, object]
    meta: Dict[str, object]
    fingerprint: str

    def static_plan(self):
        from ..analysis.classify import StaticPlan

        return StaticPlan.from_payload(self.payload)


def save_static_plan(path: str, static_plan,
                     meta: Optional[Mapping[str, object]] = None) -> str:
    """Write a static persist-plan artifact; returns its fingerprint.

    ``static_plan`` is duck-typed (anything with ``to_payload()``), so the
    analysis package stays an optional consumer of core, not a dependency.
    """
    payload: Dict[str, object] = dict(static_plan.to_payload())
    payload["meta"] = _sanitize_meta(meta or {})
    return _write_envelope(path, STATIC_PLAN_KIND, payload)


def load_static_plan(path: str) -> StaticPlanArtifact:
    payload, fp = _read_envelope(path, STATIC_PLAN_KIND)
    return StaticPlanArtifact(
        app_name=str(payload["app"]),
        payload={k: v for k, v in payload.items() if k != "meta"},
        meta=dict(payload.get("meta", {})),
        fingerprint=fp,
    )


# ------------------------------------------------------------- profile codec
def profile_to_payload(profile: RecomputeProfile) -> Dict[str, object]:
    return {
        "app": str(profile.app_name),
        "fault": dict(profile.fault_spec),
        "fractions": {
            c: float(profile.fractions.get(c, 0.0))
            for c in ("S1", "S2", "S3", "S4")
        },
        "extra_iters_hist": [[int(i), int(c)] for i, c in profile.extra_iters_hist],
        "golden_iters": int(profile.golden_iters),
        "n_records": int(profile.n_records),
    }


def profile_from_payload(d: Mapping[str, object]) -> RecomputeProfile:
    return RecomputeProfile(
        app_name=str(d["app"]),
        fault_spec=dict(d["fault"]),
        fractions={k: float(v) for k, v in dict(d["fractions"]).items()},
        extra_iters_hist=tuple((int(i), int(c)) for i, c in d["extra_iters_hist"]),
        golden_iters=int(d["golden_iters"]),
        n_records=int(d["n_records"]),
    )


@dataclass(frozen=True)
class ProfileArtifact:
    """A loaded recompute-profile artifact (verified fingerprint)."""

    profile: RecomputeProfile
    meta: Dict[str, object]
    fingerprint: str

    @property
    def app_name(self) -> str:
        return self.profile.app_name

    @property
    def fault(self) -> FaultModel:
        """The fault model the profile's campaign ran under."""
        return fault_model_from_spec(self.profile.fault_spec)


def save_profile(
    path: str,
    profile: RecomputeProfile,
    meta: Optional[Mapping[str, object]] = None,
) -> str:
    """Write a recompute-profile artifact; returns its fingerprint.

    This is the contract between the characterization pipeline and the
    system simulator: per-app, per-fault-model S1–S4 rates plus the measured
    extra-recompute-iteration histogram, fingerprinted so a hand-edited or
    truncated profile can never silently steer an efficiency study.
    """
    payload: Dict[str, object] = profile_to_payload(profile)
    payload["meta"] = _sanitize_meta(meta or {})
    return _write_envelope(path, PROFILE_KIND, payload)


def load_profile(path: str) -> ProfileArtifact:
    payload, fp = _read_envelope(path, PROFILE_KIND)
    return ProfileArtifact(
        profile=profile_from_payload(payload),
        meta=dict(payload.get("meta", {})),
        fingerprint=fp,
    )


def profile_from_workflow(
    artifact: "WorkflowArtifact", which: str = "best"
) -> RecomputeProfile:
    """A :class:`RecomputeProfile` from a stored workflow summary.

    Workflow artifacts carry per-campaign S1–S4 fractions but not the raw
    records, so the recompute-cost histogram is empty — the simulator then
    prices S2 recoveries at the NVM-restore cost alone (optimistic; prefer a
    profile saved by :func:`save_profile` from a live campaign when one is
    available).  ``which`` selects the measured campaign: ``"best"``
    (persist-everywhere, the plan's upper bound) or ``"baseline"``.
    """
    if which not in artifact.campaign_fractions:
        raise ArtifactError(
            f"workflow artifact has no {which!r} campaign "
            f"(have {sorted(artifact.campaign_fractions)})"
        )
    return RecomputeProfile.from_fractions(
        artifact.app_name,
        artifact.campaign_fractions[which],
        fault_spec=artifact.fault_spec,
    )


# -------------------------------------------------------------------- replay
def replay_plan(
    artifact: Union[str, PlanArtifact, WorkflowArtifact],
    app: IterativeApp,
    cache: Optional[CacheConfig] = None,
    n_tests: int = 100,
    seed: int = 0,
    fault: Optional[FaultModel] = None,
    n_workers: int = 1,
    store_path: Optional[str] = None,
) -> CampaignResult:
    """Run a crash campaign under a plan loaded from an artifact.

    ``fault=None`` replays under the model the plan was characterized with,
    and ``cache=None`` under the recorded characterization cache geometry
    (both rehydrated from the artifact) — replaying under a different model
    is the cross-fault robustness experiment of
    ``benchmarks/bench_recomputability.py --robustness-matrix``; S1–S4
    numbers from a *different cache geometry* would not be comparable to
    the artifact's recorded expectations, so only pass ``cache`` when that
    shift is the experiment.
    """
    if isinstance(artifact, (str, os.PathLike)):
        artifact = load_plan(os.fspath(artifact))
    if artifact.app_name != app.name:
        raise ArtifactError(
            f"plan artifact belongs to app {artifact.app_name!r}, "
            f"cannot replay on {app.name!r}"
        )
    if fault is None:
        fault = artifact.fault
    if cache is None:
        cache = artifact.cache if artifact.cache is not None else CacheConfig()
    tester = CrashTester(app, artifact.plan, cache, seed=seed, fault=fault)
    return tester.run_campaign(n_tests, n_workers=n_workers, store_path=store_path)
