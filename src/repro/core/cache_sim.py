"""NVCT cache model: write-back LRU cache between the app and the NVM arena.

The paper's NVCT tool is a PIN-based cache simulator that tracks, at
cache-block granularity, which values have reached NVM and which are dirty in
the (volatile) cache when a random crash fires.  We reproduce it with an
event-driven simulation:

* an application iteration is a sequence of *regions*; each region performs
  ordered read/write **sweeps** over its declared data objects (HPC solver
  loops and XLA fusions write arrays in sweep order);
* a fully-associative write-back, write-allocate LRU cache of
  ``capacity_blocks`` sits in front of NVM.  Dirty blocks reach NVM when
  evicted (natural write-back) or when an EasyCrash flush (CLWB semantics:
  write back, stay resident, become clean) targets their object;
* a crash at access-time ``W`` loses every dirty block still resident; the
  NVM image is the per-block mixture of the latest written-back versions.

Efficiency: a *crash window* (the two iterations around the crash point) is
simulated **once**, producing timestamped write-back records; every crash
test inside the window is then resolved vectorially from the records.  The
window is assumed to start cache-consistent, which is exact whenever an
iteration touches more blocks than the cache holds (the paper selects inputs
so the footprint exceeds the LLC; small-footprint apps are explicitly
EasyCrash-unsuitable, §8).  ``tests/test_cache_sim.py`` cross-checks the
record machinery against a brute-force simulator with hypothesis.

Two window-simulation engines produce bit-for-bit identical
:class:`WindowTrace` output:

* ``engine="ref"`` — the exact per-access ``OrderedDict`` LRU
  (:func:`simulate_window`'s historical body), kept as the reference oracle;
* ``engine="vec"`` — :func:`simulate_window_vec`, a structure-of-arrays
  simulator that walks the access stream *run-at-a-time*: the LRU recency
  list is represented as a deque of block-range runs with lazy invalidation,
  sweeps are processed as hit/miss groups, and eviction write-backs, flush
  events and timestamps come out of NumPy array ops instead of per-access
  dict mutation.  ``tests/test_campaign_vec.py`` holds the differential and
  property equivalence suite.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from .blocks import DEFAULT_BLOCK_BYTES

#: window-simulation engines accepted by :func:`simulate_window` and the
#: campaign layers above it (``CrashTester(engine=...)``)
ENGINES = ("ref", "vec")


class TornBlock(NamedTuple):
    """A cacheline whose in-flight store landed partially at the crash.

    Bytes ``[0, cut_bytes)`` of block ``block`` of ``obj`` carry the new
    version written by region occurrence ``seq``; the suffix keeps whatever
    the resolved NVM image held.  Produced by fault models
    (:mod:`repro.core.faults`), consumed by :func:`resolve_window_images` /
    :func:`apply_torn_blocks`.
    """

    obj: str
    block: int
    cut_bytes: int
    seq: int


@dataclass(frozen=True)
class CacheConfig:
    capacity_blocks: int = 2048
    block_bytes: int = DEFAULT_BLOCK_BYTES

    def spec(self) -> Dict[str, object]:
        return {
            "capacity_blocks": int(self.capacity_blocks),
            "block_bytes": int(self.block_bytes),
        }


# --------------------------------------------------------------------- events
@dataclass(frozen=True)
class Sweep:
    """Sequential pass over all blocks of ``obj``; write sweeps dirty them.

    ``hot``: objects re-read continuously while this sweep runs (e.g. the
    centroid table during a k-means assign pass).  Their blocks are
    re-accessed every ``hot_every`` accesses, so the LRU never ages them out
    — which is how small hot objects become *chronically dirty* and leave
    only ancient values in NVM (paper §8).
    """

    obj: str
    write: bool
    hot: Tuple[str, ...] = ()
    hot_every: int = 16


@dataclass(frozen=True)
class Flush:
    """EasyCrash persistence op on ``obj`` (CLWB: write back + keep + clean)."""

    obj: str


Event = object  # Sweep | Flush


@dataclass(frozen=True)
class RegionEvents:
    """One region occurrence inside a window."""

    seq: int            # global sequence number of this region occurrence
    iter_idx: int       # application iteration it belongs to
    region_idx: int     # index into the app's region list
    events: Tuple[Event, ...]


@dataclass
class SweepRecord:
    t_start: int
    obj: str
    seq: int
    n_blocks: int


@dataclass
class WindowTrace:
    """Everything a crash test needs, produced by one window simulation."""

    obj_blocks: Dict[str, int]
    # write-back records per object: arrays sorted by time
    wb_t: Dict[str, np.ndarray]
    wb_block: Dict[str, np.ndarray]
    wb_seq: Dict[str, np.ndarray]
    # write sweeps in time order (for live-value reconstruction)
    sweeps: List[SweepRecord]
    # region spans: (seq, iter_idx, region_idx, t0, t1)
    spans: List[Tuple[int, int, int, int, int]]
    t_end: int
    # write accounting over the window
    eviction_writes: int = 0
    flush_writes: int = 0
    flushed_clean_blocks: int = 0
    flush_ops: int = 0

    def span_for_time(self, t: int) -> Tuple[int, int, int, int, int]:
        for span in self.spans:
            if span[3] <= t < span[4]:
                return span
        return self.spans[-1]

    def sweep_soa(self) -> Tuple[np.ndarray, np.ndarray]:
        """SoA view of the write sweeps: ``(t_start, n_blocks)`` arrays in
        sweep order.  Sweeps never overlap in time, so the sweep in flight at
        a crash time (if any) is found by one ``searchsorted`` over
        ``t_start`` instead of a Python scan — the fault models' tearing
        hooks use this to locate the store queue they operate on."""
        soa = getattr(self, "_sweep_soa", None)
        if soa is None or soa[0].size != len(self.sweeps):
            soa = (
                np.fromiter((s.t_start for s in self.sweeps), np.int64, len(self.sweeps)),
                np.fromiter((s.n_blocks for s in self.sweeps), np.int64, len(self.sweeps)),
            )
            # WindowTrace is a plain (unfrozen) dataclass: memoize in place
            self._sweep_soa = soa
        return soa


class _LRU:
    """Exact fully-associative LRU write-back cache at block granularity.

    Alongside the recency dict, a per-object *dirty-block index* is
    maintained on every access / eviction / clean: ``_dirty[obj]`` maps
    block -> writer seq in recency order restricted to that object's dirty
    lines.  ``dirty_lines_of`` / ``dirty_resident_mask`` read the index in
    O(dirty blocks of obj) instead of walking the full cache — the historical
    full-cache scans made every flush (and every per-crash-point mask) cost
    O(capacity) regardless of how little of the object was dirty.
    """

    def __init__(self, capacity: int):
        self.capacity = capacity
        # (obj, block) -> writer seq (or -1 if clean)
        self._lines: "OrderedDict[Tuple[str, int], int]" = OrderedDict()
        # obj -> OrderedDict[block, seq]: the object's dirty lines, in the
        # same relative recency order they hold in _lines
        self._dirty: Dict[str, "OrderedDict[int, int]"] = {}

    def access(self, key: Tuple[str, int], writer_seq: int) -> Optional[Tuple[str, int, int]]:
        """Access one block; returns an eviction record (obj, block, seq) or None.

        ``writer_seq >= 0`` marks a write (dirties the line); ``-1`` is a read.
        """
        lines = self._lines
        prev = lines.pop(key, None)
        if prev is None and len(lines) >= self.capacity:
            evk, evseq = lines.popitem(last=False)
            if evseq >= 0:
                del self._dirty[evk[0]][evk[1]]
                evicted = (evk[0], evk[1], evseq)
            else:
                evicted = None
        else:
            evicted = None
        if writer_seq >= 0:
            lines[key] = writer_seq
            d = self._dirty.setdefault(key[0], OrderedDict())
            d.pop(key[1], None)
            d[key[1]] = writer_seq
        else:
            keep = prev if prev is not None and prev >= 0 else -1
            lines[key] = keep
            if keep >= 0:
                # a read hit of a dirty line moves it to MRU: mirror the move
                d = self._dirty[key[0]]
                d.pop(key[1], None)
                d[key[1]] = keep
        return evicted

    def dirty_lines_of(self, obj: str) -> List[Tuple[int, int]]:
        return list(self._dirty.get(obj, {}).items())

    def clean_obj(self, obj: str) -> None:
        d = self._dirty.get(obj)
        if not d:
            return
        lines = self._lines
        for blk in d:
            lines[(obj, blk)] = -1  # in-place: cleaning never changes recency
        d.clear()

    def dirty_resident_mask(self, obj: str, n_blocks: int) -> np.ndarray:
        m = np.zeros(n_blocks, dtype=bool)
        d = self._dirty.get(obj)
        if d:
            m[np.fromiter(d.keys(), np.int64, len(d))] = True
        return m


def simulate_window(
    cfg: CacheConfig,
    obj_blocks: Mapping[str, int],
    regions: Sequence[RegionEvents],
    engine: str = "ref",
) -> WindowTrace:
    """Run the event trace once; emit timestamped write-back records.

    Time advances by one unit per block access.  Flushes are instantaneous
    (they do not advance time) — the paper measures flush cost separately.

    ``engine`` selects the simulator: ``"ref"`` (default here — the exact
    per-access oracle this function has always been) or ``"vec"`` (the SoA
    run-at-a-time engine, :func:`simulate_window_vec`).  Both produce
    bit-for-bit identical :class:`WindowTrace` output.
    """
    if engine == "vec":
        return simulate_window_vec(cfg, obj_blocks, regions)
    if engine != "ref":
        raise ValueError(f"unknown window engine {engine!r}; have {ENGINES}")
    cache = _LRU(cfg.capacity_blocks)
    wb: Dict[str, List[Tuple[int, int, int]]] = {o: [] for o in obj_blocks}
    sweeps: List[SweepRecord] = []
    spans: List[Tuple[int, int, int, int, int]] = []
    trace = WindowTrace(
        obj_blocks=dict(obj_blocks),
        wb_t={}, wb_block={}, wb_seq={}, sweeps=sweeps, spans=spans, t_end=0,
    )
    t = 0
    for reg in regions:
        t0 = t
        for ev in reg.events:
            if isinstance(ev, Sweep):
                nb = obj_blocks[ev.obj]
                if ev.write:
                    sweeps.append(SweepRecord(t, ev.obj, reg.seq, nb))
                writer = reg.seq if ev.write else -1
                for b in range(nb):
                    evicted = cache.access((ev.obj, b), writer)
                    if evicted is not None:
                        eo, eb, eseq = evicted
                        wb[eo].append((t, eb, eseq))
                        trace.eviction_writes += 1
                    t += 1
                    if ev.hot and b % ev.hot_every == ev.hot_every - 1:
                        # refresh hot objects (reads; no time advance — they
                        # hit in L1 and cost nothing on the sweep timescale)
                        for h in ev.hot:
                            for hb in range(obj_blocks[h]):
                                ev2 = cache.access((h, hb), -1)
                                if ev2 is not None:
                                    eo, eb, eseq = ev2
                                    wb[eo].append((t, eb, eseq))
                                    trace.eviction_writes += 1
            elif isinstance(ev, Flush):
                dirty = cache.dirty_lines_of(ev.obj)
                nb = obj_blocks[ev.obj]
                for blk, seq in dirty:
                    wb[ev.obj].append((t, blk, seq))
                trace.flush_writes += len(dirty)
                trace.flushed_clean_blocks += nb - len(dirty)
                trace.flush_ops += 1
                cache.clean_obj(ev.obj)
            else:  # pragma: no cover - defensive
                raise TypeError(f"unknown event {ev!r}")
        spans.append((reg.seq, reg.iter_idx, reg.region_idx, t0, t))
    trace.t_end = t
    for o, recs in wb.items():
        if recs:
            arr = np.asarray(recs, dtype=np.int64)
            order = np.argsort(arr[:, 0], kind="stable")
            arr = arr[order]
            trace.wb_t[o] = arr[:, 0]
            trace.wb_block[o] = arr[:, 1]
            trace.wb_seq[o] = arr[:, 2]
        else:
            trace.wb_t[o] = np.zeros(0, dtype=np.int64)
            trace.wb_block[o] = np.zeros(0, dtype=np.int64)
            trace.wb_seq[o] = np.zeros(0, dtype=np.int64)
    return trace


# ------------------------------------------------------------ the SoA engine
class _RunLRU:
    """Run-structured exact LRU: the recency list as a deque of block runs.

    The access stream of :func:`simulate_window` is highly structured — whole
    objects swept block 0..nb-1 in order, hot objects re-read in full — so
    the LRU recency list is, at all times, a concatenation of *runs* of
    blocks of one object.  This class maintains that run list directly:

    * ``runs`` — deque of ``[run_id, obj, blocks]`` from LRU (head) to MRU
      (tail), with **lazy invalidation**: when a block is re-accessed it is
      appended to a new tail run and its old entry goes stale; stale entries
      are filtered with one vectorized ``loc`` comparison when the head is
      popped for eviction.
    * ``loc[obj][blk]`` — id of the run the block validly resides in (-1 when
      not resident); ``seq[obj][blk]`` — the dirty writer seq (-1 clean).

    A sweep is processed as alternating *hit groups* (move a block range to
    MRU: one run append) and *miss groups* (insert a range; evict exactly the
    overflow from the head, write-back records and their timestamps emitted
    as array slices).  Per-event cost is O(runs touched), not O(blocks).

    Equivalence argument for the miss group (the one subtle case): evictions
    pop valid lines strictly from the head while the group's own blocks are
    appended at the tail, and the k-th eviction of a group of n misses
    happens at access index ``no_evict + k`` — before that access's insert.
    A group block can therefore only be popped after every older valid line
    is consumed, by which point at least as many group blocks have been
    inserted as are popped, which is exactly the per-access order the
    reference engine executes.  ``tests/test_campaign_vec.py`` checks the
    equivalence property against the oracle under hypothesis.
    """

    __slots__ = ("capacity", "size", "runs", "loc", "seq", "_next_id")

    def __init__(self, capacity: int, obj_blocks: Mapping[str, int]):
        self.capacity = capacity
        self.size = 0
        self.runs: "deque[list]" = deque()
        self.loc = {o: np.full(nb, -1, np.int64) for o, nb in obj_blocks.items()}
        self.seq = {o: np.full(nb, -1, np.int64) for o, nb in obj_blocks.items()}
        self._next_id = 0

    def _new_run(self, obj: str, lo: int, hi: int) -> int:
        rid = self._next_id
        self._next_id += 1
        self.runs.append([rid, obj, np.arange(lo, hi, dtype=np.int64)])
        return rid

    def access_range(
        self,
        obj: str,
        lo: int,
        hi: int,
        w_seq: int,
        t0: int,
        dt: int,
        emit: Callable[[str, np.ndarray, np.ndarray, np.ndarray], None],
    ) -> None:
        """Access blocks ``lo..hi-1`` of ``obj`` in order; access ``j``
        happens at time ``t0 + dt*(j-lo)`` (``dt=0``: hot refresh, which the
        sweep clock treats as free)."""
        loc = self.loc[obj]
        j = lo
        while j < hi:
            res = loc[j:hi] >= 0
            first = bool(res[0])
            flips = np.flatnonzero(res != first)
            glen = int(flips[0]) if flips.size else (hi - j)
            if first:
                self._hit_group(obj, j, j + glen, w_seq)
            else:
                self._miss_group(obj, j, j + glen, w_seq, t0 + dt * (j - lo), dt, emit)
            j += glen

    def _hit_group(self, obj: str, lo: int, hi: int, w_seq: int) -> None:
        # re-accessed resident blocks move to MRU; reads keep their dirty seq
        rid = self._new_run(obj, lo, hi)
        self.loc[obj][lo:hi] = rid
        if w_seq >= 0:
            self.seq[obj][lo:hi] = w_seq

    def _miss_group(
        self, obj: str, lo: int, hi: int, w_seq: int, t0: int, dt: int, emit
    ) -> None:
        n = hi - lo
        no_evict = min(n, max(0, self.capacity - self.size))
        n_evict = n - no_evict
        rid = self._new_run(obj, lo, hi)
        self.loc[obj][lo:hi] = rid
        self.seq[obj][lo:hi] = w_seq if w_seq >= 0 else -1
        self.size += no_evict  # each evicting access pops one line, inserts one
        if n_evict:
            times = t0 + dt * (no_evict + np.arange(n_evict, dtype=np.int64))
            self._evict(n_evict, times, emit)

    def _evict(self, n_evict: int, times: np.ndarray, emit) -> None:
        k = 0
        while k < n_evict:
            run = self.runs[0]
            rid, obj, blocks = run
            valid = np.flatnonzero(self.loc[obj][blocks] == rid)
            if valid.size == 0:
                self.runs.popleft()
                continue
            take = min(valid.size, n_evict - k)
            idx = valid[:take]
            segs = blocks[idx]
            seqs = self.seq[obj][segs]
            dirty = seqs >= 0
            if dirty.any():
                emit(obj, times[k:k + take][dirty], segs[dirty], seqs[dirty])
            self.loc[obj][segs] = -1
            if take == valid.size:
                self.runs.popleft()
            else:
                run[2] = blocks[int(idx[take - 1]) + 1:]
            k += take

    def flush(self, obj: str, t: int, emit) -> int:
        """CLWB ``obj``: emit its dirty resident lines in recency order (the
        reference engine's OrderedDict walk order), clean them in place."""
        n_dirty = 0
        seq = self.seq[obj]
        loc = self.loc[obj]
        for run in self.runs:
            rid, o, blocks = run
            if o != obj:
                continue
            mask = (loc[blocks] == rid) & (seq[blocks] >= 0)
            if mask.any():
                segs = blocks[mask]
                emit(obj, np.full(segs.size, t, np.int64), segs, seq[segs])
                seq[segs] = -1
                n_dirty += segs.size
        return int(n_dirty)


def simulate_window_vec(
    cfg: CacheConfig,
    obj_blocks: Mapping[str, int],
    regions: Sequence[RegionEvents],
) -> WindowTrace:
    """SoA window simulator: bit-for-bit :func:`simulate_window`, array-at-a-time.

    The event stream is walked run-at-a-time through :class:`_RunLRU`;
    write-back records (eviction and flush) are emitted as array batches in
    the reference engine's exact emission order, so the stable per-object
    time sort below reproduces its ``wb_*`` arrays exactly — including the
    relative order of same-timestamp records, which the batch image resolver
    and the tearing hooks both rely on.
    """
    cache = _RunLRU(cfg.capacity_blocks, obj_blocks)
    wb: Dict[str, List[Tuple[np.ndarray, np.ndarray, np.ndarray]]] = {
        o: [] for o in obj_blocks
    }
    sweeps: List[SweepRecord] = []
    spans: List[Tuple[int, int, int, int, int]] = []
    trace = WindowTrace(
        obj_blocks=dict(obj_blocks),
        wb_t={}, wb_block={}, wb_seq={}, sweeps=sweeps, spans=spans, t_end=0,
    )

    def emit(obj: str, ts: np.ndarray, blks: np.ndarray, seqs: np.ndarray) -> None:
        wb[obj].append((ts, blks, seqs))
        trace.eviction_writes += ts.size

    t = 0
    for reg in regions:
        t0 = t
        for ev in reg.events:
            if isinstance(ev, Sweep):
                nb = obj_blocks[ev.obj]
                if ev.write:
                    sweeps.append(SweepRecord(t, ev.obj, reg.seq, nb))
                writer = reg.seq if ev.write else -1
                if not ev.hot:
                    cache.access_range(ev.obj, 0, nb, writer, t, 1, emit)
                    t += nb
                else:
                    # hot refreshes fire after each access b with
                    # b % hot_every == hot_every - 1, at the already-advanced
                    # clock; the refresh accesses are free (dt=0)
                    e = ev.hot_every
                    b = 0
                    while b < nb:
                        ce = min(nb, (b // e + 1) * e)
                        cache.access_range(ev.obj, b, ce, writer, t, 1, emit)
                        t += ce - b
                        if ce % e == 0:
                            for h in ev.hot:
                                cache.access_range(h, 0, obj_blocks[h], -1, t, 0, emit)
                        b = ce
            elif isinstance(ev, Flush):
                n_dirty = cache.flush(
                    ev.obj, t, lambda obj, ts, blks, seqs: wb[obj].append((ts, blks, seqs))
                )
                trace.flush_writes += n_dirty
                trace.flushed_clean_blocks += obj_blocks[ev.obj] - n_dirty
                trace.flush_ops += 1
            else:  # pragma: no cover - defensive
                raise TypeError(f"unknown event {ev!r}")
        spans.append((reg.seq, reg.iter_idx, reg.region_idx, t0, t))
    trace.t_end = t
    for o, batches in wb.items():
        if batches:
            ts = np.concatenate([b[0] for b in batches])
            blks = np.concatenate([b[1] for b in batches])
            seqs = np.concatenate([b[2] for b in batches])
            order = np.argsort(ts, kind="stable")
            trace.wb_t[o] = ts[order]
            trace.wb_block[o] = blks[order]
            trace.wb_seq[o] = seqs[order]
        else:
            trace.wb_t[o] = np.zeros(0, dtype=np.int64)
            trace.wb_block[o] = np.zeros(0, dtype=np.int64)
            trace.wb_seq[o] = np.zeros(0, dtype=np.int64)
    return trace


def _apply_versions(
    base: np.ndarray,
    blocks: np.ndarray,
    seqs: np.ndarray,
    versions: Mapping[int, np.ndarray],
    block_bytes: int,
) -> np.ndarray:
    """Overwrite ``base`` blockwise with versioned values, in record order."""
    out = np.ascontiguousarray(base).copy()
    flat = out.view(np.uint8).reshape(-1)
    nbytes = flat.size
    for blk, seq in zip(blocks.tolist(), seqs.tolist()):
        src = versions[seq]
        sflat = np.ascontiguousarray(src).view(np.uint8).reshape(-1)
        lo = blk * block_bytes
        hi = min(lo + block_bytes, nbytes)
        flat[lo:hi] = sflat[lo:hi]
    return flat.view(base.dtype).reshape(base.shape)


def resolve_nvm_image(
    trace: WindowTrace,
    crash_t: int,
    start_values: Mapping[str, np.ndarray],
    seq_values: Mapping[int, Mapping[str, np.ndarray]],
    block_bytes: int,
    chronic_base: Optional[Mapping[str, np.ndarray]] = None,
) -> Dict[str, np.ndarray]:
    """NVM image at ``crash_t``: latest written-back version per block.

    ``chronic_base``: for objects re-dirtied every iteration, blocks with *no*
    write-back anywhere in the window were — by steady-state periodicity —
    never written back since the value in ``chronic_base`` (the last flush,
    or initialization).  This captures the paper's §8 small-hot-object case:
    data resident in cache forever leaves only ancient values in NVM.
    """
    out: Dict[str, np.ndarray] = {}
    for obj, base in start_values.items():
        base = _chronic_adjusted_base(
            trace, obj, np.asarray(base), chronic_base, block_bytes
        )
        t = trace.wb_t[obj]
        n = int(np.searchsorted(t, crash_t, side="right"))
        if n == 0:
            out[obj] = np.array(base, copy=True)
            continue
        needed = set(trace.wb_seq[obj][:n].tolist())
        versions = {seq: seq_values[seq][obj] for seq in needed}
        out[obj] = _apply_versions(
            base, trace.wb_block[obj][:n], trace.wb_seq[obj][:n], versions, block_bytes
        )
    return out


def _chronic_adjusted_base(
    trace: WindowTrace,
    obj: str,
    base: np.ndarray,
    chronic_base: Optional[Mapping[str, np.ndarray]],
    block_bytes: int,
) -> np.ndarray:
    """Replace blocks with no write-back anywhere in the window by their
    chronic (last-flushed / initial) values — the paper's §8 small-hot-object
    case, where data resident in cache forever leaves only ancient NVM."""
    from .blocks import mix_blocks, obj_num_blocks

    if chronic_base is None or obj not in chronic_base:
        return base
    nb = obj_num_blocks(base, block_bytes)
    chronic_mask = np.ones(nb, dtype=bool)
    if trace.wb_block[obj].size:
        seen = np.unique(trace.wb_block[obj])
        chronic_mask[seen[seen < nb]] = False
    if not chronic_mask.any():
        return base
    return mix_blocks(chronic_base[obj], base, ~chronic_mask, block_bytes)


def apply_torn_blocks(
    image: Dict[str, np.ndarray],
    torn: Sequence[TornBlock],
    seq_values: Mapping[int, Mapping[str, np.ndarray]],
    block_bytes: int,
) -> Dict[str, np.ndarray]:
    """Land partial cachelines on a resolved NVM image, in place.

    For each :class:`TornBlock`, the first ``cut_bytes`` bytes of the block
    take the torn store's version; the rest of the block keeps the image's
    value.  Arrays in ``image`` must own their data (the resolvers' snapshots
    do); they are mutated and the same dict is returned.
    """
    for tb in torn:
        if tb.obj not in image:
            continue
        versions = seq_values.get(tb.seq, {})
        if tb.obj not in versions:
            continue
        dst = image[tb.obj].view(np.uint8).reshape(-1)
        src = np.ascontiguousarray(versions[tb.obj]).view(np.uint8).reshape(-1)
        lo = tb.block * block_bytes
        hi = min(lo + min(int(tb.cut_bytes), block_bytes), dst.size)
        if hi > lo:
            dst[lo:hi] = src[lo:hi]
    return image


def resolve_window_images(
    trace: WindowTrace,
    crash_ts: Sequence[int],
    start_values: Mapping[str, np.ndarray],
    seq_values: Mapping[int, Mapping[str, np.ndarray]],
    block_bytes: int,
    chronic_base: Optional[Mapping[str, np.ndarray]] = None,
    tearing: Optional[Sequence[Optional[Sequence[TornBlock]]]] = None,
) -> Tuple[List[Dict[str, np.ndarray]], List[Dict[str, np.ndarray]]]:
    """Batch form of :func:`resolve_nvm_image` + :func:`resolve_live_values`.

    All crash times of one window are resolved in a single ascending pass
    over the window's write-back records and write sweeps: each record/sweep
    byte range is applied to a running image exactly once, and a snapshot is
    taken at every crash time.  Equivalent to calling the single-shot
    resolvers per crash time (write-backs compose in record order; sweeps
    never overlap in time, so extending the in-flight sweep before applying
    later ones reproduces the per-time application order), but one campaign
    window costs one pass instead of one pass per test.

    ``tearing`` (the fault-model hook): an optional per-crash list of
    :class:`TornBlock` partial-store patches, aligned with ``crash_ts``;
    each is applied to that crash's NVM snapshot only — the running image
    and the other crashes' snapshots are unaffected.

    Returns ``(nvm_images, live_values)`` aligned with ``crash_ts``.
    """
    order = sorted(range(len(crash_ts)), key=lambda i: crash_ts[i])
    nvm_out: List[Optional[Dict[str, np.ndarray]]] = [None] * len(crash_ts)
    live_out: List[Optional[Dict[str, np.ndarray]]] = [None] * len(crash_ts)

    shapes: Dict[str, Tuple[np.dtype, Tuple[int, ...]]] = {}
    nvm_cur: Dict[str, np.ndarray] = {}    # running NVM image, flat uint8
    live_cur: Dict[str, np.ndarray] = {}   # running live image, flat uint8
    for obj, base in start_values.items():
        base = np.asarray(base)
        shapes[obj] = (base.dtype, base.shape)
        nvm_base = _chronic_adjusted_base(trace, obj, base, chronic_base, block_bytes)
        nvm_cur[obj] = np.ascontiguousarray(nvm_base).copy().view(np.uint8).reshape(-1)
        live_cur[obj] = np.ascontiguousarray(base).copy().view(np.uint8).reshape(-1)
    wb_cursor = {obj: 0 for obj in start_values}
    sweep_done = [0] * len(trace.sweeps)

    for idx in order:
        ct = int(crash_ts[idx])
        nvm_snap: Dict[str, np.ndarray] = {}
        for obj in start_values:
            n = int(np.searchsorted(trace.wb_t[obj], ct, side="right"))
            c = wb_cursor[obj]
            if n > c:
                flat = nvm_cur[obj]
                nbytes = flat.size
                blocks = trace.wb_block[obj][c:n].tolist()
                seqs = trace.wb_seq[obj][c:n].tolist()
                for blk, seq in zip(blocks, seqs):
                    src = np.ascontiguousarray(seq_values[seq][obj]).view(np.uint8).reshape(-1)
                    lo = blk * block_bytes
                    hi = min(lo + block_bytes, nbytes)
                    flat[lo:hi] = src[lo:hi]
                wb_cursor[obj] = n
            dtype, shape = shapes[obj]
            nvm_snap[obj] = nvm_cur[obj].copy().view(dtype).reshape(shape)
        if tearing is not None and tearing[idx]:
            apply_torn_blocks(nvm_snap, tearing[idx], seq_values, block_bytes)
        nvm_out[idx] = nvm_snap

        for si, sw in enumerate(trace.sweeps):
            if sw.t_start >= ct:
                break
            if sw.obj not in live_cur:
                continue
            done = min(sw.n_blocks, ct - sw.t_start)
            prev = sweep_done[si]
            if done > prev:
                flat = live_cur[sw.obj]
                src = np.ascontiguousarray(seq_values[sw.seq][sw.obj]).view(np.uint8).reshape(-1)
                lo = prev * block_bytes
                hi = min(done * block_bytes, flat.size)
                if hi > lo:
                    flat[lo:hi] = src[lo:hi]
                sweep_done[si] = done
        live_snap: Dict[str, np.ndarray] = {}
        for obj, flat in live_cur.items():
            dtype, shape = shapes[obj]
            live_snap[obj] = flat.copy().view(dtype).reshape(shape)
        live_out[idx] = live_snap
    return nvm_out, live_out  # type: ignore[return-value]


def resolve_live_values(
    trace: WindowTrace,
    crash_t: int,
    start_values: Mapping[str, np.ndarray],
    seq_values: Mapping[int, Mapping[str, np.ndarray]],
    block_bytes: int,
) -> Dict[str, np.ndarray]:
    """True (cache-inclusive) values at ``crash_t``: all writes applied,
    the in-flight sweep applied partially."""
    out = {o: np.array(v, copy=True) for o, v in start_values.items()}
    for sw in trace.sweeps:
        if sw.t_start >= crash_t:
            break
        if sw.obj not in out:
            continue
        done = min(sw.n_blocks, crash_t - sw.t_start)
        if done <= 0:
            continue
        base = out[sw.obj]
        flat = np.ascontiguousarray(base).copy().view(np.uint8).reshape(-1)
        src = np.ascontiguousarray(seq_values[sw.seq][sw.obj]).view(np.uint8).reshape(-1)
        hi = min(done * block_bytes, flat.size)
        flat[:hi] = src[:hi]
        out[sw.obj] = flat.view(base.dtype).reshape(base.shape)
    return out
