"""NVCT cache model: write-back LRU cache between the app and the NVM arena.

The paper's NVCT tool is a PIN-based cache simulator that tracks, at
cache-block granularity, which values have reached NVM and which are dirty in
the (volatile) cache when a random crash fires.  We reproduce it with an
event-driven simulation:

* an application iteration is a sequence of *regions*; each region performs
  ordered read/write **sweeps** over its declared data objects (HPC solver
  loops and XLA fusions write arrays in sweep order);
* a fully-associative write-back, write-allocate LRU cache of
  ``capacity_blocks`` sits in front of NVM.  Dirty blocks reach NVM when
  evicted (natural write-back) or when an EasyCrash flush (CLWB semantics:
  write back, stay resident, become clean) targets their object;
* a crash at access-time ``W`` loses every dirty block still resident; the
  NVM image is the per-block mixture of the latest written-back versions.

Efficiency: a *crash window* (the two iterations around the crash point) is
simulated **once**, producing timestamped write-back records; every crash
test inside the window is then resolved vectorially from the records.  The
window is assumed to start cache-consistent, which is exact whenever an
iteration touches more blocks than the cache holds (the paper selects inputs
so the footprint exceeds the LLC; small-footprint apps are explicitly
EasyCrash-unsuitable, §8).  ``tests/test_cache_sim.py`` cross-checks the
record machinery against a brute-force simulator with hypothesis.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from .blocks import DEFAULT_BLOCK_BYTES


class TornBlock(NamedTuple):
    """A cacheline whose in-flight store landed partially at the crash.

    Bytes ``[0, cut_bytes)`` of block ``block`` of ``obj`` carry the new
    version written by region occurrence ``seq``; the suffix keeps whatever
    the resolved NVM image held.  Produced by fault models
    (:mod:`repro.core.faults`), consumed by :func:`resolve_window_images` /
    :func:`apply_torn_blocks`.
    """

    obj: str
    block: int
    cut_bytes: int
    seq: int


@dataclass(frozen=True)
class CacheConfig:
    capacity_blocks: int = 2048
    block_bytes: int = DEFAULT_BLOCK_BYTES


# --------------------------------------------------------------------- events
@dataclass(frozen=True)
class Sweep:
    """Sequential pass over all blocks of ``obj``; write sweeps dirty them.

    ``hot``: objects re-read continuously while this sweep runs (e.g. the
    centroid table during a k-means assign pass).  Their blocks are
    re-accessed every ``hot_every`` accesses, so the LRU never ages them out
    — which is how small hot objects become *chronically dirty* and leave
    only ancient values in NVM (paper §8).
    """

    obj: str
    write: bool
    hot: Tuple[str, ...] = ()
    hot_every: int = 16


@dataclass(frozen=True)
class Flush:
    """EasyCrash persistence op on ``obj`` (CLWB: write back + keep + clean)."""

    obj: str


Event = object  # Sweep | Flush


@dataclass(frozen=True)
class RegionEvents:
    """One region occurrence inside a window."""

    seq: int            # global sequence number of this region occurrence
    iter_idx: int       # application iteration it belongs to
    region_idx: int     # index into the app's region list
    events: Tuple[Event, ...]


@dataclass
class SweepRecord:
    t_start: int
    obj: str
    seq: int
    n_blocks: int


@dataclass
class WindowTrace:
    """Everything a crash test needs, produced by one window simulation."""

    obj_blocks: Dict[str, int]
    # write-back records per object: arrays sorted by time
    wb_t: Dict[str, np.ndarray]
    wb_block: Dict[str, np.ndarray]
    wb_seq: Dict[str, np.ndarray]
    # write sweeps in time order (for live-value reconstruction)
    sweeps: List[SweepRecord]
    # region spans: (seq, iter_idx, region_idx, t0, t1)
    spans: List[Tuple[int, int, int, int, int]]
    t_end: int
    # write accounting over the window
    eviction_writes: int = 0
    flush_writes: int = 0
    flushed_clean_blocks: int = 0
    flush_ops: int = 0

    def span_for_time(self, t: int) -> Tuple[int, int, int, int, int]:
        for span in self.spans:
            if span[3] <= t < span[4]:
                return span
        return self.spans[-1]


class _LRU:
    """Exact fully-associative LRU write-back cache at block granularity."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        # (obj, block) -> writer seq (or -1 if clean)
        self._lines: "OrderedDict[Tuple[str, int], int]" = OrderedDict()

    def access(self, key: Tuple[str, int], writer_seq: int) -> Optional[Tuple[str, int, int]]:
        """Access one block; returns an eviction record (obj, block, seq) or None.

        ``writer_seq >= 0`` marks a write (dirties the line); ``-1`` is a read.
        """
        lines = self._lines
        prev = lines.pop(key, None)
        if prev is None and len(lines) >= self.capacity:
            evk, evseq = lines.popitem(last=False)
            evicted = (evk[0], evk[1], evseq) if evseq >= 0 else None
        else:
            evicted = None
        if writer_seq >= 0:
            lines[key] = writer_seq
        else:
            lines[key] = prev if prev is not None and prev >= 0 else -1
        return evicted

    def dirty_lines_of(self, obj: str) -> List[Tuple[int, int]]:
        return [(blk, seq) for (o, blk), seq in self._lines.items() if o == obj and seq >= 0]

    def clean_obj(self, obj: str) -> None:
        for k in list(self._lines.keys()):
            if k[0] == obj and self._lines[k] >= 0:
                self._lines[k] = -1

    def dirty_resident_mask(self, obj: str, n_blocks: int) -> np.ndarray:
        m = np.zeros(n_blocks, dtype=bool)
        for (o, blk), seq in self._lines.items():
            if o == obj and seq >= 0:
                m[blk] = True
        return m


def simulate_window(
    cfg: CacheConfig,
    obj_blocks: Mapping[str, int],
    regions: Sequence[RegionEvents],
) -> WindowTrace:
    """Run the event trace once; emit timestamped write-back records.

    Time advances by one unit per block access.  Flushes are instantaneous
    (they do not advance time) — the paper measures flush cost separately.
    """
    cache = _LRU(cfg.capacity_blocks)
    wb: Dict[str, List[Tuple[int, int, int]]] = {o: [] for o in obj_blocks}
    sweeps: List[SweepRecord] = []
    spans: List[Tuple[int, int, int, int, int]] = []
    trace = WindowTrace(
        obj_blocks=dict(obj_blocks),
        wb_t={}, wb_block={}, wb_seq={}, sweeps=sweeps, spans=spans, t_end=0,
    )
    t = 0
    for reg in regions:
        t0 = t
        for ev in reg.events:
            if isinstance(ev, Sweep):
                nb = obj_blocks[ev.obj]
                if ev.write:
                    sweeps.append(SweepRecord(t, ev.obj, reg.seq, nb))
                writer = reg.seq if ev.write else -1
                for b in range(nb):
                    evicted = cache.access((ev.obj, b), writer)
                    if evicted is not None:
                        eo, eb, eseq = evicted
                        wb[eo].append((t, eb, eseq))
                        trace.eviction_writes += 1
                    t += 1
                    if ev.hot and b % ev.hot_every == ev.hot_every - 1:
                        # refresh hot objects (reads; no time advance — they
                        # hit in L1 and cost nothing on the sweep timescale)
                        for h in ev.hot:
                            for hb in range(obj_blocks[h]):
                                ev2 = cache.access((h, hb), -1)
                                if ev2 is not None:
                                    eo, eb, eseq = ev2
                                    wb[eo].append((t, eb, eseq))
                                    trace.eviction_writes += 1
            elif isinstance(ev, Flush):
                dirty = cache.dirty_lines_of(ev.obj)
                nb = obj_blocks[ev.obj]
                for blk, seq in dirty:
                    wb[ev.obj].append((t, blk, seq))
                trace.flush_writes += len(dirty)
                trace.flushed_clean_blocks += nb - len(dirty)
                trace.flush_ops += 1
                cache.clean_obj(ev.obj)
            else:  # pragma: no cover - defensive
                raise TypeError(f"unknown event {ev!r}")
        spans.append((reg.seq, reg.iter_idx, reg.region_idx, t0, t))
    trace.t_end = t
    for o, recs in wb.items():
        if recs:
            arr = np.asarray(recs, dtype=np.int64)
            order = np.argsort(arr[:, 0], kind="stable")
            arr = arr[order]
            trace.wb_t[o] = arr[:, 0]
            trace.wb_block[o] = arr[:, 1]
            trace.wb_seq[o] = arr[:, 2]
        else:
            trace.wb_t[o] = np.zeros(0, dtype=np.int64)
            trace.wb_block[o] = np.zeros(0, dtype=np.int64)
            trace.wb_seq[o] = np.zeros(0, dtype=np.int64)
    return trace


def _apply_versions(
    base: np.ndarray,
    blocks: np.ndarray,
    seqs: np.ndarray,
    versions: Mapping[int, np.ndarray],
    block_bytes: int,
) -> np.ndarray:
    """Overwrite ``base`` blockwise with versioned values, in record order."""
    out = np.ascontiguousarray(base).copy()
    flat = out.view(np.uint8).reshape(-1)
    nbytes = flat.size
    for blk, seq in zip(blocks.tolist(), seqs.tolist()):
        src = versions[seq]
        sflat = np.ascontiguousarray(src).view(np.uint8).reshape(-1)
        lo = blk * block_bytes
        hi = min(lo + block_bytes, nbytes)
        flat[lo:hi] = sflat[lo:hi]
    return flat.view(base.dtype).reshape(base.shape)


def resolve_nvm_image(
    trace: WindowTrace,
    crash_t: int,
    start_values: Mapping[str, np.ndarray],
    seq_values: Mapping[int, Mapping[str, np.ndarray]],
    block_bytes: int,
    chronic_base: Optional[Mapping[str, np.ndarray]] = None,
) -> Dict[str, np.ndarray]:
    """NVM image at ``crash_t``: latest written-back version per block.

    ``chronic_base``: for objects re-dirtied every iteration, blocks with *no*
    write-back anywhere in the window were — by steady-state periodicity —
    never written back since the value in ``chronic_base`` (the last flush,
    or initialization).  This captures the paper's §8 small-hot-object case:
    data resident in cache forever leaves only ancient values in NVM.
    """
    out: Dict[str, np.ndarray] = {}
    for obj, base in start_values.items():
        base = _chronic_adjusted_base(
            trace, obj, np.asarray(base), chronic_base, block_bytes
        )
        t = trace.wb_t[obj]
        n = int(np.searchsorted(t, crash_t, side="right"))
        if n == 0:
            out[obj] = np.array(base, copy=True)
            continue
        needed = set(trace.wb_seq[obj][:n].tolist())
        versions = {seq: seq_values[seq][obj] for seq in needed}
        out[obj] = _apply_versions(
            base, trace.wb_block[obj][:n], trace.wb_seq[obj][:n], versions, block_bytes
        )
    return out


def _chronic_adjusted_base(
    trace: WindowTrace,
    obj: str,
    base: np.ndarray,
    chronic_base: Optional[Mapping[str, np.ndarray]],
    block_bytes: int,
) -> np.ndarray:
    """Replace blocks with no write-back anywhere in the window by their
    chronic (last-flushed / initial) values — the paper's §8 small-hot-object
    case, where data resident in cache forever leaves only ancient NVM."""
    from .blocks import mix_blocks, obj_num_blocks

    if chronic_base is None or obj not in chronic_base:
        return base
    nb = obj_num_blocks(base, block_bytes)
    chronic_mask = np.ones(nb, dtype=bool)
    if trace.wb_block[obj].size:
        seen = np.unique(trace.wb_block[obj])
        chronic_mask[seen[seen < nb]] = False
    if not chronic_mask.any():
        return base
    return mix_blocks(chronic_base[obj], base, ~chronic_mask, block_bytes)


def apply_torn_blocks(
    image: Dict[str, np.ndarray],
    torn: Sequence[TornBlock],
    seq_values: Mapping[int, Mapping[str, np.ndarray]],
    block_bytes: int,
) -> Dict[str, np.ndarray]:
    """Land partial cachelines on a resolved NVM image, in place.

    For each :class:`TornBlock`, the first ``cut_bytes`` bytes of the block
    take the torn store's version; the rest of the block keeps the image's
    value.  Arrays in ``image`` must own their data (the resolvers' snapshots
    do); they are mutated and the same dict is returned.
    """
    for tb in torn:
        if tb.obj not in image:
            continue
        versions = seq_values.get(tb.seq, {})
        if tb.obj not in versions:
            continue
        dst = image[tb.obj].view(np.uint8).reshape(-1)
        src = np.ascontiguousarray(versions[tb.obj]).view(np.uint8).reshape(-1)
        lo = tb.block * block_bytes
        hi = min(lo + min(int(tb.cut_bytes), block_bytes), dst.size)
        if hi > lo:
            dst[lo:hi] = src[lo:hi]
    return image


def resolve_window_images(
    trace: WindowTrace,
    crash_ts: Sequence[int],
    start_values: Mapping[str, np.ndarray],
    seq_values: Mapping[int, Mapping[str, np.ndarray]],
    block_bytes: int,
    chronic_base: Optional[Mapping[str, np.ndarray]] = None,
    tearing: Optional[Sequence[Optional[Sequence[TornBlock]]]] = None,
) -> Tuple[List[Dict[str, np.ndarray]], List[Dict[str, np.ndarray]]]:
    """Batch form of :func:`resolve_nvm_image` + :func:`resolve_live_values`.

    All crash times of one window are resolved in a single ascending pass
    over the window's write-back records and write sweeps: each record/sweep
    byte range is applied to a running image exactly once, and a snapshot is
    taken at every crash time.  Equivalent to calling the single-shot
    resolvers per crash time (write-backs compose in record order; sweeps
    never overlap in time, so extending the in-flight sweep before applying
    later ones reproduces the per-time application order), but one campaign
    window costs one pass instead of one pass per test.

    ``tearing`` (the fault-model hook): an optional per-crash list of
    :class:`TornBlock` partial-store patches, aligned with ``crash_ts``;
    each is applied to that crash's NVM snapshot only — the running image
    and the other crashes' snapshots are unaffected.

    Returns ``(nvm_images, live_values)`` aligned with ``crash_ts``.
    """
    order = sorted(range(len(crash_ts)), key=lambda i: crash_ts[i])
    nvm_out: List[Optional[Dict[str, np.ndarray]]] = [None] * len(crash_ts)
    live_out: List[Optional[Dict[str, np.ndarray]]] = [None] * len(crash_ts)

    shapes: Dict[str, Tuple[np.dtype, Tuple[int, ...]]] = {}
    nvm_cur: Dict[str, np.ndarray] = {}    # running NVM image, flat uint8
    live_cur: Dict[str, np.ndarray] = {}   # running live image, flat uint8
    for obj, base in start_values.items():
        base = np.asarray(base)
        shapes[obj] = (base.dtype, base.shape)
        nvm_base = _chronic_adjusted_base(trace, obj, base, chronic_base, block_bytes)
        nvm_cur[obj] = np.ascontiguousarray(nvm_base).copy().view(np.uint8).reshape(-1)
        live_cur[obj] = np.ascontiguousarray(base).copy().view(np.uint8).reshape(-1)
    wb_cursor = {obj: 0 for obj in start_values}
    sweep_done = [0] * len(trace.sweeps)

    for idx in order:
        ct = int(crash_ts[idx])
        nvm_snap: Dict[str, np.ndarray] = {}
        for obj in start_values:
            n = int(np.searchsorted(trace.wb_t[obj], ct, side="right"))
            c = wb_cursor[obj]
            if n > c:
                flat = nvm_cur[obj]
                nbytes = flat.size
                blocks = trace.wb_block[obj][c:n].tolist()
                seqs = trace.wb_seq[obj][c:n].tolist()
                for blk, seq in zip(blocks, seqs):
                    src = np.ascontiguousarray(seq_values[seq][obj]).view(np.uint8).reshape(-1)
                    lo = blk * block_bytes
                    hi = min(lo + block_bytes, nbytes)
                    flat[lo:hi] = src[lo:hi]
                wb_cursor[obj] = n
            dtype, shape = shapes[obj]
            nvm_snap[obj] = nvm_cur[obj].copy().view(dtype).reshape(shape)
        if tearing is not None and tearing[idx]:
            apply_torn_blocks(nvm_snap, tearing[idx], seq_values, block_bytes)
        nvm_out[idx] = nvm_snap

        for si, sw in enumerate(trace.sweeps):
            if sw.t_start >= ct:
                break
            if sw.obj not in live_cur:
                continue
            done = min(sw.n_blocks, ct - sw.t_start)
            prev = sweep_done[si]
            if done > prev:
                flat = live_cur[sw.obj]
                src = np.ascontiguousarray(seq_values[sw.seq][sw.obj]).view(np.uint8).reshape(-1)
                lo = prev * block_bytes
                hi = min(done * block_bytes, flat.size)
                if hi > lo:
                    flat[lo:hi] = src[lo:hi]
                sweep_done[si] = done
        live_snap: Dict[str, np.ndarray] = {}
        for obj, flat in live_cur.items():
            dtype, shape = shapes[obj]
            live_snap[obj] = flat.copy().view(dtype).reshape(shape)
        live_out[idx] = live_snap
    return nvm_out, live_out  # type: ignore[return-value]


def resolve_live_values(
    trace: WindowTrace,
    crash_t: int,
    start_values: Mapping[str, np.ndarray],
    seq_values: Mapping[int, Mapping[str, np.ndarray]],
    block_bytes: int,
) -> Dict[str, np.ndarray]:
    """True (cache-inclusive) values at ``crash_t``: all writes applied,
    the in-flight sweep applied partially."""
    out = {o: np.array(v, copy=True) for o, v in start_values.items()}
    for sw in trace.sweeps:
        if sw.t_start >= crash_t:
            break
        if sw.obj not in out:
            continue
        done = min(sw.n_blocks, crash_t - sw.t_start)
        if done <= 0:
            continue
        base = out[sw.obj]
        flat = np.ascontiguousarray(base).copy().view(np.uint8).reshape(-1)
        src = np.ascontiguousarray(seq_values[sw.seq][sw.obj]).view(np.uint8).reshape(-1)
        hi = min(done * block_bytes, flat.size)
        flat[:hi] = src[:hi]
        out[sw.obj] = flat.view(base.dtype).reshape(base.shape)
    return out
