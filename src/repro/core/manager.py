"""EasyCrash production runtime for distributed training loops.

This is the framework-facing layer: given a train-state pytree and a
:class:`PersistPlan`-style policy, the manager

* flushes the plan's state leaves to a host-local :class:`NVMArena`
  (asynchronously, on a writer thread — a straggling host never blocks the
  step, and a skipped flush only increases staleness, which EasyCrash
  tolerates by construction);
* performs delta flushes: only blocks that changed since the last flush move
  (CPU stand-in for the ``delta_snapshot`` Pallas kernel);
* takes full coordinated checkpoints at the Young interval stretched by the
  measured recomputability (MTBF' = MTBF / (1 - R));
* on restart, tries the EasyCrash path (arena image + acceptance
  verification) before falling back to the last full checkpoint.

Every host persists only its own shards: the mechanism is O(local bytes) and
has zero cross-host traffic, so it scales to arbitrarily many nodes.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

from .arena import NVMArena
from .efficiency import young_interval


def _cast_like(img: np.ndarray, target: np.ndarray) -> np.ndarray:
    """Cast a loaded array to the target dtype; np.load round-trips extension
    dtypes (bfloat16) as raw void bytes, which only ``view`` can recover."""
    if img.dtype == target.dtype:
        return img
    if img.dtype.kind == "V" and img.dtype.itemsize == target.dtype.itemsize:
        return img.view(target.dtype)
    return img.astype(target.dtype)


def flatten_state(state: Mapping[str, Any], prefix: str = "") -> Dict[str, np.ndarray]:
    """Flatten a nested dict pytree of arrays into 'a/b/c' -> ndarray."""
    out: Dict[str, np.ndarray] = {}
    for k, v in state.items():
        key = f"{prefix}{k}"
        if isinstance(v, Mapping):
            out.update(flatten_state(v, key + "/"))
        else:
            out[key] = np.asarray(v)
    return out


def unflatten_state(flat: Mapping[str, np.ndarray]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for k, v in flat.items():
        parts = k.split("/")
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return out


@dataclass
class FlushPolicy:
    """Production analogue of :class:`PersistPlan`.

    ``leaves``: state leaves (flat names, prefix match allowed) to persist.
    ``every_steps``: flush cadence in optimizer steps (the 'frequency x').
    ``async_flush``: persist on a background thread (drops to sync in tests).
    ``max_pending``: back-pressure bound; beyond it flushes are *skipped*
    (bounded staleness instead of a stalled step — straggler mitigation).
    ``persist_mode``: which blocks a flush moves to NVM —
    ``"auto"`` (arena's own byte diff), ``"delta"`` (incremental: changed
    blocks only, detected by the ``delta_snapshot`` kernel, CPU reference off
    TPU) or ``"full"`` (whole-object rewrite, the C/R-style baseline).  All
    three produce byte-identical NVM images; they differ only in write
    traffic, which ``ManagerStats.bytes_written`` measures.
    """

    leaves: Tuple[str, ...]
    every_steps: int = 1
    async_flush: bool = True
    max_pending: int = 2
    persist_mode: str = "auto"

    def __post_init__(self):
        if self.persist_mode not in ("auto", "delta", "full"):
            raise ValueError(
                f"unknown persist_mode {self.persist_mode!r}; use 'auto', 'delta' or 'full'"
            )


@dataclass
class ManagerStats:
    flushes_issued: int = 0
    flushes_skipped: int = 0
    blocks_written: int = 0
    bytes_written: int = 0
    checkpoints_taken: int = 0
    easycrash_restores: int = 0
    checkpoint_restores: int = 0


class EasyCrashManager:
    def __init__(
        self,
        arena: NVMArena,
        policy: FlushPolicy,
        checkpoint_save: Optional[Callable[[int, Mapping[str, Any]], None]] = None,
        checkpoint_restore: Optional[Callable[[], Optional[Tuple[int, Dict[str, Any]]]]] = None,
        mtbf: Optional[float] = None,
        t_chk: Optional[float] = None,
        recomputability: float = 0.0,
        step_time: float = 1.0,
    ):
        self.arena = arena
        self.policy = policy
        self.checkpoint_save = checkpoint_save
        self.checkpoint_restore = checkpoint_restore
        self.stats = ManagerStats()
        self._q: "queue.Queue[Optional[Tuple[int, Dict[str, np.ndarray]]]]" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None
        if policy.async_flush:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()
        # checkpoint cadence in *steps*, from Young's formula on the stretched
        # MTBF (paper §7); None disables periodic checkpoints.
        self.checkpoint_every: Optional[int] = None
        if mtbf is not None and t_chk is not None:
            mtbf_ec = mtbf / max(1e-9, (1.0 - min(recomputability, 0.999999)))
            self.checkpoint_every = max(1, int(young_interval(t_chk, mtbf_ec) / step_time))

    # ------------------------------------------------------------------ flush
    @staticmethod
    def _match(name: str, leaf: str) -> bool:
        if leaf.endswith("*"):
            return name.startswith(leaf[:-1])
        return name == leaf or name.startswith(leaf + "/")

    def _selected(self, flat: Mapping[str, np.ndarray]) -> Dict[str, np.ndarray]:
        return {
            name: arr
            for name, arr in flat.items()
            if any(self._match(name, l) for l in self.policy.leaves)
        }

    def maybe_flush(self, step: int, state: Mapping[str, Any]) -> bool:
        """Issue an EasyCrash persistence op if the cadence says so.

        Returns True if a flush was issued (or enqueued)."""
        if step % self.policy.every_steps != 0:
            return False
        flat = flatten_state(state)
        sel = self._selected(flat)
        sel["__step__"] = np.asarray(step, dtype=np.int64)
        payload = {k: np.array(v, copy=True) for k, v in sel.items()}
        if self.policy.async_flush:
            if self._q.qsize() >= self.policy.max_pending:
                self.stats.flushes_skipped += 1   # straggler mitigation: skip
                return False
            self._q.put((step, payload))
        else:
            self._flush_now(step, payload)
        self.stats.flushes_issued += 1
        return True

    def _flush_now(self, step: int, payload: Mapping[str, np.ndarray]) -> None:
        from .delta_persist import persist_mask_for

        for name, arr in payload.items():
            mask = persist_mask_for(
                self.policy.persist_mode, self.arena.peek(name), arr,
                self.arena.block_bytes,
            )
            written = self.arena.flush(name, arr, dirty_resident_mask=mask)
            self.stats.blocks_written += written
            self.stats.bytes_written += written * self.arena.block_bytes
        self.arena.save_manifest()

    def _drain(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                self._flush_now(*item)
            except BaseException as e:  # surfaced on barrier()
                self._last_error = e

    def barrier(self) -> None:
        """Wait for all pending flushes (checkpoint/shutdown boundary)."""
        if self.policy.async_flush:
            while not self._q.empty():
                time.sleep(0.001)
            # one more roundtrip so an in-flight item finishes
            self._q.put((int(-1), {}))
            while not self._q.empty():
                time.sleep(0.001)
        if self._last_error is not None:
            raise self._last_error

    def close(self) -> None:
        if self._worker is not None:
            self.barrier()
            self._q.put(None)
            self._worker.join(timeout=5)
            self._worker = None

    # ------------------------------------------------------------- checkpoint
    def maybe_checkpoint(self, step: int, state: Mapping[str, Any]) -> bool:
        if (
            self.checkpoint_save is None
            or self.checkpoint_every is None
            or step == 0
            or step % self.checkpoint_every != 0
        ):
            return False
        self.barrier()
        self.checkpoint_save(step, state)
        self.stats.checkpoints_taken += 1
        return True

    # ---------------------------------------------------------------- restore
    def restore(
        self,
        init_state: Mapping[str, Any],
        verify: Optional[Callable[[Dict[str, Any], int], bool]] = None,
    ) -> Tuple[Dict[str, Any], int, str]:
        """Recovery: EasyCrash path first, checkpoint fallback second.

        ``verify(state, step)`` is the acceptance hook deciding whether the
        NVM image is usable; recomputability-by-construction means it may
        accept inconsistent-but-convergent images.
        Returns (state, step, source) with source in
        {"easycrash", "checkpoint", "fresh"}.
        """
        flat_init = flatten_state(init_state)
        # --- EasyCrash path: arena image over init state
        names = set(self.arena.names())
        if "__step__" in names:
            merged = dict(flat_init)
            for name in names:
                if name == "__step__" or name.startswith("__chk__/"):
                    continue
                if name in merged:
                    img = self.arena.get(name)
                    if img.shape == merged[name].shape:
                        merged[name] = _cast_like(img, merged[name])
            step = int(self.arena.get("__step__"))
            candidate = unflatten_state(merged)
            if verify is None or verify(candidate, step):
                self.stats.easycrash_restores += 1
                return candidate, step, "easycrash"
        # --- checkpoint fallback
        if self.checkpoint_restore is not None:
            got = self.checkpoint_restore()
            if got is not None:
                step, state = got
                self.stats.checkpoint_restores += 1
                return state, step, "checkpoint"
        return dict(init_state), 0, "fresh"
