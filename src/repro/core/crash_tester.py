"""NVCT: crash-test campaigns for application recomputability (paper §3–4).

A campaign repeatedly: picks a uniformly random crash point, synthesises the
post-crash NVM image through the cache model (:mod:`repro.core.cache_sim`),
restarts the application from the image, runs it to completion and classifies
the outcome:

* **S1** — passes acceptance verification with no extra iterations
  (the paper's definition of *successful recomputation*);
* **S2** — passes, but needed extra iterations;
* **S3** — interruption (exception / non-finite blow-up during recompute);
* **S4** — verification still fails after 2x the original iteration budget.

Recomputability = |S1| / |tests| (paper §2.2).  Each record also carries the
per-object data-inconsistency rate, which feeds the Spearman selection
(:mod:`repro.core.selection`).

What a "crash" *is* is pluggable: a :class:`~repro.core.faults.FaultModel`
controls the crash-point distribution, cacheline tearing, image corruption
and crashes-during-recovery.  The default :class:`~repro.core.faults.PowerFail`
reproduces the historical single-clean-power-fail engine bit-for-bit.
"""
from __future__ import annotations

import os
from concurrent.futures import ProcessPoolExecutor, as_completed
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .blocks import inconsistent_rate
from .cache_sim import (
    ENGINES,
    CacheConfig,
    Flush,
    RegionEvents,
    Sweep,
    WindowTrace,
    resolve_nvm_image,
    resolve_window_images,
    simulate_window,
)
from .faults import FaultModel, PowerFail
from .regions import IterativeApp, Region, State, VerifyResult, object_blocks
from .trace_cache import WindowPayload, WindowTraceCache, shared_trace_cache


def default_engine() -> str:
    """Window/recompute engine when none is requested: ``REPRO_ENGINE`` in
    the environment, else ``"vec"`` (the engines are bit-for-bit identical,
    so the default is simply the fast one)."""
    eng = os.environ.get("REPRO_ENGINE", "vec")
    if eng not in ENGINES:
        raise ValueError(f"REPRO_ENGINE={eng!r}: unknown engine; have {ENGINES}")
    return eng


def _lane_batch_target() -> int:
    """Lanes the vec engine aims to stack per batched-recompute call
    (``REPRO_LANE_BATCH``); also the shard-chunk size of
    :meth:`CrashTester.run_shards`, which bounds how many resolved NVM
    images are held at once."""
    try:
        return max(1, int(os.environ.get("REPRO_LANE_BATCH", "64")))
    except ValueError:
        return 64


@dataclass(frozen=True)
class PersistPlan:
    """Which objects to flush, where, and how often.

    ``region_freq[k] = x`` flushes the plan's objects at the end of region
    ``k`` on iterations where ``iter_idx % x == 0`` (frequency interpolation
    of Eq. 5).  An empty ``region_freq`` means no EasyCrash flushes at all.
    """

    objects: Tuple[str, ...] = ()
    region_freq: Mapping[int, int] = field(default_factory=dict)

    @staticmethod
    def none() -> "PersistPlan":
        return PersistPlan((), {})

    @staticmethod
    def at_loop_end(objects: Sequence[str], app: IterativeApp, x: int = 1) -> "PersistPlan":
        """Persist at the end of each main-loop iteration (paper Fig 2a)."""
        last = len(app.regions()) - 1
        return PersistPlan(tuple(objects), {last: x})

    @staticmethod
    def best(objects: Sequence[str], app: IterativeApp) -> "PersistPlan":
        """Persist at every region, every iteration (paper's costly upper bound)."""
        return PersistPlan(tuple(objects), {k: 1 for k in range(len(app.regions()))})


@dataclass(frozen=True)
class CrashRecord:
    iter_idx: int
    region_idx: int
    frac: float
    inconsistency: Dict[str, float]
    outcome: str          # "S1" | "S2" | "S3" | "S4"
    extra_iters: int
    verify_metric: float
    #: importance weight of the test that produced this record (1.0 for the
    #: historical uniform draw); self-normalized estimators divide by the
    #: weight sum, so uniform campaigns are numerically unchanged
    weight: float = 1.0


@dataclass(frozen=True)
class PlannedTest:
    """One pre-drawn crash test: campaign randomness is fully resolved up
    front (same draw order as the historical serial engine), so execution
    order — serial, sharded, parallel, resumed — cannot change the result.

    ``fault_seed`` carries the test's fault-model entropy (torn-write /
    bit-flip / recovery-crash decisions), pre-drawn by the planner for models
    that need it; 0 for the default :class:`~repro.core.faults.PowerFail`,
    whose planning draws are exactly the historical two per test.

    ``weight`` is the importance weight when the campaign's crash points
    were drawn from a biased proposal (``CrashTester(sampler=...)``): the
    uniform-over-proposal likelihood ratio, 1.0 for the historical uniform
    draw.  It rides into the :class:`CrashRecord` so stores and estimators
    see it.
    """

    index: int        # position in the campaign (stable output ordering)
    crash_iter: int   # iteration whose window the crash falls in
    crash_t: int      # crash time inside the window, in block accesses
    fault_seed: int = 0
    weight: float = 1.0


@dataclass(frozen=True)
class CampaignResult:
    app_name: str
    plan: PersistPlan
    records: List[CrashRecord]
    golden_iters: int
    window_write_stats: Dict[str, float]

    @property
    def n(self) -> int:
        return len(self.records)

    def spec(self) -> Dict[str, object]:
        """Strict-JSON identity of this campaign's inputs and outcome."""
        return {
            "app": self.app_name,
            "plan": {
                "objects": list(self.plan.objects),
                "region_freq": sorted(
                    (int(k), int(v)) for k, v in self.plan.region_freq.items()
                ),
            },
            "n_tests": self.n,
            "golden_iters": int(self.golden_iters),
            "class_fractions": self.class_fractions(),
            "window_write_stats": {
                k: float(v) for k, v in sorted(self.window_write_stats.items())
            },
        }

    def class_fractions(self) -> Dict[str, float]:
        out = {c: 0.0 for c in ("S1", "S2", "S3", "S4")}
        for r in self.records:
            out[r.outcome] += 1
        return {c: v / max(1, self.n) for c, v in out.items()}

    def weighted_class_fractions(self) -> Dict[str, float]:
        """Self-normalized IS estimate of the S1–S4 rates: sum of record
        weights per class over the total weight.  For a uniform campaign
        (all weights 1.0) this is exactly :meth:`class_fractions`."""
        out = {c: 0.0 for c in ("S1", "S2", "S3", "S4")}
        total = 0.0
        for r in self.records:
            out[r.outcome] += r.weight
            total += r.weight
        if total <= 0.0:
            return {c: 0.0 for c in out}
        return {c: v / total for c, v in out.items()}

    @property
    def recomputability(self) -> float:
        return self.class_fractions()["S1"]

    @property
    def weighted_recomputability(self) -> float:
        """S1 rate under the self-normalized IS estimator (== plain
        :attr:`recomputability` for uniform weights)."""
        return self.weighted_class_fractions()["S1"]

    def effective_n(self) -> float:
        """Kish effective sample size of the campaign's weights."""
        w = np.array([r.weight for r in self.records], dtype=float)
        s2 = float(np.sum(w * w))
        return float(np.sum(w)) ** 2 / s2 if s2 > 0.0 else 0.0

    def per_region_recomputability(self) -> Dict[int, Tuple[float, int]]:
        """region_idx -> (recomputability c_k, sample count)."""
        groups: Dict[int, List[CrashRecord]] = {}
        for r in self.records:
            groups.setdefault(r.region_idx, []).append(r)
        return {
            k: (sum(1 for r in v if r.outcome == "S1") / len(v), len(v))
            for k, v in groups.items()
        }

    def vectors_for_selection(self, obj: str) -> Tuple[np.ndarray, np.ndarray]:
        """(inconsistency rates, success indicator) for Spearman analysis."""
        x = np.array([r.inconsistency.get(obj, 0.0) for r in self.records])
        y = np.array([1.0 if r.outcome == "S1" else 0.0 for r in self.records])
        return x, y


class CrashTester:
    """NVCT driver bound to one application and one persist plan."""

    def __init__(
        self,
        app: IterativeApp,
        plan: PersistPlan,
        cache: CacheConfig = CacheConfig(),
        seed: int = 0,
        max_extra_factor: float = 2.0,
        fault: Optional[FaultModel] = None,
        engine: Optional[str] = None,
        trace_cache: Optional[WindowTraceCache] = None,
        sampler=None,
        lane_batch: Optional[int] = None,
    ):
        """``engine`` selects the campaign hot path — ``"vec"`` (SoA window
        simulator, batched recompute for apps with ``supports_batched_step``)
        or ``"ref"`` (the historical per-access / per-test oracle); ``None``
        resolves :func:`default_engine`.  Results are bit-for-bit identical.

        ``lane_batch`` caps how many restart lanes the vec engine stacks per
        batched-recompute call (and per shard chunk in :meth:`run_shards`);
        ``None`` falls back to the ``REPRO_LANE_BATCH`` environment variable
        (default 64).  Like ``engine`` it is an execution-strategy knob, not
        an experiment parameter: campaign results and store fingerprints are
        identical at any value.

        ``trace_cache`` is the cross-campaign window cache; ``None`` uses the
        process-shared one (:func:`~repro.core.trace_cache.shared_trace_cache`).
        Pass a private :class:`~repro.core.trace_cache.WindowTraceCache` to
        isolate a tester (benchmarks measuring cold paths do).

        ``sampler`` replaces the fault model's crash-point draw with an
        importance-sampled one (duck-typed:
        ``draw(rng, planner) -> (crash_iter, crash_t, weight)`` plus a
        JSON-safe ``spec()``; see
        :class:`~repro.core.adaptive.StaticPriorSampler`).  Planning-only:
        workers executing pre-drawn shards never consult it."""
        self.app = app
        self.plan = plan
        self.cache = cache
        self.seed = seed
        self.max_extra_factor = max_extra_factor
        self.fault = fault if fault is not None else PowerFail()
        self.sampler = sampler
        self.lane_batch = lane_batch
        self.engine = engine if engine is not None else default_engine()
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}; have {ENGINES}")
        self._trace_cache = trace_cache if trace_cache is not None else shared_trace_cache()
        self._golden_states: Optional[List[State]] = None
        self._golden_iters: int = 0
        self._golden_final: Optional[State] = None
        self._window_cache: Dict[int, Tuple[WindowTrace, Dict[int, Dict[str, np.ndarray]], int]] = {}
        self._iter_time: Optional[int] = None
        self._region_spans: Optional[List[Tuple[int, int]]] = None
        self._digest: Optional[str] = None
        # vec-engine fast paths: one canonical steady-state trace per
        # relative flush schedule, one init() per campaign for restart lanes
        self._canon_trace: Dict[tuple, Tuple[WindowTrace, int]] = {}
        self._init_base: Optional[State] = None

    # ---------------------------------------------------------------- golden
    def _ensure_golden(self) -> None:
        if self._golden_states is not None:
            return
        app = self.app
        state = app.init(self.seed)
        states = [
            {k: np.array(v, copy=True) for k, v in state.items()}
        ]
        it = 0
        while it < app.n_iters:
            state = app.run_iteration(state)
            it += 1
            states.append({k: np.array(v, copy=True) for k, v in state.items()})
            if app.converged(state, it):
                break
        self._golden_states = states
        self._golden_iters = it
        self._golden_final = state
        golden_verify = app.verify(state)
        if not golden_verify.passed:
            raise RuntimeError(
                f"golden run of {app.name} fails its own acceptance verification: "
                f"{golden_verify}"
            )

    @property
    def golden_iters(self) -> int:
        self._ensure_golden()
        return self._golden_iters

    def lane_batch_target(self) -> int:
        """Lanes the vec engine stacks per batched-recompute call: the
        constructor's ``lane_batch`` when given, else ``REPRO_LANE_BATCH``."""
        if self.lane_batch is not None:
            return max(1, int(self.lane_batch))
        return _lane_batch_target()

    def release_caches(self) -> None:
        """Drop the golden trajectory and window-image caches.

        Both re-materialise on demand (``_ensure_golden`` is deterministic),
        so this only trades recompute for memory — the workflow orchestrator
        calls it once a campaign's shards are assembled, so W+2 coexisting
        testers don't pin W+2 full golden trajectories.
        """
        self._golden_states = None
        self._golden_final = None
        self._window_cache = {}

    # ---------------------------------------------------------------- events
    def _tracked_objects(self, state: State) -> List[str]:
        regs = self.app.regions()
        names: List[str] = []
        for r in regs:
            for o in tuple(r.reads) + tuple(r.writes):
                if o not in names and o in state:
                    names.append(o)
        return names

    def _region_events(self, region: Region, region_idx: int, iter_idx: int) -> List[object]:
        events: List[object] = []
        hot = tuple(region.hot_reads)
        for o in region.reads:
            if o in hot:
                continue  # hot objects ride along with the big sweeps
            events.append(Sweep(o, write=False, hot=hot))
        for o in region.writes:
            events.append(Sweep(o, write=True, hot=hot))
        x = self.plan.region_freq.get(region_idx)
        if x and iter_idx % x == 0:
            for o in self.plan.objects:
                events.append(Flush(o))
        return events

    def _window_payload(self, state0: State, first: int, last: int) -> WindowPayload:
        """The plan-independent half of a window simulation: re-run the
        region functions over iterations [first, last] from ``state0`` (not
        mutated) and snapshot each region occurrence's written values."""
        app = self.app
        regs = app.regions()
        state = {k: np.array(v, copy=True) for k, v in state0.items()}
        tracked = self._tracked_objects(state)
        obj_blocks = object_blocks(state, tracked, self.cache.block_bytes)
        seq_values: Dict[int, Dict[str, np.ndarray]] = {}
        meta: List[Tuple[int, int, int]] = []
        seq = 0
        for it in range(first, last + 1):
            for ridx, region in enumerate(regs):
                state = region.fn(state)
                seq_values[seq] = {
                    o: np.array(state[o], copy=True) for o in region.writes if o in state
                }
                meta.append((seq, it, ridx))
                seq += 1
        return WindowPayload(seq_values, obj_blocks, tuple(meta))

    def _trace_from_payload(
        self, payload: WindowPayload, last: int
    ) -> Tuple[WindowTrace, Dict[int, Dict[str, np.ndarray]], int]:
        """The plan-dependent half: rebuild the event stream (flushes come
        from the persist plan) and run the selected cache-sim engine."""
        regs = self.app.regions()
        region_events = [
            RegionEvents(
                seq=seq,
                iter_idx=it,
                region_idx=ridx,
                events=tuple(self._region_events(regs[ridx], ridx, it)),
            )
            for (seq, it, ridx) in payload.meta
        ]
        trace = simulate_window(
            self.cache, payload.obj_blocks, region_events, engine=self.engine
        )
        crash_span_start = next(t0 for (s, it, ridx, t0, t1) in trace.spans if it == last)
        return trace, payload.seq_values, crash_span_start

    def _simulate_window_from(
        self, state0: State, first: int, last: int
    ) -> Tuple[WindowTrace, Dict[int, Dict[str, np.ndarray]], int]:
        """Simulate iterations [first, last] starting from ``state0``.

        ``state0`` is not mutated.  Returns the window trace, the per-region
        written values, and the time the *last* iteration's span starts at
        (crash times are drawn from the last iteration of a window).
        """
        return self._trace_from_payload(
            self._window_payload(state0, first, last), last
        )

    def _flush_schedule(self, first: int, last: int) -> Tuple[tuple, tuple]:
        """The window's *effective* flush schedule — which (iteration,
        region) slots actually fire, and what they flush.  Plans that fire
        nothing inside a window normalize to the same (empty) key, so e.g. a
        region-isolated campaign shares the baseline trace for windows its
        flush frequency skips."""
        fired = tuple(
            (it, ridx)
            for it in range(first, last + 1)
            for ridx, x in sorted(self.plan.region_freq.items())
            if x and it % x == 0
        )
        return (fired, tuple(self.plan.objects)) if fired else ((), ())

    def _simulate_crash_window(
        self, crash_iter: int
    ) -> Tuple[WindowTrace, Dict[int, Dict[str, np.ndarray]], int]:
        """Simulate iterations [crash_iter-1, crash_iter] once; cache result.

        Two cache layers: the tester-local ``_window_cache`` (this campaign)
        and the process-shared :class:`WindowTraceCache`, which lets the
        other campaigns of a workflow — and replays of the same plan under
        other fault models — reuse the window instead of re-simulating it.
        """
        if crash_iter in self._window_cache:
            return self._window_cache[crash_iter]
        self._ensure_golden()
        first = max(0, crash_iter - 1)
        shared = self._trace_cache
        wkey = (shared.app_token(self.app), self._state_digest(), first, crash_iter)
        tkey = wkey + (
            int(self.cache.capacity_blocks),
            int(self.cache.block_bytes),
            self._flush_schedule(first, crash_iter),
            self.engine,
        )
        result = shared.get_trace(tkey)
        if result is None:
            payload = shared.get_payload(wkey + (int(self.cache.block_bytes),))
            if payload is None:
                payload = self._window_payload(
                    self._golden_states[first], first, crash_iter
                )
                shared.put_payload(wkey + (int(self.cache.block_bytes),), payload)
            result = self._trace_from_canonical(payload, first, crash_iter)
            if result is None:
                result = self._trace_from_payload(payload, crash_iter)
                self._put_canonical(result[0], first, crash_iter)
            shared.put_trace(tkey, result)
        self._window_cache[crash_iter] = result
        return result

    # Steady-state windows ([ci-1, ci] with ci >= 2) start from the same
    # cold cache and replay the same event stream — the plan's flushes are
    # the only per-window variation, and only through the *relative* firing
    # pattern.  The cache dynamics are therefore shift-invariant in the
    # crash iteration: one simulated trace serves every steady window with
    # the same relative schedule, after relabeling the iteration indices in
    # its region spans.  The ref oracle never takes this path.
    def _canon_key(self, first: int, last: int) -> Optional[tuple]:
        if self.engine != "vec" or first != last - 1 or first < 1:
            return None
        fired, objs = self._flush_schedule(first, last)
        return (tuple((it - first, ridx) for it, ridx in fired), objs)

    def _put_canonical(self, trace: WindowTrace, first: int, last: int) -> None:
        key = self._canon_key(first, last)
        if key is not None and key not in self._canon_trace:
            self._canon_trace[key] = (trace, first)

    def _trace_from_canonical(
        self, payload: WindowPayload, first: int, last: int
    ) -> Optional[Tuple[WindowTrace, Dict[int, Dict[str, np.ndarray]], int]]:
        from dataclasses import replace

        key = self._canon_key(first, last)
        if key is None or key not in self._canon_trace:
            return None
        canon, canon_first = self._canon_trace[key]
        if canon.obj_blocks != payload.obj_blocks:
            return None
        delta = first - canon_first
        spans = [(s, it + delta, r, t0, t1) for (s, it, r, t0, t1) in canon.spans]
        trace = canon if delta == 0 else replace(canon, spans=spans)
        crash_span_start = next(t0 for (s, it, r, t0, t1) in trace.spans if it == last)
        return trace, payload.seq_values, crash_span_start

    # -------------------------------------------------------------- planning
    def region_time_spans(self) -> List[Tuple[int, int]]:
        """Per-region ``(t0, t1)`` offsets within one iteration's window clock.

        ``simulate_window`` advances time one unit per swept block (hot
        refreshes and flushes are free), so region span boundaries are pure
        arithmetic over object sizes — campaign planning never needs to
        simulate a window.  Fault models use these spans to bias crash-point
        draws toward specific regions.
        """
        if self._region_spans is not None:
            return self._region_spans
        self._ensure_golden()
        state0 = self._golden_states[0]
        tracked = self._tracked_objects(state0)
        blocks = object_blocks(state0, tracked, self.cache.block_bytes)
        spans: List[Tuple[int, int]] = []
        t = 0
        for region in self.app.regions():
            t0 = t
            hot = tuple(region.hot_reads)
            for o in region.reads:
                if o not in hot and o in blocks:
                    t += blocks[o]
            for o in region.writes:
                if o in blocks:
                    t += blocks[o]
            spans.append((t0, t))
        self._region_spans = spans
        return spans

    def _iter_access_time(self) -> int:
        """Block accesses one iteration contributes to a window's clock."""
        if self._iter_time is not None:
            return self._iter_time
        spans = self.region_time_spans()
        self._iter_time = spans[-1][1] if spans else 0
        return self._iter_time

    def window_bounds(self, crash_iter: int) -> Tuple[int, int]:
        """(t_lo, t_end) of the crash span: the window is iterations
        [crash_iter-1, crash_iter] and crash times are drawn from the last."""
        it_t = self._iter_access_time()
        if crash_iter >= 1:
            return it_t, 2 * it_t
        return 0, it_t

    # historical (pre-fault-model) spelling, kept for callers and tests
    _window_bounds = window_bounds

    def _draw_test(self, rng: np.random.Generator, index: int) -> PlannedTest:
        """One planned test via the fault model's crash-point hook; models
        that need per-test entropy get a fault seed drawn *after* the crash
        point, so the default model's draw stream stays the historical one.
        An attached ``sampler`` takes over the crash-point draw (and supplies
        the importance weight); the fault model keeps its other hooks."""
        if self.sampler is not None:
            crash_iter, crash_t, weight = self.sampler.draw(rng, self)
        else:
            crash_iter, crash_t = self.fault.draw_crash_point(rng, self)
            weight = 1.0
        fault_seed = (
            int(rng.integers(0, np.iinfo(np.int64).max))
            if self.fault.uses_test_entropy
            else 0
        )
        return PlannedTest(index, crash_iter, crash_t, fault_seed, weight)

    def plan_campaign(self, n_tests: int, seed: Optional[int] = None) -> List[PlannedTest]:
        """Pre-draw every crash point (and per-test fault entropy) with the
        campaign RNG.

        For the default :class:`~repro.core.faults.PowerFail` model the draw
        order (crash iteration, then crash time within the iteration's
        window) is exactly the historical serial engine's, so a planned
        campaign at ``n_workers=1`` reproduces it bit-for-bit.
        """
        self._ensure_golden()
        rng = np.random.default_rng(self.seed if seed is None else seed)
        return [self._draw_test(rng, i) for i in range(n_tests)]

    # ----------------------------------------------------------------- tests
    def run_one(self, rng: np.random.Generator) -> CrashRecord:
        self._ensure_golden()
        test = self._draw_test(rng, 0)
        (_, record), = self.run_window_tests(test.crash_iter, [test])
        return record

    def run_window_tests(
        self, crash_iter: int, tests: Sequence[PlannedTest]
    ) -> List[Tuple[int, CrashRecord]]:
        """Execute all planned tests of one crash window (one shard).

        The window is simulated once and **all** its crash points are
        resolved in a single vectorial pass over the window's write-back
        records (:func:`resolve_window_images`).  On the ``"vec"`` engine,
        apps that declare ``supports_batched_step`` then run the restart /
        recompute phase as stacked lanes with per-lane early-exit masks
        (:meth:`_classify_lanes_batched`) instead of one Python loop per
        test; results are bit-for-bit the serial classification.
        """
        items = self._prepare_window_items(crash_iter, tests)
        outcomes = self._classify_items(items, crash_iter)
        return [
            self._record_for(crash_iter, item, outcome)
            for item, outcome in zip(items, outcomes)
        ]

    def _prepare_window_items(
        self, crash_iter: int, tests: Sequence[PlannedTest]
    ) -> List[dict]:
        """Simulate + resolve one window: everything up to (but excluding)
        the restart/classification phase, one dict per planned test."""
        self._ensure_golden()
        app = self.app
        trace, seq_values, _ = self._simulate_crash_window(crash_iter)
        first = max(0, crash_iter - 1)
        start_values = {
            o: self._golden_states[first][o]
            for o in trace.obj_blocks
            if o in self._golden_states[first]
        }
        candidates = [o for o in app.candidates if o in start_values]
        chronic = self._chronic_base(candidates, crash_iter) if crash_iter >= 1 else None
        tearing = [
            self.fault.torn_blocks(t, trace, self.cache.block_bytes) for t in tests
        ]
        nvms, lives = resolve_window_images(
            trace, [t.crash_t for t in tests],
            {o: start_values[o] for o in candidates},
            seq_values, self.cache.block_bytes,
            chronic_base=chronic,
            tearing=tearing,
        )

        protected = tuple(self.plan.objects)
        if app.iterator_object:
            protected += (app.iterator_object,)
        items: List[dict] = []
        for test, nvm, live in zip(tests, nvms, lives):
            seq, it, region_idx, t0, t1 = trace.span_for_time(test.crash_t)
            frac = (test.crash_t - t0) / max(1, (t1 - t0))
            nvm = self.fault.corrupt_image(test, nvm, protected)
            inconsistency = {o: inconsistent_rate(nvm[o], live[o]) for o in candidates}

            # All candidates restart from the NVM image (paper §5.1: "the
            # candidates are directly read from NVM"); the plan only controls
            # which get *flushed* (and therefore how consistent they are).
            # The loop iterator is always flushed at iteration end (paper
            # fn. 3), so its NVM value is the bookmarked restart iteration,
            # not the torn cache-model value.
            persisted = dict(nvm)
            if app.iterator_object and app.iterator_object in persisted:
                bookmark = np.asarray(persisted[app.iterator_object])
                persisted[app.iterator_object] = np.full_like(bookmark, crash_iter)
            items.append({
                "test": test,
                "region_idx": region_idx,
                "frac": float(frac),
                "inconsistency": inconsistency,
                "persisted": persisted,
            })
        return items

    def _classify_items(
        self, items: Sequence[dict], crash_iter: int
    ) -> List[Tuple[str, int, float]]:
        """Classify prepared test items; batches eligible lanes on ``vec``."""
        results: List[Optional[Tuple[str, int, float]]] = [None] * len(items)
        lanes: List[Tuple[int, dict]] = []
        batchable = self.engine == "vec" and self.app.supports_batched_step
        for i, item in enumerate(items):
            test = item["test"]
            recovery = self.fault.recovery_plan(test, crash_iter, self._golden_iters)
            if recovery is not None:
                # recovery-from-recovery simulates a fresh window on the live
                # trajectory: inherently per-lane, never batched
                results[i] = self._restart_with_recovery_crash(
                    item["persisted"], crash_iter, test, recovery
                )
            elif batchable:
                lanes.append((i, item))
            else:
                results[i] = self._restart_and_classify(item["persisted"], crash_iter)
        if lanes:
            for (i, _), outcome in zip(
                lanes,
                self._classify_lanes_batched(
                    [(item["persisted"], crash_iter) for _, item in lanes]
                ),
            ):
                results[i] = outcome
        return results  # type: ignore[return-value]

    def _record_for(
        self, crash_iter: int, item: dict, outcome: Tuple[str, int, float]
    ) -> Tuple[int, CrashRecord]:
        kind, extra, metric = outcome
        return (
            item["test"].index,
            CrashRecord(
                iter_idx=crash_iter,
                region_idx=item["region_idx"],
                frac=item["frac"],
                inconsistency=item["inconsistency"],
                outcome=kind,
                extra_iters=extra,
                verify_metric=metric,
                weight=float(item["test"].weight),
            ),
        )

    # ------------------------------------------------- batched lane recompute
    class _Lane:
        __slots__ = ("index", "state", "it", "extra", "phase", "last_metric")

        def __init__(self, index: int, state: State, it: int):
            self.index = index
            self.state = state
            self.it = it
            self.extra = 0
            # "A": run_to_completion; "B0": awaiting entry verify;
            # "B": extra iterations; "done": classified
            self.phase = "A"
            self.last_metric = float("nan")

    @staticmethod
    def _call_padded(fn, states: List[State], *extra_lists):
        """Call an app ``*_batch`` hook with the lane list padded to the next
        power-of-two length.  Stacked hooks jit-compile per batch shape; as
        lanes finish, an unpadded batch would shrink by ones and recompile
        every round.  Padding replicates lane 0 (every hook is lane-
        independent, so the real lanes' outputs are untouched) and the
        padded tail of the result is dropped."""
        n = len(states)
        b = 1
        while b < n:
            b <<= 1
        if b == n:
            return fn(states, *extra_lists)
        pad = b - n
        padded = list(states) + [states[0]] * pad
        pextra = [list(e) + [e[0]] * pad for e in extra_lists]
        return fn(padded, *pextra)[:n]

    def _step_lanes(self, lanes: List["CrashTester._Lane"]) -> List["CrashTester._Lane"]:
        """One batched iteration for every lane; on a batch-level failure,
        falls back to per-lane serial steps and returns the lanes whose
        serial step raised (their exception is theirs alone)."""
        app = self.app
        try:
            new_states = self._call_padded(
                app.run_iteration_batch, [l.state for l in lanes]
            )
        except Exception as e:  # noqa: BLE001 - attribute the failure per lane
            import warnings

            warnings.warn(
                f"{app.name}: run_iteration_batch raised ({e!r}); falling "
                f"back to per-lane serial steps — the vec engine is paying "
                f"for a broken batched hook",
                RuntimeWarning, stacklevel=2,
            )
            failed = []
            for l in lanes:
                try:
                    l.state = app.run_iteration(l.state)
                except Exception:  # noqa: BLE001
                    failed.append(l)
            return failed
        for l, s in zip(lanes, new_states):
            l.state = s
        return []

    def _restart_init_cached(self, persisted: Mapping[str, np.ndarray]) -> State:
        """vec-path ``restart_init``: ``init()`` is deterministic in the
        seed, so restart lanes deep-copy one memoized base state instead of
        re-running it per lane.  Apps overriding ``restart_init`` keep their
        own semantics (and cost)."""
        if type(self.app).restart_init is not IterativeApp.restart_init:
            return self.app.restart_init(self.seed, persisted)
        if self._init_base is None:
            self._init_base = self.app.init(self.seed)
        state = {k: np.array(v, copy=True) for k, v in self._init_base.items()}
        for k, v in persisted.items():
            if k in state:
                state[k] = np.array(v, copy=True).astype(state[k].dtype, copy=False)
        return state

    def _classify_lanes_batched(
        self, lanes: Sequence[Tuple[Mapping[str, np.ndarray], int]]
    ) -> List[Tuple[str, int, float]]:
        """Stacked-lane replica of :meth:`_restart_and_classify`.

        All lanes advance together through ``run_iteration_batch`` — one
        dispatch per step for the whole batch instead of one per region per
        test — while per-lane masks replicate the serial control flow
        exactly: the run-to-completion loop with its converged() early exit
        (phase A), the acceptance verify (B0), and the extra-iteration loop
        up to the recompute budget (phase B).  Any per-lane exception — in
        restart, a blown-up convergence check, a verify — classifies that
        lane S3 with the serial path's (0, nan) payload.  Lanes may enter
        with different restart iterations (cross-window batches do).
        """
        app = self.app
        budget = int(self.max_extra_factor * self._golden_iters)
        golden_iters = self._golden_iters
        out: List[Optional[Tuple[str, int, float]]] = [None] * len(lanes)
        live: List[CrashTester._Lane] = []
        for i, (persisted, restart_iter) in enumerate(lanes):
            try:
                state = self._restart_init_cached(persisted)
            except Exception:  # noqa: BLE001 - serial path: any failure is S3
                out[i] = ("S3", 0, float("nan"))
                continue
            lane = CrashTester._Lane(i, state, restart_iter)
            if lane.it >= golden_iters:
                lane.phase = "B0"  # run_to_completion would execute nothing
            live.append(lane)

        # jit-resident phase A: apps with a lane driver run the whole
        # run-to-completion loop in one donated-buffer dispatch per bucket
        # instead of one run_iteration_batch dispatch per iteration; lanes
        # the driver cannot decide bit-exactly (blow-ups, overflow screens)
        # come back flagged and are reclassified through the serial path,
        # which also owns their exception capture (S3 semantics untouched)
        a_entry = [l for l in live if l.phase == "A"]
        if a_entry and app.supports_lane_driver:
            try:
                sts, nits, oks = app.advance_lanes(
                    [l.state for l in a_entry], [l.it for l in a_entry],
                    golden_iters,
                )
            except Exception as e:  # noqa: BLE001 - driver is an optimization
                import warnings

                warnings.warn(
                    f"{app.name}: advance_lanes raised ({e!r}); falling back "
                    f"to the host-loop phase A — the lane driver is broken",
                    RuntimeWarning, stacklevel=2,
                )
            else:
                for l, s, nit, ok in zip(a_entry, sts, nits, oks):
                    if ok:
                        l.state = s
                        l.it = int(nit)
                        l.phase = "B0"
                    else:
                        out[l.index] = self._restart_and_classify(*lanes[l.index])
                        l.phase = "done"
                live = [l for l in live if l.phase != "done"]

        active = live
        while active:
            # entry verifies for lanes that just finished the run phase
            b0 = [l for l in active if l.phase == "B0"]
            if b0:
                for l, res in zip(b0, self._call_padded(app.verify_batch, [l.state for l in b0])):
                    if isinstance(res, BaseException):
                        out[l.index] = ("S3", 0, float("nan"))
                        l.phase = "done"
                    elif res.passed:
                        out[l.index] = ("S1", 0, res.metric)
                        l.phase = "done"
                    elif l.it >= budget:
                        out[l.index] = ("S4", 0, res.metric)
                        l.phase = "done"
                    else:
                        l.phase = "B"
            active = [l for l in active if l.phase != "done"]
            if not active:
                break

            # one batched step for every still-running lane, A and B alike
            for l in self._step_lanes(active):
                out[l.index] = ("S3", 0, float("nan"))
                l.phase = "done"
            active = [l for l in active if l.phase != "done"]

            a_lanes = [l for l in active if l.phase == "A"]
            for l in a_lanes:
                l.it += 1
            if a_lanes:
                convs = self._call_padded(
                    app.converged_batch,
                    [l.state for l in a_lanes], [l.it for l in a_lanes],
                )
                for l, c in zip(a_lanes, convs):
                    if isinstance(c, BaseException):
                        out[l.index] = ("S3", 0, float("nan"))
                        l.phase = "done"
                    elif c or l.it >= golden_iters:
                        l.phase = "B0"

            b_lanes = [l for l in active if l.phase == "B"]
            for l in b_lanes:
                l.it += 1
                l.extra += 1
            if b_lanes:
                for l, res in zip(
                    b_lanes,
                    self._call_padded(app.verify_batch, [l.state for l in b_lanes]),
                ):
                    if isinstance(res, BaseException):
                        out[l.index] = ("S3", 0, float("nan"))
                        l.phase = "done"
                    elif res.passed:
                        out[l.index] = ("S2", l.extra, res.metric)
                        l.phase = "done"
                    elif l.it >= budget:
                        out[l.index] = ("S4", l.extra, res.metric)
                        l.phase = "done"
            active = [l for l in active if l.phase != "done"]
        return out  # type: ignore[return-value]

    def _chronic_base(self, candidates, crash_iter: int) -> Dict[str, np.ndarray]:
        """Steady-state base values for chronically-cached blocks: the last
        flushed image if the plan ever flushes the object, else the initial
        value (paper §8: small hot objects leave only ancient data in NVM)."""
        app = self.app
        regs = app.regions()
        written = set()
        for r in regs:
            written.update(r.writes)
        out: Dict[str, np.ndarray] = {}
        for o in candidates:
            if o not in written:
                continue
            flushed_iters = []
            if o in self.plan.objects:
                for k, x in self.plan.region_freq.items():
                    if x:
                        cand = ((crash_iter - 1) // x) * x
                        if cand >= 0:
                            flushed_iters.append(cand)
            if flushed_iters:
                f = max(flushed_iters)
                out[o] = self._golden_states[min(f + 1, len(self._golden_states) - 1)][o]
            else:
                out[o] = self._golden_states[0][o]
        return out

    def _finish_classify(self, state: State, it: int) -> Tuple[str, int, float]:
        """Classify a finished recompute run: S1 (passes), S2 (passes after
        extra iterations, up to the budget), S4 (budget exhausted)."""
        app = self.app
        budget = int(self.max_extra_factor * self._golden_iters)
        res = app.verify(state)
        if res.passed:
            return "S1", 0, res.metric
        extra = 0
        while it < budget:
            state = app.run_iteration(state)
            it += 1
            extra += 1
            res = app.verify(state)
            if res.passed:
                return "S2", extra, res.metric
        return "S4", extra, res.metric

    def _classify_test(
        self, persisted: Mapping[str, np.ndarray], restart_iter: int, test: PlannedTest
    ) -> Tuple[str, int, float]:
        """Restart-and-classify, routed through the fault model's recovery
        hook: models may crash the recompute run itself."""
        recovery = self.fault.recovery_plan(test, restart_iter, self._golden_iters)
        if recovery is None:
            return self._restart_and_classify(persisted, restart_iter)
        return self._restart_with_recovery_crash(persisted, restart_iter, test, recovery)

    def _restart_and_classify(
        self, persisted: Mapping[str, np.ndarray], restart_iter: int
    ) -> Tuple[str, int, float]:
        app = self.app
        golden_iters = self._golden_iters
        try:
            state = app.restart_init(self.seed, persisted)
            state, executed = app.run_to_completion(state, restart_iter, golden_iters)
            return self._finish_classify(state, restart_iter + executed)
        except Exception:  # incl. FloatingPointError blow-ups
            return "S3", 0, float("nan")

    def _restart_with_recovery_crash(
        self,
        persisted: Mapping[str, np.ndarray],
        restart_iter: int,
        test: PlannedTest,
        recovery: Tuple[int, float],
    ) -> Tuple[str, int, float]:
        """Recovery-from-recovery: run the recompute up to the second crash's
        window, simulate that window on the *live recompute trajectory*,
        resolve the second NVM image and restart again.

        The second window starts cache-consistent and carries no chronic
        base (the recompute trajectory is not in the steady-state regime the
        chronic adjustment models).  If the recompute converges before the
        second crash iteration, the run simply finished first and is
        classified as usual.
        """
        app = self.app
        recrash_iter, u = recovery
        try:
            state = app.restart_init(self.seed, persisted)
            it = restart_iter
            w_first = max(restart_iter, recrash_iter - 1)
            while it < w_first:
                state = app.run_iteration(state)
                it += 1
                if app.converged(state, it):
                    return self._finish_classify(state, it)

            trace, seq_values, span_start = self._simulate_window_from(
                state, w_first, recrash_iter
            )
            span = max(1, trace.t_end - span_start)
            crash_t2 = span_start + min(int(u * span), span - 1)
            candidates = [
                o for o in app.candidates if o in state and o in trace.obj_blocks
            ]
            image = resolve_nvm_image(
                trace, crash_t2,
                {o: state[o] for o in candidates},
                seq_values, self.cache.block_bytes,
            )
            persisted2 = dict(image)
            if app.iterator_object and app.iterator_object in persisted2:
                bookmark = np.asarray(persisted2[app.iterator_object])
                persisted2[app.iterator_object] = np.full_like(bookmark, recrash_iter)
            state2 = app.restart_init(self.seed, persisted2)
            state2, executed = app.run_to_completion(
                state2, recrash_iter, self._golden_iters
            )
            return self._finish_classify(state2, recrash_iter + executed)
        except Exception:  # incl. FloatingPointError blow-ups
            return "S3", 0, float("nan")

    # -------------------------------------------------------------- campaign
    def _state_digest(self) -> str:
        """Digest of the golden run's initial state: distinguishes same-named
        apps with different problem configurations (grid, tolerance, data
        seed), whose crash records must never be mixed in one store."""
        import hashlib

        if self._digest is not None:
            return self._digest
        self._ensure_golden()
        h = hashlib.sha256()
        for name in sorted(self._golden_states[0]):
            arr = np.ascontiguousarray(self._golden_states[0][name])
            h.update(name.encode())
            h.update(str(arr.dtype).encode())
            h.update(str(arr.shape).encode())
            h.update(arr.tobytes())
        self._digest = h.hexdigest()[:16]
        return self._digest

    def _fingerprint(self, n_tests: int, seed: int) -> Dict[str, object]:
        """Identity of a campaign for the resume store: any change here means
        stored shard results are not reusable.  Values must survive a JSON
        round-trip unchanged (the store compares the parsed header against
        this dict), so: only str/int/float/bool, lists of lists — no tuples.
        """
        fp: Dict[str, object] = {
            "store_version": 1,
            "app": self.app.name,
            "state_digest": self._state_digest(),
            "n_tests": int(n_tests),
            "seed": int(seed),
            "golden_iters": int(self.golden_iters),
            "plan_objects": list(self.plan.objects),
            "plan_freq": sorted([int(k), int(v)] for k, v in self.plan.region_freq.items()),
            "cache_blocks": int(self.cache.capacity_blocks),
            "block_bytes": int(self.cache.block_bytes),
            "max_extra_factor": float(self.max_extra_factor),
            # a store is bound to one failure model: resuming a PowerFail
            # store with, say, TornWrite would silently mix taxonomies
            "fault": self.fault.spec(),
        }
        # only when a sampler is attached, so every historical (uniform)
        # fingerprint is byte-identical — but an importance-sampled store can
        # never be resumed with different weights (or none at all)
        if self.sampler is not None:
            fp["sampler"] = self.sampler.spec()
        return fp

    def _shards(self, tests: Sequence[PlannedTest]) -> Dict[int, List[PlannedTest]]:
        """Group planned tests by crash window; the shard id is the window's
        crash iteration.  Within a shard tests keep campaign order."""
        shards: Dict[int, List[PlannedTest]] = {}
        for t in tests:
            shards.setdefault(t.crash_iter, []).append(t)
        return shards

    # --------------------------------------------------- shard-level campaign API
    # run_campaign decomposes into three order-independent pieces so that an
    # external scheduler (the workflow orchestrator) can interleave shards of
    # *different* campaigns on one shared worker pool:
    #   plan_shards       -> the campaign's full shard map (pure planning)
    #   run_window_tests  -> execute one shard (anywhere, any order)
    #   assemble_campaign -> deterministic CampaignResult from shard results
    def plan_shards(
        self, n_tests: int, seed: Optional[int] = None
    ) -> Tuple[List[PlannedTest], Dict[int, List[PlannedTest]]]:
        """Plan a campaign and group it into shards (one per crash window)."""
        tests = self.plan_campaign(n_tests, seed)
        return tests, self._shards(tests)

    def run_shards(
        self,
        shards: Mapping[int, Sequence[PlannedTest]],
        on_shard=None,
    ) -> Dict[int, List[Tuple[int, CrashRecord]]]:
        """Execute several shards in-process, batching lanes **across**
        windows.

        CI-sized campaigns put only one or two tests in each crash window, so
        batching inside a single shard barely amortizes anything.  Here the
        vec engine groups consecutive shards into chunks of up to
        ``REPRO_LANE_BATCH`` lanes (restart states of one app all share
        shapes), resolves each window's images, then classifies the whole
        chunk through :meth:`_classify_lanes_batched`.  ``on_shard(ci,
        records)`` fires as each shard's records are assembled — after its
        chunk completes, which is also the durability granularity when the
        caller appends to a campaign store.  Results are identical to
        calling :meth:`run_window_tests` per shard, in any order.
        """
        use_batch = self.engine == "vec" and self.app.supports_batched_step
        out: Dict[int, List[Tuple[int, CrashRecord]]] = {}
        if not use_batch:
            for ci, ts in shards.items():
                recs = self.run_window_tests(ci, ts)
                out[ci] = recs
                if on_shard is not None:
                    on_shard(ci, recs)
            return out

        target = self.lane_batch_target()
        chunk: List[Tuple[int, Sequence[PlannedTest]]] = []
        lanes_in_chunk = 0
        for ci, ts in shards.items():
            chunk.append((ci, ts))
            lanes_in_chunk += len(ts)
            if lanes_in_chunk >= target:
                self._run_shard_chunk(chunk, out, on_shard)
                chunk, lanes_in_chunk = [], 0
        if chunk:
            self._run_shard_chunk(chunk, out, on_shard)
        return out

    def _run_shard_chunk(self, chunk, out, on_shard) -> None:
        """Prepare every shard of the chunk, classify all lanes at once."""
        prepared = [(ci, ts, self._prepare_window_items(ci, ts)) for ci, ts in chunk]
        results: Dict[int, List[Tuple[str, int, float]]] = {}
        batch_lanes: List[Tuple[int, int, dict]] = []  # (ci, item_idx, item)
        for ci, ts, items in prepared:
            results[ci] = [None] * len(items)  # type: ignore[list-item]
            for j, item in enumerate(items):
                test = item["test"]
                recovery = self.fault.recovery_plan(test, ci, self._golden_iters)
                if recovery is not None:
                    results[ci][j] = self._restart_with_recovery_crash(
                        item["persisted"], ci, test, recovery
                    )
                else:
                    batch_lanes.append((ci, j, item))
        if batch_lanes:
            outcomes = self._classify_lanes_batched(
                [(item["persisted"], ci) for ci, _, item in batch_lanes]
            )
            for (ci, j, _), outcome in zip(batch_lanes, outcomes):
                results[ci][j] = outcome
        for ci, ts, items in prepared:
            recs = [
                self._record_for(ci, item, outcome)
                for item, outcome in zip(items, results[ci])
            ]
            out[ci] = recs
            if on_shard is not None:
                on_shard(ci, recs)

    def payload_picklable(self) -> Tuple[bool, Optional[BaseException]]:
        """Whether this tester's campaign payload can cross a process
        boundary (apps holding jitted closures, e.g. LMTrainApp, cannot)."""
        import pickle

        try:
            pickle.dumps((self.app, self.plan, self.cache, self.fault))
            return True, None
        except Exception as e:  # noqa: BLE001 - any pickling failure
            return False, e

    def assemble_campaign(
        self,
        tests: Sequence[PlannedTest],
        shard_results: Mapping[int, List[Tuple[int, CrashRecord]]],
    ) -> CampaignResult:
        """Stitch shard results back into a :class:`CampaignResult`.

        Records are re-ordered by original test index, so the result is
        independent of shard execution order (serial, parallel, resumed).
        """
        indexed = sorted(
            (pair for recs in shard_results.values() for pair in recs),
            key=lambda pair: pair[0],
        )
        records = [r for _, r in indexed]

        # steady-state write accounting from the first test's crash window
        # (matches the historical engine, whose first simulated window was
        # the first test's)
        stats: Dict[str, float] = {}
        if tests:
            trace, _, _ = self._simulate_crash_window(tests[0].crash_iter)
            n_iters_in_window = 2
            stats = {
                "eviction_writes_per_iter": trace.eviction_writes / n_iters_in_window,
                "flush_writes_per_iter": trace.flush_writes / n_iters_in_window,
                "flushed_clean_per_iter": trace.flushed_clean_blocks / n_iters_in_window,
                "flush_ops_per_iter": trace.flush_ops / n_iters_in_window,
            }
        return CampaignResult(
            app_name=self.app.name,
            plan=self.plan,
            records=records,
            golden_iters=self._golden_iters,
            window_write_stats=stats,
        )

    def run_campaign(
        self,
        n_tests: int,
        seed: Optional[int] = None,
        n_workers: int = 1,
        store_path: Optional[str] = None,
    ) -> CampaignResult:
        """Run a crash-test campaign.

        * ``n_workers > 1`` fans the campaign's shards (one per crash
          window) out over a :class:`~concurrent.futures.ProcessPoolExecutor`.
          All randomness is pre-drawn by :meth:`plan_campaign`, so the result
          is identical for every worker count — and ``n_workers=1`` (which
          runs fully in-process) is bit-for-bit the historical serial engine.
        * ``store_path`` appends each completed shard to a JSONL
          :class:`~repro.core.campaign_store.CampaignStore`; re-running the
          same campaign against an existing (possibly truncated) store
          executes only the missing shards.
        """
        eff_seed = self.seed if seed is None else seed
        tests, shards = self.plan_shards(n_tests, eff_seed)

        store = None
        done: Dict[int, List[Tuple[int, CrashRecord]]] = {}
        if store_path is not None:
            from .campaign_store import CampaignStore

            store = CampaignStore(store_path)
            done = store.load_or_create(self._fingerprint(n_tests, eff_seed))
            done = {k: v for k, v in done.items() if k in shards}
        pending = {ci: ts for ci, ts in shards.items() if ci not in done}

        results: Dict[int, List[Tuple[int, CrashRecord]]] = dict(done)
        if n_workers > 1 and len(pending) > 1:
            # apps that hold jitted closures (e.g. LMTrainApp) cannot cross a
            # process boundary; fall back to the identical serial engine
            import warnings

            ok, err = self.payload_picklable()
            if not ok:
                warnings.warn(
                    f"{self.app.name}: campaign payload is not picklable "
                    f"({err!r}); running shards serially", RuntimeWarning,
                    stacklevel=2,
                )
                n_workers = 1
        if n_workers <= 1 or len(pending) <= 1:
            # in-process: lanes batch across windows (run_shards); completed
            # shards land in the store as their chunk finishes
            on_shard = None
            if store is not None:
                on_shard = store.append_shard
            results.update(self.run_shards(pending, on_shard=on_shard))
        else:
            with campaign_executor(
                n_workers=min(n_workers, len(pending)),
                app=self.app, cache=self.cache,
                max_extra_factor=self.max_extra_factor, fault=self.fault,
                engine=self.engine, lane_batch=self.lane_batch,
            ) as ex:
                futs = {
                    ex.submit(_shard_worker_run, "", self.plan, self.seed, ci, ts): ci
                    for ci, ts in pending.items()
                }
                for fut in as_completed(futs):
                    _, ci, recs = fut.result()
                    if store is not None:
                        store.append_shard(ci, recs)
                    results[ci] = recs

        return self.assemble_campaign(tests, results)


# ------------------------------------------------------------- worker plumbing
# Each worker process hosts a *cache of CrashTesters*, keyed by campaign: the
# pool initializer pins the shared payload (app, cache model, fault model) and
# every submitted shard names its campaign (persist plan + seed).  A single-
# campaign run uses one key; the workflow orchestrator multiplexes all of a
# workflow's campaigns over the same pool, so a worker pays each campaign's
# golden run once and then amortises it across every shard it executes.
_WORKER_HOST: Optional[
    Tuple[IterativeApp, CacheConfig, float, Optional[FaultModel], Optional[str], Optional[int]]
] = None
_WORKER_TESTERS: "OrderedDict[str, Tuple[PersistPlan, int, CrashTester]]" = None  # type: ignore[assignment]
#: LRU bound on coexisting per-campaign testers in one worker: each pins a
#: full golden trajectory, so an unbounded cache would multiply resident
#: memory by the campaign count (isolated-mode workflows run W+2 campaigns).
#: Evicting only costs a deterministic golden re-run if that campaign's
#: shards come back around.
_WORKER_TESTER_CAP = 8


def _shard_worker_init(
    app: IterativeApp,
    cache: CacheConfig,
    max_extra_factor: float,
    fault: Optional[FaultModel] = None,
    engine: Optional[str] = None,
    lane_batch: Optional[int] = None,
) -> None:
    global _WORKER_HOST, _WORKER_TESTERS
    from collections import OrderedDict

    _WORKER_HOST = (app, cache, max_extra_factor, fault, engine, lane_batch)
    _WORKER_TESTERS = OrderedDict()


def _shard_worker_run(
    campaign_key: str,
    plan: PersistPlan,
    seed: int,
    crash_iter: int,
    tests: Sequence[PlannedTest],
) -> Tuple[str, int, List[Tuple[int, CrashRecord]]]:
    assert _WORKER_HOST is not None, "worker used before initialization"
    cached = _WORKER_TESTERS.get(campaign_key)
    # the cache is keyed by campaign key but *validated* against the plan and
    # seed each shard carries: a rebound key must never reuse a stale tester
    if cached is not None and (cached[0], cached[1]) == (plan, seed):
        tester = cached[2]
    else:
        app, cache, max_extra_factor, fault, engine, lane_batch = _WORKER_HOST
        tester = CrashTester(
            app, plan, cache, seed=seed,
            max_extra_factor=max_extra_factor, fault=fault, engine=engine,
            lane_batch=lane_batch,
        )
        _WORKER_TESTERS[campaign_key] = (plan, seed, tester)
        while len(_WORKER_TESTERS) > _WORKER_TESTER_CAP:
            _WORKER_TESTERS.popitem(last=False)
    _WORKER_TESTERS.move_to_end(campaign_key)
    return campaign_key, crash_iter, tester.run_window_tests(crash_iter, tests)


def campaign_executor(
    n_workers: int,
    app: IterativeApp,
    cache: CacheConfig,
    max_extra_factor: float = 2.0,
    fault: Optional[FaultModel] = None,
    engine: Optional[str] = None,
    lane_batch: Optional[int] = None,
) -> ProcessPoolExecutor:
    """A shard worker pool bound to one (app, cache, fault) payload.

    Submit shards with ``ex.submit(_shard_worker_run, key, plan, seed, ci,
    tests)`` — campaigns with distinct keys coexist on the same pool.
    """
    import multiprocessing as mp

    # spawn, not fork: jax is multithreaded and forked children
    # deadlock (REPRO_MP_START exists for non-jax substrates only)
    ctx = mp.get_context(os.environ.get("REPRO_MP_START", "spawn"))
    return ProcessPoolExecutor(
        max_workers=n_workers,
        mp_context=ctx,
        initializer=_shard_worker_init,
        initargs=(app, cache, max_extra_factor, fault, engine, lane_batch),
    )
