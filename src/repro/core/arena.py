"""NVM arena: the persistent image of application data objects.

The arena emulates NVM-as-main-memory in *app-direct* mode (paper §2.3):
a byte-addressable persistent region that survives crashes.  Two concerns
live here:

* value storage — one numpy array per named data object (the "NVM image"),
  optionally backed by memory-mapped files so a killed process can reattach
  (the memory-mapped-file offset mechanism the paper describes);
* write accounting — every block written back (by eviction, by an explicit
  flush, or by a checkpoint copy) is counted, reproducing the paper's Fig 9
  endurance comparison.  Flushing a clean or non-resident block costs no
  NVM write, which is the asymmetry EasyCrash exploits.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, Iterable, Mapping, Optional

import numpy as np

from .blocks import DEFAULT_BLOCK_BYTES, block_diff_mask, mix_blocks, obj_num_blocks
from .durable import durable_replace


@dataclass
class WriteStats:
    """NVM write counters, in units of blocks."""

    eviction_writes: int = 0     # natural write-backs from the (emulated) cache
    flush_writes: int = 0        # EasyCrash persistence operations
    checkpoint_writes: int = 0   # C/R data copies
    flush_ops: int = 0           # number of persistence operations issued
    flushed_clean_blocks: int = 0  # blocks flushed that caused no write

    @property
    def total(self) -> int:
        return self.eviction_writes + self.flush_writes + self.checkpoint_writes

    def as_dict(self) -> Dict[str, int]:
        return {
            "eviction_writes": self.eviction_writes,
            "flush_writes": self.flush_writes,
            "checkpoint_writes": self.checkpoint_writes,
            "flush_ops": self.flush_ops,
            "flushed_clean_blocks": self.flushed_clean_blocks,
            "total": self.total,
        }


class NVMArena:
    """Persistent store for named data objects at block granularity."""

    def __init__(
        self,
        block_bytes: int = DEFAULT_BLOCK_BYTES,
        backing_dir: Optional[str] = None,
    ):
        self.block_bytes = int(block_bytes)
        self.backing_dir = backing_dir
        self._store: Dict[str, np.ndarray] = {}
        self.stats = WriteStats()
        if backing_dir:
            os.makedirs(backing_dir, exist_ok=True)

    # ------------------------------------------------------------------ values
    def names(self) -> Iterable[str]:
        return self._store.keys()

    def __contains__(self, name: str) -> bool:
        return name in self._store

    def get(self, name: str) -> np.ndarray:
        """Read the NVM image of an object (copy: loads survive app writes)."""
        return self._store[name].copy()

    def peek(self, name: str) -> Optional[np.ndarray]:
        """No-copy view of the current NVM image (delta-mask computation).

        Callers must not mutate the result; ``None`` if never persisted.
        """
        return self._store.get(name)

    def snapshot(self) -> Dict[str, np.ndarray]:
        return {k: v.copy() for k, v in self._store.items()}

    def install(self, name: str, value: np.ndarray, count_writes: bool = False) -> None:
        """Install a full image (initialization / checkpoint restore path)."""
        value = np.array(value, copy=True)
        if count_writes:
            self.stats.checkpoint_writes += obj_num_blocks(value, self.block_bytes)
        self._store[name] = value
        self._persist_to_backing(name)

    # ------------------------------------------------------------ block writes
    def writeback_blocks(
        self, name: str, new_value: np.ndarray, block_mask: np.ndarray
    ) -> None:
        """Natural cache eviction: masked blocks of ``new_value`` reach NVM."""
        cur = self._store[name]
        n = int(np.count_nonzero(block_mask))
        if n == 0:
            return
        self.stats.eviction_writes += n
        self._store[name] = mix_blocks(cur, new_value, block_mask, self.block_bytes)

    def flush(
        self,
        name: str,
        live_value: np.ndarray,
        dirty_resident_mask: Optional[np.ndarray] = None,
    ) -> int:
        """EasyCrash persistence operation (CLWB semantics).

        Every block of the object is *issued*, but only blocks that are dirty
        and resident in the cache cause an NVM write.  When no cache model is
        attached (production runtime), ``dirty_resident_mask=None`` falls back
        to a value diff against the current NVM image — the delta_snapshot
        kernel's behaviour, which is a superset of "dirty and resident"
        (an evicted-then-clean block diffs as unchanged).
        Returns the number of blocks actually written.
        """
        live_value = np.asarray(live_value)
        cur = self._store.get(name)
        if cur is not None and cur.nbytes != live_value.nbytes:
            cur = None  # object was reallocated/grown: full rewrite
        if cur is None:
            # first flush: everything is logically dirty
            nb = obj_num_blocks(live_value, self.block_bytes)
            self._store[name] = np.array(live_value, copy=True)
            self.stats.flush_writes += nb
            self.stats.flush_ops += 1
            self._persist_to_backing(name)
            return nb
        if dirty_resident_mask is None:
            dirty_resident_mask = block_diff_mask(cur, live_value, self.block_bytes)
        mask = np.asarray(dirty_resident_mask, dtype=bool)
        written = int(np.count_nonzero(mask))
        total = mask.size
        self.stats.flush_writes += written
        self.stats.flushed_clean_blocks += total - written
        self.stats.flush_ops += 1
        if written:
            self._store[name] = mix_blocks(cur, live_value, mask, self.block_bytes)
            self._persist_to_backing(name)
        return written

    def checkpoint_copy(self, name: str, value: np.ndarray) -> None:
        """Traditional C/R data copy: every block of the object is written."""
        value = np.asarray(value)
        self.stats.checkpoint_writes += obj_num_blocks(value, self.block_bytes)
        self._store[f"__chk__/{name}"] = np.array(value, copy=True)

    # -------------------------------------------------------------- durability
    # Backing files follow the shared durable-replace protocol
    # (:mod:`repro.core.durable`): ``reattach`` must never see an empty or
    # torn image, even after power loss mid-rename.
    def _backing_path(self, name: str) -> str:
        safe = name.replace("/", "__")
        return os.path.join(self.backing_dir, f"{safe}.npy")  # type: ignore[arg-type]

    def _persist_to_backing(self, name: str) -> None:
        if not self.backing_dir:
            return
        path = self._backing_path(name)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.save(f, self._store[name])
            f.flush()
            os.fsync(f.fileno())
        durable_replace(tmp, path)

    def save_manifest(self) -> None:
        if not self.backing_dir:
            return
        manifest = {
            "block_bytes": self.block_bytes,
            "objects": {k: str(v.dtype) for k, v in self._store.items()},
        }
        path = os.path.join(self.backing_dir, "manifest.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        durable_replace(tmp, path)

    @classmethod
    def reattach(cls, backing_dir: str) -> "NVMArena":
        """Reload a persisted arena after a crash (the restart path)."""
        path = os.path.join(backing_dir, "manifest.json")
        with open(path) as f:
            manifest = json.load(f)
        arena = cls(block_bytes=manifest["block_bytes"], backing_dir=backing_dir)
        objects = manifest["objects"]
        if isinstance(objects, list):  # legacy manifests without dtypes
            objects = {name: None for name in objects}
        for name, dtype_s in objects.items():
            arr = np.load(arena._backing_path(name))
            if dtype_s is not None and str(arr.dtype) != dtype_s:
                want = np.dtype(dtype_s)
                # np.load round-trips extension dtypes (bfloat16) as void
                if arr.dtype.kind == "V" and arr.dtype.itemsize == want.itemsize:
                    arr = arr.view(want)
                else:
                    arr = arr.astype(want)
            arena._store[name] = arr
        return arena
