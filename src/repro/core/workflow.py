"""The four-step EasyCrash workflow (paper §5.3).

Step 1 — run a crash-test campaign without persistence, collecting per-object
inconsistency rates and recompute outcomes.
Step 2 — Spearman selection of critical data objects.
Step 3 — run a second campaign persisting the critical objects at every
region (this also yields c_k^max per region), then solve the knapsack for
critical code regions and flush frequencies under (t_s, tau).
Step 4 — production: run with the resulting :class:`PersistPlan`.

``run_workflow`` executes steps 1–3 and returns everything a production run
(or the benchmarks reproducing the paper's figures) needs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cache_sim import CacheConfig
from .crash_tester import CampaignResult, CrashTester, PersistPlan
from .efficiency import SystemConfig, tau_threshold
from .faults import FaultModel
from .regions import IterativeApp
from .selection import (
    ObjectScore,
    RegionSelection,
    critical_objects,
    select_objects,
    select_regions,
    select_regions_from_gains,
)


@dataclass
class WorkflowResult:
    app_name: str
    baseline_campaign: CampaignResult          # step 1: no persistence
    object_scores: List[ObjectScore]           # step 2
    critical: Tuple[str, ...]
    best_campaign: CampaignResult              # step 3 input: persist everywhere
    region_selection: RegionSelection
    plan: PersistPlan                          # step 4 product
    tau: float
    t_s: float

    def summary(self) -> Dict[str, float]:
        return {
            "baseline_recomputability": self.baseline_campaign.recomputability,
            "best_recomputability": self.best_campaign.recomputability,
            "expected_recomputability": self.region_selection.expected_recomputability,
            "planned_overhead": self.region_selection.total_overhead,
            "n_critical_objects": float(len(self.critical)),
            "n_critical_regions": float(len(self.region_selection.choices)),
            "tau": self.tau,
        }


def estimate_region_overheads(
    app: IterativeApp,
    objects: Sequence[str],
    flush_cost_per_block: float = 0.1,
    block_bytes: int = 64,
) -> List[float]:
    """Estimate l_k: cost of flushing the selected objects at region k, as a
    fraction of one iteration's execution time.

    The paper estimates l_k from the measured cost of flushing one cache
    block times the object block count, deliberately assuming every block is
    resident+dirty (an overestimate, then doubled for reload cost — kept
    here).  Execution time per region is proxied by its access volume times
    its declared cost weight; ``flush_cost_per_block`` calibrates a CLWB
    write-back against one region "access" (a region access implies FLOPs,
    a flush is a pure streaming store — the paper measures ~0.03 s per
    persist op against seconds-long iterations).
    """
    state = app.init(0)
    regs = app.regions()
    region_time = []
    for r in regs:
        vol = sum(
            max(1, -(-np.asarray(state[o]).nbytes // block_bytes))
            for o in tuple(r.reads) + tuple(r.writes)
            if o in state
        )
        region_time.append(max(1.0, vol) * r.cost)
    total_time = sum(region_time)
    flush_blocks = sum(
        max(1, -(-np.asarray(state[o]).nbytes // block_bytes))
        for o in objects
        if o in state
    )
    # x2: CLFLUSH-style invalidation forces reloads (paper §5.2 "How to use")
    l_once = 2.0 * flush_cost_per_block * flush_blocks
    return [l_once / total_time for _ in regs]


def region_time_fractions(app: IterativeApp, block_bytes: int = 64) -> List[float]:
    """a_k: execution-time fraction per region (access-volume x cost proxy)."""
    state = app.init(0)
    regs = app.regions()
    t = []
    for r in regs:
        vol = sum(
            max(1, -(-np.asarray(state[o]).nbytes // block_bytes))
            for o in tuple(r.reads) + tuple(r.writes)
            if o in state
        )
        t.append(max(1.0, vol) * r.cost)
    s = sum(t)
    return [x / s for x in t]


def run_workflow(
    app: IterativeApp,
    n_tests: int = 200,
    cache: CacheConfig = CacheConfig(),
    system: Optional[SystemConfig] = None,
    t_s: float = 0.03,
    p_threshold: float = 0.01,
    freq_options: Sequence[int] = (1, 2, 4, 8),
    seed: int = 0,
    region_measure: str = "isolated",
    n_workers: int = 1,
    fault_model: Optional[FaultModel] = None,
) -> WorkflowResult:
    """Steps 1–3.

    ``n_workers`` is handed to every campaign the workflow runs
    (:meth:`repro.core.crash_tester.CrashTester.run_campaign`); results are
    identical for every worker count.

    ``fault_model`` selects what a "crash" is for every campaign the
    workflow runs (:mod:`repro.core.faults`); ``None`` is the paper's clean
    power failure.  Characterizing under one model and deploying the plan
    under another is exactly the scenario-robustness question the fault
    sweep in ``benchmarks/bench_recomputability.py`` measures.

    ``region_measure`` selects how c_k^max is estimated:

    * ``"paper"`` — one persist-everywhere campaign, per-region grouping
      (§5.2's shortcut; cheap but mis-attributes when flushing at region j
      changes the image seen by crashes in region k);
    * ``"isolated"`` — one small campaign per region with flushes at that
      region only (the paper's own Fig 4b methodology).  Costs W extra
      campaigns but measures the true marginal gain of each region.
    """
    system = system or SystemConfig(mtbf=12 * 3600.0, t_chk=320.0)
    tau = tau_threshold(system, t_s=t_s)

    # Step 1: baseline campaign (NVM holds whatever eviction left there).
    baseline = CrashTester(
        app, PersistPlan.none(), cache, seed=seed, fault=fault_model
    ).run_campaign(n_tests, n_workers=n_workers)

    # Step 2: Spearman object selection.  The loop iterator is excluded: it
    # is *always* persisted (paper fn. 3), never subject to selection.
    sel_candidates = [c for c in app.candidates if c != app.iterator_object]
    scores = select_objects(baseline, sel_candidates, p_threshold)
    crit = critical_objects(scores)
    if not crit:
        # fall back to the most negatively-correlated object: persisting
        # nothing would make step 3 vacuous (paper always persists >=1 object)
        ranked = sorted(
            (s for s in scores if not np.isnan(s.rs)), key=lambda s: s.rs
        )
        crit = (ranked[0].name,) if ranked else tuple(sel_candidates[:1])

    # Step 3: measure per-region recomputability with persistence, then
    # solve the knapsack.
    n_regions = len(app.regions())
    a = region_time_fractions(app, cache.block_bytes)
    l = estimate_region_overheads(app, crit, block_bytes=cache.block_bytes)
    best_plan = PersistPlan.best(crit, app)
    best = CrashTester(app, best_plan, cache, seed=seed + 1, fault=fault_model).run_campaign(
        n_tests, n_workers=n_workers
    )

    if region_measure == "paper":
        c_base_map = baseline.per_region_recomputability()
        c_max_map = best.per_region_recomputability()
        c_base = [c_base_map.get(k, (baseline.recomputability, 0))[0] for k in range(n_regions)]
        c_max = [
            max(c_max_map.get(k, (best.recomputability, 0))[0], c_base[k])
            for k in range(n_regions)
        ]
        sel = select_regions(a, c_base, c_max, l, t_s=t_s, tau=tau, freq_options=freq_options)
    elif region_measure == "isolated":
        gains = {}
        overheads = {}
        per_region_n = max(30, n_tests // 2)
        for k in range(n_regions):
            plan_k = PersistPlan(objects=crit, region_freq={k: 1})
            camp_k = CrashTester(
                app, plan_k, cache, seed=seed + 2 + k, fault=fault_model
            ).run_campaign(per_region_n, n_workers=n_workers)
            gains[k] = camp_k.recomputability - baseline.recomputability
            overheads[k] = l[k]
        sel = select_regions_from_gains(
            gains, overheads, baseline.recomputability, t_s=t_s, tau=tau,
            freq_options=freq_options,
        )
    else:
        raise ValueError(f"unknown region_measure {region_measure!r}")

    plan = PersistPlan(objects=crit, region_freq=sel.plan_freqs())
    return WorkflowResult(
        app_name=app.name,
        baseline_campaign=baseline,
        object_scores=scores,
        critical=crit,
        best_campaign=best,
        region_selection=sel,
        plan=plan,
        tau=tau,
        t_s=t_s,
    )
