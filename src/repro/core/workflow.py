"""The four-step EasyCrash workflow (paper §5.3).

Step 1 — run a crash-test campaign without persistence, collecting per-object
inconsistency rates and recompute outcomes.
Step 2 — Spearman selection of critical data objects.
Step 3 — run a second campaign persisting the critical objects at every
region (this also yields c_k^max per region), then solve the knapsack for
critical code regions and flush frequencies under (t_s, tau).
Step 4 — production: run with the resulting :class:`PersistPlan`.

``run_workflow`` executes steps 1–3 and returns everything a production run
(or the benchmarks reproducing the paper's figures) needs.

Orchestration: a workflow is not one campaign but W+2 of them (baseline,
persist-everywhere, and — in ``"isolated"`` mode — one per region).  The
default ``scheduler="shared"`` flattens all of them into a single task graph
of (campaign, shard) units executed on **one** shared process pool: the only
true barrier is after the baseline campaign (step 2's Spearman selection
decides what the remaining campaigns persist); past it, every shard of every
remaining campaign interleaves freely.  ``scheduler="serial"`` is the
historical engine (each campaign back-to-back with its own pool); results
are bit-for-bit identical between the two, at every worker count.

``store_path=`` appends each completed shard to a
:class:`~repro.core.campaign_store.WorkflowStore`; a killed ``run_workflow``
resumes from it and executes only the shards that never landed.
"""
from __future__ import annotations

import dataclasses
import warnings
from concurrent.futures import as_completed
from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .adaptive import (
    AdaptiveReport,
    RegionEvidence,
    SequentialConfig,
    StaticPriorSampler,
    final_rate_interval,
    selection_invariant,
    shard_rounds,
)
from .cache_sim import CacheConfig
from .crash_tester import (
    CampaignResult,
    CrashRecord,
    CrashTester,
    PersistPlan,
    PlannedTest,
    _shard_worker_run,
    campaign_executor,
)
from .efficiency import SystemConfig, tau_threshold
from .faults import FaultModel
from .regions import IterativeApp
from .selection import (
    ObjectScore,
    RegionSelection,
    critical_objects,
    select_objects,
    select_regions,
    select_regions_from_gains,
)

#: bump when the workflow-store line layout changes
WORKFLOW_STORE_VERSION = 1


@dataclass(frozen=True)
class WorkflowConfig:
    """Everything :func:`run_workflow` needs besides the app, in one frozen,
    validated object.

    The fields are exactly the historical keyword arguments; a config built
    with all defaults reproduces the historical default workflow bit for
    bit.  ``replace(**overrides)`` derives a variant (the idiom for sweeps);
    :meth:`spec` is the single serialization point — artifact and
    resume-store fingerprints are computed from it, never from ad-hoc field
    plumbing.

    ``shard_callback`` is runtime plumbing (progress reporting, crash
    injection in tests), not workflow identity: it is excluded from
    :meth:`spec`, so attaching one cannot invalidate a resume store.
    """

    n_tests: int = 200
    cache: CacheConfig = CacheConfig()  # frozen dataclass: safe shared default
    system: Optional[SystemConfig] = None
    t_s: float = 0.03
    p_threshold: float = 0.01
    freq_options: Tuple[int, ...] = (1, 2, 4, 8)
    seed: int = 0
    region_measure: str = "isolated"
    n_workers: int = 1
    fault_model: Optional[FaultModel] = None
    scheduler: str = "shared"
    store_path: Optional[str] = None
    shard_callback: Optional[Callable[[str, int], None]] = None
    engine: Optional[str] = None
    #: vec-engine lane-bucket cap (lanes stacked per batched-recompute
    #: dispatch); ``None`` defers to the ``REPRO_LANE_BATCH`` environment
    #: variable.  Execution plumbing like ``engine``: results are identical
    #: at any value, so it is excluded from :meth:`spec`.
    lane_batch: Optional[int] = None
    #: where the persist plan comes from: ``"measured"`` (the paper's W+2
    #: campaign), ``"static"`` (the jaxpr dataflow prediction, no campaigns
    #: at all), ``"static+verify"`` (campaigns only for the regions the
    #: static classification is uncertain about; confident decisions are
    #: taken as-is), or ``"adaptive"`` (every region campaigned, but
    #: importance-sampled from the static priors and early-stopped the
    #: moment the knapsack decision is settled — see
    #: :mod:`repro.core.adaptive`)
    plan_source: str = "measured"
    #: sequential-stopping knobs for the adaptive scheduler.  ``None`` with
    #: ``plan_source="adaptive"`` resolves to ``SequentialConfig()``; with
    #: ``"static+verify"`` it turns the surviving (uncertain-region)
    #: campaigns adaptive too; with any other plan_source it is an error.
    stopping: Optional[SequentialConfig] = None

    def __post_init__(self):
        object.__setattr__(self, "freq_options",
                           tuple(int(x) for x in self.freq_options))
        if self.n_tests < 1:
            raise ValueError(f"n_tests must be >= 1, got {self.n_tests}")
        if self.region_measure not in ("paper", "isolated"):
            raise ValueError(f"unknown region_measure {self.region_measure!r}")
        if self.scheduler not in ("shared", "serial"):
            raise ValueError(f"unknown scheduler {self.scheduler!r}")
        if self.scheduler != "shared" and (
            self.store_path is not None or self.shard_callback is not None
        ):
            raise ValueError(
                "store_path/shard_callback require the 'shared' scheduler"
            )
        if self.plan_source not in ("measured", "static", "static+verify", "adaptive"):
            raise ValueError(f"unknown plan_source {self.plan_source!r}")
        if self.plan_source == "static" and self.store_path is not None:
            raise ValueError(
                "plan_source='static' runs no campaigns; store_path is "
                "meaningless there"
            )
        if self.plan_source in ("static+verify", "adaptive") and self.region_measure != "isolated":
            raise ValueError(
                f"plan_source={self.plan_source!r} works on per-region campaigns and "
                f"requires region_measure='isolated'"
            )
        if self.stopping is not None and not isinstance(self.stopping, SequentialConfig):
            raise ValueError(
                f"stopping must be a SequentialConfig, got "
                f"{type(self.stopping).__name__}"
            )
        if self.stopping is not None and self.plan_source not in ("adaptive", "static+verify"):
            raise ValueError(
                "stopping requires plan_source='adaptive' or 'static+verify' "
                f"(got {self.plan_source!r})"
            )
        if self.plan_source == "adaptive" and self.scheduler != "shared":
            raise ValueError(
                "plan_source='adaptive' executes deterministic shard rounds "
                "and requires the 'shared' scheduler"
            )
        if (
            self.plan_source == "static+verify"
            and self.stopping is not None
            and self.scheduler != "shared"
        ):
            raise ValueError("stopping requires the 'shared' scheduler")

    def replace(self, **overrides) -> "WorkflowConfig":
        """A copy with the given fields overridden (re-validated)."""
        return dataclasses.replace(self, **overrides)

    def resolved_system(self) -> SystemConfig:
        return self.system or SystemConfig(mtbf=12 * 3600.0, t_chk=320.0)

    def adaptive_mode(self) -> bool:
        """Whether region campaigns run under the sequential scheduler."""
        return self.plan_source == "adaptive" or (
            self.plan_source == "static+verify" and self.stopping is not None
        )

    def resolved_stopping(self) -> SequentialConfig:
        return self.stopping if self.stopping is not None else SequentialConfig()

    def spec(self, app: IterativeApp, baseline_tester: CrashTester) -> Dict[str, object]:
        """Workflow identity (JSON-round-trip safe) for stores + artifacts.

        Only fields that change campaign *results* participate; execution
        plumbing (n_workers, scheduler, store_path, shard_callback, engine,
        lane_batch — all bit-for-bit invariant by contract) does not.
        """
        from .faults import PowerFail

        fault = self.fault_model if self.fault_model is not None else PowerFail()
        d = {
            "workflow_store_version": WORKFLOW_STORE_VERSION,
            "app": app.name,
            "state_digest": baseline_tester._state_digest(),
            "n_tests": int(self.n_tests),
            "seed": int(self.seed),
            "region_measure": str(self.region_measure),
            "t_s": float(self.t_s),
            "p_threshold": float(self.p_threshold),
            "freq_options": [int(x) for x in self.freq_options],
            "cache_blocks": int(self.cache.capacity_blocks),
            "block_bytes": int(self.cache.block_bytes),
            "fault": fault.spec(),
        }
        # only when non-default, so every historical fingerprint is unchanged
        if self.plan_source != "measured":
            d["plan_source"] = str(self.plan_source)
        if self.adaptive_mode():
            # the stopping rule changes which shards execute, so it is
            # workflow identity (resolved, so "adaptive" with stopping=None
            # and with an explicit default SequentialConfig() are the same
            # workflow — they are)
            d["stopping"] = self.resolved_stopping().spec()
        return d


@dataclass(frozen=True)
class CampaignSpec:
    """One campaign of a workflow's task graph, identified by ``key``
    (``"baseline"``, ``"best"``, ``"region:<k>"``).

    ``sampler`` (optional) importance-samples the campaign's crash points
    at planning time (:class:`~repro.core.adaptive.StaticPriorSampler`);
    it participates in the campaign's store fingerprint.
    """

    key: str
    plan: PersistPlan
    seed: int
    n_tests: int
    sampler: Optional[StaticPriorSampler] = None


@dataclass(frozen=True)
class RoundsResult:
    """What :meth:`WorkflowOrchestrator.run_rounds` executed.

    ``campaigns`` hold each campaign's result over the *executed prefix*
    only; ``planned``/``executed`` are the full pre-drawn test lists and the
    tests whose rounds actually ran.
    """

    campaigns: Dict[str, CampaignResult]
    planned: Dict[str, List[PlannedTest]]
    executed: Dict[str, List[PlannedTest]]
    rounds_executed: int
    rounds_total: int
    stopped_early: bool

    def spec(self) -> Dict[str, object]:
        return {
            "rounds_executed": self.rounds_executed,
            "rounds_total": self.rounds_total,
            "stopped_early": self.stopped_early,
            "campaigns": {k: c.spec() for k, c in sorted(self.campaigns.items())},
            "planned": {k: len(v) for k, v in sorted(self.planned.items())},
            "executed": {k: len(v) for k, v in sorted(self.executed.items())},
        }


class _PerCampaignRunner:
    """The historical scheduler: each campaign runs to completion on its own
    pool (``CrashTester.run_campaign``), strictly in submission order."""

    def __init__(self, app, cache, fault, n_workers, max_extra_factor=2.0, engine=None,
                 lane_batch=None):
        self.app, self.cache, self.fault = app, cache, fault
        self.n_workers = n_workers
        self.max_extra_factor = max_extra_factor
        self.engine = engine
        self.lane_batch = lane_batch

    def run(self, specs: Sequence[CampaignSpec]) -> Dict[str, CampaignResult]:
        out: Dict[str, CampaignResult] = {}
        for s in specs:
            out[s.key] = CrashTester(
                self.app, s.plan, self.cache, seed=s.seed,
                max_extra_factor=self.max_extra_factor, fault=self.fault,
                engine=self.engine, lane_batch=self.lane_batch,
            ).run_campaign(s.n_tests, n_workers=self.n_workers)
        return out

    def close(self) -> None:
        pass


class WorkflowOrchestrator:
    """Shared-pool scheduler for a workflow's (campaign, shard) task graph.

    * One :class:`~concurrent.futures.ProcessPoolExecutor` for the whole
      workflow: workers are spawned once (not once per campaign) and each
      worker hosts one :class:`CrashTester` per campaign it has seen, so
      per-campaign golden runs are paid at most once per worker.
    * Shards of different campaigns in the same :meth:`run` batch interleave
      freely — a straggler window of one region's campaign no longer blocks
      every other region's campaign from starting.
    * All campaign randomness is pre-drawn at planning time, so scheduling
      (order, worker count, resume) cannot change any result.
    * With a :class:`~repro.core.campaign_store.WorkflowStore` attached,
      completed shards are durably appended as they land and a resumed
      workflow executes only the missing ones.
    """

    def __init__(
        self,
        app: IterativeApp,
        cache: CacheConfig,
        fault: Optional[FaultModel],
        n_workers: int = 1,
        store=None,
        shard_callback: Optional[Callable[[str, int], None]] = None,
        max_extra_factor: float = 2.0,
        engine: Optional[str] = None,
        lane_batch: Optional[int] = None,
    ):
        self.app, self.cache, self.fault = app, cache, fault
        self.n_workers = n_workers
        self.store = store
        self.shard_callback = shard_callback
        self.max_extra_factor = max_extra_factor
        self.engine = engine
        self.lane_batch = lane_batch
        self._testers: Dict[str, Tuple[CampaignSpec, CrashTester]] = {}
        self._ex = None
        self._pickle_checked = False

    # ------------------------------------------------------------- plumbing
    def tester(self, spec: CampaignSpec) -> CrashTester:
        """The parent-side tester of one campaign (planning + assembly).

        A campaign key names one identity for the orchestrator's lifetime:
        parent and worker caches are keyed by it, so silently rebinding a
        key to a different plan/seed would hand back results computed under
        the old campaign.
        """
        cached = self._testers.get(spec.key)
        if cached is not None:
            prev, t = cached
            if (prev.plan, prev.seed, prev.sampler) != (spec.plan, spec.seed, spec.sampler):
                raise ValueError(
                    f"campaign key {spec.key!r} already bound to a different "
                    f"plan/seed/sampler in this orchestrator; use a fresh key"
                )
            return t
        t = CrashTester(
            self.app, spec.plan, self.cache, seed=spec.seed,
            max_extra_factor=self.max_extra_factor, fault=self.fault,
            engine=self.engine, sampler=spec.sampler,
            lane_batch=self.lane_batch,
        )
        self._testers[spec.key] = (spec, t)
        return t

    def _pool(self):
        if self._ex is None:
            self._ex = campaign_executor(
                n_workers=self.n_workers, app=self.app, cache=self.cache,
                max_extra_factor=self.max_extra_factor, fault=self.fault,
                engine=self.engine, lane_batch=self.lane_batch,
            )
        return self._ex

    def _use_pool(self, n_pending: int) -> bool:
        if self.n_workers <= 1 or n_pending <= 1:
            return False
        if self._ex is not None:
            return True
        if not self._pickle_checked:
            self._pickle_checked = True
            ok, err = CrashTester(
                self.app, PersistPlan.none(), self.cache, fault=self.fault
            ).payload_picklable()
            if not ok:
                import warnings

                warnings.warn(
                    f"{self.app.name}: workflow payload is not picklable "
                    f"({err!r}); running shards serially", RuntimeWarning,
                    stacklevel=3,
                )
                self.n_workers = 1
        return self.n_workers > 1

    # ------------------------------------------------------------ execution
    def run(self, specs: Sequence[CampaignSpec]) -> Dict[str, CampaignResult]:
        """Execute a batch of campaigns, interleaving their shards."""
        planned: Dict[str, Tuple[List[PlannedTest], Dict[int, List[PlannedTest]]]] = {}
        results: Dict[str, Dict[int, List[Tuple[int, CrashRecord]]]] = {}
        pending: List[Tuple[CampaignSpec, int, List[PlannedTest]]] = []
        for spec in specs:
            planned[spec.key] = self.tester(spec).plan_shards(spec.n_tests, spec.seed)
        stored: Dict[str, Dict[int, List[Tuple[int, CrashRecord]]]] = {}
        if self.store is not None:
            # one store pass registers/validates the whole batch
            stored = self.store.register_campaigns({
                spec.key: self.tester(spec)._fingerprint(spec.n_tests, spec.seed)
                for spec in specs
            })
        for spec in specs:
            tests, shards = planned[spec.key]
            done = {
                k: v for k, v in stored.get(spec.key, {}).items() if k in shards
            }
            results[spec.key] = done
            for ci, ts in shards.items():
                if ci not in done:
                    pending.append((spec, ci, ts))

        self._execute_pending(pending, results)

        out = {
            key: self._testers[key][1].assemble_campaign(planned[key][0], results[key])
            for key in planned
        }
        for key in planned:
            # the campaign is assembled; don't keep W+2 golden trajectories
            # pinned in the parent for the rest of the workflow
            self._testers[key][1].release_caches()
        return out

    def _execute_pending(
        self,
        pending: Sequence[Tuple[CampaignSpec, int, List[PlannedTest]]],
        results: Dict[str, Dict[int, List[Tuple[int, CrashRecord]]]],
    ) -> None:
        """Execute pending (campaign, shard) units; land each as it finishes."""
        if self._use_pool(len(pending)):
            ex = self._pool()
            futs = {
                ex.submit(_shard_worker_run, spec.key, spec.plan, spec.seed, ci, ts):
                    spec.key
                for spec, ci, ts in pending
            }
            for fut in as_completed(futs):
                key, ci, recs = fut.result()
                self._land(key, ci, recs, results)
        else:
            # in-process: hand each campaign's pending shards to run_shards,
            # which batches recompute lanes across windows on the vec engine;
            # _land fires per shard exactly as the per-shard loop did
            by_spec: Dict[str, Tuple[CampaignSpec, Dict[int, List[PlannedTest]]]] = {}
            for spec, ci, ts in pending:
                by_spec.setdefault(spec.key, (spec, {}))[1][ci] = ts
            for key, (spec, shard_map) in by_spec.items():
                self.tester(spec).run_shards(
                    shard_map,
                    on_shard=lambda ci, recs, _k=key: self._land(_k, ci, recs, results),
                )

    def run_rounds(
        self,
        specs: Sequence[CampaignSpec],
        round_tests: int,
        min_rounds: int,
        should_stop,
    ) -> "RoundsResult":
        """Execute campaigns in deterministic barrier rounds with early stop.

        Each campaign's shards are partitioned by
        :func:`~repro.core.adaptive.shard_rounds` (whole shards, planned-test
        order, ~``round_tests`` tests per round) — a pure function of the
        plan.  Round *r* of every campaign executes together (pool or
        in-process, identical results), lands durably, and then
        ``should_stop(partial, executed, planned)`` is evaluated on the
        completed prefix: ``partial`` maps campaign key to the
        :class:`CampaignResult` over the executed tests so far, ``executed``
        / ``planned`` map keys to test lists.  Because the executed set
        after each round — and therefore the stop round — depends only on
        the completed-round prefix, worker count and kill/resume cannot
        change any result bit.  Stored shards beyond the stop round (never
        produced by this scheduler, but a store is append-only) are ignored
        deterministically.
        """
        planned: Dict[str, Tuple[List[PlannedTest], Dict[int, List[PlannedTest]]]] = {}
        for spec in specs:
            planned[spec.key] = self.tester(spec).plan_shards(spec.n_tests, spec.seed)
        stored: Dict[str, Dict[int, List[Tuple[int, CrashRecord]]]] = {}
        if self.store is not None:
            stored = self.store.register_campaigns({
                spec.key: self.tester(spec)._fingerprint(spec.n_tests, spec.seed)
                for spec in specs
            })
        rounds_by_key = {
            spec.key: shard_rounds(planned[spec.key][0], planned[spec.key][1], round_tests)
            for spec in specs
        }
        rounds_total = max((len(r) for r in rounds_by_key.values()), default=0)

        results: Dict[str, Dict[int, List[Tuple[int, CrashRecord]]]] = {
            spec.key: {} for spec in specs
        }
        executed: Dict[str, List[PlannedTest]] = {spec.key: [] for spec in specs}
        planned_tests = {key: planned[key][0] for key in planned}
        stopped_early = False
        rounds_executed = 0
        for r in range(rounds_total):
            pending: List[Tuple[CampaignSpec, int, List[PlannedTest]]] = []
            for spec in specs:
                rounds_k = rounds_by_key[spec.key]
                if r >= len(rounds_k):
                    continue
                shards = planned[spec.key][1]
                for ci in rounds_k[r]:
                    executed[spec.key].extend(shards[ci])
                    done = stored.get(spec.key, {}).get(ci)
                    if done is not None:
                        results[spec.key][ci] = done
                    else:
                        pending.append((spec, ci, shards[ci]))
            self._execute_pending(pending, results)
            rounds_executed = r + 1
            if rounds_executed >= min_rounds and rounds_executed < rounds_total:
                partial = self._assemble_prefix(specs, executed, results)
                if should_stop(partial, executed, planned_tests):
                    stopped_early = True
                    break

        campaigns = self._assemble_prefix(specs, executed, results)
        for spec in specs:
            self._testers[spec.key][1].release_caches()
        return RoundsResult(
            campaigns=campaigns,
            planned=planned_tests,
            executed=executed,
            rounds_executed=rounds_executed,
            rounds_total=rounds_total,
            stopped_early=stopped_early,
        )

    def _assemble_prefix(
        self,
        specs: Sequence[CampaignSpec],
        executed: Mapping[str, List[PlannedTest]],
        results: Mapping[str, Dict[int, List[Tuple[int, CrashRecord]]]],
    ) -> Dict[str, CampaignResult]:
        return {
            spec.key: self._testers[spec.key][1].assemble_campaign(
                sorted(executed[spec.key], key=lambda t: t.index),
                results[spec.key],
            )
            for spec in specs
        }

    def _land(self, key, ci, recs, results) -> None:
        if self.store is not None:
            self.store.append_shard(key, ci, recs)
        results[key][ci] = recs
        if self.shard_callback is not None:
            self.shard_callback(key, ci)

    def close(self) -> None:
        if self._ex is not None:
            self._ex.shutdown()
            self._ex = None


@dataclass(frozen=True)
class WorkflowResult:
    app_name: str
    baseline_campaign: Optional[CampaignResult]  # step 1 (None for plan_source="static")
    object_scores: List[ObjectScore]           # step 2
    critical: Tuple[str, ...]
    best_campaign: Optional[CampaignResult]    # step 3 input (None for "static")
    region_selection: RegionSelection
    plan: PersistPlan                          # step 4 product
    tau: float
    t_s: float
    #: provenance + cost of the plan: which source produced it and how many
    #: crash tests the workflow actually executed to get there
    plan_source: str = "measured"
    tests_executed: int = 0
    #: the :class:`repro.analysis.classify.StaticPlan` evidence, when a
    #: static plan_source was used (duck-typed: core does not import analysis)
    static_plan: Optional[object] = None
    #: the sequential scheduler's stopping decision + per-region evidence,
    #: when the workflow ran adaptively
    adaptive: Optional[AdaptiveReport] = None

    def summary(self) -> Dict[str, float]:
        nan = float("nan")
        return {
            "baseline_recomputability": (
                self.baseline_campaign.recomputability
                if self.baseline_campaign is not None else nan),
            "best_recomputability": (
                self.best_campaign.recomputability
                if self.best_campaign is not None else nan),
            "expected_recomputability": self.region_selection.expected_recomputability,
            "planned_overhead": self.region_selection.total_overhead,
            "n_critical_objects": float(len(self.critical)),
            "n_critical_regions": float(len(self.region_selection.choices)),
            "tau": self.tau,
            "tests_executed": float(self.tests_executed),
        }

    def spec(self) -> Dict[str, object]:
        """JSON-round-trip-safe identity of the workflow outcome."""
        def _f(x: float):
            x = float(x)
            return x if x == x and abs(x) != float("inf") else None

        return {
            "app": self.app_name,
            "plan_source": self.plan_source,
            "critical": list(self.critical),
            "plan": {
                "objects": list(self.plan.objects),
                "region_freq": sorted(
                    [int(k), int(v)] for k, v in self.plan.region_freq.items()
                ),
            },
            "tau": _f(self.tau),
            "t_s": _f(self.t_s),
            "tests_executed": int(self.tests_executed),
            "summary": {k: _f(v) for k, v in self.summary().items()},
            # only when the workflow ran adaptively: historical specs unchanged
            **({"adaptive": self.adaptive.to_payload()}
               if self.adaptive is not None else {}),
        }

    def recompute_profile(self, which: str = "best", fault: Optional[FaultModel] = None):
        """The workflow's measured :class:`~repro.core.sysim.RecomputeProfile`
        — S1–S4 rates plus the extra-recompute-iteration histogram — for the
        system-efficiency simulator.

        ``which`` picks the measured campaign: ``"best"`` (persist
        everywhere — the upper bound the knapsack plan approaches) or
        ``"baseline"`` (no EasyCrash flushes at all).  ``fault`` must name
        the model the workflow ran under (``run_workflow(fault_model=)``);
        ``None`` is the default clean power failure.
        """
        from .sysim import RecomputeProfile

        campaigns = {"best": self.best_campaign, "baseline": self.baseline_campaign}
        if which not in campaigns:
            raise ValueError(f"which={which!r}, expected one of {sorted(campaigns)}")
        if campaigns[which] is None:
            raise ValueError(
                f"workflow ran with plan_source={self.plan_source!r}: no "
                f"{which!r} campaign was measured"
            )
        return RecomputeProfile.from_campaign(campaigns[which], fault=fault)


def estimate_region_overheads(
    app: IterativeApp,
    objects: Sequence[str],
    flush_cost_per_block: float = 0.1,
    block_bytes: int = 64,
) -> List[float]:
    """Estimate l_k: cost of flushing the selected objects at region k, as a
    fraction of one iteration's execution time.

    The paper estimates l_k from the measured cost of flushing one cache
    block times the object block count, deliberately assuming every block is
    resident+dirty (an overestimate, then doubled for reload cost — kept
    here).  Execution time per region is proxied by its access volume times
    its declared cost weight; ``flush_cost_per_block`` calibrates a CLWB
    write-back against one region "access" (a region access implies FLOPs,
    a flush is a pure streaming store — the paper measures ~0.03 s per
    persist op against seconds-long iterations).
    """
    state = app.init(0)
    regs = app.regions()
    region_time = []
    for r in regs:
        vol = sum(
            max(1, -(-np.asarray(state[o]).nbytes // block_bytes))
            for o in tuple(r.reads) + tuple(r.writes)
            if o in state
        )
        region_time.append(max(1.0, vol) * r.cost)
    total_time = sum(region_time)
    flush_blocks = sum(
        max(1, -(-np.asarray(state[o]).nbytes // block_bytes))
        for o in objects
        if o in state
    )
    # x2: CLFLUSH-style invalidation forces reloads (paper §5.2 "How to use")
    l_once = 2.0 * flush_cost_per_block * flush_blocks
    return [l_once / total_time for _ in regs]


def region_time_fractions(app: IterativeApp, block_bytes: int = 64) -> List[float]:
    """a_k: execution-time fraction per region (access-volume x cost proxy)."""
    state = app.init(0)
    regs = app.regions()
    t = []
    for r in regs:
        vol = sum(
            max(1, -(-np.asarray(state[o]).nbytes // block_bytes))
            for o in tuple(r.reads) + tuple(r.writes)
            if o in state
        )
        t.append(max(1.0, vol) * r.cost)
    s = sum(t)
    return [x / s for x in t]


def workflow_fingerprint(
    app: IterativeApp,
    baseline_tester: CrashTester,
    n_tests: int,
    seed: int,
    cache: CacheConfig,
    region_measure: str,
    t_s: float,
    p_threshold: float,
    freq_options: Sequence[int],
    fault: FaultModel,
) -> Dict[str, object]:
    """Identity of a workflow for the resume store (JSON-round-trip safe).

    Thin compatibility wrapper over :meth:`WorkflowConfig.spec` — the one
    serialization point for workflow identity.
    """
    cfg = WorkflowConfig(
        n_tests=n_tests, cache=cache, t_s=t_s, p_threshold=p_threshold,
        freq_options=tuple(freq_options), seed=seed,
        region_measure=region_measure, fault_model=fault,
    )
    return cfg.spec(app, baseline_tester)


def run_workflow(app: IterativeApp, config=None, /, **kwargs) -> WorkflowResult:
    """Steps 1–3.

    Primary signature: ``run_workflow(app, WorkflowConfig(...))``; extra
    keyword arguments are applied as overrides via
    :meth:`WorkflowConfig.replace`.  The historical 14-keyword form
    (``run_workflow(app, n_tests=..., cache=..., ...)``) still works as a
    deprecation shim that builds the same config — results are identical.

    ``n_workers`` workers execute the workflow's crash-test shards; results
    are identical for every worker count.

    ``engine`` selects the campaign hot path (``"vec"`` | ``"ref"``, see
    :class:`~repro.core.crash_tester.CrashTester`); results are bit-for-bit
    identical between engines.  The workflow's campaigns share simulated
    crash windows through the process-wide
    :class:`~repro.core.trace_cache.WindowTraceCache` — the baseline and
    per-region campaigns reuse each other's window payloads, and replaying
    the same plan (robustness matrix, artifact replay) reuses whole traces.

    ``scheduler`` selects how the workflow's W+2 campaigns are executed:

    * ``"shared"`` (default) — the :class:`WorkflowOrchestrator`: one shared
      process pool for every campaign, shards of independent campaigns
      interleaved;
    * ``"serial"`` — the historical path: each campaign back-to-back through
      :meth:`~repro.core.crash_tester.CrashTester.run_campaign`, each with
      its own pool.  Bit-for-bit identical results, slower wall-clock.

    ``store_path`` (``"shared"`` scheduler only) appends every completed
    shard to a :class:`~repro.core.campaign_store.WorkflowStore`: kill the
    workflow at any point, re-run the same call, and only the missing shards
    execute.  ``shard_callback(campaign_key, shard_id)`` fires after each
    executed shard has been durably stored (progress reporting, crash
    injection in tests).

    ``fault_model`` selects what a "crash" is for every campaign the
    workflow runs (:mod:`repro.core.faults`); ``None`` is the paper's clean
    power failure.  Characterizing under one model and deploying the plan
    under another is exactly the scenario-robustness question the fault
    sweep in ``benchmarks/bench_recomputability.py`` measures.

    ``region_measure`` selects how c_k^max is estimated:

    * ``"paper"`` — one persist-everywhere campaign, per-region grouping
      (§5.2's shortcut; cheap but mis-attributes when flushing at region j
      changes the image seen by crashes in region k);
    * ``"isolated"`` — one small campaign per region with flushes at that
      region only (the paper's own Fig 4b methodology).  Costs W extra
      campaigns but measures the true marginal gain of each region.
    """
    if isinstance(config, WorkflowConfig):
        cfg = config.replace(**kwargs) if kwargs else config
    elif config is None:
        if kwargs:
            # stacklevel=2 attributes the warning to run_workflow's caller
            # (the site that must migrate), not this shim; it fires before
            # WorkflowConfig validation so even a call with bad kwargs tells
            # the caller to migrate.  tests/test_workflow_config.py pins the
            # warning's origin.
            warnings.warn(
                "run_workflow(app, n_tests=..., ...) keyword form is "
                "deprecated; pass run_workflow(app, WorkflowConfig(...))",
                DeprecationWarning, stacklevel=2,
            )
        cfg = WorkflowConfig(**kwargs)
    elif isinstance(config, int):
        # legacy positional n_tests
        warnings.warn(
            "run_workflow(app, n_tests) positional form is deprecated; "
            "pass run_workflow(app, WorkflowConfig(n_tests=...))",
            DeprecationWarning, stacklevel=2,
        )
        cfg = WorkflowConfig(n_tests=config, **kwargs)
    else:
        raise TypeError(
            f"config must be a WorkflowConfig (or legacy kwargs), got "
            f"{type(config).__name__}"
        )

    n_tests, cache, seed = cfg.n_tests, cfg.cache, cfg.seed
    t_s, p_threshold, freq_options = cfg.t_s, cfg.p_threshold, cfg.freq_options
    region_measure, fault_model = cfg.region_measure, cfg.fault_model
    tau = tau_threshold(cfg.resolved_system(), t_s=t_s)

    static_plan = None
    if cfg.plan_source != "measured":
        # lazy import: core must not import analysis at module load
        from ..analysis.classify import analyze_app

        static_plan = analyze_app(app, cache=cache, seed=seed)

    if cfg.plan_source == "static":
        # no campaigns at all: the dataflow classification is the plan
        sel = static_plan.region_selection(
            t_s=t_s, tau=tau, freq_options=freq_options
        )
        crit = static_plan.persist_objects()
        plan = PersistPlan(objects=crit, region_freq=sel.plan_freqs())
        return WorkflowResult(
            app_name=app.name,
            baseline_campaign=None,
            object_scores=[],
            critical=crit,
            best_campaign=None,
            region_selection=sel,
            plan=plan,
            tau=tau,
            t_s=t_s,
            plan_source="static",
            tests_executed=0,
            static_plan=static_plan,
        )

    if cfg.scheduler == "serial":
        runner = _PerCampaignRunner(
            app, cache, fault_model, cfg.n_workers, engine=cfg.engine,
            lane_batch=cfg.lane_batch,
        )
    else:
        store = None
        runner = WorkflowOrchestrator(
            app, cache, fault_model, cfg.n_workers,
            shard_callback=cfg.shard_callback, engine=cfg.engine,
            lane_batch=cfg.lane_batch,
        )
        if cfg.store_path is not None:
            from .campaign_store import WorkflowStore

            store = WorkflowStore(cfg.store_path)
            store.load_or_create(cfg.spec(
                app,
                runner.tester(CampaignSpec("baseline", PersistPlan.none(), seed, n_tests)),
            ))
            runner.store = store

    try:
        # Step 1: baseline campaign (NVM holds whatever eviction left there).
        # This is the task graph's one true barrier: step 2's selection (and
        # therefore every later campaign's persist plan) depends on it.
        baseline = runner.run(
            [CampaignSpec("baseline", PersistPlan.none(), seed, n_tests)]
        )["baseline"]

        # Step 2: Spearman object selection.  The loop iterator is excluded:
        # it is *always* persisted (paper fn. 3), never subject to selection.
        sel_candidates = [c for c in app.candidates if c != app.iterator_object]
        scores = select_objects(baseline, sel_candidates, p_threshold)
        crit = critical_objects(scores)
        if not crit:
            # fall back to the most negatively-correlated object: persisting
            # nothing would make step 3 vacuous (paper always persists >=1)
            ranked = sorted(
                (s for s in scores if not np.isnan(s.rs)), key=lambda s: s.rs
            )
            crit = (ranked[0].name,) if ranked else tuple(sel_candidates[:1])

        # Step 3: measure per-region recomputability with persistence, then
        # solve the knapsack.  Every remaining campaign is independent, so
        # the shared scheduler flattens them into one interleaved shard batch.
        n_regions = len(app.regions())
        a = region_time_fractions(app, cache.block_bytes)
        l = estimate_region_overheads(app, crit, block_bytes=cache.block_bytes)
        adaptive_mode = cfg.adaptive_mode()
        stopping = cfg.resolved_stopping() if adaptive_mode else None
        sampler = None
        region_ids: List[int] = []
        region_specs: List[CampaignSpec] = []
        per_region_n = max(30, n_tests // 2)
        if region_measure == "isolated":
            # which regions get a measurement campaign: "adaptive" campaigns
            # all of them (cheaply — IS + early stop); static+verify only the
            # regions whose static classification is uncertain; "measured"
            # all of them, brute force.  Seeds stay seed+2+k so any campaign
            # that does run draws the same stream as the full workflow's.
            if static_plan is not None and cfg.plan_source == "static+verify":
                region_ids = static_plan.uncertain_regions()
            else:
                region_ids = list(range(n_regions))
            if adaptive_mode and stopping.sampler_bias > 0 and region_ids:
                sampler = StaticPriorSampler(
                    static_plan.window_confidences(), bias=stopping.sampler_bias
                )
            region_specs = [
                CampaignSpec(
                    f"region:{k}",
                    PersistPlan(objects=crit, region_freq={k: 1}),
                    seed + 2 + k,
                    per_region_n,
                    sampler=sampler,
                )
                for k in region_ids
            ]
        specs = [CampaignSpec("best", PersistPlan.best(crit, app), seed + 1, n_tests)]
        adaptive_report = None
        if adaptive_mode:
            c_base = baseline.recomputability
            overheads = {k: l[k] for k in range(n_regions)}
            decisions = {r.index: r.decision for r in static_plan.regions}
            campaigned = set(region_ids)
            best_in_rounds = cfg.plan_source == "adaptive"
            if best_in_rounds:
                # Pure adaptive mode: the knapsack's gains are region-vs-
                # baseline, so the persist-everything reference never feeds
                # the decision.  Its remaining uncertainty therefore cannot
                # change the plan — the stopping criterion applies to it
                # verbatim, and it rides the same rounds as the regions,
                # stopping the moment the region evidence settles the plan.
                best = None
                rounds_specs = specs + region_specs
            else:
                # static+verify composition: confident-persist regions take
                # their gain from the reference headroom, so the reference
                # *is* consumed by the decision and must be measured in full.
                best = runner.run(specs)["best"]
                rounds_specs = region_specs

            def _fixed_gain(k: int) -> float:
                # regions static+verify trusts without measuring: same gain
                # attribution as the non-adaptive static+verify path below
                if decisions.get(k) == "persist":
                    return best.recomputability - c_base
                return 0.0

            def _evidence(partial, executed, planned_tests, key, z):
                camp = partial[key]
                vals = [1.0 if rec.outcome == "S1" else 0.0 for rec in camp.records]
                ws = [rec.weight for rec in camp.records]
                done = {t.index for t in executed[key]}
                rem = [
                    t.weight for t in planned_tests[key] if t.index not in done
                ]
                return final_rate_interval(vals, ws, rem, z)

            def _should_stop(partial, executed, planned_tests) -> bool:
                point_gains: Dict[int, float] = {}
                boxes: Dict[int, Tuple[float, float]] = {}
                for k in range(n_regions):
                    if k in campaigned:
                        lo, hi, rate, _ = _evidence(
                            partial, executed, planned_tests,
                            f"region:{k}", stopping.z,
                        )
                        if rate != rate:  # no evidence yet
                            return False
                        point_gains[k] = rate - c_base
                        boxes[k] = (lo - c_base, hi - c_base)
                    else:
                        point_gains[k] = _fixed_gain(k)
                return selection_invariant(
                    point_gains, boxes, overheads, c_base, t_s=t_s, tau=tau,
                    freq_options=freq_options, max_corners=stopping.max_corners,
                ) is not None

            if rounds_specs:
                rounds = runner.run_rounds(
                    rounds_specs, stopping.round_tests, stopping.min_rounds,
                    _should_stop,
                )
            else:
                rounds = RoundsResult({}, {}, {}, 0, 0, False)
            if best_in_rounds:
                best = rounds.campaigns["best"]
                campaigns = dict(rounds.campaigns)
            else:
                campaigns = {"best": best, **rounds.campaigns}
            evidence = []
            for k in region_ids:
                lo, hi, rate, n_eff = _evidence(
                    rounds.campaigns, rounds.executed, rounds.planned,
                    f"region:{k}", stopping.z,
                )
                evidence.append(RegionEvidence(
                    region=k,
                    executed=rounds.campaigns[f"region:{k}"].n,
                    planned=per_region_n,
                    rate=rate,
                    interval=(lo, hi),
                    n_eff=n_eff,
                ))
            reference_ev = None
            if best_in_rounds:
                lo, hi, rate, n_eff = _evidence(
                    rounds.campaigns, rounds.executed, rounds.planned,
                    "best", stopping.z,
                )
                reference_ev = RegionEvidence(
                    region=-1,
                    executed=best.n,
                    planned=n_tests,
                    rate=rate,
                    interval=(lo, hi),
                    n_eff=n_eff,
                )
            adaptive_report = AdaptiveReport(
                rounds_executed=rounds.rounds_executed,
                rounds_total=rounds.rounds_total,
                stopped_early=rounds.stopped_early,
                tests_executed=sum(c.n for c in rounds.campaigns.values()),
                tests_planned=(
                    per_region_n * len(region_ids)
                    + (n_tests if best_in_rounds else 0)
                ),
                regions=tuple(evidence),
                stopping=stopping.spec(),
                sampler=None if sampler is None else sampler.spec(),
                reference=reference_ev,
            )
        else:
            specs += region_specs
            campaigns = runner.run(specs)
            best = campaigns["best"]

        if region_measure == "paper":
            c_base_map = baseline.per_region_recomputability()
            c_max_map = best.per_region_recomputability()
            c_base = [c_base_map.get(k, (baseline.recomputability, 0))[0] for k in range(n_regions)]
            c_max = [
                max(c_max_map.get(k, (best.recomputability, 0))[0], c_base[k])
                for k in range(n_regions)
            ]
            sel = select_regions(a, c_base, c_max, l, t_s=t_s, tau=tau, freq_options=freq_options)
        else:
            decisions = (
                {r.index: r.decision for r in static_plan.regions}
                if static_plan is not None else {}
            )
            gains = {}
            overheads = {}
            for k in range(n_regions):
                camp_k = campaigns.get(f"region:{k}")
                if camp_k is not None:
                    # the self-normalized weighted rate: recovers the
                    # uniform-draw estimate under importance sampling and is
                    # numerically identical to .recomputability without it
                    gains[k] = camp_k.weighted_recomputability - baseline.recomputability
                elif decisions.get(k) == "persist":
                    # confident static persist: the best campaign's headroom
                    # is the gain flushing every iteration at one region can
                    # at most realize — the same quantity the measured
                    # isolated campaign estimates
                    gains[k] = best.recomputability - baseline.recomputability
                else:
                    gains[k] = 0.0  # confident static skip: no gain, DP drops it
                overheads[k] = l[k]
            sel = select_regions_from_gains(
                gains, overheads, baseline.recomputability, t_s=t_s, tau=tau,
                freq_options=freq_options,
            )
    finally:
        runner.close()

    executed = baseline.n + best.n + sum(
        c.n for key, c in campaigns.items() if key.startswith("region:")
    )
    plan = PersistPlan(objects=crit, region_freq=sel.plan_freqs())
    return WorkflowResult(
        app_name=app.name,
        baseline_campaign=baseline,
        object_scores=scores,
        critical=crit,
        best_campaign=best,
        region_selection=sel,
        plan=plan,
        tau=tau,
        t_s=t_s,
        plan_source=cfg.plan_source,
        tests_executed=int(executed),
        static_plan=static_plan,
        adaptive=adaptive_report,
    )
