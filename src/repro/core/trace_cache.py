"""Cross-campaign window-trace cache.

A workflow is W+2 campaigns over the *same* application, and the robustness
matrix replays one persist plan under every fault model: most of the crash
windows those runs simulate are identical work.  Historically each
:class:`~repro.core.crash_tester.CrashTester` kept a private per-campaign
window cache, so the same window was re-simulated once per campaign and —
under the process-pool schedulers — once per worker that touched it.

This module shares that work at process scope, in two layers keyed by
content fingerprints:

* **payload layer** — the *application* side of a window: re-running the
  region functions over iterations ``[first, last]`` and snapshotting each
  region occurrence's written values (``seq_values``).  This is independent
  of the persist plan and of the cache-simulation engine, so a workflow's
  baseline / persist-everywhere / per-region campaigns all share it.
* **trace layer** — the simulated :class:`~repro.core.cache_sim.WindowTrace`
  plus its ``seq_values``, keyed additionally by the cache geometry, the
  window's *effective flush schedule* (which flushes actually fire inside
  the window — plans that fire no flush in a window share the baseline
  trace), and the engine.  Replaying a plan under a different fault model,
  re-running a campaign, or robustness-matrix sweeps hit this layer outright.

Keys carry an *app token* — a monotonically increasing id handed out per
live app object through a :class:`weakref.WeakKeyDictionary` — plus the
tester's state digest.  The token ties a cache entry to one concrete app
instance (solver parameters and all); the digest ties it to the golden
trajectory's initial state.  Tokens are never reused, so a collected app's
entries simply age out of the LRU.

Everything cached is treated as immutable by contract: the resolvers only
read ``seq_values`` and the trace arrays, and snapshot copies before
mutating images.
"""
from __future__ import annotations

import os
import threading
import weakref
from collections import OrderedDict
from typing import Dict, NamedTuple, Optional, Tuple

import numpy as np


class WindowPayload(NamedTuple):
    """Plan-independent result of re-running one window's regions."""

    seq_values: Dict[int, Dict[str, np.ndarray]]
    obj_blocks: Dict[str, int]
    #: (seq, iter_idx, region_idx) per region occurrence, in execution order
    meta: Tuple[Tuple[int, int, int], ...]


class WindowTraceCache:
    """Process-local two-layer LRU over window payloads and traces.

    Thread-safe (the workflow orchestrator's result callbacks land on the
    executor's waiter threads).  ``max_traces`` / ``max_payloads`` bound the
    resident entries; both layers hold full per-region object snapshots, so
    the caps — not entry sizes — are the memory knob.
    """

    def __init__(self, max_traces: int = 128, max_payloads: int = 32):
        self.max_traces = max_traces
        self.max_payloads = max_payloads
        self._traces: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._payloads: "OrderedDict[tuple, WindowPayload]" = OrderedDict()
        self._lock = threading.Lock()
        self._app_tokens: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
        self._next_token = 0
        self.hits = 0
        self.misses = 0
        self.payload_hits = 0
        self.payload_misses = 0

    # ------------------------------------------------------------------ keys
    def app_token(self, app) -> int:
        """Stable, never-reused id for one live app object."""
        with self._lock:
            tok = self._app_tokens.get(app)
            if tok is None:
                tok = self._next_token
                self._next_token += 1
                self._app_tokens[app] = tok
            return tok

    # --------------------------------------------------------------- payloads
    def get_payload(self, key: tuple) -> Optional[WindowPayload]:
        with self._lock:
            p = self._payloads.get(key)
            if p is not None:
                self._payloads.move_to_end(key)
                self.payload_hits += 1
            else:
                self.payload_misses += 1
            return p

    def put_payload(self, key: tuple, payload: WindowPayload) -> None:
        if self.max_payloads <= 0:
            return
        with self._lock:
            self._payloads[key] = payload
            self._payloads.move_to_end(key)
            while len(self._payloads) > self.max_payloads:
                self._payloads.popitem(last=False)

    # ----------------------------------------------------------------- traces
    def get_trace(self, key: tuple) -> Optional[tuple]:
        """Returns ``(trace, seq_values, crash_span_start)`` or ``None``."""
        with self._lock:
            entry = self._traces.get(key)
            if entry is not None:
                self._traces.move_to_end(key)
                self.hits += 1
            else:
                self.misses += 1
            return entry

    def put_trace(self, key: tuple, entry: tuple) -> None:
        if self.max_traces <= 0:
            return
        with self._lock:
            self._traces[key] = entry
            self._traces.move_to_end(key)
            while len(self._traces) > self.max_traces:
                self._traces.popitem(last=False)

    # ------------------------------------------------------------------ admin
    def clear(self) -> None:
        with self._lock:
            self._traces.clear()
            self._payloads.clear()
            self.hits = self.misses = 0
            self.payload_hits = self.payload_misses = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "traces": len(self._traces),
                "payloads": len(self._payloads),
                "hits": self.hits,
                "misses": self.misses,
                "payload_hits": self.payload_hits,
                "payload_misses": self.payload_misses,
            }


_SHARED: Optional[WindowTraceCache] = None
_SHARED_LOCK = threading.Lock()


def shared_trace_cache() -> WindowTraceCache:
    """The process-wide cache (one per worker process, one in the parent).

    ``REPRO_TRACE_CACHE=N`` caps the trace layer (0 disables both layers);
    the payload cap scales as ``max(4, N // 4)``.
    """
    global _SHARED
    with _SHARED_LOCK:
        if _SHARED is None:
            try:
                n = int(os.environ.get("REPRO_TRACE_CACHE", "128"))
            except ValueError:
                n = 128
            _SHARED = WindowTraceCache(
                max_traces=n, max_payloads=max(4, n // 4) if n > 0 else 0
            )
        return _SHARED
