"""Model / shape configuration for the architecture zoo.

One :class:`ModelConfig` describes any of the ten assigned architectures
(dense GQA, MoE, SSM/RWKV-6, RG-LRU hybrid, audio/VLM backbones).  Layer
stacks are described as *groups* — ``(pattern, repeat)`` pairs — so hybrids
like RecurrentGemma's (rec, rec, attn) x 12 + (rec, rec) compile as one
``lax.scan`` per group.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional, Sequence, Tuple

LayerKind = Literal["attn", "rec", "rwkv"]


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    d_ff_shared: int = 0          # shared-expert MLP width (0 = none)
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    impl: Literal["sort", "dense"] = "sort"
    #: shard experts over "model" (EP) when num_experts divides the axis,
    #: else shard the expert FF dim (TP)
    expert_parallel: bool = True
    #: dispatch in G token groups (group dim sharded with the batch) so the
    #: sort/scatter stays shard-local; 1 = one global dispatch (baseline)
    dispatch_groups: int = 1


@dataclass(frozen=True)
class RecurrentConfig:
    """RG-LRU (RecurrentGemma) block parameters."""

    d_rnn: int = 0                # recurrence width (lru_width)
    conv_width: int = 4
    window: int = 2048            # local-attention window of the hybrid


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    activation: Literal["silu", "gelu", "relu2"] = "silu"
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    rec: Optional[RecurrentConfig] = None
    rwkv: Optional[RWKVConfig] = None
    #: layer groups: ((kind, kind, ...), repeat); default = all-attn
    layer_groups: Optional[Tuple[Tuple[Tuple[str, ...], int], ...]] = None
    #: number of prepended frontend embeddings (VLM patches); 0 = none
    frontend_tokens: int = 0
    #: attention is quadratic unless a window bounds it
    attn_window: Optional[int] = None
    dtype: str = "bfloat16"
    #: Adam moment dtype — f32 default, bf16 for the very large archs
    moment_dtype: str = "float32"
    remat: bool = True
    #: microbatches for gradient accumulation (1 = none)
    grad_accum: int = 1

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def groups(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        if self.layer_groups is not None:
            return self.layer_groups
        return ((("attn",), self.n_layers),)

    def total_layers(self) -> int:
        return sum(len(pat) * rep for pat, rep in self.groups)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token contexts?  SSM / windowed-attn only."""
        kinds = {k for pat, _ in self.groups for k in pat}
        if "attn" in kinds and self.attn_window is None:
            return False
        return True

    def validate(self) -> "ModelConfig":
        assert self.total_layers() == self.n_layers, (
            f"{self.name}: groups sum to {self.total_layers()} != {self.n_layers}"
        )
        if self.family == "moe":
            assert self.moe is not None
        kinds = {k for pat, _ in self.groups for k in pat}
        if "rec" in kinds:
            assert self.rec is not None and self.rec.d_rnn > 0
        if "rwkv" in kinds:
            assert self.rwkv is not None
        return self


@dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell: sequence x batch x step kind."""

    name: str
    seq_len: int
    global_batch: int
    mode: Literal["train", "prefill", "decode"]


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in SHAPES]}")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is (arch, shape) a runnable cell?  Returns (ok, reason-if-not)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k context needs sub-quadratic attention"
    return True, ""


def scaled_down(cfg: ModelConfig, layers: int = 2, width: int = 64) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    ratio = width / cfg.d_model
    d_head = max(16, int(cfg.head_dim * ratio) // 8 * 8) if cfg.d_head else None
    n_heads = max(2, min(cfg.n_heads, 4))
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    groups: Optional[Tuple] = None
    if cfg.layer_groups is not None:
        # keep one group with the full pattern, repeated once
        pat = cfg.layer_groups[0][0]
        groups = ((pat, 1),)
        layers = len(pat)
    moe = None
    if cfg.moe:
        moe = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2), d_ff_expert=width * 2, d_ff_shared=(width * 2 if cfg.moe.d_ff_shared else 0),
        )
    rec = dataclasses.replace(cfg.rec, d_rnn=width, window=32) if cfg.rec else None
    rwkv = dataclasses.replace(cfg.rwkv, head_dim=16) if cfg.rwkv else None
    return dataclasses.replace(
        cfg,
        n_layers=layers,
        d_model=width,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=width // n_heads,
        d_ff=width * 3,
        vocab=256,
        moe=moe,
        rec=rec,
        rwkv=rwkv,
        layer_groups=groups,
        frontend_tokens=min(cfg.frontend_tokens, 4),
        attn_window=min(cfg.attn_window, 32) if cfg.attn_window else None,
        grad_accum=1,
    ).validate()
