"""LM assembly for all ten architectures: embed -> layer-group scans -> logits.

Layer stacks compile as one ``lax.scan`` per *group* (a repeated pattern of
layer kinds) with rematerialization, so the HLO stays one-layer-sized even
for 96-layer models and the dry-run compiles quickly.  Per layer kind:

  attn  — GQA attention (optionally local-window) + gated MLP (or MoE)
  rec   — RG-LRU recurrence + gated MLP
  rwkv  — RWKV-6 time-mix + gated MLP (channel-mix swapped for SwiGLU of the
          same width; parameter-count equivalent — noted in DESIGN.md)

Entry points: ``init_params`` / ``param_specs`` / ``forward`` /
``loss_and_aux`` / ``prefill`` / ``init_cache`` / ``cache_specs`` /
``decode_step``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import with_logical
from .attention import (
    attention_decode,
    attention_full,
    attn_params,
    attn_specs,
    init_kv_cache,
    kv_cache_specs,
)
from .config import ModelConfig
from .layers import dtype_of, mlp_apply, mlp_params, mlp_specs, normal_init, rms_norm
from .moe import moe_apply, moe_params, moe_specs
from .rglru import (
    rglru_decode_step,
    rglru_full,
    rglru_init_state,
    rglru_params,
    rglru_specs,
    rglru_state_specs,
)
from .rwkv6 import (
    rwkv_decode_step,
    rwkv_init_state,
    rwkv_params,
    rwkv_scan_full,
    rwkv_specs,
    rwkv_state_specs,
)

Params = Dict[str, Any]


def _layer_uses_moe(cfg: ModelConfig, kind: str) -> bool:
    return cfg.moe is not None and kind == "attn"


# ------------------------------------------------------------------- params
def _sublayer_params(cfg: ModelConfig, kind: str, key, n: int) -> Dict:
    k_mix, k_ffn, k_norm = jax.random.split(key, 3)
    dt = dtype_of(cfg)
    p: Dict[str, Any] = {
        "norm1": jnp.zeros((n, cfg.d_model), dt),
        "norm2": jnp.zeros((n, cfg.d_model), dt),
    }
    if kind == "attn":
        p["attn"] = attn_params(cfg, k_mix, n)
    elif kind == "rec":
        p["rec"] = rglru_params(cfg, k_mix, n)
    elif kind == "rwkv":
        p["rwkv"] = rwkv_params(cfg, k_mix, n)
    else:
        raise ValueError(kind)
    if _layer_uses_moe(cfg, kind):
        p["moe"] = moe_params(cfg, k_ffn, n)
    else:
        p["mlp"] = mlp_params(cfg, k_ffn, n)
    return p


def _sublayer_specs(cfg: ModelConfig, kind: str, tp: int) -> Dict:
    p: Dict[str, Any] = {"norm1": (None, None), "norm2": (None, None)}
    if kind == "attn":
        p["attn"] = attn_specs(cfg, tp)
    elif kind == "rec":
        p["rec"] = rglru_specs()
    elif kind == "rwkv":
        p["rwkv"] = rwkv_specs()
    if _layer_uses_moe(cfg, kind):
        p["moe"] = moe_specs(cfg)
    else:
        p["mlp"] = mlp_specs()
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    dt = dtype_of(cfg)
    keys = jax.random.split(key, 3 + len(cfg.groups))
    params: Params = {
        "embed": normal_init(keys[0], (cfg.vocab, cfg.d_model), 1.0, dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = normal_init(
            keys[1], (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5, dt
        )
    for gi, (pattern, rep) in enumerate(cfg.groups):
        gkeys = jax.random.split(keys[3 + gi], len(pattern))
        params[f"group{gi}"] = {
            f"pos{pi}": _sublayer_params(cfg, kind, gkeys[pi], rep)
            for pi, kind in enumerate(pattern)
        }
    return params


def param_specs(cfg: ModelConfig, tp: int = 16) -> Params:
    specs: Params = {
        "embed": ("vocab", "fsdp"),
        "final_norm": (None,),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ("fsdp", "vocab")
    for gi, (pattern, rep) in enumerate(cfg.groups):
        specs[f"group{gi}"] = {
            f"pos{pi}": _sublayer_specs(cfg, kind, tp)
            for pi, kind in enumerate(pattern)
        }
    return specs


# ------------------------------------------------------------------ forward
def _apply_sublayer(
    cfg: ModelConfig, kind: str, lp: Dict, x: jax.Array, positions: jax.Array,
    impl: str,
) -> Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if kind == "attn":
        h = attention_full(lp["attn"], h, cfg, positions, window=cfg.attn_window, impl=impl)
    elif kind == "rec":
        h = rglru_full(lp["rec"], h, cfg, impl=impl)
    elif kind == "rwkv":
        h = rwkv_scan_full(lp["rwkv"], h, cfg, impl=impl)
    x = x + h
    h = rms_norm(x, lp["norm2"], cfg.norm_eps)
    if "moe" in lp:
        h, aux = moe_apply(lp["moe"], h, cfg)
    else:
        h = mlp_apply(lp["mlp"], h, cfg)
    return x + h, aux


def _run_groups(
    cfg: ModelConfig, params: Params, x: jax.Array, positions: jax.Array, impl: str,
) -> Tuple[jax.Array, jax.Array]:
    aux_total = jnp.zeros((), jnp.float32)
    for gi, (pattern, rep) in enumerate(cfg.groups):
        gparams = params[f"group{gi}"]

        def body(carry, layer_params, pattern=pattern):
            h, aux = carry
            for pi, kind in enumerate(pattern):
                h, a = _apply_sublayer(cfg, kind, layer_params[f"pos{pi}"], h, positions, impl)
                aux = aux + a
            return (h, aux), None

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), gparams)
    return x, aux_total


def _embed(cfg: ModelConfig, params: Params, tokens: jax.Array,
           patches: Optional[jax.Array]) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    return with_logical(x, "batch", "seq", None)


def _logits(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    return with_logical(logits, "batch", None, "vocab")


def forward(
    cfg: ModelConfig, params: Params, tokens: jax.Array,
    patches: Optional[jax.Array] = None, impl: str = "reference",
) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S_text); patches: (B, P, d) or None.
    Returns (logits (B, S_total, V), aux_loss)."""
    x = _embed(cfg, params, tokens, patches)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, aux = _run_groups(cfg, params, x, positions, impl)
    return _logits(cfg, params, x), aux


def loss_and_aux(
    cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
    impl: str = "reference",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy (f32), z-loss, MoE aux.  ``batch["tokens"]``:
    (B, S_text); optional ``batch["patches"]``: (B, P, d)."""
    tokens = batch["tokens"]
    patches = batch.get("patches")
    inputs = tokens[:, :-1]
    labels = tokens[:, 1:]
    logits, aux = forward(cfg, params, inputs, patches, impl)
    # predictions for text labels sit at the last (S_text - 1) positions
    logits = logits[:, -labels.shape[1]:, :].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    z_loss = 1e-4 * (logz ** 2).mean()
    total = nll + z_loss + 0.01 * aux
    return total, {"nll": nll, "z_loss": z_loss, "moe_aux": aux}


# -------------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    cache: Dict[str, Any] = {"t": jnp.zeros((), jnp.int32)}
    for gi, (pattern, rep) in enumerate(cfg.groups):
        g: Dict[str, Any] = {}
        for pi, kind in enumerate(pattern):
            if kind == "attn":
                g[f"pos{pi}"] = init_kv_cache(cfg, rep, batch, max_len, window=cfg.attn_window)
            elif kind == "rec":
                g[f"pos{pi}"] = rglru_init_state(cfg, rep, batch)
            elif kind == "rwkv":
                g[f"pos{pi}"] = rwkv_init_state(cfg, rep, batch)
        cache[f"group{gi}"] = g
    return cache


def cache_specs(cfg: ModelConfig, tp: int = 16) -> Dict:
    specs: Dict[str, Any] = {"t": ()}
    for gi, (pattern, rep) in enumerate(cfg.groups):
        g: Dict[str, Any] = {}
        for pi, kind in enumerate(pattern):
            if kind == "attn":
                g[f"pos{pi}"] = kv_cache_specs(cfg, tp)
            elif kind == "rec":
                g[f"pos{pi}"] = rglru_state_specs()
            elif kind == "rwkv":
                g[f"pos{pi}"] = rwkv_state_specs()
        specs[f"group{gi}"] = g
    return specs


def decode_step(
    cfg: ModelConfig, params: Params, token: jax.Array, cache: Dict,
) -> Tuple[jax.Array, Dict]:
    """token: (B, 1) int32.  Returns (logits (B, 1, V), updated cache)."""
    t = cache["t"]
    x = jnp.take(params["embed"], token, axis=0)
    x = with_logical(x, "batch", None, None)
    new_cache: Dict[str, Any] = {"t": t + 1}

    for gi, (pattern, rep) in enumerate(cfg.groups):
        gparams = params[f"group{gi}"]
        gcache = cache[f"group{gi}"]

        def body(h, xs, pattern=pattern):
            layer_params, layer_cache = xs
            new_layer_cache = {}
            for pi, kind in enumerate(pattern):
                lp = layer_params[f"pos{pi}"]
                lc = layer_cache[f"pos{pi}"]
                hin = rms_norm(h, lp["norm1"], cfg.norm_eps)
                if kind == "attn":
                    y, ck, cv = attention_decode(
                        lp["attn"], hin, lc["k"], lc["v"], cfg, t, window=cfg.attn_window
                    )
                    new_layer_cache[f"pos{pi}"] = {"k": ck, "v": cv}
                elif kind == "rec":
                    y, hh, conv = rglru_decode_step(lp["rec"], hin, lc["h"], lc["conv"], cfg)
                    new_layer_cache[f"pos{pi}"] = {"h": hh, "conv": conv}
                elif kind == "rwkv":
                    y, S, x_last = rwkv_decode_step(lp["rwkv"], hin, lc["S"], lc["x_last"], cfg)
                    new_layer_cache[f"pos{pi}"] = {"S": S, "x_last": x_last}
                h = h + y
                hin = rms_norm(h, lp["norm2"], cfg.norm_eps)
                if "moe" in lp:
                    y, _ = moe_apply(lp["moe"], hin, cfg, decode=True)
                else:
                    y = mlp_apply(lp["mlp"], hin, cfg)
                h = h + y
            return h, new_layer_cache

        x, new_gcache = jax.lax.scan(body, x, (gparams, gcache))
        new_cache[f"group{gi}"] = new_gcache
    return _logits(cfg, params, x), new_cache


# ------------------------------------------------------------------- prefill
def prefill(
    cfg: ModelConfig, params: Params, tokens: jax.Array,
    patches: Optional[jax.Array] = None, impl: str = "reference",
) -> Tuple[jax.Array, Dict]:
    """Full-sequence pass that also builds the decode cache.

    For simplicity and HLO size, the cache is built by re-projecting K/V per
    layer inside the same scan (attention outputs are unchanged); recurrent
    states come from one extra step-scan over the final chunk for SSM layers.
    Returns (last-token logits (B, V), cache).
    """
    x = _embed(cfg, params, tokens, patches)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    cache: Dict[str, Any] = {"t": jnp.asarray(S, jnp.int32)}

    for gi, (pattern, rep) in enumerate(cfg.groups):
        gparams = params[f"group{gi}"]

        def body(carry, layer_params, pattern=pattern):
            h = carry
            new_layer_cache = {}
            for pi, kind in enumerate(pattern):
                lp = layer_params[f"pos{pi}"]
                hin = rms_norm(h, lp["norm1"], cfg.norm_eps)
                if kind == "attn":
                    y = attention_full(lp["attn"], hin, cfg, positions,
                                       window=cfg.attn_window, impl=impl)
                    new_layer_cache[f"pos{pi}"] = _kv_for_cache(cfg, lp["attn"], hin, positions)
                elif kind == "rec":
                    y = rglru_full(lp["rec"], hin, cfg, impl=impl)
                    new_layer_cache[f"pos{pi}"] = _rec_state_after(cfg, lp["rec"], hin)
                elif kind == "rwkv":
                    y = rwkv_scan_full(lp["rwkv"], hin, cfg, impl=impl)
                    new_layer_cache[f"pos{pi}"] = _rwkv_state_after(cfg, lp["rwkv"], hin)
                h = h + y
                hin = rms_norm(h, lp["norm2"], cfg.norm_eps)
                if "moe" in lp:
                    y, _ = moe_apply(lp["moe"], hin, cfg)
                else:
                    y = mlp_apply(lp["mlp"], hin, cfg)
                h = h + y
            return h, new_layer_cache

        x, gcache = jax.lax.scan(body, x, gparams)
        cache[f"group{gi}"] = gcache
    logits = _logits(cfg, params, x[:, -1:, :])
    return logits[:, 0, :], cache


def _kv_for_cache(cfg: ModelConfig, p: Dict, x: jax.Array, positions: jax.Array) -> Dict:
    from .attention import _split_heads
    from .layers import apply_rope, rope_angles

    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    k = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wk"]), hkv, dh)
    v = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wv"]), hkv, dh)
    cos, sin = rope_angles(positions, dh, cfg.rope_theta)
    k = apply_rope(k, cos, sin)
    if cfg.attn_window:
        k = k[:, -cfg.attn_window:]
        v = v[:, -cfg.attn_window:]
    return {"k": k, "v": v}


def _rec_state_after(cfg: ModelConfig, p: Dict, x: jax.Array) -> Dict:
    """Final RG-LRU state after the sequence (recompute via scan tail)."""
    from .rglru import _causal_conv, _gates

    b = x.shape[0]
    xr = jnp.einsum("bsd,de->bse", x, p["w_in_x"])
    prefix = jnp.zeros((b, cfg.rec.conv_width - 1, xr.shape[-1]), xr.dtype)
    conv_out = _causal_conv(xr, p["conv"], prefix)
    a, gx = _gates(p, conv_out)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, hh = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return {"h": hh[:, -1], "conv": xr[:, -(cfg.rec.conv_width - 1):]}


def _rwkv_state_after(cfg: ModelConfig, p: Dict, x: jax.Array) -> Dict:
    from .rwkv6 import _head_split, _n_heads, _projections

    H, dh = _n_heads(cfg), cfg.rwkv.head_dim
    b, s, d = x.shape
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    _, k, v, w, _ = _projections(p, x, x_prev, cfg)
    k = _head_split(k, H, dh).astype(jnp.float32)
    v = _head_split(v, H, dh).astype(jnp.float32)
    w = _head_split(w, H, dh)

    def step(S, inputs):
        kt, vt, wt = inputs
        kv = kt[..., :, None] * vt[..., None, :]
        return wt[..., :, None] * S + kv, None

    S0 = jnp.zeros((b, H, dh, dh), jnp.float32)
    S, _ = jax.lax.scan(step, S0, (jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0), jnp.moveaxis(w, 1, 0)))
    return {"S": S, "x_last": x[:, -1]}
