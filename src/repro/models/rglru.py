"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t)            recurrence gate
    i_t = sigmoid(W_x x_t)            input gate
    a_t = a^(c * r_t)                 with a = sigmoid(Lambda), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

preceded by a short causal conv1d, inside a gated block (GeGLU-style).  The
recurrence is *diagonal*, so the full-sequence path uses
``jax.lax.associative_scan`` — O(log S) depth, trivially parallel — and the
Pallas kernel (``repro.kernels.rglru_scan``) implements the blocked version.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import with_logical
from .config import ModelConfig
from .layers import dtype_of, normal_init

_C = 8.0


def rglru_params(cfg: ModelConfig, key, n: int) -> Dict:
    d = cfg.d_model
    dr = cfg.rec.d_rnn
    cw = cfg.rec.conv_width
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "w_in_x": normal_init(ks[0], (n, d, dr), s, dt),     # recurrence branch
        "w_in_g": normal_init(ks[1], (n, d, dr), s, dt),     # gate branch
        "conv": normal_init(ks[2], (n, cw, dr), cw ** -0.5, dt),
        "w_gate_a": normal_init(ks[3], (n, dr, dr), dr ** -0.5, dt),
        "w_gate_x": normal_init(ks[4], (n, dr, dr), dr ** -0.5, dt),
        # Lambda init so a = sigmoid(L) in ~(0.9, 0.999)
        "lamb": normal_init(ks[5], (n, dr), 0.5, jnp.float32) + 4.0,
        "w_out": normal_init(ks[6], (n, dr, d), dr ** -0.5, dt),
    }


def rglru_specs() -> Dict:
    return {
        "w_in_x": (None, "fsdp", "rnn"),
        "w_in_g": (None, "fsdp", "rnn"),
        "conv": (None, None, "rnn"),
        "w_gate_a": (None, "fsdp", "rnn"),
        "w_gate_x": (None, "fsdp", "rnn"),
        "lamb": (None, "rnn"),
        "w_out": (None, "rnn", "fsdp"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, prefix: jax.Array) -> jax.Array:
    """Depthwise causal conv.  x: (B,S,dr); w: (cw,dr); prefix: (B,cw-1,dr)."""
    cw = w.shape[0]
    xp = jnp.concatenate([prefix, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(cw):
        out = out + xp[:, i : i + x.shape[1]] * w[cw - 1 - i][None, None, :]
    return out


def _gates(p: Dict, xr: jax.Array):
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_gate_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_gate_x"]).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(p["lamb"])[None, None, :]   # log a_t <= 0
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xr.astype(jnp.float32))
    return a, gated_x


def rglru_full(p: Dict, x: jax.Array, cfg: ModelConfig, impl: str = "reference") -> jax.Array:
    """Full-sequence RG-LRU block.  x: (B, S, d)."""
    b, s, d = x.shape
    xr = jnp.einsum("bsd,de->bse", x, p["w_in_x"])
    g = jnp.einsum("bsd,de->bse", x, p["w_in_g"])
    xr = with_logical(xr, "batch", None, "rnn")
    prefix = jnp.zeros((b, cfg.rec.conv_width - 1, xr.shape[-1]), xr.dtype)
    xr = _causal_conv(xr, p["conv"], prefix)
    a, gx = _gates(p, xr)

    if impl == "pallas":
        from ..kernels.rglru_scan.ops import rglru_scan

        h = rglru_scan(a, gx)
    else:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        aa, hh = jax.lax.associative_scan(combine, (a, gx), axis=1)
        h = hh
    h = h.astype(x.dtype) * jax.nn.gelu(g)
    out = jnp.einsum("bse,ed->bsd", h, p["w_out"])
    return with_logical(out, "batch", "seq", None)


def rglru_init_state(cfg: ModelConfig, n_layers: int, batch: int) -> Dict:
    dr, cw = cfg.rec.d_rnn, cfg.rec.conv_width
    return {
        "h": jnp.zeros((n_layers, batch, dr), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, cw - 1, dr), dtype_of(cfg)),
    }


def rglru_state_specs() -> Dict:
    return {"h": (None, "batch", "rnn"), "conv": (None, "batch", None, "rnn")}


def rglru_decode_step(
    p: Dict, x: jax.Array, h: jax.Array, conv_state: jax.Array, cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One token.  x: (B,1,d); h: (B,dr); conv_state: (B,cw-1,dr)."""
    xr = jnp.einsum("bsd,de->bse", x, p["w_in_x"])
    g = jnp.einsum("bsd,de->bse", x, p["w_in_g"])
    xr_conv = _causal_conv(xr, p["conv"], conv_state)
    new_conv = jnp.concatenate([conv_state, xr], axis=1)[:, 1:]
    a, gx = _gates(p, xr_conv)
    h_new = a[:, 0] * h + gx[:, 0]
    y = h_new[:, None, :].astype(x.dtype) * jax.nn.gelu(g)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, h_new, new_conv
