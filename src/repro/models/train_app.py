"""LM training as an EasyCrash IterativeApp.

This closes the loop between the paper and the LM substrate: SGD/Adam
training *is* one of the paper's "naturally resilient iterative methods"
(§2.2 cites k-means and CNN training), so the crash-test machinery runs on a
reduced transformer exactly like on CG/MG.

Data objects (the paper's granularity is whole objects, so parameter /
moment trees flatten to one vector each):

    params — the weights            (expected: critical)
    mu, nu — Adam moments           (expected: non-critical — they re-warm)
    grads  — last gradient          (temporal)
    k      — step counter           (always persisted)

Regions mirror the paper's first-level loop structure of one optimizer
step: ``grads`` (fwd+bwd), ``moments`` (Adam moment accumulation), and
``apply`` (bias-corrected parameter update + bookkeeping).  Acceptance
verification: eval loss within a band of the golden run's final loss —
fidelity-threshold acceptance, the ML analogue of a convergence test.

Registered in the suite app registry as ``"lm-train"``
(:func:`repro.hpc.suite.get_app`).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.regions import IterativeApp, Region, State, VerifyResult
from .config import ModelConfig, scaled_down
from .transformer import init_params, loss_and_aux

_B1, _B2, _EPS = 0.9, 0.95, 1e-8


def _synthetic_batch(key_int, batch: int, seq: int, vocab: int) -> jnp.ndarray:
    """Learnable stream: affine next-token map with 10% noise."""
    key = jax.random.PRNGKey(9000)
    key = jax.random.fold_in(key, key_int)
    k1, k2, k3 = jax.random.split(key, 3)
    t0 = jax.random.randint(k1, (batch, 1), 0, vocab)
    toks = [t0]
    tok = t0
    for _ in range(seq):
        tok = (tok * 7 + 3) % vocab
        toks.append(tok)
    tokens = jnp.concatenate(toks, axis=1)
    noise = jax.random.bernoulli(k2, 0.1, tokens.shape)
    rand = jax.random.randint(k3, tokens.shape, 0, vocab)
    return jnp.where(noise, rand, tokens).astype(jnp.int32)


class LMTrainApp(IterativeApp):
    name = "lm-train"
    candidates = ("params", "mu", "nu", "k")
    iterator_object = "k"
    #: campaign fault tuning: the parameter vector is the one chronically
    #: dirty hot object (read by fwd+bwd every step, rewritten every apply),
    #: so silent corruption there is the interesting SDC surface, and
    #: correlated failures should concentrate in the dominant grads region.
    fault_defaults = {
        "bit-flip": {"n_bits": 8},
        "correlated-region": {"shape": 3.0},
    }

    def __init__(
        self,
        base: ModelConfig = None,
        n_iters: int = 40,
        batch: int = 8,
        seq: int = 32,
        lr: float = 2e-2,
        loss_band: float = 1.05,
        width: int = 64,
        seed: int = 0,
    ):
        from ..configs import get_arch

        base = base or get_arch("stablelm-1.6b")
        self.cfg = scaled_down(base, width=width)
        self.n_iters = n_iters
        self.batch = batch
        self.seq = seq
        self.lr = lr
        self.loss_band = loss_band
        self._seed = seed
        self._shapes = None
        self._treedef = None
        self._golden_loss = None
        self._build()

    # ------------------------------------------------------------- plumbing
    def _build(self):
        cfg = self.cfg
        p0 = init_params(cfg, jax.random.PRNGKey(self._seed))
        leaves, treedef = jax.tree.flatten(p0)
        self._treedef = treedef
        self._shapes = [(l.shape, l.dtype) for l in leaves]
        self._sizes = [int(np.prod(s)) for s, _ in self._shapes]

        def unflatten(vec):
            out = []
            off = 0
            for (shape, dt), size in zip(self._shapes, self._sizes):
                out.append(vec[off:off + size].reshape(shape).astype(dt))
                off += size
            return jax.tree.unflatten(self._treedef, out)

        def flatten(tree):
            return jnp.concatenate(
                [x.reshape(-1).astype(jnp.float32) for x in jax.tree.leaves(tree)]
            )

        self._unflatten = unflatten
        self._flatten = flatten

        def grad_fn(vec, it):
            params = unflatten(vec)
            tokens = _synthetic_batch(it, self.batch, self.seq, cfg.vocab)
            loss, _ = loss_and_aux(cfg, params, {"tokens": tokens})
            return loss

        self._vgrad = jax.jit(jax.grad(grad_fn))
        # batched-lane gradient: ``lax.map`` keeps each lane's HLO identical
        # to the serial ``_vgrad`` body (a vmapped fwd+bwd would batch the
        # matmuls into different reduction tilings — not bitwise)
        self._vgrad_batch = jax.jit(
            lambda vecs, its: jax.lax.map(
                lambda xs: jax.grad(grad_fn)(xs[0], xs[1]), (vecs, its)
            )
        )

        @jax.jit
        def eval_fn(vec):
            params = unflatten(vec)
            losses = []
            for i in range(4):
                tokens = _synthetic_batch(100_000 + i, self.batch, self.seq, cfg.vocab)
                loss, _ = loss_and_aux(cfg, params, {"tokens": tokens})
                losses.append(loss)
            return jnp.stack(losses).mean()

        self._eval = eval_fn

    # ----------------------------------------------------------------- state
    def init(self, seed: int = 0) -> State:
        p0 = init_params(self.cfg, jax.random.PRNGKey(self._seed))
        vec = np.asarray(self._flatten(p0))
        return {
            "params": vec,
            "mu": np.zeros_like(vec),
            "nu": np.zeros_like(vec),
            "grads": np.zeros_like(vec),
            "k": np.zeros(1, np.int64),
        }

    def _region_grads(self, s: State) -> State:
        s = dict(s)
        g = self._vgrad(jnp.asarray(s["params"]), np.int32(s["k"][0]))
        s["grads"] = np.asarray(g, np.float32)
        return s

    def _region_moments(self, s: State) -> State:
        s = dict(s)
        g = s["grads"]
        s["mu"] = _B1 * s["mu"] + (1 - _B1) * g
        s["nu"] = _B2 * s["nu"] + (1 - _B2) * g * g
        return s

    def _region_apply(self, s: State) -> State:
        s = dict(s)
        t = int(s["k"][0]) + 1
        mu_hat = s["mu"] / (1 - _B1 ** t)
        nu_hat = s["nu"] / (1 - _B2 ** t)
        s["params"] = s["params"] - self.lr * mu_hat / (np.sqrt(nu_hat) + _EPS)
        s["k"] = s["k"] + 1
        return s

    def regions(self) -> Tuple[Region, ...]:
        return (
            Region("grads", self._region_grads, writes=("grads",),
                   reads=("params", "k"), cost=3.0, hot_reads=("params",)),
            Region("moments", self._region_moments, writes=("mu", "nu"),
                   reads=("grads", "mu", "nu"), cost=1.0),
            Region("apply", self._region_apply, writes=("params", "k"),
                   reads=("mu", "nu", "params", "k"), cost=1.0),
        )

    # ------------------------------------------------------- batched recompute
    # The gradient (the expensive part) batches through ``lax.map``; the Adam
    # math replays the serial numpy regions per lane, so every lane is
    # bitwise the serial trajectory (asserted by the lm-train engine-parity
    # test in tests/test_model_apps.py).
    supports_batched_step = True

    def batched_kernels(self):
        from ..core.regions import BatchedKernel

        s = self.init(0)
        vecs = np.stack([s["params"]] * 2)
        its = np.zeros(2, np.int32)
        return (
            BatchedKernel("vgrad_batch", self._vgrad_batch,
                          (vecs, its), {0: 0, 1: 0}),
        )

    def run_iteration_batch(self, states):
        vecs = np.stack([s["params"] for s in states])
        its = np.asarray([int(s["k"][0]) for s in states], np.int32)
        grads = np.asarray(
            self._vgrad_batch(jnp.asarray(vecs), jnp.asarray(its)), np.float32
        )
        out = []
        for i, s in enumerate(states):
            s = dict(s)
            s["grads"] = grads[i]
            s = self._region_moments(s)
            s = self._region_apply(s)
            out.append(s)
        return out

    # ----------------------------------------------------------- verification
    def _golden(self) -> float:
        if self._golden_loss is None:
            s = self.init(self._seed)
            for _ in range(self.n_iters):
                s = self.run_iteration(s)
            self._golden_loss = float(self._eval(jnp.asarray(s["params"])))
        return self._golden_loss

    def verify(self, state: State) -> VerifyResult:
        loss = float(self._eval(jnp.asarray(state["params"])))
        target = self._golden() * self.loss_band
        return VerifyResult(bool(np.isfinite(loss) and loss <= target), loss)

    def progress(self, state: State) -> float:
        return float(self._eval(jnp.asarray(state["params"])))
