"""LM training as an EasyCrash IterativeApp.

This closes the loop between the paper and the LM substrate: SGD/Adam
training *is* one of the paper's "naturally resilient iterative methods"
(§2.2 cites k-means and CNN training), so the crash-test machinery runs on a
reduced transformer exactly like on CG/MG.

Data objects (the paper's granularity is whole objects, so parameter /
moment trees flatten to one vector each):

    params — the weights            (expected: critical)
    mu, nu — Adam moments           (expected: non-critical — they re-warm)
    grads  — last gradient          (temporal)
    k      — step counter           (always persisted)

Regions: ``grads`` (fwd+bwd) and ``update`` (optimizer).  Acceptance
verification: eval loss within a band of the golden run's final loss —
fidelity-threshold acceptance, the ML analogue of a convergence test.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.regions import IterativeApp, Region, State, VerifyResult
from .config import ModelConfig, scaled_down
from .transformer import init_params, loss_and_aux


def _synthetic_batch(key_int: int, batch: int, seq: int, vocab: int) -> jnp.ndarray:
    """Learnable stream: affine next-token map with 10% noise."""
    key = jax.random.PRNGKey(9000)
    key = jax.random.fold_in(key, key_int)
    k1, k2, k3 = jax.random.split(key, 3)
    t0 = jax.random.randint(k1, (batch, 1), 0, vocab)
    toks = [t0]
    tok = t0
    for _ in range(seq):
        tok = (tok * 7 + 3) % vocab
        toks.append(tok)
    tokens = jnp.concatenate(toks, axis=1)
    noise = jax.random.bernoulli(k2, 0.1, tokens.shape)
    rand = jax.random.randint(k3, tokens.shape, 0, vocab)
    return jnp.where(noise, rand, tokens).astype(jnp.int32)


class LMTrainApp(IterativeApp):
    name = "lm-train"
    candidates = ("params", "mu", "nu", "k")
    iterator_object = "k"

    def __init__(
        self,
        base: ModelConfig = None,
        n_iters: int = 40,
        batch: int = 8,
        seq: int = 32,
        lr: float = 2e-2,
        loss_band: float = 1.05,
        seed: int = 0,
    ):
        from ..configs import get_arch

        base = base or get_arch("stablelm-1.6b")
        self.cfg = scaled_down(base, width=64)
        self.n_iters = n_iters
        self.batch = batch
        self.seq = seq
        self.lr = lr
        self.loss_band = loss_band
        self._seed = seed
        self._shapes = None
        self._treedef = None
        self._golden_loss = None
        self._build()

    # ------------------------------------------------------------- plumbing
    def _build(self):
        cfg = self.cfg
        p0 = init_params(cfg, jax.random.PRNGKey(self._seed))
        leaves, treedef = jax.tree.flatten(p0)
        self._treedef = treedef
        self._shapes = [(l.shape, l.dtype) for l in leaves]
        self._sizes = [int(np.prod(s)) for s, _ in self._shapes]

        def unflatten(vec):
            out = []
            off = 0
            for (shape, dt), size in zip(self._shapes, self._sizes):
                out.append(vec[off:off + size].reshape(shape).astype(dt))
                off += size
            return jax.tree.unflatten(self._treedef, out)

        def flatten(tree):
            return jnp.concatenate(
                [x.reshape(-1).astype(jnp.float32) for x in jax.tree.leaves(tree)]
            )

        self._unflatten = unflatten
        self._flatten = flatten

        @jax.jit
        def grad_fn(vec, it):
            params = unflatten(vec)
            tokens = _synthetic_batch(it, self.batch, self.seq, cfg.vocab)
            loss, _ = loss_and_aux(cfg, params, {"tokens": tokens})
            return loss

        self._vgrad = jax.jit(jax.grad(grad_fn))

        @jax.jit
        def eval_fn(vec):
            params = unflatten(vec)
            losses = []
            for i in range(4):
                tokens = _synthetic_batch(100_000 + i, self.batch, self.seq, cfg.vocab)
                loss, _ = loss_and_aux(cfg, params, {"tokens": tokens})
                losses.append(loss)
            return jnp.stack(losses).mean()

        self._eval = eval_fn

    # ----------------------------------------------------------------- state
    def init(self, seed: int = 0) -> State:
        p0 = init_params(self.cfg, jax.random.PRNGKey(self._seed))
        vec = np.asarray(self._flatten(p0))
        return {
            "params": vec,
            "mu": np.zeros_like(vec),
            "nu": np.zeros_like(vec),
            "grads": np.zeros_like(vec),
            "k": np.zeros(1, np.int64),
        }

    def _region_grads(self, s: State) -> State:
        s = dict(s)
        g = self._vgrad(jnp.asarray(s["params"]), int(s["k"][0]))
        s["grads"] = np.asarray(g, np.float32)
        return s

    def _region_update(self, s: State) -> State:
        s = dict(s)
        b1, b2, eps = 0.9, 0.95, 1e-8
        t = int(s["k"][0]) + 1
        g = s["grads"]
        mu = b1 * s["mu"] + (1 - b1) * g
        nu = b2 * s["nu"] + (1 - b2) * g * g
        mu_hat = mu / (1 - b1 ** t)
        nu_hat = nu / (1 - b2 ** t)
        s["params"] = s["params"] - self.lr * mu_hat / (np.sqrt(nu_hat) + eps)
        s["mu"], s["nu"] = mu, nu
        s["k"] = s["k"] + 1
        return s

    def regions(self) -> Tuple[Region, ...]:
        return (
            Region("grads", self._region_grads, writes=("grads",),
                   reads=("params", "k"), cost=3.0),
            Region("update", self._region_update,
                   writes=("mu", "nu", "params", "k"),
                   reads=("grads", "mu", "nu", "params"), cost=1.0),
        )

    # ----------------------------------------------------------- verification
    def _golden(self) -> float:
        if self._golden_loss is None:
            s = self.init(self._seed)
            for _ in range(self.n_iters):
                s = self.run_iteration(s)
            self._golden_loss = float(self._eval(jnp.asarray(s["params"])))
        return self._golden_loss

    def verify(self, state: State) -> VerifyResult:
        loss = float(self._eval(jnp.asarray(state["params"])))
        target = self._golden() * self.loss_band
        return VerifyResult(bool(np.isfinite(loss) and loss <= target), loss)

    def progress(self, state: State) -> float:
        return float(self._eval(jnp.asarray(state["params"])))
