"""RWKV-6 "Finch" time-mix block (arXiv:2404.05892), attention-free.

State: one matrix S in R^{dh x dh} per head.  Recurrence per token t:

    S_t = diag(w_t) . S_{t-1} + k_t^T v_t            (data-dependent decay)
    y_t = r_t . (diag(u) . k_t^T v_t + S_{t-1})

with w_t = exp(-exp(decay_t)) computed from the token (the "dynamic decay"
that distinguishes v6 from v5).  The full-sequence path uses a *chunked*
formulation (parallel within a chunk, sequential across chunks) — the same
scheme the Pallas kernel implements on TPU; ``repro.kernels.rwkv6_scan.ref``
holds the step-by-step oracle.

Token-shift mixing (lerp between x_t and x_{t-1}) follows the RWKV design;
the low-rank "data-dependent lerp" (ddlerp) uses a single small MLP per
projection for clarity.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import with_logical
from .config import ModelConfig
from .layers import dtype_of, normal_init, rms_norm


def _n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv.head_dim


def rwkv_params(cfg: ModelConfig, key, n: int) -> Dict:
    d = cfg.d_model
    dh = cfg.rwkv.head_dim
    H = _n_heads(cfg)
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 10)
    s = d ** -0.5
    lora = max(32, d // 32)
    return {
        "mix_lerp": jnp.zeros((n, 5, d), dt),          # r,k,v,w,g token-shift lerps
        "w_r": normal_init(ks[0], (n, d, d), s, dt),
        "w_k": normal_init(ks[1], (n, d, d), s, dt),
        "w_v": normal_init(ks[2], (n, d, d), s, dt),
        "w_g": normal_init(ks[3], (n, d, d), s, dt),
        "w_o": normal_init(ks[4], (n, d, d), s, dt),
        # dynamic decay: d -> lora -> d
        "wd_a": normal_init(ks[5], (n, d, lora), s, dt),
        "wd_b": normal_init(ks[6], (n, lora, d), lora ** -0.5, dt),
        "decay_base": jnp.full((n, d), -6.0, jnp.float32) + normal_init(ks[9], (n, d), 0.3, jnp.float32),
        "bonus_u": normal_init(ks[7], (n, H, dh), 0.3, jnp.float32),
        "ln_x": jnp.zeros((n, d), dt),                 # per-head group-norm gain
    }


def rwkv_specs() -> Dict:
    return {
        "mix_lerp": (None, None, None),
        "w_r": (None, "fsdp", "heads"),
        "w_k": (None, "fsdp", "heads"),
        "w_v": (None, "fsdp", "heads"),
        "w_g": (None, "fsdp", "heads"),
        "w_o": (None, "heads", "fsdp"),
        "wd_a": (None, "fsdp", None),
        "wd_b": (None, None, "heads"),
        "decay_base": (None, "heads"),
        # (L, H, dh): H=40 does not divide a 16-way model axis — replicate
        # (tiny tensor; the big per-head state shards via the d_model dim)
        "bonus_u": (None, None, None),
        "ln_x": (None, None),
    }


def _projections(p: Dict, x: jax.Array, x_prev: jax.Array, cfg: ModelConfig):
    """Token-shift lerped projections.  x: (B, S, d); x_prev: (B, S, d) is x
    shifted right by one token (decode passes the cached last token)."""
    lerp = p["mix_lerp"]  # (5, d)
    def mix(i):
        m = lerp[i][None, None, :]
        return x + (x_prev - x) * m
    r = jnp.einsum("bsd,de->bse", mix(0), p["w_r"])
    k = jnp.einsum("bsd,de->bse", mix(1), p["w_k"])
    v = jnp.einsum("bsd,de->bse", mix(2), p["w_v"])
    dec_in = mix(3)
    g = jnp.einsum("bsd,de->bse", mix(4), p["w_g"])
    # dynamic decay (f32 for stability): w = exp(-exp(base + lora(x)))
    dd = jnp.einsum("bsd,dl->bsl", dec_in, p["wd_a"])
    dd = jnp.einsum("bsl,ld->bsd", jnp.tanh(dd), p["wd_b"])
    logdecay = p["decay_base"][None, None, :] + dd.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logdecay))  # in (0, 1)
    return r, k, v, w, g


def _head_split(x, H, dh):
    b, s, _ = x.shape
    return x.reshape(b, s, H, dh)


def rwkv_scan_full(
    p: Dict, x: jax.Array, cfg: ModelConfig, impl: str = "reference",
) -> jax.Array:
    """Full-sequence RWKV-6.  x: (B, S, d) -> (B, S, d)."""
    H, dh = _n_heads(cfg), cfg.rwkv.head_dim
    b, s, d = x.shape
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, w, g = _projections(p, x, x_prev, cfg)
    r = _head_split(r, H, dh).astype(jnp.float32)
    k = _head_split(k, H, dh).astype(jnp.float32)
    v = _head_split(v, H, dh).astype(jnp.float32)
    w = _head_split(w, H, dh)

    if impl == "pallas":
        from ..kernels.rwkv6_scan.ops import rwkv6_scan

        y = rwkv6_scan(r, k, v, w, p["bonus_u"])
    elif impl == "chunked":
        y = _rwkv_chunked(r, k, v, w, p["bonus_u"])
    else:
        def step(S, inputs):
            rt, kt, vt, wt = inputs          # (B,H,dh) each
            kv = kt[..., :, None] * vt[..., None, :]        # (B,H,dh,dh)
            att = S + p["bonus_u"][None, :, :, None] * kv
            y = jnp.einsum("bhk,bhkv->bhv", rt, att)
            S = wt[..., :, None] * S + kv
            return S, y

        S0 = jnp.zeros((b, H, dh, dh), jnp.float32)
        xs = (
            jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
            jnp.moveaxis(v, 1, 0), jnp.moveaxis(w, 1, 0),
        )
        _, ys = jax.lax.scan(step, S0, xs)
        y = jnp.moveaxis(ys, 0, 1)  # (B,S,H,dh)

    y = y.reshape(b, s, d).astype(x.dtype)
    y = rms_norm(y, p["ln_x"], cfg.norm_eps)     # group-norm stand-in
    y = y * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", y, p["w_o"])
    return with_logical(out, "batch", "seq", None)


def _rwkv_chunked(r, k, v, w, u, chunk: int = 128):
    """Layout-native chunked RWKV-6 on (B, S, H, D) — §Perf iteration 4.

    Same math as :func:`rwkv_chunked_bhtd` but without the (B,S,H,D) ->
    (B,H,S,D) transposes of all four streams (HLO copies of full
    activations): splitting S into (nc, c) is a free reshape, and only the
    cross-chunk scan inputs move their chunk axis to the front.

    chunk=128 measured best on the memory roofline; the model's decay
    parameterization (w = exp(-exp(-6 +- 1.3)) >= 0.99/step) keeps in-chunk
    log-decay sums << the clamp bound at this length.
    """
    b, s, h, dh = r.shape
    c = min(chunk, s)
    if s % c != 0:
        y = rwkv_chunked_bhtd(
            jnp.swapaxes(r, 1, 2), jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2), jnp.swapaxes(w, 1, 2), u, chunk=chunk,
        )
        return jnp.swapaxes(y, 1, 2)
    nc = s // c
    logw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30))
    rc = r.reshape(b, nc, c, h, dh)
    kc = k.reshape(b, nc, c, h, dh)
    vc = v.reshape(b, nc, c, h, dh)
    lw = logw.reshape(b, nc, c, h, dh)
    L = jnp.cumsum(lw, axis=2)
    L_prev = L - lw
    L_end = L[:, :, -1:, :, :]
    clamp = lambda x: jnp.clip(x, -30.0, 30.0)
    r_hat = rc * jnp.exp(clamp(L_prev))
    k_hat = kc * jnp.exp(clamp(-L))
    k_end = kc * jnp.exp(clamp(L_end - L))

    f32, bf = jnp.float32, jnp.bfloat16
    A = jnp.einsum("bnchd,bnshd->bnhcs", r_hat.astype(bf), k_hat.astype(bf),
                   preferred_element_type=f32)
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
    A = jnp.where(mask[None, None, None], A, 0.0)
    diag = jnp.einsum("bnchd,bnchd->bnch", rc * u[None, None, None, :, :], kc)
    y_intra = jnp.einsum("bnhcs,bnshd->bnchd", A.astype(bf), vc.astype(bf),
                         preferred_element_type=f32) + diag[..., None] * vc
    S_contrib = jnp.einsum("bnshd,bnshv->bnhdv", k_end.astype(bf), vc.astype(bf),
                           preferred_element_type=f32)

    def body(S, inputs):
        rh, sc, le = inputs                 # (B,c,H,D), (B,H,D,D), (B,1,H,D)
        y_inter = jnp.einsum("bchd,bhdv->bchv", rh, S)
        S = jnp.exp(clamp(le[:, 0]))[..., :, None] * S + sc
        return S, y_inter

    S0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    xs = (jnp.moveaxis(r_hat, 1, 0), jnp.moveaxis(S_contrib, 1, 0),
          jnp.moveaxis(L_end, 1, 0))
    _, y_inter = jax.lax.scan(body, S0, xs)
    y = y_intra + jnp.moveaxis(y_inter, 0, 1)
    return y.reshape(b, s, h, dh)


def rwkv_chunked_bhtd(r, k, v, w, u, chunk: int = 64):
    """Chunked RWKV-6: matmul form inside chunks, state carried across.

    Within a chunk of C tokens, with per-dim log-decays L_t = sum_{s<=t} ln w_s:
        y_t = (r_t . e^{L_{t-1}}) S_in
            + sum_{s<t} <r_t . e^{L_{t-1}-L_s}, k_s> v_s + <r_t . u, k_t> v_t
        S_out = diag(e^{L_C}) S_in + sum_s (k_s . e^{L_C-L_s})^T v_s
    so the intra-chunk part is one masked (C x C) matmul per head — the state
    touches HBM once per *chunk* instead of once per token, cutting the
    memory-roofline term by ~C (the same scheme the Pallas kernel runs
    on-chip on TPU; this is its XLA-portable form for the dry-run and CPU).
    Exponent differences are clamped at +-30: heavier-decayed terms are
    below f32 resolution of the survivors anyway.  Inputs (B, H, T, D).
    """
    b, h, t, dh = r.shape
    c = min(chunk, t)
    assert t % c == 0
    nc = t // c
    logw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30))
    rc = r.reshape(b, h, nc, c, dh)
    kc = k.reshape(b, h, nc, c, dh)
    vc = v.reshape(b, h, nc, c, dh)
    lw = logw.reshape(b, h, nc, c, dh)
    L = jnp.cumsum(lw, axis=3)                  # L_t (inclusive)
    L_prev = L - lw                             # L_{t-1}
    L_end = L[:, :, :, -1:, :]                  # L_C
    clamp = lambda x: jnp.clip(x, -30.0, 30.0)
    r_hat = rc * jnp.exp(clamp(L_prev))         # r_t e^{L_{t-1}}
    k_hat = kc * jnp.exp(clamp(-L))             # k_s e^{-L_s}
    k_end = kc * jnp.exp(clamp(L_end - L))      # k_s e^{L_C - L_s}

    # big einsums run in bf16 with f32 accumulation (MXU-native); the
    # exponent math above stays f32
    f32 = jnp.float32
    bf = jnp.bfloat16
    A = jnp.einsum("bhncd,bhnsd->bhncs", r_hat.astype(bf), k_hat.astype(bf),
                   preferred_element_type=f32)
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
    A = jnp.where(mask[None, None, None], A, 0.0)
    diag = jnp.einsum("bhncd,bhncd->bhnc", rc * u[None, :, None, None, :], kc)
    y_intra = jnp.einsum("bhncs,bhnsv->bhncv", A.astype(bf), vc.astype(bf),
                         preferred_element_type=f32) + diag[..., None] * vc
    S_contrib = jnp.einsum("bhnsd,bhnsv->bhndv", k_end.astype(bf), vc.astype(bf),
                           preferred_element_type=f32)

    def body(S, inputs):
        rh, sc, le = inputs                     # (B,H,C,D), (B,H,D,D), (B,H,1,D)
        y_inter = jnp.einsum("bhcd,bhdv->bhcv", rh, S)
        S = jnp.exp(clamp(le[:, :, 0]))[..., :, None] * S + sc
        return S, y_inter

    S0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    xs = (jnp.moveaxis(r_hat, 2, 0), jnp.moveaxis(S_contrib, 2, 0),
          jnp.moveaxis(L_end, 2, 0))
    _, y_inter = jax.lax.scan(body, S0, xs)
    y = y_intra + jnp.moveaxis(y_inter, 0, 2)
    return y.reshape(b, h, t, dh)


def rwkv_init_state(cfg: ModelConfig, n_layers: int, batch: int) -> Dict:
    H, dh = _n_heads(cfg), cfg.rwkv.head_dim
    return {
        "S": jnp.zeros((n_layers, batch, H, dh, dh), jnp.float32),
        "x_last": jnp.zeros((n_layers, batch, cfg.d_model), dtype_of(cfg)),
    }


def rwkv_state_specs() -> Dict:
    return {
        "S": (None, "batch", "heads", None, None),
        "x_last": (None, "batch", None),
    }


def rwkv_decode_step(
    p: Dict, x: jax.Array, S: jax.Array, x_last: jax.Array, cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One token.  x: (B, 1, d); S: (B, H, dh, dh); x_last: (B, d)."""
    H, dh = _n_heads(cfg), cfg.rwkv.head_dim
    b, _, d = x.shape
    r, k, v, w, g = _projections(p, x, x_last[:, None, :], cfg)
    rt = _head_split(r, H, dh)[:, 0].astype(jnp.float32)
    kt = _head_split(k, H, dh)[:, 0].astype(jnp.float32)
    vt = _head_split(v, H, dh)[:, 0].astype(jnp.float32)
    wt = _head_split(w, H, dh)[:, 0]
    kv = kt[..., :, None] * vt[..., None, :]
    att = S + p["bonus_u"][None, :, :, None] * kv
    y = jnp.einsum("bhk,bhkv->bhv", rt, att)
    S_new = wt[..., :, None] * S + kv
    y = y.reshape(b, 1, d).astype(x.dtype)
    y = rms_norm(y, p["ln_x"], cfg.norm_eps) * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", y, p["w_o"])
    return out, S_new, x[:, 0]
