"""Shared layers: norms, rotary embedding, MLPs, initializers."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import with_logical
from .config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def normal_init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":  # Nemotron-4 squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


# ------------------------------------------------------------------ rotary
def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions: (...,) int32 -> (cos, sin) of shape (..., head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D); cos/sin: (S, D/2) or broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # broadcast cos/sin over head axis: (S, 1, D/2)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
    ).astype(x.dtype)


# --------------------------------------------------------------------- MLP
def mlp_params(cfg: ModelConfig, key, n: int, d_ff: Optional[int] = None) -> Dict:
    """Stacked gated-MLP params for ``n`` layers."""
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d ** -0.5
    scale_out = ff ** -0.5
    return {
        "w_gate": normal_init(k1, (n, d, ff), scale_in, dt),
        "w_up": normal_init(k2, (n, d, ff), scale_in, dt),
        "w_down": normal_init(k3, (n, ff, d), scale_out, dt),
    }


def mlp_specs() -> Dict:
    return {
        "w_gate": (None, "fsdp", "ff"),
        "w_up": (None, "fsdp", "ff"),
        "w_down": (None, "ff", "fsdp"),
    }


def mlp_apply(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d). Megatron-style: ff dim sharded, down-proj row-parallel."""
    act = activation_fn(cfg.activation)
    h = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = with_logical(act(h) * u, "batch", None, "ff")
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return with_logical(out, "batch", "seq", None)
