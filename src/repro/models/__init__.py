"""Model zoo: configs + functional transformer implementation."""
from .config import (
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    RecurrentConfig,
    SHAPES,
    ShapeConfig,
    get_shape,
    scaled_down,
    shape_applicable,
)
from .transformer import (
    cache_specs,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_and_aux,
    param_specs,
    prefill,
)

__all__ = [
    "ModelConfig", "MoEConfig", "RWKVConfig", "RecurrentConfig", "SHAPES",
    "ShapeConfig", "get_shape", "scaled_down", "shape_applicable",
    "cache_specs", "decode_step", "forward", "init_cache", "init_params",
    "loss_and_aux", "param_specs", "prefill",
]
