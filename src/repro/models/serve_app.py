"""Autoregressive decode as an EasyCrash IterativeApp.

``launch/serve.py``'s decode loop, wrapped in the campaign abstraction so
S1–S4 rates and persist plans exist for *serving*, not just training.  One
main-loop iteration decodes one token for a batch of sessions:

    cache  — KV / recurrent decode state, flattened to one vector
             (expected: critical — it is the session)
    tokens — the committed token buffer, prompt + generated
    next   — the staged not-yet-committed token        (temporal)
    k      — decode-step counter                       (always persisted)

Regions: ``decode`` (the transformer step + greedy argmax) and ``commit``
(append the staged token, advance the counter).

Intrinsic fault tolerance here is *bounded decode divergence*: a crash that
leaves a stale cache image in NVM restarts with the bookmarked step counter
but decode state from an earlier step — greedy decoding then re-derives the
stream, and acceptance verification is prefix/token match against the golden
stream (``match_frac``).  Unlike the HPC apps there is no fixed point pulling
the state back, so persistence of the cache matters more, which is exactly
what the campaign measures.

Registered in the suite app registry as ``"decode"``
(:func:`repro.hpc.suite.get_app`).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.regions import IterativeApp, Region, State, VerifyResult
from .config import ModelConfig, scaled_down
from .transformer import decode_step, init_cache, init_params, prefill


class DecodeApp(IterativeApp):
    name = "decode"
    candidates = ("cache", "tokens", "next", "k")
    iterator_object = "k"
    #: campaign fault tuning: each KV slot is written once and then read for
    #: the rest of the stream — ancient-but-large cold state, so spread bit
    #: flips wide; correlated failures should strike the dominant decode
    #: region where the cache is mid-update.
    fault_defaults = {
        "bit-flip": {"n_bits": 16},
        "correlated-region": {"shape": 3.0},
    }

    def __init__(
        self,
        base: ModelConfig = None,
        n_iters: int = 32,
        batch: int = 2,
        prompt_len: int = 8,
        width: int = 32,
        match_frac: float = 0.9,
        seed: int = 0,
    ):
        from ..configs import get_arch

        base = base or get_arch("stablelm-1.6b")
        self.cfg = scaled_down(base, width=width)
        self.n_iters = n_iters
        self.batch = batch
        self.prompt_len = prompt_len
        self.max_len = prompt_len + n_iters + 1
        self.match_frac = match_frac
        self._seed = seed
        self._golden_tokens = None
        self._build()

    # ------------------------------------------------------------- plumbing
    def _build(self):
        cfg = self.cfg
        self._params = init_params(cfg, jax.random.PRNGKey(self._seed))
        template = init_cache(cfg, self.batch, self.max_len)
        template = {k: v for k, v in template.items() if k != "t"}
        leaves, treedef = jax.tree.flatten(template)
        self._treedef = treedef
        self._shapes = [(l.shape, l.dtype) for l in leaves]
        self._sizes = [int(np.prod(s)) for s, _ in self._shapes]

        def unflatten(vec):
            out = []
            off = 0
            for (shape, dt), size in zip(self._shapes, self._sizes):
                out.append(vec[off:off + size].reshape(shape).astype(dt))
                off += size
            return jax.tree.unflatten(self._treedef, out)

        def flatten(tree):
            return jnp.concatenate([
                x.reshape(-1).astype(jnp.float32) for x in jax.tree.leaves(tree)
            ])

        self._flatten = flatten

        @jax.jit
        def decode_flat(vec, token, t):
            cache = unflatten(vec)
            cache["t"] = t
            logits, new_cache = decode_step(cfg, self._params, token, cache)
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
            new_cache = {k: v for k, v in new_cache.items() if k != "t"}
            return flatten(new_cache), nxt

        self._decode_flat = decode_flat

        @jax.jit
        def prefill_fn(prompts):
            logits, pcache = prefill(cfg, self._params, prompts)
            full = init_cache(cfg, self.batch, self.max_len)
            from ..launch.serve import _splice_cache

            spliced = _splice_cache(cfg, full, pcache, self.prompt_len)
            spliced = {k: v for k, v in spliced.items() if k != "t"}
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return flatten(spliced), first

        self._prefill = prefill_fn

    # ----------------------------------------------------------------- state
    def init(self, seed: int = 0) -> State:
        prompts = jax.random.randint(
            jax.random.PRNGKey(7), (self.batch, self.prompt_len), 0, self.cfg.vocab
        ).astype(jnp.int32)
        vec, first = self._prefill(prompts)
        tokens = np.zeros((self.batch, self.max_len), np.int32)
        tokens[:, : self.prompt_len] = np.asarray(prompts)
        tokens[:, self.prompt_len] = np.asarray(first)
        return {
            "cache": np.asarray(vec, np.float32),
            "tokens": tokens,
            "next": np.zeros((self.batch, 1), np.int32),
            "k": np.zeros(1, np.int64),
        }

    def _region_decode(self, s: State) -> State:
        s = dict(s)
        t = self.prompt_len + int(s["k"][0])
        vec, nxt = self._decode_flat(
            jnp.asarray(s["cache"]),
            jnp.asarray(s["tokens"][:, t:t + 1]),
            np.int32(t),
        )
        s["cache"] = np.asarray(vec, np.float32)
        s["next"] = np.asarray(nxt, np.int32)
        return s

    def _region_commit(self, s: State) -> State:
        s = dict(s)
        t = self.prompt_len + int(s["k"][0])
        tokens = np.array(s["tokens"], copy=True)
        tokens[:, t + 1] = s["next"][:, 0]
        s["tokens"] = tokens
        s["k"] = s["k"] + 1
        return s

    def regions(self) -> Tuple[Region, ...]:
        return (
            Region("decode", self._region_decode, writes=("cache", "next"),
                   reads=("cache", "tokens", "k"), cost=4.0,
                   hot_reads=("tokens",)),
            Region("commit", self._region_commit, writes=("tokens", "k"),
                   reads=("next", "tokens", "k"), cost=0.2),
        )

    # ----------------------------------------------------------- verification
    def _golden(self) -> np.ndarray:
        if self._golden_tokens is None:
            s = self.init(self._seed)
            for _ in range(self.n_iters):
                s = self.run_iteration(s)
            self._golden_tokens = np.array(s["tokens"], copy=True)
        return self._golden_tokens

    def _match_fraction(self, state: State) -> float:
        golden = self._golden()
        lo, hi = self.prompt_len, self.prompt_len + self.n_iters + 1
        got = np.asarray(state["tokens"])[:, lo:hi]
        want = golden[:, lo:hi]
        return float(np.mean(got == want))

    def verify(self, state: State) -> VerifyResult:
        frac = self._match_fraction(state)
        return VerifyResult(frac >= self.match_frac, frac,
                            detail=f"token match {frac:.3f}")

    def progress(self, state: State) -> float:
        # residual-style metric: divergence from the golden stream
        return 1.0 - self._match_fraction(state)
