"""GQA attention: train/prefill (full-sequence) and decode (KV cache) paths.

Sharding: query heads go to "heads" (model axis); K/V projections replicate
when n_kv_heads doesn't divide the TP degree (the GQA<TP case) and the decode
KV cache is then sequence-sharded ("kv_seq") instead of head-sharded.
Supports causal and local-window (RecurrentGemma) masking.

The full-sequence path can route through the Pallas flash-attention kernel
(``impl="pallas"``) on TPU; the einsum reference is the default and the
numerically-identical oracle.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import with_logical
from .config import ModelConfig
from .layers import apply_rope, dtype_of, normal_init, rope_angles


def attn_params(cfg: ModelConfig, key, n: int) -> Dict:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    so = (hq * dh) ** -0.5
    return {
        "wq": normal_init(k1, (n, d, hq * dh), s, dt),
        "wk": normal_init(k2, (n, d, hkv * dh), s, dt),
        "wv": normal_init(k3, (n, d, hkv * dh), s, dt),
        "wo": normal_init(k4, (n, hq * dh, d), so, dt),
    }


def attn_specs(cfg: ModelConfig, tp: int = 16) -> Dict:
    kv_sharded = cfg.n_kv_heads % tp == 0
    kv = "heads" if kv_sharded else None
    return {
        "wq": (None, "fsdp", "heads"),
        "wk": (None, "fsdp", kv),
        "wv": (None, "fsdp", kv),
        "wo": (None, "heads", "fsdp"),
    }


def _split_heads(x: jax.Array, n_heads: int, d_head: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, d_head)


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, Hkv, D) -> (B, S, Hkv*groups, D) for GQA."""
    if groups == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, groups, d)).reshape(b, s, h * groups, d)


def _mask_bias(seq_q: int, seq_k: int, offset: int, window: Optional[int], dtype) -> jax.Array:
    """(seq_q, seq_k) additive mask; q position i attends k position j iff
    j <= i+offset and (window is None or j > i+offset-window)."""
    qpos = jnp.arange(seq_q)[:, None] + offset
    kpos = jnp.arange(seq_k)[None, :]
    ok = kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, -1e30).astype(dtype)


def attention_full(
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    window: Optional[int] = None,
    impl: str = "reference",
) -> jax.Array:
    """Full-sequence causal attention.  x: (B, S, d); positions: (S,)."""
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wq"]), hq, dh)
    k = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wk"]), hkv, dh)
    v = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wv"]), hkv, dh)
    cos, sin = rope_angles(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = with_logical(q, "batch", None, "heads", None)
    k = with_logical(k, "batch", None, "kv_heads" if hkv % 8 == 0 else None, None)

    if impl == "pallas":
        from ..kernels.flash_attention.ops import flash_attention

        out = flash_attention(q, _repeat_kv(k, hq // hkv), _repeat_kv(v, hq // hkv),
                              causal=True, window=window)
    elif impl == "chunked":
        out = _attention_chunked(q, _repeat_kv(k, hq // hkv), _repeat_kv(v, hq // hkv),
                                 window=window)
    else:
        k = _repeat_kv(k, hq // hkv)
        v = _repeat_kv(v, hq // hkv)
        scale = dh ** -0.5
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
        bias = _mask_bias(q.shape[1], k.shape[1], 0, window, jnp.float32)
        probs = jax.nn.softmax(scores.astype(jnp.float32) + bias, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)

    out = out.reshape(x.shape[0], x.shape[1], hq * dh)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return with_logical(y, "batch", "seq", None)


def _attention_chunked(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, window: Optional[int] = None, chunk: int = 512,
) -> jax.Array:
    """Flash-style causal attention as a ``lax.scan`` over KV chunks.

    Never materializes the (S x S) score matrix — per scan step only a
    (B, H, S, chunk) tile exists, so HBM traffic drops by ~S/chunk relative
    to the naive einsum path.  This is the XLA-portable analogue of the
    Pallas ``flash_attention`` kernel (same online-softmax recurrence), used
    where Pallas cannot compile (CPU dry-runs) and as the §Perf
    beyond-baseline attention for the memory-bound archs.
    """
    b, s, h, d = q.shape
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    scale = d ** -0.5
    nk = s // c
    qf = q.astype(jnp.float32) * scale
    kc = k.astype(jnp.float32).reshape(b, nk, c, h, d)
    vc = v.astype(jnp.float32).reshape(b, nk, c, h, d)
    qpos = jnp.arange(s)

    def body(carry, inputs):
        m, l, acc = carry
        kci, vci, ik = inputs
        kpos = ik * c + jnp.arange(c)
        sc = jnp.einsum("bqhd,bkhd->bhqk", qf, kci)
        ok = kpos[None, :] <= qpos[:, None]
        if window is not None:
            ok &= kpos[None, :] > qpos[:, None] - window
        sc = jnp.where(ok[None, None], sc, -1e30)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        p = jnp.where(ok[None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vci)
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    acc0 = jnp.zeros((b, h, s, d), jnp.float32)
    xs = (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(nk))
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.swapaxes(out, 1, 2).astype(q.dtype)


def init_kv_cache(cfg: ModelConfig, n_layers: int, batch: int, max_len: int,
                  window: Optional[int] = None) -> Dict:
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    length = min(max_len, window) if window else max_len
    dt = dtype_of(cfg)
    return {
        "k": jnp.zeros((n_layers, batch, length, hkv, dh), dt),
        "v": jnp.zeros((n_layers, batch, length, hkv, dh), dt),
    }


def kv_cache_specs(cfg: ModelConfig, tp: int = 16) -> Dict:
    if cfg.n_kv_heads % tp == 0:
        spec = (None, "batch", None, "kv_heads", None)
    else:
        spec = (None, "batch", "kv_seq", None, None)
    return {"k": spec, "v": spec}


def attention_decode(
    p: Dict,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    cfg: ModelConfig,
    t: jax.Array,
    window: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode.  x: (B, 1, d); cache: (B, L, Hkv, dh); t: scalar
    position of the new token.  Returns (y, new_cache_k, new_cache_v).

    With a window, the cache is a rolling buffer of size W and the slot is
    t mod W; otherwise the cache is absolute-addressed.
    """
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b = x.shape[0]
    length = cache_k.shape[1]
    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wq"]), hq, dh)
    k = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wk"]), hkv, dh)
    v = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wv"]), hkv, dh)
    cos, sin = rope_angles(t[None], dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    slot = (t % length) if window else t
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)

    kk = _repeat_kv(cache_k, hq // hkv)
    vv = _repeat_kv(cache_v, hq // hkv)
    scale = dh ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * scale  # (B, H, 1, L)
    kpos = jnp.arange(length)
    if window:
        valid = (kpos <= t % length) | (t >= length)  # rolling buffer: all valid once full
    else:
        valid = kpos <= t
    bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)
    probs = jax.nn.softmax(scores.astype(jnp.float32) + bias[None, None, None, :], axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(x.dtype), vv)
    out = out.reshape(b, 1, hq * dh)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return with_logical(y, "batch", None, None), cache_k, cache_v
