"""Mixture-of-Experts FFN: top-k router + capacity-buffered sort dispatch.

Two implementations sharing the router:

* ``sort`` (production): argsort tokens by expert, scatter into per-expert
  capacity buffers, one batched einsum over stacked expert weights, scatter
  back with gate weighting.  Over-capacity tokens are dropped (standard
  Switch/GShard semantics; capacity_factor controls slack).  Buffers shard
  over "experts" (EP) or "expert_ff" (TP) per the config.
* ``dense`` (oracle): every expert processes every token, combined by gate
  weight.  O(E/k) more FLOPs — used for tiny smoke tests and as the
  correctness reference for the dispatch path.

Shared experts (Qwen-MoE, Llama-4) are a plain gated MLP added to the routed
output.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import with_logical
from .config import ModelConfig
from .layers import activation_fn, dtype_of, mlp_apply, mlp_params, mlp_specs, normal_init


def moe_params(cfg: ModelConfig, key, n: int) -> Dict:
    assert cfg.moe is not None
    m = cfg.moe
    d = cfg.d_model
    dt = dtype_of(cfg)
    keys = jax.random.split(key, 5)
    s_in = d ** -0.5
    s_out = m.d_ff_expert ** -0.5
    p = {
        "router": normal_init(keys[0], (n, d, m.num_experts), s_in, jnp.float32),
        "w_gate": normal_init(keys[1], (n, m.num_experts, d, m.d_ff_expert), s_in, dt),
        "w_up": normal_init(keys[2], (n, m.num_experts, d, m.d_ff_expert), s_in, dt),
        "w_down": normal_init(keys[3], (n, m.num_experts, m.d_ff_expert, d), s_out, dt),
    }
    if m.d_ff_shared:
        p["shared"] = mlp_params(cfg, keys[4], n, d_ff=m.d_ff_shared)
    return p


def moe_specs(cfg: ModelConfig) -> Dict:
    m = cfg.moe
    ep = m.expert_parallel
    e_ax = "experts" if ep else None
    f_ax = None if ep else "expert_ff"
    p = {
        "router": (None, "fsdp", None),
        "w_gate": (None, e_ax, "fsdp", f_ax),
        "w_up": (None, e_ax, "fsdp", f_ax),
        "w_down": (None, e_ax, f_ax, "fsdp"),
    }
    if m.d_ff_shared:
        p["shared"] = mlp_specs()
    return p


def _route(x2d: jax.Array, router: jax.Array, m) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x2d: (T, d) -> (gates (T,k), experts (T,k) int32, aux losses)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router)
    gates_all = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(gates_all, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance + router-z auxiliary losses (GShard / ST-MoE)
    density = jnp.mean(jax.nn.one_hot(experts[:, 0], m.num_experts), axis=0)
    density_prob = jnp.mean(gates_all, axis=0)
    lb_loss = m.num_experts * jnp.sum(density * density_prob)
    z_loss = m.router_z_loss * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gates, experts.astype(jnp.int32), lb_loss + z_loss


def _expert_mlp(w_gate, w_up, w_down, h, act):
    """h: (E, C, d) -> (E, C, d) through per-expert gated MLP."""
    g = jnp.einsum("ecd,edf->ecf", h, w_gate)
    u = jnp.einsum("ecd,edf->ecf", h, w_up)
    g = with_logical(act(g) * u, "experts", None, "expert_ff")
    return jnp.einsum("ecf,efd->ecd", g, w_down)


def moe_apply(p: Dict, x: jax.Array, cfg: ModelConfig, decode: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).

    ``decode=True`` forces the dense path: a decode step is weight-bandwidth
    bound (every expert's weights stream from HBM regardless of routing), so
    capacity buffers would only add dropping artefacts for zero savings.
    """
    m = cfg.moe
    act = activation_fn(cfg.activation)
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    gates, experts, aux = _route(x2d, p["router"], m)
    T = b * s

    if m.impl == "dense" or decode:
        # oracle: all experts on all tokens
        h = jnp.einsum("td,edf->tef", x2d, p["w_gate"])
        u = jnp.einsum("td,edf->tef", x2d, p["w_up"])
        y_all = jnp.einsum("tef,efd->ted", act(h) * u, p["w_down"])
        combine = jnp.zeros((T, m.num_experts), x.dtype)
        combine = combine.at[jnp.arange(T)[:, None], experts].add(gates.astype(x.dtype))
        y = jnp.einsum("ted,te->td", y_all, combine)
    else:
        # sort-based capacity dispatch, optionally in shard-local groups
        G = max(1, m.dispatch_groups)
        assert T % G == 0, (T, G)
        tg = T // G
        cap = int(max(1, round(tg * m.top_k / m.num_experts * m.capacity_factor)))

        def dispatch(xg, gg, eg):
            """One group's tokens through the experts.  xg: (tg, d)."""
            flat_e = eg.reshape(-1)                       # (tg*k,)
            flat_t = jnp.repeat(jnp.arange(tg), m.top_k)  # token of each slot
            flat_g = gg.reshape(-1)
            order = jnp.argsort(flat_e, stable=True)
            se, st, sg = flat_e[order], flat_t[order], flat_g[order]
            pos = jnp.arange(se.shape[0], dtype=jnp.int32)
            run_start = jnp.full((m.num_experts,), se.shape[0], jnp.int32).at[se].min(pos)
            pos_in_e = pos - run_start[se]
            keep = pos_in_e < cap
            slot = jnp.where(keep, se * cap + pos_in_e, m.num_experts * cap)
            buf = jnp.zeros((m.num_experts * cap + 1, d), x.dtype)
            buf = buf.at[slot].set(xg[st])
            h = buf[: m.num_experts * cap].reshape(m.num_experts, cap, d)
            return h, (slot, st, sg, keep)

        def combine(yb, meta):
            slot, st, sg, keep = meta
            yb = jnp.concatenate([yb.reshape(m.num_experts * cap, d),
                                  jnp.zeros((1, d), x.dtype)], axis=0)
            contrib = yb[slot] * sg[:, None].astype(x.dtype)
            return jnp.zeros((tg, d), x.dtype).at[st].add(
                jnp.where(keep[:, None], contrib, 0.0))

        if G == 1:
            h, meta = dispatch(x2d, gates, experts)
            h = with_logical(h, "experts", None, None)
            yb = _expert_mlp(p["w_gate"], p["w_up"], p["w_down"], h, act)
            y = combine(yb, meta)
        else:
            xg = with_logical(x2d.reshape(G, tg, d), "batch", None, None)
            gg = gates.reshape(G, tg, m.top_k)
            eg = experts.reshape(G, tg, m.top_k)
            h, meta = jax.vmap(dispatch)(xg, gg, eg)      # (G, E, cap, d)
            h = with_logical(h, "batch", "experts", None, None)
            gge = jnp.einsum("gecd,edf->gecf", h, p["w_gate"])
            uge = jnp.einsum("gecd,edf->gecf", h, p["w_up"])
            hh = with_logical(act(gge) * uge, "batch", "experts", None, "expert_ff")
            yb = jnp.einsum("gecf,efd->gecd", hh, p["w_down"])
            y = jax.vmap(combine)(yb, meta).reshape(T, d)

    if m.d_ff_shared:
        y = y + mlp_apply(p["shared"], x, cfg).reshape(T, d)
    return with_logical(y.reshape(b, s, d), "batch", "seq", None), aux
