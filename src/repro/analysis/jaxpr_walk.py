"""Trace app regions to jaxprs and walk their dataflow.

Regions are plain ``dict -> dict`` transitions over numpy arrays, so tracing
them with :func:`jax.make_jaxpr` needs one accommodation: many region
bodies round-trip values through ``np.asarray`` (the state contract is
numpy), which would force a concrete value out of a tracer.
:func:`numpy_shim` patches ``np.asarray``/``np.array`` to pass jax tracers
through unchanged for the duration of a trace — the same shim makes
``jax.jvp`` work for the damping probe in :mod:`repro.analysis.classify`.

The walker computes, for every value a region writes, (a) which state
objects it depends on and (b) which primitives sit on those input-dependent
paths — with the operand roles that matter for crash classification:
comparisons, ``argmin``/``sort``, ``select_n`` with a data-dependent
predicate, and gathers/scatters with data-dependent *indices* are tagged
``discrete:*`` (a crashed stale input can flip them by a whole category, so
no contraction argument applies); constant-index scatters (boundary pins)
and iota-derived masks are not.

Not every region traces — some call ``int(...)``/``float(...)`` on state
(host-side control flow) or index in place.  That is a *finding*, not an
error: :func:`trace_region` returns ``ok=False`` and the classifier falls
back to the region's declared reads/writes at reduced confidence.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Mapping, Sequence, Tuple

import numpy as np

import jax

from ..core.regions import Region, State

#: tag recorded for objects written by a region that could not be traced
UNTRACED = "<untraced>"

_TracerT = jax.core.Tracer

# discrete-valued primitives, by the operand role that makes them discrete
_DISCRETE_ALWAYS = frozenset({"argmin", "argmax", "sort", "top_k"})
_DISCRETE_CMP = frozenset({"eq", "ne", "lt", "le", "gt", "ge"})
#: primitive -> positions of its *index* operands; the op is discrete only
#: when an index is data-dependent (constant-index pins/segment ids are not)
_INDEX_OPERANDS = {
    "gather": (1,),
    "scatter": (1,),
    "scatter-add": (1,),
    "scatter-mul": (1,),
    "scatter-min": (1,),
    "scatter-max": (1,),
    "dynamic_slice": slice(1, None),
    "dynamic_update_slice": slice(2, None),
}


@contextlib.contextmanager
def numpy_shim():
    """Let ``np.asarray``/``np.array`` pass jax tracers through unchanged."""
    orig_asarray, orig_array = np.asarray, np.array

    def asarray(x, dtype=None, **kw):
        if isinstance(x, _TracerT):
            return x if dtype is None else x.astype(dtype)
        return orig_asarray(x, dtype=dtype, **kw)

    def array(x, dtype=None, **kw):
        if isinstance(x, _TracerT):
            return x if dtype is None else x.astype(dtype)
        return orig_array(x, dtype=dtype, **kw)

    np.asarray, np.array = asarray, array
    try:
        yield
    finally:
        np.asarray, np.array = orig_asarray, orig_array


@dataclass(frozen=True)
class RegionTrace:
    """Dataflow summary of one region (or the declared-metadata fallback)."""

    name: str
    ok: bool
    #: written object -> state objects its new value depends on
    deps: Mapping[str, FrozenSet[str]]
    #: written object -> primitives on its input-dependent paths
    #: (plus ``discrete:*`` tags and :data:`UNTRACED`)
    ops: Mapping[str, FrozenSet[str]]
    #: statically estimated bytes this region writes per iteration
    write_bytes: int
    error: str = ""

    def reads(self) -> FrozenSet[str]:
        """State objects whose current value this region consumes."""
        out: FrozenSet[str] = frozenset()
        for d in self.deps.values():
            out |= d
        return out


Info = Tuple[FrozenSet[str], FrozenSet[str]]  # (deps, ops)
_EMPTY: Info = (frozenset(), frozenset())


def _sub_jaxprs(eqn) -> List[object]:
    out = []
    for v in eqn.params.values():
        if hasattr(v, "jaxpr"):  # ClosedJaxpr (checked first: it proxies .eqns)
            out.append(v.jaxpr)
        elif hasattr(v, "eqns"):  # open Jaxpr
            out.append(v)
        elif isinstance(v, (tuple, list)):
            for x in v:
                if hasattr(x, "jaxpr"):
                    out.append(x.jaxpr)
                elif hasattr(x, "eqns"):
                    out.append(x)
    return out


def _all_prims(jaxpr) -> FrozenSet[str]:
    """Every primitive name reachable from ``jaxpr`` (transitively)."""
    out = set()
    for eqn in jaxpr.eqns:
        out.add(eqn.primitive.name)
        for sub in _sub_jaxprs(eqn):
            out |= _all_prims(sub)
    return frozenset(out)


def _discrete_tags(eqn, in_info: Sequence[Info]) -> FrozenSet[str]:
    """``discrete:*`` tags this equation contributes, given operand deps."""
    name = eqn.primitive.name
    if name in _DISCRETE_ALWAYS and any(d for d, _ in in_info):
        return frozenset({f"discrete:{name}"})
    if name in _DISCRETE_CMP and any(d for d, _ in in_info):
        return frozenset({f"discrete:{name}"})
    if name == "select_n" and in_info and in_info[0][0]:
        # data-dependent predicate: the selection itself can flip
        return frozenset({"discrete:select_n"})
    idx = _INDEX_OPERANDS.get(name)
    if idx is not None:
        pos = list(range(len(in_info)))[idx] if isinstance(idx, slice) else list(idx)
        if any(p < len(in_info) and in_info[p][0] for p in pos):
            return frozenset({f"discrete:{name}"})
    return frozenset()


def walk_jaxpr(jaxpr, in_info: Sequence[Info]) -> List[Info]:
    """Propagate (deps, ops) from a jaxpr's invars to its outvars.

    ``pjit``-style single-body higher-order primitives recurse exactly;
    multi-branch/looping ones (``scan``/``while``/``cond``) join
    conservatively — all outputs depend on all data-dependent inputs, and
    every primitive inside counts as on-path.
    """
    env: Dict[object, Info] = {}
    for var, info in zip(jaxpr.invars, in_info):
        env[var] = info
    for var in jaxpr.constvars:
        env[var] = _EMPTY

    def read(atom) -> Info:
        if isinstance(atom, jax.core.Literal):
            return _EMPTY
        return env.get(atom, _EMPTY)

    for eqn in jaxpr.eqns:
        infos = [read(v) for v in eqn.invars]
        deps = frozenset().union(*(d for d, _ in infos)) if infos else frozenset()
        if not deps:
            for ov in eqn.outvars:
                env[ov] = _EMPTY
            continue
        subs = _sub_jaxprs(eqn)
        if len(subs) == 1 and len(subs[0].invars) == len(eqn.invars):
            # pjit / closed_call / custom_jvp-style: exact recursion
            out_infos = walk_jaxpr(subs[0], infos)
            for ov, info in zip(eqn.outvars, out_infos):
                env[ov] = info
            continue
        ops = frozenset().union(*(o for _, o in infos)) if infos else frozenset()
        if subs:
            inner = frozenset().union(*(_all_prims(s) for s in subs))
            ops |= {eqn.primitive.name} | inner
            ops |= {f"discrete:{p}" for p in inner
                    if p in _DISCRETE_ALWAYS | _DISCRETE_CMP | {"select_n"}
                    or p in _INDEX_OPERANDS}
        else:
            ops |= {eqn.primitive.name} | _discrete_tags(eqn, infos)
        for ov in eqn.outvars:
            env[ov] = (deps, ops)
    return [read(v) for v in jaxpr.outvars]


def trace_region(state: State, region: Region,
                 const_objects: FrozenSet[str] = frozenset()) -> RegionTrace:
    """Trace one region against an example state; falls back to declared
    metadata (``reads + writes``, self-dependent, :data:`UNTRACED`) when the
    region body cannot be traced.

    ``const_objects`` names state entries no region ever writes: they are
    rebuilt bit-identically by ``restart_init`` after a crash, so for crash
    dataflow they are constants — a scatter whose indices come from a
    read-only pin table is *not* data-dependent."""
    keys = sorted(state)

    def fn(s):
        out = region.fn(dict(s))
        return {k: out[k] for k in region.writes if k in out}

    try:
        with numpy_shim():
            closed = jax.make_jaxpr(fn)(dict(state))
    except Exception as e:  # noqa: BLE001 - untraceable is a finding, not an error
        deps = {w: (frozenset(region.reads) | {w}) - const_objects
                for w in region.writes}
        ops = {w: frozenset({UNTRACED}) for w in region.writes}
        wb = sum(int(np.asarray(state[w]).nbytes) for w in region.writes if w in state)
        return RegionTrace(region.name, False, deps, ops, wb,
                           error=f"{type(e).__name__}: {e}")

    jaxpr = closed.jaxpr
    # dict input flattens in sorted-key order, one leaf per state entry
    in_info: List[Info] = [
        (_EMPTY[0] if k in const_objects else frozenset({k}), frozenset())
        for k in keys
    ]
    out_info = walk_jaxpr(jaxpr, in_info)
    written = [w for w in sorted(region.writes)]
    # output dict flattens in sorted-key order too
    deps = {}
    ops = {}
    wb = 0
    for w, (d, o), var in zip(written, out_info, jaxpr.outvars):
        deps[w] = d
        ops[w] = o
        aval = getattr(var, "aval", None)
        if aval is not None and hasattr(aval, "shape") and hasattr(aval, "dtype"):
            wb += int(np.prod(aval.shape, dtype=np.int64)) * np.dtype(aval.dtype).itemsize
        elif w in state:
            wb += int(np.asarray(state[w]).nbytes)
    return RegionTrace(region.name, True, deps, ops, int(wb))
