"""Pre-PR check entry point: ``python -m repro.analysis.lint``.

Default: run the bitwise-batchability determinism lint over every registered
app that opts into the vectorized campaign engine
(``supports_batched_step``).  ``--all`` additionally runs ``ruff`` over the
repo — one command for the whole pre-PR gate.  Exit status is non-zero on
any finding.
"""
from __future__ import annotations

import argparse
import subprocess
import sys
from typing import List


def run_determinism_lint(app_names: List[str] | None = None) -> int:
    from ..hpc.suite import app_names as registry_names, get_app
    from .determinism_lint import lint_app

    names = app_names or registry_names()
    failures = 0
    checked = 0
    for name in names:
        app = get_app(name)
        if not app.supports_batched_step:
            continue
        kernels = app.batched_kernels()
        if not kernels:
            print(f"[determinism] {name}: supports_batched_step but exposes "
                  f"no batched_kernels() — nothing to check", file=sys.stderr)
            failures += 1
            continue
        for kname, findings in lint_app(app).items():
            checked += 1
            if findings:
                failures += len(findings)
                for f in findings:
                    print(f"[determinism] FAIL {f}", file=sys.stderr)
            else:
                print(f"[determinism] ok   {name}/{kname}")
    print(f"[determinism] {checked} kernels checked, {failures} findings")
    return 1 if failures else 0


def run_ruff() -> int:
    import importlib.util

    if importlib.util.find_spec("ruff") is None:
        print("[ruff] not installed; skipping", file=sys.stderr)
        return 0
    cmd = [sys.executable, "-m", "ruff", "check",
           "src", "tests", "benchmarks", "examples"]
    print("[ruff]", " ".join(cmd[1:]))
    try:
        return subprocess.call(cmd)
    except FileNotFoundError:
        print("[ruff] not installed; skipping", file=sys.stderr)
        return 0


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="bitwise-batchability determinism lint (+ ruff with --all)",
    )
    ap.add_argument("--all", action="store_true",
                    help="also run ruff: the full one-command pre-PR check")
    ap.add_argument("--app", action="append", default=None,
                    help="restrict the determinism lint to specific apps")
    args = ap.parse_args(argv)

    rc = run_determinism_lint(args.app)
    if args.all:
        rc = max(rc, run_ruff())
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
