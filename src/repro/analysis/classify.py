"""Classify tracked data objects from region dataflow; emit a StaticPlan.

Classification lattice (per non-iterator candidate object, over one composed
main-loop iteration):

* ``dead`` — the object is overwritten before any region reads its crashed
  value, and its new value does not depend on its old one.  A stale NVM
  image is simply never consumed: skip.
* ``reconstructible`` — not self-dependent, but read before overwritten: a
  pure function of *other* objects' previous values, so it is rebuilt as
  soon as those are right: skip.
* ``crash-critical`` — the self-dependent update path contains a discrete
  primitive (``argmin``, data-dependent compares/selects/scatters), the
  object is an integer tally, or the app declares an ``exact-accumulator``
  hint: one stale input flips category membership or double-counts, and no
  remaining iterations repair it: persist.
* ``accumulator`` — a smooth self-dependent update.  Whether it
  self-corrects is *quantitative*: the damping probe pushes a unit jvp
  perturbation of the object through one composed iteration; a contraction
  factor below :data:`DAMPING_THRESHOLD` means the next iterations absorb a
  stale image (skip), above means the error survives long enough to exhaust
  the remaining-iteration budget (persist).

The jvp probe is only consulted on that smooth branch — through ``argmin``
and friends the derivative is an honest zero while the value dependence is
maximal, which is exactly why discrete detection is primitive-based.

Untraceable regions degrade *confidence*, not class, when the object has at
least one traced writer; objects with no traced writer fall back to
conservative crash-critical at low confidence.  Region confidences below
:data:`CONFIDENCE_THRESHOLD` are what ``plan_source="static+verify"`` still
measures with a campaign.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..core.cache_sim import CacheConfig
from ..core.crash_tester import PersistPlan
from ..core.selection import RegionSelection, select_regions_from_gains
from .jaxpr_walk import UNTRACED, RegionTrace, numpy_shim, trace_region

#: jvp contraction factor separating self-correcting from fragile smooth
#: accumulators.  Calibrated on the suite: heat's parabolic smoother damps a
#: unit perturbation to ~0.15 per iteration (recomputes for free), while
#: sor's over-relaxed sweep (~0.93), mg's V-cycle (~0.64) and pagerank's
#: damped power iteration (~0.49) all keep enough of the error to spill
#: late crashes into S2.
DAMPING_THRESHOLD = 0.3

#: classification confidence below which static+verify still runs the
#: region's measurement campaign
CONFIDENCE_THRESHOLD = 0.6


@dataclass(frozen=True)
class ObjectReport:
    name: str
    klass: str                     # dead | reconstructible | accumulator | crash-critical
    decision: str                  # persist | skip
    confidence: float
    damping: Optional[float]       # jvp contraction factor (smooth branch only)
    rationale: str


@dataclass(frozen=True)
class RegionReport:
    index: int
    name: str
    decision: str                  # persist | skip
    confidence: float
    traced: bool
    write_bytes: int               # statically estimated bytes written per iteration
    rationale: str


@dataclass(frozen=True)
class StaticPlan:
    """The predicted persist plan, with the evidence that produced it."""

    app_name: str
    objects: Tuple[ObjectReport, ...]
    regions: Tuple[RegionReport, ...]
    region_overheads: Tuple[float, ...]
    damping_threshold: float = DAMPING_THRESHOLD
    confidence_threshold: float = CONFIDENCE_THRESHOLD

    def persist_objects(self) -> Tuple[str, ...]:
        return tuple(o.name for o in self.objects if o.decision == "persist")

    def object_report(self, name: str) -> ObjectReport:
        for o in self.objects:
            if o.name == name:
                return o
        raise KeyError(name)

    def region_decisions(self) -> Dict[str, str]:
        return {r.name: r.decision for r in self.regions}

    def uncertain_regions(self) -> List[int]:
        """Regions whose static decision static+verify still measures."""
        return [r.index for r in self.regions
                if r.confidence < self.confidence_threshold]

    def window_confidences(self) -> Tuple[float, ...]:
        """Per-region decision confidence, indexed by region position.

        Crash times inside an iteration's window map 1:1 onto code regions
        (:meth:`~repro.core.crash_tester.CrashTester.region_time_spans`), so
        this vector is the per-*window* prior the adaptive scheduler's
        importance sampler tilts crash-point draws with: low confidence ->
        more samples land there.
        """
        return tuple(
            r.confidence for r in sorted(self.regions, key=lambda r: r.index)
        )

    def write_traffic_bytes(self) -> int:
        return sum(r.write_bytes for r in self.regions)

    def region_selection(
        self,
        t_s: float = 0.03,
        tau: float = 0.0,
        freq_options: Tuple[int, ...] = (1, 2, 4, 8),
    ) -> RegionSelection:
        """Knapsack over the *predicted* persist regions: gain is the static
        confidence (no campaign ran, so there is no measured gain), overhead
        the same flush-cost estimate the measured workflow uses."""
        gains = {r.index: (r.confidence if r.decision == "persist" else 0.0)
                 for r in self.regions}
        overheads = {r.index: self.region_overheads[r.index] for r in self.regions}
        return select_regions_from_gains(
            gains, overheads, 0.0, t_s=t_s, tau=tau, freq_options=freq_options,
        )

    def persist_plan(
        self,
        t_s: float = 0.03,
        tau: float = 0.0,
        freq_options: Tuple[int, ...] = (1, 2, 4, 8),
    ) -> PersistPlan:
        sel = self.region_selection(t_s=t_s, tau=tau, freq_options=freq_options)
        return PersistPlan(objects=self.persist_objects(),
                           region_freq=sel.plan_freqs())

    # ------------------------------------------------------------- artifact
    def to_payload(self) -> Dict[str, object]:
        def _f(x: Optional[float]):
            return None if x is None or not np.isfinite(x) else float(x)

        return {
            "app": self.app_name,
            "damping_threshold": float(self.damping_threshold),
            "confidence_threshold": float(self.confidence_threshold),
            "objects": [
                {"name": o.name, "class": o.klass, "decision": o.decision,
                 "confidence": round(float(o.confidence), 6),
                 "damping": _f(o.damping), "rationale": o.rationale}
                for o in self.objects
            ],
            "regions": [
                {"index": r.index, "name": r.name, "decision": r.decision,
                 "confidence": round(float(r.confidence), 6),
                 "traced": bool(r.traced), "write_bytes": int(r.write_bytes),
                 "rationale": r.rationale}
                for r in self.regions
            ],
            "region_overheads": [round(float(x), 9) for x in self.region_overheads],
        }

    def spec(self) -> Dict[str, object]:
        return self.to_payload()

    @classmethod
    def from_payload(cls, d: Mapping[str, object]) -> "StaticPlan":
        return cls(
            app_name=str(d["app"]),
            objects=tuple(
                ObjectReport(
                    name=str(o["name"]), klass=str(o["class"]),
                    decision=str(o["decision"]),
                    confidence=float(o["confidence"]),
                    damping=None if o.get("damping") is None else float(o["damping"]),
                    rationale=str(o.get("rationale", "")),
                )
                for o in d["objects"]
            ),
            regions=tuple(
                RegionReport(
                    index=int(r["index"]), name=str(r["name"]),
                    decision=str(r["decision"]),
                    confidence=float(r["confidence"]),
                    traced=bool(r["traced"]),
                    write_bytes=int(r["write_bytes"]),
                    rationale=str(r.get("rationale", "")),
                )
                for r in d["regions"]
            ),
            region_overheads=tuple(float(x) for x in d["region_overheads"]),
            damping_threshold=float(d["damping_threshold"]),
            confidence_threshold=float(d["confidence_threshold"]),
        )


def _damping_probe(app, traces: List[RegionTrace], obj: str,
                   probe_iters: int = 3) -> Optional[float]:
    """||jvp|| of obj -> obj through one composed iteration of the traceable
    regions, at a mid-trajectory state with a deterministic unit direction."""
    state0 = app.init(0)
    if not np.issubdtype(np.asarray(state0[obj]).dtype, np.floating):
        return None
    regs = app.regions()
    s_mid = dict(state0)
    for _ in range(probe_iters):
        s_mid = app.run_iteration(s_mid)

    def f(x):
        s = {k: jnp.asarray(v) for k, v in s_mid.items()}
        s[obj] = x
        for r, tr in zip(regs, traces):
            if tr.ok:
                s = {**s, **r.fn(dict(s))}
        return s[obj]

    x0 = jnp.asarray(s_mid[obj])
    rng = np.random.default_rng(0)
    v = rng.standard_normal(np.asarray(state0[obj]).shape).astype(np.float32)
    v = v / max(np.linalg.norm(v), 1e-30)
    v = jnp.asarray(v).astype(x0.dtype)
    try:
        with numpy_shim():
            _, dv = jax.jvp(f, (x0,), (v,))
        return float(jnp.linalg.norm(dv))
    except Exception:  # noqa: BLE001 - probe failure degrades to conservative
        return None


def _classify_object(
    app,
    obj: str,
    state0: Mapping[str, np.ndarray],
    traces: List[RegionTrace],
    end_info: Mapping[str, Tuple[frozenset, frozenset]],
    read_before_write: bool,
    hints: Mapping[str, str],
    damping_threshold: float,
) -> ObjectReport:
    regs = app.regions()
    writers = [i for i, r in enumerate(regs) if obj in r.writes]
    traced_writers = [i for i in writers if traces[i].ok]
    coverage = (len(traced_writers) / len(writers)) if writers else 1.0
    deps, ops = end_info.get(obj, (frozenset({obj}), frozenset()))
    self_dep = obj in deps
    discrete = sorted(t for t in ops if t.startswith("discrete:"))
    untraced = UNTRACED in ops
    hint = hints.get(obj)

    if hint == "exact-accumulator":
        return ObjectReport(
            obj, "crash-critical", "persist", 0.9, None,
            "app-declared exact accumulator: re-execution double-counts, "
            "verification is exact",
        )
    if not writers:
        return ObjectReport(obj, "dead", "skip", 0.5, None,
                            "never written inside the main loop")
    if not self_dep and not read_before_write:
        return ObjectReport(
            obj, "dead", "skip", 0.95 * max(coverage, 0.5), None,
            "overwritten every iteration before any read: a stale NVM image "
            "is never consumed",
        )
    if not self_dep:
        return ObjectReport(
            obj, "reconstructible", "skip", 0.85 * max(coverage, 0.5), None,
            "pure function of other objects' previous values: rebuilt once "
            "those are restored",
        )
    if np.issubdtype(np.asarray(state0[obj]).dtype, np.integer):
        return ObjectReport(
            obj, "crash-critical", "persist", max(0.85 * coverage, 0.4), None,
            "integer self-accumulation: a lost increment is permanent",
        )
    if discrete:
        return ObjectReport(
            obj, "crash-critical", "persist", max(0.85 * coverage, 0.4), None,
            f"discrete primitives on the self-update path ({', '.join(discrete)}): "
            "stale inputs flip category membership, no contraction applies",
        )
    if untraced and coverage == 0.0:
        return ObjectReport(
            obj, "crash-critical", "persist", 0.35, None,
            "self-dependent with no traceable writer: conservative persist",
        )
    damping = _damping_probe(app, traces, obj)
    if damping is None:
        return ObjectReport(
            obj, "accumulator", "persist", 0.45, None,
            "smooth self-update but the damping probe failed: conservative persist",
        )
    conf = coverage * min(0.9, 0.55 + abs(damping - damping_threshold))
    if damping < damping_threshold:
        return ObjectReport(
            obj, "accumulator", "skip", conf, damping,
            f"self-correcting: one iteration damps a unit perturbation to "
            f"{damping:.3f} (< {damping_threshold}), remaining iterations "
            f"absorb a stale image",
        )
    return ObjectReport(
        obj, "accumulator", "persist", conf, damping,
        f"fragile accumulator: damping {damping:.3f} >= {damping_threshold}, "
        f"stale-image error survives into the acceptance budget",
    )


def analyze_app(
    app,
    cache: Optional[CacheConfig] = None,
    seed: int = 0,
    damping_threshold: float = DAMPING_THRESHOLD,
    confidence_threshold: float = CONFIDENCE_THRESHOLD,
) -> StaticPlan:
    """Trace, classify, and predict a persist plan for one registered app."""
    from ..core.workflow import estimate_region_overheads

    state0 = app.init(seed)
    regs = app.regions()
    # objects no region writes are rebuilt by restart_init: constants for
    # crash dataflow (read-only pin tables, link matrices, sources)
    all_writes = frozenset().union(*(frozenset(r.writes) for r in regs))
    consts = frozenset(state0) - all_writes
    traces = [trace_region(state0, r, const_objects=consts) for r in regs]
    candidates = [c for c in app.candidates if c != app.iterator_object]
    hints = app.static_hints()

    # compose regions in sweep order: end-of-iteration (deps, ops) of every
    # object in terms of start-of-iteration values
    cur: Dict[str, Tuple[frozenset, frozenset]] = {
        k: (frozenset({k}), frozenset()) for k in state0
    }
    read_before_write = {c: False for c in candidates}
    written = {c: False for c in candidates}
    for r, tr in zip(regs, traces):
        region_reads = tr.reads() if tr.ok else frozenset(r.reads) | frozenset(r.writes)
        for c in candidates:
            if c in region_reads and not written[c]:
                read_before_write[c] = True
        new: Dict[str, Tuple[frozenset, frozenset]] = {}
        for w in r.writes:
            srcs = tr.deps.get(w, frozenset())
            infos = [cur.get(i, (frozenset({i}), frozenset())) for i in srcs]
            deps = frozenset().union(*(d for d, _ in infos)) if infos else frozenset()
            ops = tr.ops.get(w, frozenset())
            for _, o in infos:
                ops = ops | o
            new[w] = (deps, ops)
        cur.update(new)
        for c in candidates:
            if c in r.writes:
                written[c] = True

    obj_reports = tuple(
        _classify_object(app, c, state0, traces, cur, read_before_write[c],
                         hints, damping_threshold)
        for c in candidates
    )
    by_name = {o.name: o for o in obj_reports}
    persist = {o.name for o in obj_reports if o.decision == "persist"}

    # region decision: a region flushes iff it writes (or hot-re-reads) a
    # persist-decided object — plus the iterator bookmark region, which is
    # always flushed whenever anything at all is persisted (paper fn. 3)
    region_reports = []
    for i, (r, tr) in enumerate(zip(regs, traces)):
        triggers = (set(r.writes) | set(r.hot_reads)) & persist
        iterator_trigger = (
            app.iterator_object in r.writes and bool(persist) and not triggers
        )
        at_stake = [by_name[c] for c in candidates
                    if c in set(r.writes) | set(r.hot_reads)]
        if triggers:
            conf = min(by_name[o].confidence for o in triggers)
            why = f"writes/hot-reads persist-decided {sorted(triggers)}"
            decision = "persist"
        elif iterator_trigger:
            conf = min(by_name[o].confidence for o in persist)
            why = "iterator bookmark region (flushes whenever anything persists)"
            decision = "persist"
        else:
            conf = min((o.confidence for o in at_stake), default=0.9)
            stake = sorted(o.name for o in at_stake)
            why = (f"touches only skip-decided objects {stake}" if stake
                   else "touches no tracked candidates")
            decision = "skip"
        region_reports.append(RegionReport(
            index=i, name=r.name, decision=decision, confidence=conf,
            traced=tr.ok, write_bytes=tr.write_bytes, rationale=why,
        ))

    block_bytes = cache.block_bytes if cache is not None else 64
    overheads = estimate_region_overheads(
        app, sorted(persist), block_bytes=block_bytes,
    ) if persist else [0.0 for _ in regs]

    return StaticPlan(
        app_name=app.name,
        objects=obj_reports,
        regions=tuple(region_reports),
        region_overheads=tuple(float(x) for x in overheads),
        damping_threshold=damping_threshold,
        confidence_threshold=confidence_threshold,
    )
