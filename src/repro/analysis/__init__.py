"""Static persist-plan analysis (algorithm-directed characterization).

The W+2 crash-test workflow *measures* which data objects and code regions
are worth persisting.  This package *derives* most of those answers from the
program itself: each region is traced to a jaxpr, a dataflow pass classifies
every tracked object (dead-across-crash / reconstructible / accumulator /
crash-critical), and the result is a predicted :class:`~repro.analysis
.classify.StaticPlan` with per-object confidence — consumed by
``run_workflow(plan_source="static" | "static+verify")``.

On the same walker, :mod:`repro.analysis.determinism_lint` checks batched
step kernels for bitwise-per-lane safety (``python -m repro.analysis.lint``).
"""
from .classify import (  # noqa: F401
    CONFIDENCE_THRESHOLD,
    DAMPING_THRESHOLD,
    ObjectReport,
    RegionReport,
    StaticPlan,
    analyze_app,
)
from .determinism_lint import LintFinding, lint_app, lint_batched_fn  # noqa: F401
from .jaxpr_walk import RegionTrace, numpy_shim, trace_region  # noqa: F401
