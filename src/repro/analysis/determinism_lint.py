"""Bitwise-batchability lint for batched step kernels.

The vectorized campaign engine requires every ``supports_batched_step`` app
to advance stacked restart lanes *bitwise identically* to the serial hooks
(``core/regions.py`` contract).  The classic violation is a vmapped matmul:
``vmap(lambda u: A @ u)`` batches the contraction into a matrix-matrix
product with a different reduction tiling, so lane i's result is no longer
the serial matvec bit for bit — found by hand in the PR that introduced the
vec engine, institutionalized here.

The lint walks a batched kernel's jaxpr propagating, per intermediate value,
*which axis carries the lane dimension* (or none).  An operation is safe
when each lane's slice of its output is computed by exactly the scalar/array
program the serial kernel would run:

* elementwise and shape-only ops preserve the lane axis;
* reductions over non-lane axes are per-lane;
* ``scan`` whose mapped ``xs`` carry the lane on axis 0 and whose
  consts/carry are lane-free executes its body once per lane
  (``lax.map`` — the sanctioned way to batch a matmul);
* ``scan``/``while`` with a *laned carry* (a vmapped ``fori_loop``) recurse
  into the body with the same lane layout.

Everything else touching a laned value is a finding, with ``dot_general``
called out specially: **any** contraction with a lane-carrying operand is
flagged, even lane-as-batch-dim forms, because batched GEMM tilings are not
guaranteed bitwise-per-lane — the default-deny that makes the lint an
allowlist, not a blocklist.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np  # noqa: F401  (kernels build example args with numpy)

import jax

#: ops whose output element (i, ...) depends only on operand elements
#: (i, ...) — lane axis passes straight through
_ELEMENTWISE = frozenset({
    "add", "sub", "mul", "div", "rem", "pow", "integer_pow", "neg", "abs",
    "sign", "floor", "ceil", "round", "exp", "log", "log1p", "expm1",
    "sqrt", "rsqrt", "cbrt", "sin", "cos", "tan", "asin", "acos", "atan",
    "atan2", "sinh", "cosh", "tanh", "erf", "erfc", "erf_inv", "logistic",
    "max", "min", "and", "or", "xor", "not", "shift_left",
    "shift_right_logical", "shift_right_arithmetic", "eq", "ne", "lt", "le",
    "gt", "ge", "select_n", "clamp", "nextafter", "convert_element_type",
    "reduce_precision", "stop_gradient", "copy", "real", "imag", "conj",
    "is_finite", "square", "exp2", "log2", "population_count", "clz",
})

_REDUCTIONS = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "reduce_xor", "argmax", "argmin",
})

_CUMULATIVE = frozenset({
    "cumsum", "cumprod", "cummax", "cummin", "cumlogsumexp",
})


@dataclass(frozen=True)
class LintFinding:
    kernel: str
    primitive: str
    reason: str

    def __str__(self) -> str:
        return f"{self.kernel}: {self.primitive}: {self.reason}"


class _Walker:
    def __init__(self, kernel: str):
        self.kernel = kernel
        self.findings: List[LintFinding] = []

    def flag(self, prim: str, reason: str) -> None:
        self.findings.append(LintFinding(self.kernel, prim, reason))

    # ---------------------------------------------------------------- walk
    def walk(self, jaxpr, in_lanes: Sequence[Optional[int]]) -> List[Optional[int]]:
        env: Dict[object, Optional[int]] = {}
        for var, lane in zip(jaxpr.invars, in_lanes):
            env[var] = lane
        for var in jaxpr.constvars:
            env[var] = None

        def read(atom) -> Optional[int]:
            if isinstance(atom, jax.core.Literal):
                return None
            return env.get(atom, None)

        for eqn in jaxpr.eqns:
            lanes = [read(v) for v in eqn.invars]
            outs = self._eqn(eqn, lanes)
            for ov, lane in zip(eqn.outvars, outs):
                env[ov] = lane
        return [read(v) for v in jaxpr.outvars]

    def _eqn(self, eqn, lanes: Sequence[Optional[int]]) -> List[Optional[int]]:
        prim = eqn.primitive.name
        n_out = len(eqn.outvars)
        laned = [x for x in lanes if x is not None]
        if not laned:
            return [None] * n_out
        lane = laned[0]

        if prim == "dot_general":
            # default-deny: batched GEMM reduction tilings are not
            # guaranteed bitwise-per-lane, whatever role the lane dim plays
            self.flag(prim, "contraction with a lane-carrying operand is not "
                            "bitwise-per-lane; batch matmuls with lax.map")
            return [None] * n_out

        if prim in _ELEMENTWISE:
            if any(x != lane for x in laned):
                self.flag(prim, f"operands disagree on lane axis {sorted(set(laned))}")
            return [lane] * n_out

        if prim in _REDUCTIONS:
            axes = tuple(int(a) for a in eqn.params.get("axes", ()))
            if lane in axes:
                self.flag(prim, f"reduces over the lane axis {lane} "
                                f"(cross-lane reduction)")
                return [None] * n_out
            out_lane = lane - sum(1 for a in axes if a < lane)
            return [out_lane] * n_out

        if prim in _CUMULATIVE:
            axis = int(eqn.params.get("axis", 0))
            if axis == lane:
                self.flag(prim, "cumulative op along the lane axis")
                return [None] * n_out
            return [lane] * n_out

        if prim == "broadcast_in_dim":
            bcast = tuple(int(d) for d in eqn.params["broadcast_dimensions"])
            return [bcast[lane]] * n_out

        if prim == "transpose":
            perm = tuple(int(p) for p in eqn.params["permutation"])
            return [perm.index(lane)] * n_out

        if prim == "reshape":
            in_shape = tuple(eqn.invars[0].aval.shape)
            new_sizes = tuple(int(s) for s in eqn.params["new_sizes"])
            if lane == 0 and new_sizes and in_shape and new_sizes[0] == in_shape[0]:
                return [0] * n_out
            self.flag(prim, f"reshape {in_shape} -> {new_sizes} mixes the "
                            f"lane axis into other dimensions")
            return [None] * n_out

        if prim == "squeeze":
            dims = tuple(int(d) for d in eqn.params.get("dimensions", ()))
            if lane in dims:
                self.flag(prim, "squeezes away the lane axis")
                return [None] * n_out
            return [lane - sum(1 for d in dims if d < lane)] * n_out

        if prim == "expand_dims":
            dims = tuple(int(d) for d in eqn.params.get("dimensions", ()))
            out_lane = lane + sum(1 for d in dims if d <= lane)
            return [out_lane] * n_out

        if prim == "pad":
            cfg = eqn.params["padding_config"]
            lo, hi, interior = cfg[lane]
            if int(lo) or int(hi) or int(interior):
                self.flag(prim, "pads along the lane axis (adds phantom lanes)")
                return [None] * n_out
            return [lane] * n_out

        if prim in ("slice", "rev"):
            # static slice/reverse: each output lane is one input lane's data
            return [lane] * n_out

        if prim == "concatenate":
            if any(x is not None and x != lane for x in lanes):
                self.flag(prim, "operands disagree on lane axis")
            return [lane] * n_out

        if prim == "scan":
            return self._scan(eqn, lanes)

        if prim == "while":
            return self._while(eqn, lanes)

        if prim in ("pjit", "closed_call", "core_call", "remat", "remat2",
                    "custom_jvp_call", "custom_vjp_call",
                    "custom_jvp_call_jaxpr", "checkpoint"):
            sub = self._single_sub(eqn)
            if sub is not None and len(sub.invars) == len(lanes):
                return self.walk(sub, lanes)
            self.flag(prim, "call primitive with unrecognized body layout")
            return [None] * n_out

        self.flag(prim, f"primitive not on the bitwise-per-lane allowlist "
                        f"(lane axis {lane})")
        return [None] * n_out

    # ------------------------------------------------------- control flow
    @staticmethod
    def _single_sub(eqn):
        subs = []
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):  # ClosedJaxpr proxies .eqns, check first
                subs.append(v.jaxpr)
            elif hasattr(v, "eqns"):
                subs.append(v)
        return subs[0] if len(subs) == 1 else None

    def _scan(self, eqn, lanes: Sequence[Optional[int]]) -> List[Optional[int]]:
        p = eqn.params
        num_consts = int(p.get("num_consts", 0))
        num_carry = int(p.get("num_carry", p.get("num_carries", 0)))
        body = p["jaxpr"].jaxpr if hasattr(p["jaxpr"], "jaxpr") else p["jaxpr"]
        consts = lanes[:num_consts]
        carry = lanes[num_consts:num_consts + num_carry]
        xs = lanes[num_consts + num_carry:]
        n_ys = len(eqn.outvars) - num_carry

        lane_is_scan_dim = any(x == 0 for x in xs if x is not None)
        if lane_is_scan_dim:
            if all(x in (None, 0) for x in xs) and all(c is None for c in carry) \
                    and all(c is None for c in consts):
                # lax.map: the scan dimension *is* the lane dimension, so the
                # body executes the serial program once per lane — safe by
                # construction, body needs no lane tracking
                return [None] * num_carry + [0] * n_ys
            self.flag("scan", "scans over the lane axis while consts/carry "
                              "also carry lanes: steps mix lanes")
            return [None] * len(eqn.outvars)
        # vmapped loop: consts and carry keep their lane layout inside the
        # body (loop-invariant batched operands become laned consts), xs
        # lose the scan axis
        inner_xs = [None if x is None else x - 1 for x in xs]
        inner_out = self.walk(body, list(consts) + list(carry) + inner_xs)
        carry_out = inner_out[:num_carry]
        ys_out = inner_out[num_carry:]
        if list(carry_out) != list(carry):
            self.flag("scan", f"carry lane layout changes across iterations "
                              f"({list(carry)} -> {list(carry_out)})")
        outer_ys = [
            (0 if lane_is_scan_dim else None) if y is None else y + 1
            for y in ys_out
        ]
        return list(carry_out) + outer_ys

    def _while(self, eqn, lanes: Sequence[Optional[int]]) -> List[Optional[int]]:
        p = eqn.params
        cn = int(p.get("cond_nconsts", 0))
        bn = int(p.get("body_nconsts", 0))
        cond = p["cond_jaxpr"].jaxpr if hasattr(p["cond_jaxpr"], "jaxpr") else p["cond_jaxpr"]
        body = p["body_jaxpr"].jaxpr if hasattr(p["body_jaxpr"], "jaxpr") else p["body_jaxpr"]
        cond_consts = lanes[:cn]
        body_consts = lanes[cn:cn + bn]
        carry = lanes[cn + bn:]
        self.walk(cond, list(cond_consts) + list(carry))
        carry_out = self.walk(body, list(body_consts) + list(carry))
        if list(carry_out) != list(carry):
            self.flag("while", f"carry lane layout changes across iterations "
                               f"({list(carry)} -> {list(carry_out)})")
        return list(carry_out)


def lint_batched_fn(name, fn, args, batched) -> List[LintFinding]:
    """Lint one batched kernel: ``batched`` maps argument positions to the
    lane axis they carry.  Returns the (possibly empty) finding list; a
    kernel whose laned outputs lose track of the lane is also a finding."""
    closed = jax.make_jaxpr(fn)(*args)
    # map flattened invars back to argument positions
    lanes: List[Optional[int]] = []
    for i, a in enumerate(args):
        leaves = jax.tree_util.tree_leaves(a)
        lanes.extend([batched.get(i)] * len(leaves))
    w = _Walker(name)
    w.walk(closed.jaxpr, lanes)
    return w.findings


def lint_app(app) -> Dict[str, List[LintFinding]]:
    """Lint every declared batched kernel of one app."""
    out: Dict[str, List[LintFinding]] = {}
    for k in app.batched_kernels():
        out[k.name] = lint_batched_fn(
            f"{app.name}/{k.name}", k.fn, k.args, dict(k.batched)
        )
    return out
