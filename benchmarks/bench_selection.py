"""Paper Fig 4a/4b + Fig 5: what to persist, and where.

Fig 4a — persisting each MG data object alone (u / r / k) at loop end.
Fig 4b — persisting u at the end of each single code region R1..R4.
Fig 5  — three strategies: none / selected objects / all candidates.
"""
from __future__ import annotations

from .common import APPS, Timer, campaign_size, campaign_workers, emit


def run(fast: bool = True):
    from repro.core import CrashTester, PersistPlan
    from repro.core.selection import select_objects
    from repro.hpc.suite import bench_app, ci_app, default_cache

    n = campaign_size(fast)
    workers = campaign_workers()
    app = ci_app("mg") if fast else bench_app("mg")
    cache = default_cache(app)
    rows = []

    base = CrashTester(app, PersistPlan.none(), cache, seed=0).run_campaign(n, n_workers=workers)
    rows.append({"figure": "4a", "config": "none", "recomputability": round(base.recomputability, 3)})
    for obj in ("u", "r", "k"):
        camp = CrashTester(app, PersistPlan.at_loop_end((obj,), app), cache,
                           seed=0).run_campaign(n, n_workers=workers)
        rows.append({"figure": "4a", "config": f"persist_{obj}",
                     "recomputability": round(camp.recomputability, 3)})

    for k in range(len(app.regions())):
        plan = PersistPlan(objects=("u",), region_freq={k: 1})
        camp = CrashTester(app, plan, cache, seed=0).run_campaign(n, n_workers=workers)
        rows.append({"figure": "4b", "config": f"persist_u_at_{app.regions()[k].name}",
                     "recomputability": round(camp.recomputability, 3)})

    # Fig 5: three strategies across the suite
    for name in APPS:
        a = ci_app(name) if fast else bench_app(name)
        c = default_cache(a)
        b0 = CrashTester(a, PersistPlan.none(), c, seed=1).run_campaign(n, n_workers=workers)
        scores = select_objects(b0, [x for x in a.candidates if x != a.iterator_object])
        selected = tuple(s.name for s in scores if s.critical) or tuple(a.candidates[:1])
        c_sel = CrashTester(a, PersistPlan.best(selected, a), c,
                            seed=1).run_campaign(n, n_workers=workers)
        c_all = CrashTester(a, PersistPlan.best(tuple(a.candidates), a), c,
                            seed=1).run_campaign(n, n_workers=workers)
        rows.append({
            "figure": "5", "config": name,
            "recomputability": f"none={b0.recomputability:.2f}"
                               f" selected={c_sel.recomputability:.2f}"
                               f" all={c_all.recomputability:.2f}",
        })
    emit(rows, "selection")
    return rows


if __name__ == "__main__":
    run(fast=True)
