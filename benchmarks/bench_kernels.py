"""Kernel micro-bench: Pallas (interpret) vs jnp oracle, us/call + derived
GB/s.  Absolute numbers are CPU-interpret timings (the TARGET is TPU); the
oracle column is the meaningful CPU-comparable baseline.
"""
from __future__ import annotations

import time

import numpy as np

from .common import emit


def _t(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(fast: bool = True):
    import jax
    import jax.numpy as jnp

    from repro.kernels.delta_snapshot.ops import dirty_block_mask
    from repro.kernels.delta_snapshot.ref import dirty_block_mask_reference
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_reference
    from repro.kernels.rglru_scan.ops import rglru_scan
    from repro.kernels.rglru_scan.ref import rglru_reference
    from repro.kernels.rwkv6_scan.ops import rwkv6_scan
    from repro.kernels.rwkv6_scan.ref import rwkv6_reference

    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    b, s, h, d = (1, 256, 2, 64) if fast else (2, 1024, 4, 64)
    q, k, v = (jax.random.normal(kk, (b, s, h, d)) for kk in ks[:3])
    bytes_moved = 4 * q.size * 4
    t_kern = _t(lambda: jax.block_until_ready(flash_attention(q, k, v)))
    t_ref = _t(lambda: jax.block_until_ready(attention_reference(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2))))
    rows.append({"name": "flash_attention", "us_per_call": round(t_kern, 1),
                 "ref_us": round(t_ref, 1),
                 "derived": f"GB/s={bytes_moved/t_kern/1e3:.3f}"})

    t_len = 64 if fast else 256
    r = jax.random.normal(ks[3], (1, t_len, 2, 32)) * 0.5
    kk2 = jax.random.normal(ks[4], (1, t_len, 2, 32)) * 0.5
    vv = jax.random.normal(ks[5], (1, t_len, 2, 32)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[6], (1, t_len, 2, 32)))
    u = jax.random.normal(ks[7], (2, 32)) * 0.3
    t_kern = _t(lambda: jax.block_until_ready(rwkv6_scan(r, kk2, vv, w, u, block_t=32)))
    t_ref = _t(lambda: jax.block_until_ready(rwkv6_reference(
        jnp.swapaxes(r, 1, 2), jnp.swapaxes(kk2, 1, 2), jnp.swapaxes(vv, 1, 2),
        jnp.swapaxes(w, 1, 2), u)))
    rows.append({"name": "rwkv6_scan", "us_per_call": round(t_kern, 1),
                 "ref_us": round(t_ref, 1),
                 "derived": f"tok/s={1e6*t_len/t_kern:.0f}"})

    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 128, 128)))
    x = jax.random.normal(ks[1], (2, 128, 128))
    t_kern = _t(lambda: jax.block_until_ready(rglru_scan(a, x, block_t=64)))
    t_ref = _t(lambda: jax.block_until_ready(rglru_reference(a, x)))
    rows.append({"name": "rglru_scan", "us_per_call": round(t_kern, 1),
                 "ref_us": round(t_ref, 1),
                 "derived": f"GB/s={2*a.size*4/t_kern/1e3:.3f}"})

    n = 1 << 18
    xs = jax.random.normal(ks[2], (n,))
    ps = xs.at[1234].add(1.0)
    t_kern = _t(lambda: jax.block_until_ready(dirty_block_mask(xs, ps)))
    nb = n // 256
    t_ref = _t(lambda: jax.block_until_ready(dirty_block_mask_reference(
        xs.reshape(nb, 256), ps.reshape(nb, 256))))
    rows.append({"name": "delta_snapshot", "us_per_call": round(t_kern, 1),
                 "ref_us": round(t_ref, 1),
                 "derived": f"GB/s={2*n*4/t_kern/1e3:.3f}"})
    emit(rows, "kernels")
    return rows


if __name__ == "__main__":
    run(fast=True)
