"""Paper §7 played end-to-end: system efficiency under failure traces,
driven by campaign-*measured* recompute profiles.

Where ``bench_efficiency`` evaluates the closed-form model at an assumed
recomputability, this bench runs the pipeline the paper actually argues for:

  crash campaign  ->  RecomputeProfile (S1–S4 rates + recompute-cost
  histogram)  ->  discrete-event simulation of the four policies
  (none / checkpoint-only / EasyCrash-only / hybrid)  ->  efficiency curves
  vs checkpoint cost (Fig 10 shape) and vs node count (Fig 11 shape),
  with the analytic closed forms printed alongside as a cross-check.

``T_chk`` itself is measured, not assumed: the app state's checkpoint write
is timed through :func:`repro.checkpoint.measure_checkpoint_cost` and
extrapolated to a deployment-scale checkpoint at the measured throughput
(the ``measured-t_chk`` rows).

CLI:
  python -m benchmarks.bench_sysim            # fast curves (CI-sized)
  python -m benchmarks.bench_sysim --full     # paper-sized campaigns
  python -m benchmarks.bench_sysim --smoke    # tiny trace, all 4 policies
  python -m benchmarks.bench_sysim --frontier # interval-sweep frontier JSON
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Tuple

from .common import RESULTS_DIR, campaign_size, campaign_workers, emit

FRONTIER_PATH = os.path.join(RESULTS_DIR, "sysim_frontier.json")

#: apps whose campaigns feed the curves (spectrum: grid smoother + graph)
FAST_APPS = ("sor", "pagerank")
SEED = 2024
BASE_MTBF = 12 * 3600.0
BASE_NODES = 100_000
#: the write the local tier's bandwidth is measured on (large enough that
#: per-file fsync overhead stops dominating, small enough for CI)
MEASURE_BYTES = 64 << 20
#: deployment-scale per-node checkpoint share the measured bandwidth is
#: extrapolated to (the paper's hundreds-of-seconds T_chk class)
TARGET_CHECKPOINT_BYTES = 64 << 30


#: one campaign per (app, fast) per process: ``benchmarks.run`` executes both
#: ``run`` and ``frontier``, which would otherwise re-measure identical
#: profiles — the most expensive step of the bench
_PROFILE_CACHE: Dict[Tuple[str, bool, int | None], tuple] = {}


def measured_profile(name: str, fast: bool = True, n_tests: int | None = None):
    """Run a crash campaign for ``name`` and distill its RecomputeProfile.

    The plan flushes every candidate at main-loop end (paper Fig 2a's
    canonical placement) — a cheap, representative EasyCrash deployment;
    ``--full`` replaces it with the workflow's knapsack plan.
    """
    from repro.core import CrashTester, PersistPlan, RecomputeProfile
    from repro.core.workflow import WorkflowConfig, run_workflow
    from repro.hpc.suite import bench_app, ci_app, default_cache

    key = (name, fast, n_tests)
    if key in _PROFILE_CACHE:
        return _PROFILE_CACHE[key]
    app = ci_app(name) if fast else bench_app(name)
    cache = default_cache(app)
    if fast:
        plan = PersistPlan.at_loop_end(app.candidates, app)
    else:
        wf = run_workflow(app, WorkflowConfig(
            n_tests=campaign_size(fast), cache=cache, seed=SEED,
            region_measure="paper", n_workers=campaign_workers(),
        ))
        plan = wf.plan
    camp = CrashTester(app, plan, cache, seed=SEED).run_campaign(
        n_tests or campaign_size(fast), n_workers=campaign_workers()
    )
    _PROFILE_CACHE[key] = (app, RecomputeProfile.from_campaign(camp))
    return _PROFILE_CACHE[key]


def measured_cfg():
    """A :class:`SystemConfig` whose ``T_chk`` is *measured*: this machine's
    local-tier write bandwidth on a 64 MiB shard, extrapolated to a 64 GiB
    per-node checkpoint share."""
    import numpy as np

    from repro.checkpoint import measured_system_config

    tree = {"shard": np.zeros(MEASURE_BYTES // 4, np.float32)}
    return measured_system_config(tree, mtbf=BASE_MTBF,
                                  target_bytes=TARGET_CHECKPOINT_BYTES)


def _policy_row(system, trace, profile, n_failures: int, t_s: float) -> Dict[str, float]:
    from repro.core import simulate_policy

    out = {}
    for policy in ("none", "checkpoint", "easycrash", "hybrid"):
        r = simulate_policy(policy, system, trace, profile,
                            n_failures=n_failures, t_s=t_s, seed=SEED)
        out[f"eff_{policy}"] = round(r.efficiency, 4)
    out["hybrid_gain_pct"] = round(
        100 * (out["eff_hybrid"] - out["eff_checkpoint"]), 2
    )
    return out


def run(fast: bool = True):
    """Efficiency-vs-T_chk and efficiency-vs-node-count curves."""
    from repro.core import (
        PoissonTrace,
        SystemConfig,
        efficiency_with,
        efficiency_without,
        scaled_trace,
    )
    from repro.hpc.suite import FAULT_SWEEP_APPS

    apps = FAST_APPS if fast else FAULT_SWEEP_APPS
    n_failures = 3_000 if fast else 20_000
    t_s = 0.015
    meas_cfg = measured_cfg()  # one measurement: T_chk is a machine property
    print(f"[measured] local-tier write => T_chk={meas_cfg.t_chk:.0f}s for a "
          f"{TARGET_CHECKPOINT_BYTES >> 30} GiB per-node share")
    rows: List[Dict[str, object]] = []
    for name in apps:
        app, prof = measured_profile(name, fast)
        meta = {
            "app": name,
            "success_rate": round(prof.success_rate, 4),
            "recomputability": round(prof.recomputability, 4),
        }
        # Fig 10 shape: vary checkpoint cost at fixed machine scale
        for t_chk in (32.0, 320.0, 3200.0):
            cfg = SystemConfig(mtbf=BASE_MTBF, t_chk=t_chk)
            trace = PoissonTrace(cfg.mtbf)
            row = dict(meta, figure="eff-vs-tchk", config=f"t_chk={int(t_chk)}s")
            row.update(_policy_row(cfg, trace, prof, n_failures, t_s))
            row["eff_cr_analytic"] = round(efficiency_without(cfg).efficiency, 4)
            row["eff_ec_analytic"] = round(
                efficiency_with(cfg, prof.recomputability, t_s=t_s).efficiency, 4
            )
            rows.append(row)
        # Fig 11 shape: vary machine scale at the harshest checkpoint cost
        for nodes in (100_000, 200_000, 400_000):
            trace = scaled_trace(PoissonTrace(BASE_MTBF), BASE_NODES, nodes)
            cfg = SystemConfig(mtbf=trace.mtbf, t_chk=3200.0)
            row = dict(meta, figure="eff-vs-nodes", config=f"nodes={nodes}")
            row.update(_policy_row(cfg, trace, prof, n_failures, t_s))
            row["eff_cr_analytic"] = round(efficiency_without(cfg).efficiency, 4)
            row["eff_ec_analytic"] = round(
                efficiency_with(cfg, prof.recomputability, t_s=t_s).efficiency, 4
            )
            rows.append(row)
        # measured T_chk: this machine's write bandwidth, at deployment scale
        trace = PoissonTrace(meas_cfg.mtbf)
        row = dict(meta, figure="measured-tchk",
                   config=f"t_chk={meas_cfg.t_chk:.0f}s(measured)")
        row.update(_policy_row(meas_cfg, trace, prof, n_failures, t_s))
        row["eff_cr_analytic"] = round(efficiency_without(meas_cfg).efficiency, 4)
        row["eff_ec_analytic"] = round(
            efficiency_with(meas_cfg, prof.recomputability, t_s=t_s).efficiency, 4
        )
        rows.append(row)

    gains = [r["hybrid_gain_pct"] for r in rows if r["figure"] == "eff-vs-tchk"]
    print(f"[headline] hybrid-vs-checkpoint gains (eff-vs-tchk rows): "
          f"{min(gains):.1f}..{max(gains):.1f} pts "
          f"(paper: up to 24, 15 on average)")
    emit(rows, "sysim")
    return rows


def frontier(fast: bool = True):
    """Interval-sweep efficiency frontier per app, as one JSON artifact
    (uploaded by the scheduled golden-campaigns CI job next to the
    robustness matrix)."""
    from repro.core import PoissonTrace, SystemConfig, efficiency_frontier

    apps = FAST_APPS
    n_failures = 2_000 if fast else 10_000
    cfg = SystemConfig(mtbf=BASE_MTBF, t_chk=320.0)
    doc: Dict[str, object] = {"apps": {}}
    for name in apps:
        _, prof = measured_profile(name, fast=fast)
        doc["apps"][name] = efficiency_frontier(
            cfg, PoissonTrace(cfg.mtbf), prof,
            n_failures=n_failures, t_s=0.015, seed=SEED,
        )
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(FRONTIER_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    for name, d in doc["apps"].items():
        pol = d["policies"]
        print(f"[frontier] {name}: "
              f"ckpt best {pol['checkpoint']['best']['efficiency']:.4f} "
              f"@ {pol['checkpoint']['best']['interval']:.0f}s, "
              f"hybrid best {pol['hybrid']['best']['efficiency']:.4f} "
              f"@ {pol['hybrid']['best']['interval']:.0f}s")
    print(f"[frontier] -> {FRONTIER_PATH}")
    return doc


def smoke() -> None:
    """Tiny-trace smoke for the CI fast gate: all four policies on both
    trace kinds, seeded, with sanity asserted (no campaign needed)."""
    from repro.core import (
        POLICIES,
        PoissonTrace,
        RecomputeProfile,
        SystemConfig,
        WeibullTrace,
        simulate_policy,
    )
    from repro.core.sysim import MONTH

    cfg = SystemConfig(mtbf=6 * 3600.0, t_chk=300.0)
    prof = RecomputeProfile.from_fractions(
        "smoke", {"S1": 0.7, "S2": 0.2, "S3": 0.05, "S4": 0.05},
        extra_iters_hist=((2, 3), (8, 1)),
    )
    for trace in (PoissonTrace(cfg.mtbf), WeibullTrace(cfg.mtbf, shape=0.7)):
        for policy in POLICIES:
            r = simulate_policy(policy, cfg, trace, prof, n_failures=200,
                                horizon=MONTH * 3, t_s=0.02, seed=1)
            again = simulate_policy(policy, cfg, trace, prof, n_failures=200,
                                    horizon=MONTH * 3, t_s=0.02, seed=1)
            assert 0.0 <= r.efficiency <= 1.0, (policy, r)
            assert r == again, f"{policy}: same seed must reproduce bit-for-bit"
            print(f"[smoke] {trace.spec()['trace']:8s} {policy:10s} "
                  f"eff={r.efficiency:.4f} failures={r.n_failures} "
                  f"ckpts={r.n_checkpoints} nvm={r.n_nvm_recoveries} "
                  f"fallbacks={r.n_fallbacks}")
    print("[smoke] ok")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny synthetic trace, all four policies (CI gate)")
    ap.add_argument("--frontier", action="store_true",
                    help=f"write the interval-sweep frontier to {FRONTIER_PATH}")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    if args.frontier:
        frontier(fast=not args.full)
        return
    run(fast=not args.full)


if __name__ == "__main__":
    main()
