"""Shared benchmark plumbing: app instances, campaign settings, CSV output."""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")

APPS = ("cg", "mg", "kmeans", "montecarlo", "heat", "sor", "pagerank")


def campaign_size(fast: bool) -> int:
    return 60 if fast else 300


def campaign_workers(default: int = 1) -> int:
    """Worker count for campaign fan-out (REPRO_WORKERS=N, or N=0 for all
    cores).  Campaign results are identical for every worker count."""
    raw = os.environ.get("REPRO_WORKERS", "")
    try:
        n = int(raw) if raw else default
    except ValueError:
        return default
    return os.cpu_count() or 1 if n <= 0 else n


def emit(rows: List[Dict[str, object]], name: str) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    if not rows:
        return
    keys = list(rows[0].keys())
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    with open(path, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(str(r.get(k, "")) for k in keys) + "\n")
    print(f"[{name}] {len(rows)} rows -> {path}")
    for r in rows:
        print("  " + ", ".join(f"{k}={r[k]}" for k in keys))


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
