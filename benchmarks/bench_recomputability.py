"""Paper Fig 3 + Fig 6: application recomputability across the suite.

Per app: S1–S4 class fractions without EasyCrash (Fig 3), then the staged
improvements (Fig 6): + critical-object selection at loop end, + selected
code regions (the full workflow plan), and the costly best-achievable
upper bound.  Also reports the headline "fraction of failed crashes
transformed into correct recomputation".

``--fault-sweep`` runs the scenario-diversity extension instead: every
registered fault model (:mod:`repro.core.faults`) against each app of
``FAULT_SWEEP_APPS``, emitting per-model S1–S4 breakdowns with and without
loop-end persistence — how far does the paper's headline claim survive once
"a crash" stops meaning one clean power failure?

``--robustness-matrix`` asks the deployment-time question the fault sweep
cannot: a persist plan is *characterized* under one failure flavor (a full
§5.3 workflow) and then *deployed* under every other.  For each app the
workflow runs once per fault model, each resulting plan is saved as a
fingerprinted artifact (:mod:`repro.core.artifacts`), and every
(characterized-under, deployed-under) pair is replayed — a 5x5 S1–S4
matrix per app.  Plans characterized under the clean power-fail model
meeting torn writes in production is exactly the scenario algorithm-directed
crash-consistency work worries about.
"""
from __future__ import annotations

import os

from .common import APPS, RESULTS_DIR, Timer, campaign_size, campaign_workers, emit


def run(fast: bool = True):
    from repro.core import CrashTester, PersistPlan
    from repro.core.workflow import WorkflowConfig, run_workflow
    from repro.hpc.suite import bench_app, ci_app, default_cache

    n = campaign_size(fast)
    workers = campaign_workers()
    rows = []
    agg_base_fail = 0.0
    agg_fixed = 0.0
    # the HPC suite plus the ML workload the paper's §2.2 calls out
    # (SGD/CNN training): reduced-transformer Adam training, selected from
    # the same app registry and run through the same workflow
    for name in APPS + ("lm-train",):
        n_app = n if name in APPS else max(24, n // 2)
        with Timer() as t:
            app = ci_app(name) if fast else bench_app(name)
            cache = default_cache(app)
            wf = run_workflow(app, WorkflowConfig(
                n_tests=n_app, cache=cache, seed=0, n_workers=workers))
            validated = CrashTester(app, wf.plan, cache, seed=777).run_campaign(
                n_app, n_workers=workers
            )
            best = wf.best_campaign
        base_fr = wf.baseline_campaign.class_fractions()
        val_fr = validated.class_fractions()
        base_fail = 1.0 - base_fr["S1"]
        transformed = max(0.0, val_fr["S1"] - base_fr["S1"])
        agg_base_fail += base_fail
        agg_fixed += transformed
        rows.append({
            "app": name,
            "S1_base": round(base_fr["S1"], 3),
            "S2_base": round(base_fr["S2"], 3),
            "S3_base": round(base_fr["S3"], 3),
            "S4_base": round(base_fr["S4"], 3),
            "recomp_objects_only": round(
                CrashTester(app, PersistPlan.at_loop_end(wf.critical, app), cache,
                            seed=5).run_campaign(n_app, n_workers=workers).recomputability, 3),
            "recomp_easycrash": round(val_fr["S1"], 3),
            "recomp_best": round(best.recomputability, 3),
            "critical_objects": "|".join(wf.critical),
            "plan_regions": "|".join(f"{k}:{x}" for k, x in sorted(wf.plan.region_freq.items())),
            "seconds": round(t.dt, 1),
        })
    if agg_base_fail > 0:
        print(f"[headline] EasyCrash transforms {100 * agg_fixed / agg_base_fail:.0f}% "
              f"of failed crashes into correct recomputation "
              f"(paper: 54%)")
    emit(rows, "recomputability")
    return rows


def fault_sweep(fast: bool = True):
    """Per-fault-model S1–S4 breakdowns across the fault-sweep apps."""
    from repro.core import CrashTester, PersistPlan
    from repro.core.faults import FAULT_MODELS, get_fault_model
    from repro.hpc.suite import FAULT_SWEEP_APPS, bench_app, ci_app, default_cache

    n = max(24, campaign_size(fast) // 2)
    workers = campaign_workers()
    rows = []
    for name in FAULT_SWEEP_APPS:
        app = ci_app(name) if fast else bench_app(name)
        cache = default_cache(app)
        persist = [c for c in app.candidates if c != app.iterator_object]
        for model_name in sorted(FAULT_MODELS):
            fault = get_fault_model(model_name, app=app)
            with Timer() as t:
                base = CrashTester(
                    app, PersistPlan.none(), cache, seed=0, fault=fault
                ).run_campaign(n, n_workers=workers)
                ec = CrashTester(
                    app, PersistPlan.at_loop_end(persist, app), cache, seed=0,
                    fault=fault,
                ).run_campaign(n, n_workers=workers)
            fr = base.class_fractions()
            rows.append({
                "app": name,
                "fault_model": model_name,
                "S1": round(fr["S1"], 3),
                "S2": round(fr["S2"], 3),
                "S3": round(fr["S3"], 3),
                "S4": round(fr["S4"], 3),
                "recomp_easycrash": round(ec.recomputability, 3),
                "seconds": round(t.dt, 1),
            })
    emit(rows, "fault_sweep")
    return rows


def robustness_matrix(fast: bool = True):
    """Cross-fault plan robustness: characterize under model A, deploy under
    model B, for every (A, B) pair — the portable-plan-artifact experiment.

    Characterization uses ``region_measure="paper"`` (two campaigns per
    workflow) so the matrix stays tractable: 5 workflows + 25 replays per
    app.  Plans are written to ``results/plans/`` and replayed *through the
    artifact layer* — the matrix doubles as an end-to-end test of
    save/load/replay.
    """
    from repro.core.faults import all_fault_models
    from repro.core.artifacts import load_plan, replay_plan, save_plan
    from repro.core.workflow import WorkflowConfig, run_workflow
    from repro.hpc.suite import FAULT_SWEEP_APPS, bench_app, ci_app, default_cache

    n = max(16, campaign_size(fast) // 3)
    workers = campaign_workers()
    app_names = ("kmeans", "sor") if fast else FAULT_SWEEP_APPS
    plans_dir = os.path.join(RESULTS_DIR, "plans")
    rows = []
    for name in app_names:
        app = ci_app(name) if fast else bench_app(name)
        cache = default_cache(app)
        models = all_fault_models(app)
        paths = {}
        for a_name, fault_a in models.items():
            wf = run_workflow(app, WorkflowConfig(
                n_tests=n, cache=cache, seed=0, region_measure="paper",
                n_workers=workers, fault_model=fault_a,
            ))
            p = os.path.join(plans_dir, f"{name}_{a_name}.json")
            save_plan(p, wf.plan, app_name=app.name, fault=fault_a,
                      cache=cache,
                      meta={"tau": wf.tau,
                            "expected_recomputability":
                                wf.region_selection.expected_recomputability})
            paths[a_name] = p
        for a_name in models:
            art = load_plan(paths[a_name])
            for b_name, fault_b in models.items():
                with Timer() as t:
                    camp = replay_plan(art, app, cache=cache, n_tests=n,
                                       seed=777, fault=fault_b,
                                       n_workers=workers)
                fr = camp.class_fractions()
                rows.append({
                    "app": name,
                    "characterized_under": a_name,
                    "deployed_under": b_name,
                    "S1": round(fr["S1"], 3),
                    "S2": round(fr["S2"], 3),
                    "S3": round(fr["S3"], 3),
                    "S4": round(fr["S4"], 3),
                    "plan": "|".join(
                        f"{k}:{x}" for k, x in sorted(art.plan.region_freq.items())
                    ),
                    "seconds": round(t.dt, 1),
                })
    emit(rows, "robustness_matrix")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fault-sweep", action="store_true",
                    help="per-fault-model S1-S4 breakdowns instead of Fig 3/6")
    ap.add_argument("--robustness-matrix", action="store_true",
                    help="characterize a plan under each fault model, replay "
                         "it under every other (S1-S4 matrix via artifacts)")
    ap.add_argument("--full", action="store_true",
                    help="paper-sized campaigns (default: fast CI sizes)")
    args = ap.parse_args()
    if args.robustness_matrix:
        robustness_matrix(fast=not args.full)
    else:
        (fault_sweep if args.fault_sweep else run)(fast=not args.full)
