"""Benchmark harness: one module per paper table/figure.

  bench_recomputability — Fig 3 + Fig 6 (fault-model sweep, robustness matrix)
  bench_selection       — Fig 4a/4b + Fig 5
  bench_persist_overhead— Table 4
  bench_nvm_writes      — Fig 9
  bench_efficiency      — Fig 10 + Fig 11 (closed-form model)
  bench_sysim           — Fig 10/11 shapes from the failure-trace simulator,
                          driven by campaign-measured recompute profiles
  bench_kernels         — Pallas kernels vs oracles (us/call CSV)
  bench_workflow        — shared-pool orchestrator vs serial workflow engine
  bench_roofline        — §Roofline table from the dry-run artifacts

``python -m benchmarks.run [--full]`` — default is the fast (CI-sized)
configuration; --full uses the paper-sized campaigns.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    fast = not args.full

    from . import (
        bench_efficiency,
        bench_kernels,
        bench_nvm_writes,
        bench_persist_overhead,
        bench_recomputability,
        bench_roofline,
        bench_selection,
        bench_sysim,
        bench_workflow,
    )

    benches = [
        ("recomputability", bench_recomputability.run),
        ("fault_sweep", bench_recomputability.fault_sweep),
        ("robustness_matrix", bench_recomputability.robustness_matrix),
        ("workflow_orchestrator", bench_workflow.run),
        ("selection", bench_selection.run),
        ("persist_overhead", bench_persist_overhead.run),
        ("nvm_writes", bench_nvm_writes.run),
        ("efficiency", bench_efficiency.run),
        ("sysim", bench_sysim.run),
        ("sysim_frontier", bench_sysim.frontier),
        ("kernels", bench_kernels.run),
        ("roofline", bench_roofline.run),
    ]
    failed = []
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            fn(fast=fast)
            print(f"[{name}] done in {time.time()-t0:.0f}s")
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"\nFAILED benches: {failed}")
        sys.exit(1)
    print("\nall benches complete")


if __name__ == "__main__":
    main()
