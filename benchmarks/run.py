"""Benchmark harness: one module per paper table/figure.

  bench_campaign_hotpath— ref-vs-vec campaign engine tests/sec + speedup
                          (writes the repo-root BENCH_campaign.json)
  bench_model_campaign  — model-stack campaigns (lm-train, decode) + delta
                          persist traffic (writes the repo-root BENCH_model.json)
  bench_recomputability — Fig 3 + Fig 6 (fault-model sweep, robustness matrix)
  bench_selection       — Fig 4a/4b + Fig 5
  bench_static_plan     — static analyzer vs measured plans: agreement table
                          + static+verify tests-saved on sor
  bench_adaptive        — adaptive scheduler vs brute force: tests-saved per
                          app + plan-equivalence bars (BENCH_adaptive.json)
  bench_persist_overhead— Table 4
  bench_nvm_writes      — Fig 9
  bench_efficiency      — Fig 10 + Fig 11 (closed-form model)
  bench_sysim           — Fig 10/11 shapes from the failure-trace simulator,
                          driven by campaign-measured recompute profiles
  bench_fleetsim        — replica fleet serving under failures: goodput/SLO/
                          tail latency per policy (repo-root BENCH_fleet.json)
  bench_kernels         — Pallas kernels vs oracles (us/call CSV)
  bench_workflow        — shared-pool orchestrator vs serial workflow engine
  bench_roofline        — §Roofline table from the dry-run artifacts

``python -m benchmarks.run [--full]`` — default is the fast (CI-sized)
configuration; --full uses the paper-sized campaigns.  ``--profile`` wraps
each selected benchmark in cProfile and drops the top-30 cumulative entries
next to its results, so perf work can point at measured hot spots instead
of guessed ones.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def _run_profiled(name: str, fn, fast: bool) -> None:
    import cProfile
    import pstats

    from .common import RESULTS_DIR

    pr = cProfile.Profile()
    pr.enable()
    try:
        fn(fast=fast)
    finally:
        pr.disable()
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, f"profile_{name}.txt")
        with open(path, "w") as f:
            stats = pstats.Stats(pr, stream=f)
            stats.sort_stats("cumulative").print_stats(30)
        print(f"[{name}] profile (top-30 cumulative) -> {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument(
        "--profile", action="store_true",
        help="cProfile each selected benchmark; top-30 cumulative entries "
             "are written to benchmarks/results/profile_<name>.txt",
    )
    args = ap.parse_args()
    fast = not args.full

    from . import (
        bench_adaptive,
        bench_campaign_hotpath,
        bench_efficiency,
        bench_fleetsim,
        bench_kernels,
        bench_model_campaign,
        bench_nvm_writes,
        bench_persist_overhead,
        bench_recomputability,
        bench_roofline,
        bench_selection,
        bench_static_plan,
        bench_sysim,
        bench_workflow,
    )

    benches = [
        ("campaign_hotpath", bench_campaign_hotpath.run),
        ("model_campaign", bench_model_campaign.run),
        ("recomputability", bench_recomputability.run),
        ("fault_sweep", bench_recomputability.fault_sweep),
        ("robustness_matrix", bench_recomputability.robustness_matrix),
        ("workflow_orchestrator", bench_workflow.run),
        ("static_plan", bench_static_plan.run),
        ("adaptive", bench_adaptive.run),
        ("selection", bench_selection.run),
        ("persist_overhead", bench_persist_overhead.run),
        ("nvm_writes", bench_nvm_writes.run),
        ("efficiency", bench_efficiency.run),
        ("sysim", bench_sysim.run),
        ("sysim_frontier", bench_sysim.frontier),
        ("fleetsim", bench_fleetsim.run),
        ("kernels", bench_kernels.run),
        ("roofline", bench_roofline.run),
    ]
    failed = []
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            if args.profile:
                _run_profiled(name, fn, fast)
            else:
                fn(fast=fast)
            print(f"[{name}] done in {time.time()-t0:.0f}s")
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"\nFAILED benches: {failed}")
        sys.exit(1)
    print("\nall benches complete")


if __name__ == "__main__":
    main()
