"""Model-stack campaign benchmark: LM training + decode under crashes.

The first BENCH_* series for a *model* workload (the HPC suite has
``BENCH_campaign.json``): for each of ``lm-train`` and ``decode`` —

* the full §5.3 workflow (S1–S4 rates, critical objects, knapsack plan) on
  the registry-built app;
* a validation campaign under the selected plan;
* measured persistence traffic: bytes written per flush in ``delta`` mode
  (the ``delta_snapshot`` kernel path) vs ``full`` whole-object rewrites,
  over a short production-style run of :class:`EasyCrashManager`;
* the derived flush overhead ``t_s`` (:func:`persist_overhead_fraction`)
  and the system-efficiency gain it buys at the default 12 h-MTBF system.

Outputs ``benchmarks/results/model_campaign.csv`` and the repo-root
``BENCH_model.json``.

``--smoke`` runs a seconds-scale lm-train campaign only (the fast CI gate's
model smoke): asserts the S1–S4 partition and plan validity, writes nothing.
"""
from __future__ import annotations

import json
import os

from .common import Timer, campaign_size, campaign_workers, emit

MODEL_APPS = ("lm-train", "decode")

BENCH_JSON = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_model.json")
)


def _persist_traffic(app, n_steps: int = 6):
    """Measured flush bytes per step in delta vs full mode, plus step time."""
    import numpy as np

    from repro.core.arena import NVMArena
    from repro.core.manager import EasyCrashManager, FlushPolicy

    out = {}
    for mode in ("delta", "full"):
        arena = NVMArena(block_bytes=64)
        mgr = EasyCrashManager(
            arena,
            FlushPolicy(leaves=tuple(app.candidates), async_flush=False,
                        persist_mode=mode),
        )
        s = app.init(0)
        dt = 0.0
        for step in range(1, n_steps + 1):
            with Timer() as t:
                s = app.run_iteration(s)
            dt += t.dt
            mgr.maybe_flush(step, {k: np.asarray(v) for k, v in s.items()})
        mgr.close()
        # steady state: skip the first flush (cold arena = full write)
        out[mode] = mgr.stats.bytes_written / n_steps
        out["step_time"] = dt / n_steps
    return out


def run(fast: bool = True) -> None:
    from repro.core import CrashTester, efficiency_with, efficiency_without
    from repro.core.efficiency import SystemConfig, persist_overhead_fraction
    from repro.core.workflow import WorkflowConfig, run_workflow
    from repro.hpc.suite import bench_app, ci_app, default_cache

    n = max(16, campaign_size(fast) // 3)
    workers = campaign_workers()
    system = SystemConfig(mtbf=12 * 3600.0, t_chk=320.0)
    rows = []
    for name in MODEL_APPS:
        with Timer() as t:
            app = ci_app(name) if fast else bench_app(name)
            cache = default_cache(app)
            wf = run_workflow(app, WorkflowConfig(
                n_tests=n, cache=cache, seed=0, n_workers=workers,
                system=system,
            ))
            validated = CrashTester(app, wf.plan, cache, seed=777).run_campaign(
                n, n_workers=workers
            )
        traffic = _persist_traffic(app)
        t_s_delta = persist_overhead_fraction(traffic["delta"], traffic["step_time"])
        base_fr = wf.baseline_campaign.class_fractions()
        eff0 = efficiency_without(system).efficiency
        eff1 = efficiency_with(
            system, validated.recomputability, t_s=t_s_delta
        ).efficiency
        rows.append({
            "app": name,
            "S1_base": round(base_fr["S1"], 3),
            "S2_base": round(base_fr["S2"], 3),
            "S3_base": round(base_fr["S3"], 3),
            "S4_base": round(base_fr["S4"], 3),
            "recomp_easycrash": round(validated.recomputability, 3),
            "critical_objects": "|".join(wf.critical),
            "bytes_per_flush_full": int(traffic["full"]),
            "bytes_per_flush_delta": int(traffic["delta"]),
            "delta_ratio": round(traffic["delta"] / max(traffic["full"], 1), 3),
            "t_s_delta": round(t_s_delta, 6),
            "efficiency_gain_pts": round(100 * (eff1 - eff0), 2),
            "seconds": round(t.dt, 1),
        })
    emit(rows, "model_campaign")

    payload = {
        "config": {"fast": bool(fast), "n_tests": n, "seed": 0,
                   "system": {"mtbf": system.mtbf, "t_chk": system.t_chk}},
        "results": [
            {k: r[k] for k in ("app", "recomp_easycrash", "delta_ratio",
                               "t_s_delta", "efficiency_gain_pts")}
            for r in rows
        ],
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[model_campaign] wrote {BENCH_JSON}")


def smoke() -> None:
    """Seconds-scale lm-train campaign for the fast CI gate."""
    from repro.core import CrashTester, PersistPlan
    from repro.hpc.suite import default_cache, get_app

    app = get_app("lm-train", n_iters=6, batch=2, seq=8, width=32)
    cache = default_cache(app)
    camp = CrashTester(app, PersistPlan.none(), cache, seed=0).run_campaign(6)
    fr = camp.class_fractions()
    assert abs(sum(fr.values()) - 1.0) < 1e-9, fr
    assert len(camp.records) == 6
    ec = CrashTester(
        app, PersistPlan.at_loop_end(("params",), app), cache, seed=0
    ).run_campaign(6)
    assert ec.recomputability >= camp.recomputability
    print(f"[smoke] lm-train campaign ok: base {fr} -> "
          f"persist-params R={ec.recomputability:.2f}")


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        smoke()
    else:
        run(fast="--full" not in sys.argv)
