"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads benchmarks/results/dryrun/*.json and emits, per (arch x shape x mesh):
compute/memory/collective seconds, the dominant term, model-vs-HLO FLOP
ratio, per-device HBM bytes, and the roofline fraction
(dominant-term lower bound: useful_time / dominant_term).
"""
from __future__ import annotations

import glob
import json
import os

from .common import DRYRUN_DIR, emit

PEAK = 197e12


def run(fast: bool = True):
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        d = json.load(open(path))
        if d.get("status") == "skipped":
            rows.append({
                "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
                "status": "skipped", "dominant": "-", "compute_s": "-",
                "memory_s": "-", "collective_s": "-", "useful_ratio": "-",
                "roofline_fraction": "-", "hbm_gb": "-", "note": d["reason"][:60],
            })
            continue
        if d.get("status") != "ok":
            rows.append({
                "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
                "status": "error", "dominant": "-", "compute_s": "-",
                "memory_s": "-", "collective_s": "-", "useful_ratio": "-",
                "roofline_fraction": "-", "hbm_gb": "-",
                "note": d.get("error", "")[:60],
            })
            continue
        r = d["roofline"]
        model_time = r["model_flops"] / d["n_devices"] / PEAK
        dominant_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = model_time / dominant_s if dominant_s else 0.0
        hbm = d.get("bytes_per_device") or 0
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "status": "ok",
            "dominant": r["dominant"],
            "compute_s": f"{r['compute_s']:.3e}",
            "memory_s": f"{r['memory_s']:.3e}",
            "collective_s": f"{r['collective_s']:.3e}",
            "useful_ratio": round(r["useful_ratio"], 3),
            "roofline_fraction": round(frac, 4),
            "hbm_gb": round(hbm / 1e9, 2),
            "note": "fits" if d.get("fits_16gb_hbm") else "OVER-HBM",
        })
    emit(rows, "roofline")
    return rows


if __name__ == "__main__":
    run()
