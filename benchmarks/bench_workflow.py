"""Workflow orchestrator wall-clock: one shared shard pool vs the historical
per-campaign engine.

The §5.3 workflow in ``"isolated"`` region mode runs W+2 campaigns.  The
historical scheduler runs them back-to-back, each spinning up (and tearing
down) its own process pool and each ending in a straggler barrier; the
orchestrator flattens every independent campaign into one (campaign, shard)
task batch on a single pool.  Same inputs, bit-for-bit identical results —
this benchmark measures the wall-clock difference and verifies the parity
claim on the way.

Workers default to ``REPRO_WORKERS`` (see ``benchmarks/common.py``) or 4.
"""
from __future__ import annotations

import dataclasses
import os

from .common import Timer, campaign_size, campaign_workers, emit


def _records(wf):
    return [
        [dataclasses.asdict(r) for r in camp.records]
        for camp in (wf.baseline_campaign, wf.best_campaign)
    ]


def run(fast: bool = True):
    from repro.core.workflow import WorkflowConfig, run_workflow
    from repro.hpc.suite import bench_app, ci_app, default_cache

    n = max(24, campaign_size(fast) // 2)
    workers = campaign_workers(default=min(4, os.cpu_count() or 1))
    apps = ("sor", "kmeans") if fast else ("sor", "kmeans", "mg", "pagerank")
    rows = []
    for name in apps:
        app = ci_app(name) if fast else bench_app(name)
        cache = default_cache(app)
        cfg = WorkflowConfig(n_tests=n, cache=cache, seed=0,
                             region_measure="isolated", n_workers=workers)
        with Timer() as t_serial:
            serial = run_workflow(app, cfg.replace(scheduler="serial"))
        with Timer() as t_shared:
            shared = run_workflow(app, cfg.replace(scheduler="shared"))
        parity = (
            _records(serial) == _records(shared)
            and serial.summary() == shared.summary()
            and serial.plan == shared.plan
        )
        rows.append({
            "app": name,
            "workers": workers,
            "n_tests": n,
            "serial_s": round(t_serial.dt, 2),
            "shared_s": round(t_shared.dt, 2),
            "speedup": round(t_serial.dt / max(t_shared.dt, 1e-9), 2),
            "bitwise_parity": parity,
        })
        if not parity:
            raise AssertionError(
                f"{name}: orchestrated workflow diverged from the serial path"
            )
    emit(rows, "workflow_orchestrator")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-sized campaigns (default: fast CI sizes)")
    args = ap.parse_args()
    run(fast=not args.full)
