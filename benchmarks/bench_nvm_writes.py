"""Paper Fig 9: NVM write traffic — EasyCrash vs traditional C/R.

Counted by the cache model in blocks, per iteration, normalized by the app's
natural write-back traffic (the paper's "total writes without EasyCrash and
C/R").  C/R variants copy every block of (critical | all candidate) objects;
EasyCrash flushes only dirty-resident blocks of critical objects at the plan's
regions.
"""
from __future__ import annotations

import numpy as np

from .common import APPS, campaign_size, emit


def run(fast: bool = True):
    from repro.core import CacheConfig, CrashTester, PersistPlan
    from repro.core.regions import object_blocks
    from repro.core.workflow import WorkflowConfig, run_workflow
    from repro.hpc.suite import bench_app, ci_app, default_cache

    n = campaign_size(fast) // 2
    rows = []
    for name in APPS:
        app = ci_app(name) if fast else bench_app(name)
        cache = default_cache(app)
        wf = run_workflow(app, WorkflowConfig(n_tests=n, cache=cache, seed=0))

        # baseline natural write-backs (no flushes at all)
        tester0 = CrashTester(app, PersistPlan.none(), cache, seed=3)
        tester0.run_campaign(4)
        base_stats = tester0.run_campaign(1).window_write_stats
        base = base_stats["eviction_writes_per_iter"]

        tester1 = CrashTester(app, wf.plan, cache, seed=3)
        ec_stats = tester1.run_campaign(4).window_write_stats
        ec_extra = ec_stats["flush_writes_per_iter"] + (
            ec_stats["eviction_writes_per_iter"] - base
        )

        state = app.init(0)
        crit_blocks = sum(object_blocks(state, [o for o in wf.critical if o in state], cache.block_bytes).values())
        all_blocks = sum(object_blocks(state, [o for o in app.candidates if o in state], cache.block_bytes).values())
        # per persistence operation: an EasyCrash flush writes only
        # dirty-resident blocks (bounded by the cache size — the paper's
        # Fig 9 insight); a checkpoint copies every block and re-dirties the
        # cache on the way (x2, after [Alshboul'18] as cited in §6)
        ops_per_iter = max(sum(1.0 / x for x in wf.plan.region_freq.values()), 1e-9)
        flush_op = ec_stats["flush_writes_per_iter"] / ops_per_iter
        chk_crit_op = 2.0 * crit_blocks
        chk_all_op = 2.0 * all_blocks
        rows.append({
            "app": name,
            "natural_writes_per_iter": round(base, 1),
            "flush_writes_per_op": round(flush_op, 1),
            "chk_critical_writes_per_op": chk_crit_op,
            "chk_all_writes_per_op": chk_all_op,
            "ec_vs_cr_reduction_pct": round(100 * (1 - flush_op / max(chk_crit_op, 1e-9)), 1),
            "easycrash_extra_per_iter": round(ec_extra / max(base, 1e-9), 3),
            "flushed_clean_per_iter": round(ec_stats["flushed_clean_per_iter"], 1),
        })
    red = float(np.mean([r["ec_vs_cr_reduction_pct"] for r in rows]))
    print(f"[headline] per persistence op, EasyCrash writes {red:.0f}% fewer NVM "
          f"blocks than a critical-object checkpoint copy "
          f"(paper: 44% avg reduction vs C/R)")
    emit(rows, "nvm_writes")
    return rows


if __name__ == "__main__":
    run(fast=True)
