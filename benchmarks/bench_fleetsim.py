"""Fleet serving under failures: goodput / SLO / tail latency per policy.

The ROADMAP's "millions of users" leg of the paper's efficiency claim: N
decode replicas serve a diurnal open-loop request trace while each replica
fails per its trace and recovers via the policy under test.  The EasyCrash
policies draw recovery outcomes from a crash-campaign-*measured*
:class:`~repro.core.sysim.RecomputeProfile` of the ``decode`` app (PR 6's
registry model app) and pay a *measured* delta-flush overhead
(``ManagerStats.bytes_written`` through
:func:`~repro.core.efficiency.persist_overhead_fraction`) against their
serving rate; checkpoint policies pause serving for ``t_chk`` at the
Young/stretched-Young interval and come back *cold* (every interrupted
session re-runs prefill), while NVM recoveries warm-start with their KV
caches intact.

Writes ``benchmarks/results/fleetsim.csv``, the policy-frontier JSON
``benchmarks/results/fleet_frontier.json``, and the repo-root
``BENCH_fleet.json``, asserting the acceptance claims in-bench: the hybrid
policy dominates checkpoint-only on goodput *and* p99 at paper-like failure
rates, and seeded runs are byte-identical across repeats.

CLI:
  python -m benchmarks.bench_fleetsim            # fast (CI-sized) fleet
  python -m benchmarks.bench_fleetsim --full     # paper-sized campaign + 6 h tape
  python -m benchmarks.bench_fleetsim --smoke    # synthetic profile, seconds-scale
"""
from __future__ import annotations

import json
import os
from typing import Dict, Tuple

from .common import RESULTS_DIR, campaign_size, campaign_workers, emit

BENCH_JSON = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_fleet.json")
)
FRONTIER_PATH = os.path.join(RESULTS_DIR, "fleet_frontier.json")

SEED = 2024
#: per-node MTBF 12 h (the paper's machine class); one serving replica spans
#: a 48-node shard group, so its failure trace is the node trace scaled down
PER_NODE_MTBF = 12 * 3600.0
NODES_PER_REPLICA = 48

_PROFILE_CACHE: Dict[bool, Tuple[object, object, float]] = {}


def decode_profile(fast: bool = True):
    """Campaign-measure the ``decode`` app: its RecomputeProfile (S1–S4 +
    extra-iteration histogram) and its delta-mode flush overhead ``t_s``
    (bytes written per step / NVM bandwidth / step time)."""
    import numpy as np

    from repro.core import CrashTester, PersistPlan, RecomputeProfile
    from repro.core.arena import NVMArena
    from repro.core.efficiency import persist_overhead_fraction
    from repro.core.manager import EasyCrashManager, FlushPolicy
    from repro.hpc.suite import bench_app, ci_app, default_cache

    if fast in _PROFILE_CACHE:
        return _PROFILE_CACHE[fast]
    app = ci_app("decode") if fast else bench_app("decode")
    plan = PersistPlan.at_loop_end(app.candidates, app)
    camp = CrashTester(app, plan, default_cache(app), seed=SEED).run_campaign(
        max(16, campaign_size(fast) // 3), n_workers=campaign_workers()
    )
    profile = RecomputeProfile.from_campaign(camp)

    # measured persist traffic: delta-mode bytes per decode step
    import time

    arena = NVMArena(block_bytes=64)
    mgr = EasyCrashManager(arena, FlushPolicy(
        leaves=tuple(app.candidates), async_flush=False, persist_mode="delta"))
    s = app.init(0)
    n_steps, dt = 6, 0.0
    for step in range(1, n_steps + 1):
        t0 = time.perf_counter()
        s = app.run_iteration(s)
        dt += time.perf_counter() - t0
        mgr.maybe_flush(step, {k: np.asarray(v) for k, v in s.items()})
    mgr.close()
    t_s = persist_overhead_fraction(
        mgr.stats.bytes_written / n_steps, max(dt / n_steps, 1e-6)
    )
    _PROFILE_CACHE[fast] = (app, profile, t_s)
    return _PROFILE_CACHE[fast]


def fleet_config(fast: bool, t_s: float):
    """The benchmark fleet: diurnal traffic at ~0.85 utilization, paper-like
    per-replica failure rates, serving-scale checkpoints."""
    from repro.core import (
        ArrivalProcess,
        FleetConfig,
        PoissonTrace,
        ServiceModel,
        SystemConfig,
        scaled_trace,
    )

    trace = scaled_trace(PoissonTrace(PER_NODE_MTBF), 1, NODES_PER_REPLICA)
    return FleetConfig(
        n_replicas=4,
        arrival=ArrivalProcess(rate=6.8, amplitude=0.3),
        service=ServiceModel(mean_s=0.5, sigma=0.6, prefill_s=1.5),
        trace=trace,
        system=SystemConfig(mtbf=trace.mtbf, t_chk=30.0, nvm_restore_time=2.0),
        slo_latency=2.0,
        queue_cap=48,
        horizon=(2 if fast else 6) * 3600.0,
        t_s=t_s,
        t_iter=0.05,
        seed=SEED,
    )


def run(fast: bool = True):
    from repro.core import POLICIES, fleet_frontier

    app, profile, t_s = decode_profile(fast)
    cfg = fleet_config(fast, t_s)
    print(f"[fleet] decode profile: S1-S4 {dict(profile.fractions)} "
          f"(n={profile.n_records}), measured t_s={t_s:.4f}")
    print(f"[fleet] {cfg.n_replicas} replicas, mtbf={cfg.trace.mtbf:.0f}s/replica, "
          f"rate={cfg.arrival.rate}rps, horizon={cfg.horizon/3600:.0f}h")

    doc = fleet_frontier(cfg, profile)
    rows = []
    for policy in POLICIES:
        p = doc["policies"][policy]
        rows.append({
            "policy": policy,
            "goodput": round(p["goodput"], 4),
            "offered": round(p["offered_rate"], 4),
            "loss_frac": round(p["dropped"] / max(p["arrived"], 1), 4),
            "slo_frac": round(p["slo_violation_frac"], 4),
            "p50_s": round(p["latency_p50"], 3),
            "p95_s": round(p["latency_p95"], 3),
            "p99_s": round(p["latency_p99"], 3),
            "availability": round(p["availability"], 4),
            "n_failures": p["n_failures"],
            "n_nvm": p["n_nvm_recoveries"],
            "n_fallbacks": p["n_fallbacks"],
        })
    emit(rows, "fleetsim")

    # acceptance: seeded determinism is byte-identical across repeats
    again = fleet_frontier(cfg, profile)
    assert json.dumps(doc, sort_keys=True) == json.dumps(again, sort_keys=True), \
        "fleet simulation must be byte-identical for the same seed"
    # acceptance: hybrid dominates checkpoint-only on goodput and p99
    hyb, chk = doc["policies"]["hybrid"], doc["policies"]["checkpoint"]
    assert hyb["goodput"] > chk["goodput"], (
        f"hybrid goodput {hyb['goodput']:.4f} <= checkpoint {chk['goodput']:.4f}")
    assert hyb["latency_p99"] < chk["latency_p99"], (
        f"hybrid p99 {hyb['latency_p99']:.2f}s >= checkpoint "
        f"{chk['latency_p99']:.2f}s")
    print(f"[fleet] hybrid vs checkpoint: goodput {hyb['goodput']:.3f} > "
          f"{chk['goodput']:.3f} rps, p99 {hyb['latency_p99']:.2f} < "
          f"{chk['latency_p99']:.2f} s")

    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(FRONTIER_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[fleet] frontier -> {FRONTIER_PATH}")
    payload = {
        "config": {"fast": bool(fast), "fingerprint": doc["fingerprint"],
                   "app": app.name, "t_s": round(t_s, 6),
                   "mtbf_per_replica": cfg.trace.mtbf,
                   "seed": SEED},
        "profile": doc["profile"],
        "results": rows,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[fleet] wrote {BENCH_JSON}")
    return rows


def smoke() -> None:
    """Seconds-scale synthetic-profile fleet for the CI fast gate: all four
    policies, conservation + determinism asserted, nothing written."""
    from repro.core import POLICIES, RecomputeProfile, simulate_fleet

    prof = RecomputeProfile.from_fractions(
        "smoke", {"S1": 0.7, "S2": 0.2, "S3": 0.05, "S4": 0.05},
        extra_iters_hist=((2, 3), (8, 1)),
    )
    cfg = fleet_config(fast=True, t_s=0.01).replace(horizon=900.0)
    for policy in POLICIES:
        p = prof if policy in ("easycrash", "hybrid") else None
        r = simulate_fleet(policy, cfg, p)
        again = simulate_fleet(policy, cfg, p)
        assert r == again, f"{policy}: same seed must reproduce bit-for-bit"
        assert r.arrived == r.served + r.dropped + r.in_flight, (policy, r)
        assert abs(sum(r.breakdown.values())
                   - cfg.n_replicas * cfg.horizon) < 1e-6, (policy, r.breakdown)
        print(f"[smoke] {policy:10s} goodput={r.goodput:.3f} "
              f"slo={r.slo_violation_frac:.3f} p99={r.latency_p99:.2f}s "
              f"fails={r.n_failures} nvm={r.n_nvm_recoveries}")
    print("[smoke] ok")


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="synthetic profile, seconds-scale fleet (CI gate)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    run(fast=not args.full)


if __name__ == "__main__":
    main()
