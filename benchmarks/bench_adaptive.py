"""Adaptive crash-campaign scheduler vs the brute-force W+2 workflow.

The tests-saved-per-app report: for every suite app, the brute-force
workflow total (golden in the default mode, re-measured with ``--full``)
against two adaptive runs —

* ``exact`` — uniform sampler (``sampler_bias=0``): draws bit-identical
  to brute force, so the final plan must match on EVERY app (asserted);
* ``default`` — the importance sampler at its default tilt: unbiased for
  the same rates but different finite-sample draws, so knife-edge
  knapsack decisions may resolve differently (>= 6/7 asserted).

Acceptance bars asserted here (not just reported): adaptive plan equals
brute force on >= 6/7 apps at the default config (7/7 exact), >= 40%
fewer executed crash tests on >= 3 apps, and byte-identical workflow
results at worker counts {1, 2, 4}.

``--smoke`` is the CI fast-gate subset: sor + pagerank only — early
stopping must fire and the plan must match the pinned brute-force plan.
The scheduled job runs the default mode and uploads
``BENCH_adaptive.json`` plus ``results/adaptive.csv``.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from .common import APPS, emit

BENCH_JSON = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_adaptive.json")
)
GOLDEN = os.path.join(
    os.path.dirname(__file__), "..", "tests", "golden", "static_agreement.json"
)

MIN_PLAN_MATCHES = 6      # of 7, default (IS) config; exact must be 7/7
MIN_SAVED_APPS = 3        # apps clearing MIN_SAVED_FRAC
MIN_SAVED_FRAC = 0.40
N_TESTS = 40              # the golden oracle size
WORKER_COUNTS = (1, 2, 4)


def _configs():
    from repro.core import SequentialConfig, WorkflowConfig

    def cfg(cache, **kw):
        return WorkflowConfig(n_tests=N_TESTS, seed=0, cache=cache,
                              plan_source="adaptive", **kw)

    return cfg, SequentialConfig


def _brute(name: str, fast: bool) -> Dict[str, object]:
    if fast:
        with open(GOLDEN) as f:
            g = json.load(f)[name]
        return {"tests": int(g["n_tests_total"]),
                "region_freq": dict(g["region_freq"]),
                "critical": tuple(g["critical"])}
    from repro.core import WorkflowConfig, run_workflow
    from repro.hpc.suite import ci_app, default_cache

    app = ci_app(name)
    wf = run_workflow(app, WorkflowConfig(
        n_tests=N_TESTS, seed=0, cache=default_cache(app)))
    return {"tests": wf.tests_executed,
            "region_freq": {str(k): v for k, v in wf.plan.region_freq.items()},
            "critical": wf.critical}


def adaptive_rows(apps, fast: bool) -> List[Dict[str, object]]:
    from repro.core import run_workflow
    from repro.hpc.suite import ci_app, default_cache

    cfg, SequentialConfig = _configs()
    rows: List[Dict[str, object]] = []
    for name in apps:
        brute = _brute(name, fast)
        app = ci_app(name)
        cache = default_cache(app)
        exact = run_workflow(app, cfg(
            cache, stopping=SequentialConfig(sampler_bias=0.0)))
        default = run_workflow(app, cfg(cache))
        for label, wf in (("exact", exact), ("default", default)):
            freq = {str(k): v for k, v in wf.plan.region_freq.items()}
            rows.append({
                "app": name,
                "sampler": label,
                "brute_tests": brute["tests"],
                "adaptive_tests": wf.tests_executed,
                "tests_saved_frac": round(
                    1 - wf.tests_executed / brute["tests"], 4),
                "plan_match": freq == brute["region_freq"]
                and wf.plan.objects == tuple(brute["critical"]),
                "stopped_early": wf.adaptive.stopped_early,
                "rounds": f"{wf.adaptive.rounds_executed}/"
                          f"{wf.adaptive.rounds_total}",
                "plan": "|".join(f"{k}:{v}" for k, v in sorted(freq.items())),
            })
    return rows


def worker_identity_rows() -> List[Dict[str, object]]:
    """kmeans, workers {1,2,4}: the workflow spec (every campaign record,
    the plan, the adaptive report) must be byte-identical."""
    from repro.core import run_workflow
    from repro.hpc.suite import ci_app, default_cache

    cfg, _ = _configs()
    app = ci_app("kmeans")
    cache = default_cache(app)
    specs = {}
    for w in WORKER_COUNTS:
        wf = run_workflow(app, cfg(cache, n_workers=w))
        specs[w] = json.dumps(wf.spec(), sort_keys=True)
    identical = len(set(specs.values())) == 1
    assert identical, "adaptive workflow diverged across worker counts"
    return [{
        "app": "kmeans",
        "workers": "|".join(map(str, WORKER_COUNTS)),
        "byte_identical": identical,
        "spec_bytes": len(specs[1]),
    }]


def run(fast: bool = True, smoke: bool = False) -> None:
    apps = ("sor", "pagerank") if smoke else APPS
    rows = adaptive_rows(apps, fast=fast or smoke)
    emit(rows, "adaptive")
    exact_rows = [r for r in rows if r["sampler"] == "exact"]
    if smoke:
        for r in exact_rows:
            if not r["stopped_early"]:
                raise SystemExit(
                    f"adaptive smoke: early stop never fired on {r['app']}")
            if not r["plan_match"]:
                raise SystemExit(
                    f"adaptive smoke: plan diverged from brute force on "
                    f"{r['app']}: {r['plan']}")
        print(f"[adaptive] smoke ok: early stop + plan match on {apps}")
        return

    n_exact = sum(bool(r["plan_match"]) for r in exact_rows)
    if n_exact != len(exact_rows):
        raise SystemExit(
            f"exact adaptive != brute force: {n_exact}/{len(exact_rows)}")
    default_rows = [r for r in rows if r["sampler"] == "default"]
    n_default = sum(bool(r["plan_match"]) for r in default_rows)
    if n_default < MIN_PLAN_MATCHES:
        raise SystemExit(
            f"default adaptive plan agreement regressed: "
            f"{n_default}/{len(default_rows)} (bar: {MIN_PLAN_MATCHES})")
    saved = [r["app"] for r in default_rows
             if r["tests_saved_frac"] >= MIN_SAVED_FRAC]
    if len(saved) < MIN_SAVED_APPS:
        raise SystemExit(
            f"adaptive saved >= {MIN_SAVED_FRAC:.0%} on only {saved} "
            f"(bar: {MIN_SAVED_APPS} apps)")
    workers = worker_identity_rows()
    emit(workers, "adaptive_workers")

    doc = {
        "n_tests": N_TESTS,
        "apps": rows,
        "workers": workers,
        "bars": {
            "exact_plan_matches": f"{n_exact}/{len(exact_rows)}",
            "default_plan_matches": f"{n_default}/{len(default_rows)}",
            "apps_saving_40pct": saved,
        },
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"[adaptive] wrote {BENCH_JSON}")
    print(f"[adaptive] exact {n_exact}/{len(exact_rows)} default "
          f"{n_default}/{len(default_rows)} >=40% saved on {saved}")


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="re-measure the brute-force workflows instead of "
                         "comparing against the pinned goldens")
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast gate: sor + pagerank, early stop + plan "
                         "match only")
    args = ap.parse_args()
    run(fast=not args.full, smoke=args.smoke)


if __name__ == "__main__":
    main()
