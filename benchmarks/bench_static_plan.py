"""Static persist-plan analyzer vs the measured W+2 workflow.

Two questions, one table each:

* **Agreement** — for every suite app, does the jaxpr dataflow analyzer
  (:func:`repro.analysis.analyze_app`) predict the same persist-region set
  the measured campaign workflow selects?  Fast mode compares against the
  pinned measured decisions (``tests/golden/static_agreement.json``, the
  n_tests=40 / seed=0 oracle); ``--full`` re-measures every app live and
  reports predicted-vs-measured from fresh campaigns.

* **Verify efficiency** — on sor, ``plan_source="static+verify"`` must land
  the *identical* final plan as the full measured workflow while executing
  >= 40% fewer crash tests (the acceptance bar: confident regions skip their
  isolated campaigns).  Asserted here, not just reported.

``--smoke`` is the CI fast-gate subset: agreement on sor + pagerank only,
no campaigns at all (~seconds).  The scheduled CI job runs the default mode
and uploads ``results/static_plan_agreement.csv`` as the
predicted-vs-measured report.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from .common import APPS, emit

GOLDEN = os.path.join(
    os.path.dirname(__file__), "..", "tests", "golden", "static_agreement.json"
)

#: acceptance bar: static+verify must save at least this fraction of the
#: measured workflow's crash tests on sor (while producing the same plan)
MIN_TESTS_SAVED = 0.40


def _measured_decisions(name: str, fast: bool) -> Dict[str, object]:
    """Measured persist-region decision set: golden (fast) or re-run (full)."""
    if fast:
        with open(GOLDEN) as f:
            g = json.load(f)[name]
        return {
            "persist_regions": set(g["persist_regions"]),
            "critical": tuple(g["critical"]),
            "n_tests": int(g["n_tests_total"]),
        }
    from repro.core.workflow import WorkflowConfig, run_workflow
    from repro.hpc.suite import ci_app, default_cache

    app = ci_app(name)
    wf = run_workflow(app, WorkflowConfig(
        n_tests=40, seed=0, cache=default_cache(app)))
    return {
        "persist_regions": set(wf.plan.region_freq),
        "critical": wf.critical,
        "n_tests": wf.tests_executed,
    }


def agreement_report(apps, fast: bool) -> List[Dict[str, object]]:
    from repro.analysis import analyze_app
    from repro.hpc.suite import ci_app, default_cache

    rows: List[Dict[str, object]] = []
    for name in apps:
        app = ci_app(name)
        sp = analyze_app(app, cache=default_cache(app))
        static_regions = {
            r.index for r in sp.regions if r.decision == "persist"
        }
        m = _measured_decisions(name, fast)
        agree = static_regions == m["persist_regions"]
        rows.append({
            "app": name,
            "static_regions": "|".join(map(str, sorted(static_regions))),
            "measured_regions": "|".join(map(str, sorted(m["persist_regions"]))),
            "agree": agree,
            "static_critical": "|".join(sp.persist_objects()),
            "measured_critical": "|".join(m["critical"]),
            "uncertain_regions": "|".join(map(str, sp.uncertain_regions())),
            "static_write_mib_per_iter": round(
                sp.write_traffic_bytes() / 2**20, 4),
            "measured_n_tests": m["n_tests"],
        })
    return rows


def verify_efficiency_rows() -> List[Dict[str, object]]:
    """sor: measured W+2 vs static+verify — identical plan, fewer tests."""
    from repro.core.workflow import WorkflowConfig, run_workflow
    from repro.hpc.suite import ci_app, default_cache

    app = ci_app("sor")
    cache = default_cache(app)
    measured = run_workflow(app, WorkflowConfig(
        n_tests=40, seed=0, cache=cache))
    app2 = ci_app("sor")
    verified = run_workflow(app2, WorkflowConfig(
        n_tests=40, seed=0, cache=cache, plan_source="static+verify"))

    same_plan = (
        measured.plan.objects == verified.plan.objects
        and dict(measured.plan.region_freq) == dict(verified.plan.region_freq)
    )
    saved = 1.0 - verified.tests_executed / max(1, measured.tests_executed)
    assert same_plan, (
        f"static+verify diverged from the measured plan on sor: "
        f"{verified.plan} vs {measured.plan}"
    )
    assert saved >= MIN_TESTS_SAVED, (
        f"static+verify saved only {saved:.0%} of crash tests on sor "
        f"(bar: {MIN_TESTS_SAVED:.0%})"
    )
    return [{
        "app": "sor",
        "measured_tests": measured.tests_executed,
        "verify_tests": verified.tests_executed,
        "tests_saved_frac": round(saved, 4),
        "identical_plan": same_plan,
        "plan": "|".join(
            f"{k}:{v}" for k, v in sorted(verified.plan.region_freq.items())),
    }]


def run(fast: bool = True, smoke: bool = False) -> None:
    apps = ("sor", "pagerank") if smoke else APPS
    rows = agreement_report(apps, fast=fast or smoke)
    emit(rows, "static_plan_agreement")
    n_agree = sum(bool(r["agree"]) for r in rows)
    print(f"[static_plan] agreement {n_agree}/{len(rows)} apps")
    if smoke:
        if n_agree != len(rows):
            raise SystemExit(
                f"static-plan smoke: expected full agreement on {apps}, "
                f"got {n_agree}/{len(rows)}")
        return
    # the tier-1 acceptance bar, kept in the bench as well so the scheduled
    # report can't silently regress below it
    if n_agree < 5:
        raise SystemExit(
            f"static-plan agreement regressed: {n_agree}/7 apps (bar: 5/7)")
    emit(verify_efficiency_rows(), "static_plan_verify")


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="re-measure every app's workflow instead of "
                         "comparing against the pinned golden decisions")
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast gate: sor + pagerank agreement only")
    args = ap.parse_args()
    run(fast=not args.full, smoke=args.smoke)


if __name__ == "__main__":
    main()
