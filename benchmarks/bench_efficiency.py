"""Paper Fig 10 + Fig 11: end-to-end system efficiency with and without
EasyCrash, across checkpoint costs (32 s / 320 s / 3200 s) and system scales
(100k / 200k / 400k nodes)."""
from __future__ import annotations

from .common import emit


def run(fast: bool = True):
    from repro.core.efficiency import (
        SystemConfig,
        efficiency_with,
        efficiency_without,
        scale_mtbf,
        tau_threshold,
    )

    R = 0.82   # suite-average recomputability (paper's measured average)
    t_s = 0.015
    rows = []
    for t_chk in (32.0, 320.0, 3200.0):
        cfg = SystemConfig(mtbf=12 * 3600.0, t_chk=t_chk)
        base = efficiency_without(cfg)
        ec = efficiency_with(cfg, R, t_s=t_s)
        rows.append({
            "figure": "10",
            "config": f"t_chk={int(t_chk)}s",
            "eff_cr": round(base.efficiency, 4),
            "eff_easycrash": round(ec.efficiency, 4),
            "gain_pct": round(100 * (ec.efficiency - base.efficiency), 2),
            "interval_cr_s": round(base.interval, 0),
            "interval_ec_s": round(ec.interval, 0),
            "tau": round(tau_threshold(cfg, t_s=t_s), 3),
        })
    for nodes in (100_000, 200_000, 400_000):
        mtbf = scale_mtbf(12 * 3600.0, 100_000, nodes)
        cfg = SystemConfig(mtbf=mtbf, t_chk=3200.0)
        base = efficiency_without(cfg)
        ec = efficiency_with(cfg, R, t_s=t_s)
        rows.append({
            "figure": "11",
            "config": f"nodes={nodes}",
            "eff_cr": round(base.efficiency, 4),
            "eff_easycrash": round(ec.efficiency, 4),
            "gain_pct": round(100 * (ec.efficiency - base.efficiency), 2),
            "interval_cr_s": round(base.interval, 0),
            "interval_ec_s": round(ec.interval, 0),
            "tau": round(tau_threshold(cfg, t_s=t_s), 3),
        })
    # paper §6 sensitivity: t_s = 2 / 3 / 5 % (tighter budgets persist less
    # often; here we model the efficiency side at fixed R)
    for ts in (0.02, 0.03, 0.05):
        cfg = SystemConfig(mtbf=12 * 3600.0, t_chk=320.0)
        base = efficiency_without(cfg)
        ec = efficiency_with(cfg, R, t_s=ts)
        rows.append({
            "figure": "ts-sensitivity",
            "config": f"t_s={int(100*ts)}%",
            "eff_cr": round(base.efficiency, 4),
            "eff_easycrash": round(ec.efficiency, 4),
            "gain_pct": round(100 * (ec.efficiency - base.efficiency), 2),
            "interval_cr_s": round(base.interval, 0),
            "interval_ec_s": round(ec.interval, 0),
            "tau": round(tau_threshold(cfg, t_s=ts), 3),
        })
    gains = [r["gain_pct"] for r in rows if r["figure"] == "10"]
    print(f"[headline] efficiency gains at t_chk=32/320/3200s: "
          f"{gains[0]:.1f}/{gains[1]:.1f}/{gains[2]:.1f} pts "
          f"(paper: 2/3/15 pts, up to 24)")
    emit(rows, "efficiency")
    return rows


if __name__ == "__main__":
    run(fast=True)
