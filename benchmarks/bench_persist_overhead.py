"""Paper Table 4: runtime overhead of persistence operations.

Wall-clock measured: per app we time (a) one main-loop iteration, (b) one
EasyCrash persistence op (delta flush of the selected critical objects into
the arena), then derive normalized execution time for: the EasyCrash plan,
persisting all candidates at every iteration ("without selection"), and the
best-recomputability schedule (every region, every iteration).
"""
from __future__ import annotations

import time

import numpy as np

from .common import APPS, campaign_size, emit


def _time_fn(fn, reps=5):
    fn()  # warm-up / jit
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(fast: bool = True):
    from repro.core import CacheConfig, NVMArena
    from repro.core.workflow import WorkflowConfig, run_workflow
    from repro.hpc.suite import bench_app, ci_app, default_cache

    rows = []
    n = campaign_size(fast) // 2
    for name in APPS:
        app = ci_app(name) if fast else bench_app(name)
        cache = default_cache(app)
        wf = run_workflow(app, WorkflowConfig(n_tests=n, cache=cache, seed=0))
        state = app.init(0)
        state = app.run_iteration(state)

        iter_t = _time_fn(lambda: app.run_iteration(state))

        arena = NVMArena()
        for o in wf.critical:
            arena.flush(o, state[o])

        def flush_critical():
            for o in wf.critical:
                arena.flush(o, state[o])

        def flush_all():
            for o in app.candidates:
                if o in state:
                    arena.flush(o, state[o])

        flush_t = _time_fn(flush_critical)
        flush_all_t = _time_fn(flush_all)
        # ops per iteration under each schedule
        plan_ops = sum(1.0 / x for x in wf.plan.region_freq.values())
        n_regions = len(app.regions())
        norm_ec = 1.0 + plan_ops * flush_t / max(iter_t, 1e-9)
        norm_all = 1.0 + flush_all_t / max(iter_t, 1e-9)
        norm_best = 1.0 + n_regions * flush_t / max(iter_t, 1e-9)
        rows.append({
            "app": name,
            "persist_once_ms": round(flush_t * 1e3, 3),
            "iter_ms": round(iter_t * 1e3, 3),
            "persist_ops_per_iter": round(plan_ops, 2),
            "norm_time_easycrash": round(norm_ec, 4),
            "norm_time_no_selection": round(norm_all, 4),
            "norm_time_best": round(norm_best, 4),
        })
    avg = lambda k: round(float(np.mean([r[k] for r in rows])), 4)
    rows.append({
        "app": "average",
        "persist_once_ms": avg("persist_once_ms"),
        "iter_ms": avg("iter_ms"),
        "persist_ops_per_iter": avg("persist_ops_per_iter"),
        "norm_time_easycrash": avg("norm_time_easycrash"),
        "norm_time_no_selection": avg("norm_time_no_selection"),
        "norm_time_best": avg("norm_time_best"),
    })
    print(f"[headline] EasyCrash overhead {100*(rows[-1]['norm_time_easycrash']-1):.1f}% "
          f"(paper: 1.5% avg, <=2.5% bounded by t_s=3%)")
    emit(rows, "persist_overhead")
    return rows


if __name__ == "__main__":
    run(fast=True)
