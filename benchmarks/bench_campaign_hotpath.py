"""Campaign hot-path: ref-vs-vec engine wall-clock and speedup.

Three measurements per suite app, all on identical pre-planned campaigns:

* ``ref``      — the historical engine (OrderedDict window LRU, per-test
                 Python restart loop);
* ``vec``      — the SoA window simulator + batched lane recompute, cold
                 trace cache;
* ``vec-warm`` — ``vec`` against a trace cache populated by a previous run
                 of the same campaign (the replay / robustness-matrix case).

Each configuration is run once unmeasured first so the numbers are
steady-state engine throughput, not XLA compile time (the batched hooks
jit one kernel per lane-bucket size).  S1–S4 fractions are asserted
identical across engines — the speedup is only meaningful because the
answers are bit-for-bit the same.

Outputs ``benchmarks/results/campaign_hotpath.csv`` and the repo-root
``BENCH_campaign.json`` — ``{app, engine, tests_per_sec, speedup}`` rows
plus a ``suite-geomean`` summary row that tracks the perf trajectory
across PRs.
"""
from __future__ import annotations

import json
import math
import os

from .common import APPS, Timer, campaign_size, emit

#: every suite app opts into batched recompute now — the former kmeans
#: anti-case got the jit-resident lane driver along with cg/mg/heat/
#: montecarlo, so the whole suite is benched
HOTPATH_APPS = APPS

BENCH_JSON = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_campaign.json")
)


def _run_once(name: str, engine: str, n_tests: int, fast: bool, tc=None):
    from repro.core import CrashTester, PersistPlan
    from repro.core.trace_cache import WindowTraceCache
    from repro.hpc.suite import bench_app, ci_app, default_cache

    app = (ci_app if fast else bench_app)(name)
    tester = CrashTester(
        app, PersistPlan.none(), default_cache(app), seed=123,
        engine=engine,
        trace_cache=tc if tc is not None else WindowTraceCache(0, 0),
    )
    with Timer() as t:
        camp = tester.run_campaign(n_tests)
    return camp, t.dt


def run(fast: bool = True) -> None:
    from repro.core.trace_cache import WindowTraceCache

    n_tests = campaign_size(fast)
    rows = []
    for name in HOTPATH_APPS:
        # unmeasured passes: golden-run kernels + every lane-bucket jit
        for engine in ("ref", "vec"):
            _run_once(name, engine, n_tests, fast)

        # median of 3 measured runs per configuration: one noisy scheduler
        # tick on a sub-second campaign should not move the artifact
        ref_runs = [_run_once(name, "ref", n_tests, fast) for _ in range(3)]
        vec_runs = [_run_once(name, "vec", n_tests, fast) for _ in range(3)]
        camp_ref, dt_ref = sorted(ref_runs, key=lambda cd: cd[1])[1]
        camp_vec, dt_vec = sorted(vec_runs, key=lambda cd: cd[1])[1]
        assert camp_ref.class_fractions() == camp_vec.class_fractions(), (
            f"{name}: engines disagree — speedup numbers would be meaningless"
        )
        warm_tc = WindowTraceCache()
        _run_once(name, "vec", n_tests, fast, tc=warm_tc)
        dt_warm = sorted(
            _run_once(name, "vec", n_tests, fast, tc=warm_tc)[1] for _ in range(3)
        )[1]

        for engine, dt in (("ref", dt_ref), ("vec", dt_vec), ("vec-warm", dt_warm)):
            rows.append({
                "app": name,
                "engine": engine,
                "n_tests": n_tests,
                "seconds": round(dt, 3),
                "tests_per_sec": round(n_tests / dt, 1),
                "speedup": round(dt_ref / dt, 2),
            })

    # one summary row per engine: geometric mean of the per-app speedups
    for engine in ("vec", "vec-warm"):
        sp = [r["speedup"] for r in rows if r["engine"] == engine]
        rows.append({
            "app": "suite-geomean",
            "engine": engine,
            "n_tests": n_tests,
            "seconds": "",
            "tests_per_sec": "",
            "speedup": round(math.exp(sum(math.log(s) for s in sp) / len(sp)), 2),
        })
    emit(rows, "campaign_hotpath")

    payload = {
        "config": {"fast": bool(fast), "n_tests": n_tests, "seed": 123},
        "results": [
            {k: r[k] for k in ("app", "engine", "tests_per_sec", "speedup")}
            for r in rows
        ],
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[campaign_hotpath] wrote {BENCH_JSON}")


if __name__ == "__main__":
    import sys

    run(fast="--full" not in sys.argv)
