"""Checkpoint manager: atomic commit, retention, tiers, elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig, CheckpointManager, load_pytree, save_pytree


def _tree(step):
    return {
        "params": {"w": np.full((4, 4), float(step), np.float32)},
        "opt": {"mu": np.arange(8, dtype=np.float32) * step},
        "step": np.asarray(step),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(local_dir=str(tmp_path / "l")))
    mgr.save(7, _tree(7))
    step, tree = mgr.restore()
    assert step == 7
    assert np.all(tree["params"]["w"] == 7.0)
    assert np.all(tree["opt"]["mu"] == np.arange(8) * 7)


def test_bfloat16_roundtrip(tmp_path):
    tree = {"w": np.zeros((4,), jnp.bfloat16) + jnp.bfloat16(1.5)}
    save_pytree(tree, str(tmp_path / "c"))
    back = load_pytree(str(tmp_path / "c"))
    assert back["w"].dtype == jnp.bfloat16
    assert np.all(back["w"].astype(np.float32) == 1.5)


def test_retention(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(local_dir=str(tmp_path / "l"), keep=2))
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    steps = mgr.list_steps(str(tmp_path / "l"))
    assert steps == [3, 4]


def test_remote_tier_drain_and_fallback(tmp_path):
    cfg = CheckpointConfig(local_dir=str(tmp_path / "l"),
                           remote_dir=str(tmp_path / "r"), keep=1)
    mgr = CheckpointManager(cfg)
    mgr.save(5, _tree(5))
    mgr.close()
    assert mgr.list_steps(str(tmp_path / "r")) == [5]
    # local tier destroyed (node lost): restore falls back to remote
    import shutil
    shutil.rmtree(str(tmp_path / "l"))
    os.makedirs(str(tmp_path / "l"))
    mgr2 = CheckpointManager(cfg)
    step, tree = mgr2.restore()
    assert step == 5 and np.all(tree["params"]["w"] == 5.0)


def test_elastic_reshard_restores_onto_new_mesh(tmp_path):
    """A checkpoint written logically restores onto a different mesh shape."""
    import subprocess
    import sys

    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
import sys
sys.path.insert(0, {os.path.join(os.path.dirname(__file__), '..', 'src')!r})
from repro.checkpoint import save_pytree, load_pytree
from repro.checkpoint.reshard import reshard_restore
from repro.launch.mesh import make_tiny_mesh

d = {str(tmp_path / 'c')!r}
tree = {{"w": np.arange(64, dtype=np.float32).reshape(8, 8)}}
save_pytree(tree, d)
loaded = load_pytree(d)
mesh = make_tiny_mesh()   # (data=2, model=4): a mesh the writer never saw
placed = reshard_restore(loaded, {{"w": ("fsdp", "ff")}}, mesh)
assert placed["w"].sharding.is_fully_replicated is False
np.testing.assert_array_equal(np.asarray(placed["w"]), tree["w"])
print("RESHARD_OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True)
    assert "RESHARD_OK" in out.stdout, out.stderr[-2000:]
