"""Checkpoint manager: atomic commit, retention, tiers, elastic reshard,
SIGKILL-mid-write durability, measured write costs."""
import os
import signal
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointConfig,
    CheckpointManager,
    load_pytree,
    measure_checkpoint_cost,
    measured_system_config,
    save_pytree,
    system_config_from_measurement,
    tree_nbytes,
)


def _tree(step):
    return {
        "params": {"w": np.full((4, 4), float(step), np.float32)},
        "opt": {"mu": np.arange(8, dtype=np.float32) * step},
        "step": np.asarray(step),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(local_dir=str(tmp_path / "l")))
    mgr.save(7, _tree(7))
    step, tree = mgr.restore()
    assert step == 7
    assert np.all(tree["params"]["w"] == 7.0)
    assert np.all(tree["opt"]["mu"] == np.arange(8) * 7)


def test_bfloat16_roundtrip(tmp_path):
    tree = {"w": np.zeros((4,), jnp.bfloat16) + jnp.bfloat16(1.5)}
    save_pytree(tree, str(tmp_path / "c"))
    back = load_pytree(str(tmp_path / "c"))
    assert back["w"].dtype == jnp.bfloat16
    assert np.all(back["w"].astype(np.float32) == 1.5)


def test_retention(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(local_dir=str(tmp_path / "l"), keep=2))
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    steps = mgr.list_steps(str(tmp_path / "l"))
    assert steps == [3, 4]


def test_remote_tier_drain_and_fallback(tmp_path):
    cfg = CheckpointConfig(local_dir=str(tmp_path / "l"),
                           remote_dir=str(tmp_path / "r"), keep=1)
    mgr = CheckpointManager(cfg)
    mgr.save(5, _tree(5))
    mgr.close()
    assert mgr.list_steps(str(tmp_path / "r")) == [5]
    # local tier destroyed (node lost): restore falls back to remote
    import shutil
    shutil.rmtree(str(tmp_path / "l"))
    os.makedirs(str(tmp_path / "l"))
    mgr2 = CheckpointManager(cfg)
    step, tree = mgr2.restore()
    assert step == 5 and np.all(tree["params"]["w"] == 5.0)


def test_sigkill_mid_write_restores_last_complete_checkpoint(tmp_path):
    """Kill -9 a writer mid-checkpoint: the manager must come back with the
    newest *complete* checkpoint — internally consistent, every leaf from
    the same step — because commits go through the ``core/durable.py``
    replace path (leaf fsync, manifest-last, atomic rename).  A torn
    in-flight step directory must never be listed or restored."""
    local = str(tmp_path / "l")
    code = f"""
import os, sys
sys.path.insert(0, {os.path.join(os.path.dirname(__file__), '..', 'src')!r})
import numpy as np
from repro.checkpoint import CheckpointConfig, CheckpointManager

mgr = CheckpointManager(CheckpointConfig(local_dir={local!r}, keep=3))
for step in range(1, 200):
    tree = {{
        "params": {{"w": np.full((1 << 20,), float(step), np.float32)}},
        "opt": {{"mu": np.full((1 << 20,), float(step), np.float32)}},
        "step": np.asarray(step),
    }}
    mgr.save(step, tree)
    print(f"SAVED {{step}}", flush=True)
"""
    proc = subprocess.Popen([sys.executable, "-c", code],
                            stdout=subprocess.PIPE, text=True)
    try:
        # wait for >= 2 complete checkpoints, then kill while later saves
        # (4 MiB per leaf) are in flight
        saved = 0
        for line in proc.stdout:
            if line.startswith("SAVED"):
                saved = int(line.split()[1])
            if saved >= 2:
                break
        assert saved >= 2, "writer died before producing two checkpoints"
        os.kill(proc.pid, signal.SIGKILL)
    finally:
        proc.stdout.close()
        proc.wait()
    assert proc.returncode == -signal.SIGKILL

    mgr2 = CheckpointManager(CheckpointConfig(local_dir=local))
    restored = mgr2.restore()
    assert restored is not None, "no complete checkpoint survived the kill"
    step, tree = restored
    assert step >= 2
    # internal consistency: every leaf belongs to the restored step
    assert np.all(tree["params"]["w"] == float(step))
    assert np.all(tree["opt"]["mu"] == float(step))
    assert int(tree["step"]) == step
    # only complete checkpoints are listed; torn tmp dirs are invisible
    for s in mgr2.list_steps(local):
        assert os.path.exists(os.path.join(local, f"step_{s:010d}", "manifest.json"))
    # and the manager keeps working over the debris of the killed writer
    mgr2.save(step + 1, _tree(step + 1))
    s2, t2 = mgr2.restore()
    assert s2 == step + 1 and np.all(t2["params"]["w"] == float(step + 1))


def test_measured_checkpoint_cost_and_system_config(tmp_path):
    """The manager is the measurement instrument: save() times its local
    writes, and the measured (seconds, bytes) pair turns into a SystemConfig
    with a real — optionally extrapolated — T_chk."""
    tree = _tree(3)
    mgr = CheckpointManager(CheckpointConfig(local_dir=str(tmp_path / "l")))
    assert mgr.mean_save_seconds() == 0.0
    mgr.save(1, tree)
    mgr.save(2, tree)
    assert len(mgr.save_seconds) == 2
    assert mgr.mean_save_seconds() > 0.0

    secs, nbytes = measure_checkpoint_cost(tree, repeats=2)
    assert secs > 0.0
    assert nbytes == tree_nbytes(tree) > 0

    # pure extrapolation: deterministic and linear in target_bytes
    cfg = system_config_from_measurement(0.25, 1 << 20, mtbf=7200.0)
    assert cfg.t_chk == 0.25 and cfg.mtbf == 7200.0
    cfg2 = system_config_from_measurement(0.25, 1 << 20, mtbf=7200.0,
                                          target_bytes=1 << 30)
    assert cfg2.t_chk == pytest.approx(0.25 * 1024)
    with pytest.raises(ValueError):
        system_config_from_measurement(0.0, 1 << 20, mtbf=7200.0)

    measured = measured_system_config(tree, mtbf=7200.0, repeats=2)
    assert measured.t_chk > 0.0 and measured.mtbf == 7200.0


def test_elastic_reshard_restores_onto_new_mesh(tmp_path):
    """A checkpoint written logically restores onto a different mesh shape."""
    import subprocess
    import sys

    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
import sys
sys.path.insert(0, {os.path.join(os.path.dirname(__file__), '..', 'src')!r})
from repro.checkpoint import save_pytree, load_pytree
from repro.checkpoint.reshard import reshard_restore
from repro.launch.mesh import make_tiny_mesh

d = {str(tmp_path / 'c')!r}
tree = {{"w": np.arange(64, dtype=np.float32).reshape(8, 8)}}
save_pytree(tree, d)
loaded = load_pytree(d)
mesh = make_tiny_mesh()   # (data=2, model=4): a mesh the writer never saw
placed = reshard_restore(loaded, {{"w": ("fsdp", "ff")}}, mesh)
assert placed["w"].sharding.is_fully_replicated is False
np.testing.assert_array_equal(np.asarray(placed["w"]), tree["w"])
print("RESHARD_OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True)
    assert "RESHARD_OK" in out.stdout, out.stderr[-2000:]
