"""Production runtime: arena durability, flush/restore, checkpoint fallback."""
import os

import numpy as np
import pytest

from repro.core import NVMArena
from repro.core.manager import EasyCrashManager, FlushPolicy, flatten_state, unflatten_state


def _state(step=0):
    return {
        "params": {"w": np.full((8, 8), float(step), np.float32),
                   "b": np.zeros(8, np.float32)},
        "opt": {"mu": np.ones(8, np.float32) * step},
        "step": np.asarray(step, np.int64),
    }


def test_flatten_roundtrip():
    s = _state(3)
    flat = flatten_state(s)
    assert set(flat) == {"params/w", "params/b", "opt/mu", "step"}
    back = unflatten_state(flat)
    assert np.array_equal(back["params"]["w"], s["params"]["w"])


def test_flush_and_restore(tmp_path):
    arena = NVMArena(backing_dir=str(tmp_path))
    policy = FlushPolicy(leaves=("params",), every_steps=1, async_flush=False)
    mgr = EasyCrashManager(arena, policy)
    mgr.maybe_flush(5, _state(5))
    mgr.close()

    # simulate crash: new process reattaches to the arena
    arena2 = NVMArena.reattach(str(tmp_path))
    mgr2 = EasyCrashManager(arena2, policy)
    restored, step, source = mgr2.restore(_state(0))
    assert source == "easycrash"
    assert step == 5
    assert np.all(restored["params"]["w"] == 5.0)
    # opt state was NOT in the flush policy: restores from init
    assert np.all(restored["opt"]["mu"] == 0.0)


def test_delta_flush_counts_only_dirty(tmp_path):
    arena = NVMArena(backing_dir=str(tmp_path))
    policy = FlushPolicy(leaves=("params",), every_steps=1, async_flush=False)
    mgr = EasyCrashManager(arena, policy)
    s = _state(1)
    mgr.maybe_flush(1, s)
    first = arena.stats.flush_writes
    mgr.maybe_flush(2, s)  # identical values: delta flush writes ~nothing
    second = arena.stats.flush_writes - first
    # only the __step__ scalar changed
    assert second <= 1
    assert arena.stats.flushed_clean_blocks > 0
    mgr.close()


def test_flush_cadence():
    arena = NVMArena()
    policy = FlushPolicy(leaves=("params",), every_steps=4, async_flush=False)
    mgr = EasyCrashManager(arena, policy)
    issued = [mgr.maybe_flush(s, _state(s)) for s in range(8)]
    assert issued == [True, False, False, False, True, False, False, False]


def test_async_flush_barrier(tmp_path):
    arena = NVMArena(backing_dir=str(tmp_path))
    policy = FlushPolicy(leaves=("params", "opt"), every_steps=1,
                         async_flush=True, max_pending=16)
    mgr = EasyCrashManager(arena, policy)
    for s in range(4):
        mgr.maybe_flush(s, _state(s))
    mgr.barrier()
    assert "params/w" in arena
    assert int(arena.get("__step__")) == 3
    mgr.close()


def test_async_backpressure_skips():
    """Straggler mitigation: an overloaded flush queue skips, never blocks."""
    import threading, queue as q

    arena = NVMArena()
    policy = FlushPolicy(leaves=("params",), every_steps=1,
                         async_flush=True, max_pending=1)
    mgr = EasyCrashManager(arena, policy)
    # stall the worker by grabbing the queue first
    for s in range(50):
        mgr.maybe_flush(s, _state(s))
    assert mgr.stats.flushes_skipped + mgr.stats.flushes_issued == 50
    mgr.close()


def test_verify_hook_rejects_to_checkpoint(tmp_path):
    saved = {}

    def save(step, state):
        saved["step"] = step
        saved["state"] = state

    def restore():
        if not saved:
            return None
        return saved["step"], saved["state"]

    arena = NVMArena(backing_dir=str(tmp_path))
    policy = FlushPolicy(leaves=("params",), every_steps=1, async_flush=False)
    mgr = EasyCrashManager(
        arena, policy, checkpoint_save=save, checkpoint_restore=restore,
        mtbf=3600.0, t_chk=10.0, recomputability=0.8, step_time=60.0,
    )
    assert mgr.checkpoint_every is not None
    save(3, _state(3))
    mgr.maybe_flush(7, _state(7))
    # acceptance verification rejects the arena image -> checkpoint fallback
    state, step, source = mgr.restore(_state(0), verify=lambda s, t: False)
    assert source == "checkpoint"
    assert step == 3
    assert mgr.stats.checkpoint_restores == 1


def test_young_checkpoint_interval_stretches_with_recomputability():
    arena = NVMArena()
    policy = FlushPolicy(leaves=("params",), async_flush=False)
    low = EasyCrashManager(arena, policy, mtbf=3600.0, t_chk=10.0,
                           recomputability=0.0, step_time=1.0)
    high = EasyCrashManager(arena, policy, mtbf=3600.0, t_chk=10.0,
                            recomputability=0.9, step_time=1.0)
    assert high.checkpoint_every > low.checkpoint_every
