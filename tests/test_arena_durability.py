"""NVMArena backing-store durability: reattach after a hard kill.

The arena's whole premise is that the backing dir *is* the NVM: a process
killed at any instant must reattach to complete object images.  These tests
pin the durable-replace protocol (write tmp, fsync data, atomic rename,
fsync directory) by SIGKILLing a writer mid-churn — if anyone regresses to
writing the final path in place, the reattach sees a torn file and fails.
"""
import os
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.core import NVMArena

_WRITER = textwrap.dedent("""
    import sys

    import numpy as np

    from repro.core import NVMArena

    backing = sys.argv[1]
    arena = NVMArena(backing_dir=backing)
    gen = 0
    while True:
        gen += 1
        for name in ("u", "r", "chk/z"):
            arena.install(name, np.full(4096, gen, dtype=np.float64))
        arena.save_manifest()
        print(f"ACK {gen}", flush=True)
""")


def test_reattach_after_sigkill(tmp_path):
    """Kill the writer mid-churn; every reattached object must be a complete
    image of an acknowledged-or-later generation (never empty, never torn)."""
    backing = str(tmp_path / "nvm")
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.Popen(
        [sys.executable, "-c", _WRITER, backing],
        stdout=subprocess.PIPE, text=True, env=env,
    )
    try:
        acked = 0
        deadline = time.time() + 60
        while acked < 3:
            line = proc.stdout.readline()
            if line.startswith("ACK "):
                acked = int(line.split()[1])
            if time.time() > deadline:
                pytest.fail("writer never reached generation 3")
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=30)

    arena = NVMArena.reattach(backing)
    assert set(arena.names()) == {"u", "r", "chk/z"}
    for name in arena.names():
        arr = arena.get(name)
        assert arr.shape == (4096,) and arr.dtype == np.float64
        vals = np.unique(arr)
        assert vals.size == 1, f"{name}: torn image mixes generations"
        assert int(vals[0]) >= acked, (
            f"{name}: holds gen {vals[0]}, but gen {acked} was acknowledged"
        )


def test_reattach_ignores_leftover_tmp_files(tmp_path):
    """A crash between tmp-write and rename leaves *.tmp litter; reattach
    must read only the committed images."""
    backing = str(tmp_path / "nvm")
    arena = NVMArena(backing_dir=backing)
    arena.install("u", np.arange(64, dtype=np.float32))
    arena.save_manifest()
    # simulated crash mid-persist: torn tmp files next to committed ones
    for junk in ("u.npy.tmp", "manifest.json.tmp"):
        with open(os.path.join(backing, junk), "wb") as f:
            f.write(b"\x00torn")
    re = NVMArena.reattach(backing)
    np.testing.assert_array_equal(re.get("u"), np.arange(64, dtype=np.float32))


def test_persist_is_atomic_against_reader(tmp_path):
    """Every committed backing file is loadable at any point between
    installs (no window where the final path holds partial data)."""
    backing = str(tmp_path / "nvm")
    arena = NVMArena(backing_dir=backing)
    for gen in range(1, 6):
        arena.install("u", np.full(1024, gen, dtype=np.float64))
        arena.save_manifest()
        seen = NVMArena.reattach(backing).get("u")
        assert np.unique(seen).tolist() == [float(gen)]
