"""Trip-count-aware HLO cost parser vs XLA cost_analysis ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_hlo, split_computations


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_flops_match_cost_analysis_without_scans():
    def fn(w, x):
        return jnp.tanh(x @ w) @ w.T

    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    c = _compile(fn, w, x)
    hc = analyze_hlo(c.as_text())
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jaxlib wraps the dict in a list
        ca = ca[0]
    assert hc.flops == pytest.approx(float(ca["flops"]), rel=0.01)
    assert hc.trip_counts == []


def test_scan_flops_scaled_by_trip_count():
    L = 8

    def scanned(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    ws = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    c = _compile(scanned, ws, x)
    hc = analyze_hlo(c.as_text())
    exact = 2 * 16 * 64 * 64 * L
    assert hc.flops == pytest.approx(exact, rel=0.01)
    assert L in hc.trip_counts


def test_nested_scan_multipliers():
    A, L = 3, 4

    def fn(ws, x):
        def outer(h, _):
            def inner(hh, w):
                return jnp.tanh(hh @ w), None
            h2, _ = jax.lax.scan(inner, h, ws)
            return h2, None
        h, _ = jax.lax.scan(outer, x, None, length=A)
        return h

    ws = jax.ShapeDtypeStruct((L, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    c = _compile(fn, ws, x)
    hc = analyze_hlo(c.as_text())
    exact = 2 * 8 * 32 * 32 * L * A
    assert hc.flops == pytest.approx(exact, rel=0.01)
    assert sorted(hc.trip_counts) == sorted([A, L])


def test_collectives_parsed_with_groups():
    import subprocess, sys, os

    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, sys
sys.path.insert(0, {os.path.join(os.path.dirname(__file__), '..', 'src')!r})
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_tiny_mesh

mesh = make_tiny_mesh()  # (data=2, model=4)
def fn(w, x):
    return jax.grad(lambda w: ((x @ w) ** 2).mean())(w)
w = jax.ShapeDtypeStruct((256, 512), jnp.float32)
x = jax.ShapeDtypeStruct((32, 256), jnp.float32)
with mesh:
    c = jax.jit(fn,
        in_shardings=(NamedSharding(mesh, P(None, "model")), NamedSharding(mesh, P("data", None))),
        out_shardings=NamedSharding(mesh, P(None, "model")),
    ).lower(w, x).compile()
hc = analyze_hlo(c.as_text())
assert hc.collective_bytes > 0, "expected collective traffic"
assert "all-reduce" in hc.collective_breakdown
print("COLL_OK", hc.collective_bytes)
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True)
    assert "COLL_OK" in out.stdout, out.stderr[-2000:]


def test_split_computations_structure():
    def fn(x):
        return jnp.sum(x * 2.0)

    c = _compile(fn, jax.ShapeDtypeStruct((64,), jnp.float32))
    comps, entry = split_computations(c.as_text())
    assert entry in comps
    assert len(comps[entry].ops) > 0
