"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -e .[dev])")
pytest.importorskip("jax.experimental.pallas", reason="kernel tests need a Pallas-capable jax build")
from hypothesis import given, settings, strategies as st

from repro.kernels.delta_snapshot.ops import dirty_block_mask
from repro.kernels.delta_snapshot.ref import dirty_block_mask_reference
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_reference
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_reference

pytestmark = pytest.mark.kernel


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,s,d,causal,window,blk",
    [
        (2, 4, 256, 64, True, None, 128),
        (1, 2, 128, 64, True, None, 64),
        (2, 2, 256, 64, True, 64, 64),
        (1, 3, 256, 128, False, None, 128),
        (1, 1, 512, 64, True, 128, 128),
    ],
)
def test_flash_attention_matches_ref(b, h, s, d, causal, window, blk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, h, d), dtype)
    v = jax.random.normal(ks[2], (b, s, h, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, block_q=blk, block_k=blk)
    ref = attention_reference(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=causal, window=window,
    )
    ref = jnp.swapaxes(ref, 1, 2)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_block_shape_independence():
    """Block size is a tiling choice, never a semantics choice."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (1, 256, 2, 64), jnp.float32) for kk in ks)
    a = flash_attention(q, k, v, block_q=64, block_k=64)
    b = flash_attention(q, k, v, block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ------------------------------------------------------------------ rwkv6
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,t,d,bt", [(2, 3, 64, 16, 32), (1, 2, 128, 64, 64), (1, 1, 96, 32, 32)])
def test_rwkv6_scan_matches_ref(b, h, t, d, bt, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    r = jax.random.normal(ks[0], (b, t, h, d), dtype) * 0.5
    k = jax.random.normal(ks[1], (b, t, h, d), dtype) * 0.5
    v = jax.random.normal(ks[2], (b, t, h, d), dtype) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, d), jnp.float32)).astype(dtype)
    u = (jax.random.normal(ks[4], (h, d), jnp.float32) * 0.3)
    out = rwkv6_scan(r, k, v, w, u, block_t=bt)
    ref = rwkv6_reference(
        jnp.swapaxes(r, 1, 2), jnp.swapaxes(k, 1, 2),
        jnp.swapaxes(v, 1, 2), jnp.swapaxes(w, 1, 2), u,
    )
    ref = jnp.swapaxes(ref, 1, 2)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol, rtol=tol)


def test_rwkv6_chunking_independence():
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, t, h, d = 1, 128, 2, 32
    r, k, v = (jax.random.normal(kk, (b, t, h, d)) * 0.5 for kk in ks[:3])
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, d)))
    u = jax.random.normal(ks[4], (h, d)) * 0.3
    a = rwkv6_scan(r, k, v, w, u, block_t=32)
    bb = rwkv6_scan(r, k, v, w, u, block_t=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-4)


# ------------------------------------------------------------------ rglru
@pytest.mark.parametrize("b,t,d,bt,bd", [(2, 64, 128, 32, 128), (1, 128, 256, 64, 128), (3, 32, 64, 32, 64)])
def test_rglru_scan_matches_ref(b, t, d, bt, bd):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, t, d))) * 0.98
    x = jax.random.normal(ks[1], (b, t, d))
    out = rglru_scan(a, x, block_t=bt, block_d=bd)
    ref = rglru_reference(a, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


@given(
    t_pow=st.integers(4, 7),
    d_mult=st.integers(1, 3),
    seed=st.integers(0, 1000),
)
@settings(max_examples=10, deadline=None)
def test_rglru_scan_property(t_pow, d_mult, seed):
    t, d = 2 ** t_pow, 64 * d_mult
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, t, d)))
    x = jax.random.normal(ks[1], (1, t, d))
    out = rglru_scan(a, x, block_t=min(64, t), block_d=64)
    ref = rglru_reference(a, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------- delta snapshot
def test_dirty_block_mask_exact():
    x = jnp.zeros(1024, jnp.float32)
    p = x.at[300].set(1.0)
    mask = dirty_block_mask(x, p, block_elems=256)
    assert mask.shape == (4,)
    assert mask.tolist() == [0, 1, 0, 0]


@given(
    n=st.integers(1, 5000),
    nflip=st.integers(0, 8),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=30, deadline=None)
def test_dirty_block_mask_property(n, nflip, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    p = x.copy()
    idx = rng.choice(n, size=min(nflip, n), replace=False)
    p[idx] += 1.0
    got = np.asarray(dirty_block_mask(jnp.asarray(x), jnp.asarray(p), block_elems=256))
    nb = -(-n // 256)
    xb = np.zeros(nb * 256, np.float32); xb[:n] = x
    pb = np.zeros(nb * 256, np.float32); pb[:n] = p
    ref = np.asarray(dirty_block_mask_reference(
        jnp.asarray(xb.reshape(nb, 256)), jnp.asarray(pb.reshape(nb, 256))))
    assert np.array_equal(got, ref)
    # every flipped element's block is flagged
    for i in idx:
        assert got[i // 256] == 1
