"""Failure-trace system-efficiency simulator: statistical parity with the
closed forms, seeded determinism, interval optimization, and the paper's
headline (hybrid beats checkpoint-only) from campaign-measured rates."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import CrashTester, PersistPlan
from repro.core.efficiency import (
    SystemConfig,
    efficiency_with,
    efficiency_without,
    young_interval,
)
from repro.core.sysim import (
    MONTH,
    POLICIES,
    IntervalSweep,
    PoissonTrace,
    RecomputeProfile,
    WeibullTrace,
    default_interval,
    efficiency_frontier,
    optimize_interval,
    scaled_trace,
    simulate_policy,
    trace_from_spec,
)
from repro.hpc.suite import ci_app, default_cache

CFG = SystemConfig(mtbf=12 * 3600.0, t_chk=320.0)


def _synthetic(R=0.82, s2=0.0, hist=()):
    rest = 1.0 - R - s2
    return RecomputeProfile.from_fractions(
        "synthetic", {"S1": R, "S2": s2, "S3": rest / 2, "S4": rest / 2},
        extra_iters_hist=hist,
    )


# ------------------------------------------------------------------- parity
@pytest.mark.slow
def test_checkpoint_policy_converges_to_closed_form():
    """Checkpoint-only under exponential failures must land within 1 % of
    ``efficiency_without`` at 10k failure events (acceptance criterion)."""
    want = efficiency_without(CFG).efficiency
    for seed in (0, 7):
        r = simulate_policy("checkpoint", CFG, PoissonTrace(CFG.mtbf),
                            n_failures=10_000, seed=seed)
        assert abs(r.efficiency - want) / want < 0.01, (seed, r.efficiency, want)
        assert r.n_failures == 10_000


@pytest.mark.slow
def test_hybrid_policy_converges_to_closed_form():
    """Hybrid with a fixed S1 rate (no S2 cost) must match
    ``efficiency_with`` at the same recomputability within 1 %."""
    R, t_s = 0.82, 0.015
    prof = _synthetic(R)
    want = efficiency_with(CFG, R, t_s=t_s).efficiency
    for seed in (0, 7):
        r = simulate_policy("hybrid", CFG, PoissonTrace(CFG.mtbf), prof,
                            n_failures=10_000, t_s=t_s, seed=seed)
        assert abs(r.efficiency - want) / want < 0.01, (seed, r.efficiency, want)


@pytest.mark.slow
def test_parity_holds_across_system_configs():
    for t_chk in (32.0, 3200.0):
        cfg = SystemConfig(mtbf=12 * 3600.0, t_chk=t_chk)
        r = simulate_policy("checkpoint", cfg, PoissonTrace(cfg.mtbf),
                            n_failures=10_000, seed=3)
        want = efficiency_without(cfg).efficiency
        assert abs(r.efficiency - want) / want < 0.01, (t_chk, r.efficiency, want)


# ------------------------------------------------------------- determinism
def test_seeded_determinism_and_env_invariance(monkeypatch):
    """Same seed => bit-for-bit identical result; the simulator is single-
    threaded, so worker-count knobs (REPRO_WORKERS) cannot change it."""
    prof = _synthetic(0.7, s2=0.2, hist=((2, 3), (9, 1)))
    a = simulate_policy("hybrid", CFG, PoissonTrace(CFG.mtbf), prof,
                        n_failures=500, seed=11)
    monkeypatch.setenv("REPRO_WORKERS", "8")
    b = simulate_policy("hybrid", CFG, PoissonTrace(CFG.mtbf), prof,
                        n_failures=500, seed=11)
    assert dataclasses.asdict(a) == dataclasses.asdict(b)
    c = simulate_policy("hybrid", CFG, PoissonTrace(CFG.mtbf), prof,
                        n_failures=500, seed=12)
    assert c.total_time != a.total_time


def test_policy_ordering_month_scale():
    """At month scale with a decent profile: hybrid beats checkpoint-only
    beats no protection; every efficiency is a valid fraction."""
    prof = _synthetic(0.8, s2=0.1, hist=((3, 4),))
    res = {
        p: simulate_policy(p, CFG, PoissonTrace(CFG.mtbf), prof,
                           n_failures=2_000, t_s=0.015, seed=5)
        for p in POLICIES
    }
    for p, r in res.items():
        assert 0.0 <= r.efficiency <= 1.0, (p, r.efficiency)
        assert r.total_time > 0
    assert res["hybrid"].efficiency > res["checkpoint"].efficiency
    assert res["checkpoint"].efficiency > res["none"].efficiency
    # conservation: bucketed wall time adds up to the total
    for p, r in res.items():
        assert sum(r.breakdown.values()) == pytest.approx(r.total_time)


def test_horizon_only_run_plays_the_whole_tape():
    """n_failures=0 with a horizon means 'no failure budget': the tape must
    run to the horizon, not stop at the first failure."""
    r = simulate_policy("checkpoint", CFG, PoissonTrace(CFG.mtbf),
                        n_failures=0, horizon=MONTH, seed=6)
    assert r.total_time == pytest.approx(MONTH)
    assert r.n_failures > 1  # ~60 expected at a 12 h MTBF over a month


def test_horizon_stop_and_tape_end_convention():
    """A horizon shorter than the failure budget stops the tape there, and
    in-flight work at the end counts as retained."""
    r = simulate_policy("checkpoint", CFG, PoissonTrace(CFG.mtbf),
                        n_failures=10_000, horizon=MONTH, seed=2)
    assert r.total_time == pytest.approx(MONTH)
    assert r.n_failures < 10_000
    # a failure-free tape is pure work + checkpoints: efficiency ~ T/(T+t_chk)
    quiet = PoissonTrace(1e12)
    r2 = simulate_policy("checkpoint", CFG, quiet, n_failures=10_000,
                         horizon=MONTH, seed=2)
    T = young_interval(CFG.t_chk, quiet.mtbf)
    assert r2.n_failures == 0
    assert r2.efficiency == pytest.approx(min(1.0, T / (T + CFG.t_chk)), abs=1e-3)


# ------------------------------------------------------------------ traces
def test_weibull_trace_mean_and_specs():
    rng = np.random.default_rng(0)
    tr = WeibullTrace(mtbf=7200.0, shape=0.7)
    draws = [tr.interarrival(rng) for _ in range(40_000)]
    assert np.mean(draws) == pytest.approx(7200.0, rel=0.03)
    assert tr.spec() == {"trace": "weibull", "mtbf": 7200.0, "shape": 0.7}
    assert PoissonTrace(60.0).spec() == {"trace": "poisson", "mtbf": 60.0}


def test_scaled_trace_matches_paper_scaling():
    tr = scaled_trace(PoissonTrace(12 * 3600.0), 100_000, 400_000)
    assert tr.mtbf == pytest.approx(3 * 3600.0)
    tw = scaled_trace(WeibullTrace(12 * 3600.0, shape=0.6), 100_000, 200_000)
    assert isinstance(tw, WeibullTrace) and tw.shape == 0.6
    assert tw.mtbf == pytest.approx(6 * 3600.0)


def test_trace_from_spec_round_trips():
    """spec() -> trace_from_spec reproduces the trace — including the
    output of scaled_trace, so persisted fleet/frontier configs replay."""
    for tr in (
        PoissonTrace(3600.0),
        WeibullTrace(7200.0, shape=0.55),
        scaled_trace(PoissonTrace(12 * 3600.0), 1, 48),
        scaled_trace(WeibullTrace(12 * 3600.0, shape=0.6), 100_000, 200_000),
    ):
        back = trace_from_spec(tr.spec())
        assert type(back) is type(tr)
        assert back == tr
        assert back.spec() == tr.spec()


def test_trace_from_spec_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown trace"):
        trace_from_spec({"trace": "lognormal", "mtbf": 100.0})


# ----------------------------------------------------------------- profile
def test_profile_from_campaign_measures_rates_and_histogram():
    app = ci_app("kmeans")
    camp = CrashTester(app, PersistPlan.none(), default_cache(app),
                       seed=3).run_campaign(10)
    prof = RecomputeProfile.from_campaign(camp)
    assert prof.app_name == "kmeans"
    assert prof.fractions == camp.class_fractions()
    assert prof.n_records == 10
    assert prof.golden_iters == camp.golden_iters
    s2 = [r.extra_iters for r in camp.records if r.outcome == "S2"]
    assert sum(c for _, c in prof.extra_iters_hist) == len(s2)
    if s2:
        assert prof.mean_extra_iters() == pytest.approx(np.mean(s2))
    assert prof.fault_spec.get("model") == "power-fail"


def test_profile_draws_follow_fractions():
    prof = _synthetic(0.5, s2=0.3, hist=((1, 1), (10, 3)))
    rng = np.random.default_rng(0)
    outs = [prof.draw_outcome(rng) for _ in range(20_000)]
    assert np.mean([o == "S1" for o in outs]) == pytest.approx(0.5, abs=0.02)
    assert np.mean([o == "S2" for o in outs]) == pytest.approx(0.3, abs=0.02)
    iters = [prof.draw_extra_iters(rng) for _ in range(8_000)]
    assert set(iters) == {1, 10}
    assert np.mean([i == 10 for i in iters]) == pytest.approx(0.75, abs=0.03)
    assert _synthetic(1.0).draw_extra_iters(rng) == 0  # empty histogram


def test_profile_validation():
    with pytest.raises(ValueError, match="sum"):
        RecomputeProfile.from_fractions("x", {"S1": 0.5})
    with pytest.raises(ValueError, match="sum"):
        RecomputeProfile.from_fractions(
            "x", {"S1": 0.8, "S2": 0.3, "S3": 0.1}
        )  # sums to 1.2 — silently renormalizing would fake success rates
    with pytest.raises(ValueError, match="unknown outcome"):
        RecomputeProfile("x", {}, {"S0": 1.0})


def test_empty_histogram_draws_zero_extra_iters():
    """An all-S1 campaign records no S2 outcomes, so the extra-iteration
    histogram is empty; draws must be 0 (no recompute tail), not an error."""
    prof = RecomputeProfile.from_fractions("x", {"S1": 1.0})
    assert prof.extra_iters_hist == ()
    rng = np.random.default_rng(0)
    assert [prof.draw_extra_iters(rng) for _ in range(5)] == [0] * 5
    assert prof.mean_extra_iters() == 0.0


def test_simulate_policy_validation():
    with pytest.raises(ValueError, match="unknown policy"):
        simulate_policy("raid", CFG, PoissonTrace(CFG.mtbf))
    with pytest.raises(ValueError, match="RecomputeProfile"):
        simulate_policy("hybrid", CFG, PoissonTrace(CFG.mtbf))
    with pytest.raises(ValueError, match="interval"):
        simulate_policy("checkpoint", CFG, PoissonTrace(CFG.mtbf),
                        n_failures=10, interval=-1.0)


# -------------------------------------------------------- interval sweeps
def test_default_interval_stretches_with_success_rate():
    tr = PoissonTrace(CFG.mtbf)
    base = default_interval("checkpoint", CFG, tr)
    assert base == pytest.approx(young_interval(CFG.t_chk, CFG.mtbf))
    stretched = default_interval("hybrid", CFG, tr, _synthetic(0.75))
    assert stretched == pytest.approx(young_interval(CFG.t_chk, CFG.mtbf / 0.25))
    assert default_interval("none", CFG, tr) == 0.0


def test_optimize_interval_sweeps_around_young():
    sweep = optimize_interval("checkpoint", CFG, PoissonTrace(CFG.mtbf),
                              n_failures=1_500, seed=4)
    assert isinstance(sweep, IntervalSweep)
    assert sweep.young == pytest.approx(young_interval(CFG.t_chk, CFG.mtbf))
    intervals = [p.interval for p in sweep.points]
    assert intervals == sorted(intervals)
    assert any(abs(i - sweep.young) < 1e-9 for i in intervals)
    assert sweep.best.efficiency == max(p.efficiency for p in sweep.points)
    with pytest.raises(ValueError, match="interval"):
        optimize_interval("easycrash", CFG, PoissonTrace(CFG.mtbf), _synthetic())


def test_efficiency_frontier_is_json_document():
    prof = _synthetic(0.8, s2=0.1, hist=((2, 2),))
    doc = efficiency_frontier(CFG, PoissonTrace(CFG.mtbf), prof,
                              n_failures=400, seed=1)
    round_trip = json.loads(json.dumps(doc))
    assert set(round_trip["policies"]) == set(POLICIES)
    for policy in ("checkpoint", "hybrid"):
        d = round_trip["policies"][policy]
        assert d["best"]["efficiency"] >= max(
            p["efficiency"] for p in d["sweep"]
        ) - 1e-12
    assert round_trip["profile"]["success_rate"] == pytest.approx(0.9)


# ------------------------------------------- the paper's headline, measured
@pytest.mark.slow
@pytest.mark.parametrize("name", ["sor", "pagerank"])
def test_measured_hybrid_gain_over_checkpoint(name):
    """Acceptance criterion: with campaign-measured S1–S4 rates and
    recompute-cost histograms for sor and pagerank, the hybrid policy shows
    a reproducible efficiency gain over checkpoint-only at a fixed seed.
    The profile is also worker-count invariant (same campaign, 1 vs 2
    workers)."""
    app = ci_app(name)
    cache = default_cache(app)
    plan = PersistPlan.at_loop_end(app.candidates, app)
    camp = CrashTester(app, plan, cache, seed=11).run_campaign(32)
    prof = RecomputeProfile.from_campaign(camp)
    camp2 = CrashTester(app, plan, cache, seed=11).run_campaign(32, n_workers=2)
    assert RecomputeProfile.from_campaign(camp2) == prof
    assert prof.success_rate > 0.5, f"{name}: weak profile {prof.fractions}"

    trace = PoissonTrace(CFG.mtbf)
    base = simulate_policy("checkpoint", CFG, trace,
                           n_failures=4_000, seed=3)
    hyb = simulate_policy("hybrid", CFG, trace, prof,
                          n_failures=4_000, t_s=0.015, seed=3)
    assert hyb.efficiency > base.efficiency, (
        f"{name}: hybrid {hyb.efficiency:.4f} <= checkpoint "
        f"{base.efficiency:.4f} with measured rates {prof.fractions}"
    )
    # the gain is reproducible: same seeds, same result
    assert simulate_policy("hybrid", CFG, trace, prof, n_failures=4_000,
                           t_s=0.015, seed=3).efficiency == hyb.efficiency


def test_easycrash_only_depends_on_success_rate():
    """Without a checkpoint to fall back to, EasyCrash-only lives and dies
    by its S3/S4 rate: a perfect profile retains nearly everything, a poor
    one almost nothing (restart from scratch)."""
    tr = PoissonTrace(CFG.mtbf)
    good = simulate_policy("easycrash", CFG, tr, _synthetic(1.0),
                           n_failures=1_000, t_s=0.015, seed=9)
    bad = simulate_policy("easycrash", CFG, tr, _synthetic(0.2),
                          n_failures=1_000, t_s=0.015, seed=9)
    assert good.efficiency > 0.9
    assert bad.efficiency < 0.1
    assert good.n_restarts == 0 and good.n_nvm_recoveries > 0
    assert bad.n_restarts > 0
