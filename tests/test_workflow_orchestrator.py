"""Workflow orchestrator: scheduler parity, kill/resume, store hygiene.

The contract mirrors the campaign engine's (tests/test_campaign_engine.py,
tests/test_faults.py) one level up: the orchestrated workflow must be
bit-for-bit the historical serial workflow at every worker count, and a
killed workflow must resume from its WorkflowStore executing only the
shards that never landed.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import CrashTester, PersistPlan
from repro.core.campaign_store import CampaignStoreError, WorkflowStore
from repro.core.cache_sim import CacheConfig
from repro.core.faults import TornWrite
from repro.core.workflow import run_workflow

from repro.hpc.suite import ci_app, default_cache


@pytest.fixture(scope="module")
def km_setup():
    app = ci_app("kmeans")
    return app, default_cache(app)


def _wf_dicts(wf):
    """Every campaign's records + the selection products, for bitwise diff."""
    return {
        "baseline": [dataclasses.asdict(r) for r in wf.baseline_campaign.records],
        "best": [dataclasses.asdict(r) for r in wf.best_campaign.records],
        "critical": wf.critical,
        "plan": (wf.plan.objects, tuple(sorted(wf.plan.region_freq.items()))),
        "summary": wf.summary(),
        "stats": (wf.baseline_campaign.window_write_stats,
                  wf.best_campaign.window_write_stats),
    }


# ----------------------------------------------------------------- scheduling
def test_shared_scheduler_matches_serial(km_setup):
    """The orchestrated workflow is bit-for-bit the PR-2 serial engine."""
    app, cache = km_setup
    kw = dict(n_tests=16, cache=cache, seed=0, region_measure="isolated")
    serial = run_workflow(app, scheduler="serial", **kw)
    shared = run_workflow(app, scheduler="shared", **kw)
    assert _wf_dicts(serial) == _wf_dicts(shared)


def test_shared_scheduler_matches_serial_paper_mode(km_setup):
    app, cache = km_setup
    kw = dict(n_tests=16, cache=cache, seed=0, region_measure="paper")
    serial = run_workflow(app, scheduler="serial", **kw)
    shared = run_workflow(app, scheduler="shared", **kw)
    assert _wf_dicts(serial) == _wf_dicts(shared)


@pytest.mark.slow
@pytest.mark.parametrize("n_workers", [2, 4])
def test_worker_parity(km_setup, n_workers):
    """Bit-for-bit identical workflows for n_workers in {1, 2, 4}."""
    app, cache = km_setup
    kw = dict(n_tests=10, cache=cache, seed=0, region_measure="isolated")
    one = run_workflow(app, scheduler="shared", n_workers=1, **kw)
    par = run_workflow(app, scheduler="shared", n_workers=n_workers, **kw)
    assert _wf_dicts(one) == _wf_dicts(par), n_workers


def test_bad_arguments(km_setup):
    app, cache = km_setup
    with pytest.raises(ValueError, match="scheduler"):
        run_workflow(app, n_tests=8, cache=cache, scheduler="quantum")
    with pytest.raises(ValueError, match="shared"):
        run_workflow(app, n_tests=8, cache=cache, scheduler="serial",
                     store_path="/tmp/nope.jsonl")
    with pytest.raises(ValueError, match="shared"):
        run_workflow(app, n_tests=8, cache=cache, scheduler="serial",
                     shard_callback=lambda k, s: None)


# -------------------------------------------------------------------- resume
def test_workflow_resume_after_kill(km_setup, tmp_path):
    """A workflow killed mid-run (torn trailing line in the WorkflowStore)
    resumes to the identical result, executing only the missing shards."""
    app, cache = km_setup
    path = str(tmp_path / "wf.jsonl")
    kw = dict(n_tests=12, cache=cache, seed=0, region_measure="isolated")
    full = run_workflow(app, store_path=path, **kw)

    lines = open(path).read().splitlines()
    n_shard_lines = sum(1 for ln in lines if '"type": "shard"' in ln)
    assert n_shard_lines >= 4
    # kill after ~half the shards landed, tearing the next line mid-append
    keep = len(lines) // 2
    with open(path, "w") as f:
        f.write("\n".join(lines[:keep]) + "\n" + lines[keep][: len(lines[keep]) // 2])

    # count at _prepare_window_items: once per executed shard on both the
    # per-shard and the chunked (lane-batched) vec paths
    executed = []
    orig = CrashTester._prepare_window_items

    def counting(self, crash_iter, tests):
        executed.append(crash_iter)
        return orig(self, crash_iter, tests)

    CrashTester._prepare_window_items = counting
    try:
        resumed = run_workflow(app, store_path=path, **kw)
    finally:
        CrashTester._prepare_window_items = orig

    assert _wf_dicts(resumed) == _wf_dicts(full)
    kept_shards = sum(1 for ln in lines[:keep] if '"type": "shard"' in ln)
    assert len(executed) == n_shard_lines - kept_shards  # only missing shards

    # a completed store resumes with zero shards executed
    executed.clear()
    CrashTester._prepare_window_items = counting
    try:
        again = run_workflow(app, store_path=path, **kw)
    finally:
        CrashTester._prepare_window_items = orig
    assert _wf_dicts(again) == _wf_dicts(full)
    assert executed == []


def test_shard_callback_fires_after_durable_append(km_setup, tmp_path):
    app, cache = km_setup
    path = str(tmp_path / "wf.jsonl")
    seen = []

    def cb(key, shard_id):
        # at callback time the shard must already be re-loadable
        assert shard_id in WorkflowStore(path).completed_shards(key)
        seen.append((key, shard_id))

    run_workflow(app, n_tests=8, cache=cache, seed=0, store_path=path,
                 region_measure="paper", shard_callback=cb)
    assert seen
    assert {k for k, _ in seen} == {"baseline", "best"}


def test_workflow_store_refuses_different_workflow(km_setup, tmp_path):
    app, cache = km_setup
    path = str(tmp_path / "wf.jsonl")
    kw = dict(n_tests=8, cache=cache, region_measure="paper")
    run_workflow(app, seed=0, store_path=path, **kw)
    with pytest.raises(CampaignStoreError, match="different workflow"):
        run_workflow(app, seed=1, store_path=path, **kw)
    with pytest.raises(CampaignStoreError, match="different workflow"):
        run_workflow(app, seed=0, store_path=path, fault_model=TornWrite(), **kw)


def test_workflow_store_refuses_campaign_fingerprint_clash(km_setup, tmp_path):
    """If a stored member campaign no longer matches what the resumed
    workflow would run (e.g. the critical-object set changed), the store is
    refused rather than silently mixing incompatible shard results."""
    app, cache = km_setup
    path = str(tmp_path / "wf.jsonl")
    kw = dict(n_tests=8, cache=cache, seed=0, region_measure="paper")
    run_workflow(app, store_path=path, **kw)
    lines = open(path).read().splitlines()
    doctored = []
    for ln in lines:
        d = json.loads(ln)
        if d.get("type") == "campaign" and d["key"] == "best":
            d["fingerprint"]["plan_objects"] = ["not-the-real-selection"]
        doctored.append(json.dumps(d))
    with open(path, "w") as f:
        f.write("\n".join(doctored) + "\n")
    with pytest.raises(CampaignStoreError, match="campaign 'best'"):
        run_workflow(app, store_path=path, **kw)


# ------------------------------------------------------------- store hygiene
def test_store_raises_on_midfile_corruption(km_setup, tmp_path):
    """Only a torn *trailing* line is a crash signature; an undecodable line
    with data after it is corruption and must raise, not drop a shard."""
    app, cache = km_setup
    path = str(tmp_path / "wf.jsonl")
    kw = dict(n_tests=10, cache=cache, seed=0, region_measure="paper")
    run_workflow(app, store_path=path, **kw)
    lines = open(path).read().splitlines()
    assert len(lines) >= 4
    lines[2] = lines[2][: len(lines[2]) // 2]  # mid-file torn line
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    with pytest.raises(CampaignStoreError, match="mid-file corruption"):
        run_workflow(app, store_path=path, **kw)


def test_frozen_configs():
    """CacheConfig (a shared default parameter value) and the engine's value
    dataclasses are immutable — a campaign cannot mutate another's config."""
    import dataclasses as dc

    from repro.core import CrashRecord, WorkflowResult  # noqa: F401
    from repro.core.selection import ObjectScore, RegionChoice, RegionSelection

    cfg = CacheConfig()
    with pytest.raises(dc.FrozenInstanceError):
        cfg.capacity_blocks = 1
    rec = CrashRecord(0, 0, 0.0, {}, "S1", 0, 0.0)
    with pytest.raises(dc.FrozenInstanceError):
        rec.outcome = "S4"
    score = ObjectScore("u", -0.5, 0.001, True)
    with pytest.raises(dc.FrozenInstanceError):
        score.critical = False
    sel = RegionSelection([RegionChoice(0, 1, 0.1, 0.01)], 0.9, 0.01, True)
    with pytest.raises(dc.FrozenInstanceError):
        sel.meets_tau = False


def test_orchestrator_refuses_rebound_campaign_key(km_setup):
    """A campaign key names one identity per orchestrator: rebinding it to a
    different plan/seed must raise, not silently reuse the cached tester."""
    from repro.core.workflow import CampaignSpec, WorkflowOrchestrator

    app, cache = km_setup
    orch = WorkflowOrchestrator(app, cache, fault=None)
    try:
        orch.run([CampaignSpec("probe", PersistPlan.none(), 0, 4)])
        with pytest.raises(ValueError, match="already bound"):
            orch.run([CampaignSpec("probe", PersistPlan.none(), 1, 4)])
        with pytest.raises(ValueError, match="already bound"):
            orch.run([CampaignSpec(
                "probe", PersistPlan.at_loop_end(("centroids",), app), 0, 4
            )])
        # the same identity is fine (results come from the cached tester)
        orch.run([CampaignSpec("probe", PersistPlan.none(), 0, 4)])
    finally:
        orch.close()


def test_workflow_matches_pre_orchestrator_reference(km_setup):
    """Pin the default run_workflow output against an independently computed
    serial reference (campaigns run directly through CrashTester), proving
    the orchestrator preserved the PR-2 numbers."""
    app, cache = km_setup
    wf = run_workflow(app, n_tests=14, cache=cache, seed=3,
                      region_measure="paper")
    base = CrashTester(app, PersistPlan.none(), cache, seed=3).run_campaign(14)
    assert [dataclasses.asdict(r) for r in wf.baseline_campaign.records] == \
           [dataclasses.asdict(r) for r in base.records]
    best_plan = PersistPlan.best(wf.critical, app)
    best = CrashTester(app, best_plan, cache, seed=4).run_campaign(14)
    assert [dataclasses.asdict(r) for r in wf.best_campaign.records] == \
           [dataclasses.asdict(r) for r in best.records]
    assert np.isfinite(wf.tau)
