"""Differential tests: Pallas kernels vs their pure references, CPU interpret.

Unlike ``test_kernels.py`` (hypothesis-driven sweeps), these are plain
parametrized tests so they run wherever a Pallas-capable jax exists — the
dtype x odd-shape grid is the point: non-multiple-of-block sizes exercise
the padding/tiling edges of ``delta_snapshot`` and the tail-chunk handling
of ``rwkv6_scan``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "jax.experimental.pallas", reason="kernel tests need a Pallas-capable jax build"
)

from repro.core.arena import NVMArena
from repro.core.blocks import block_diff_mask
from repro.core.delta_persist import delta_block_mask, kernel_available
from repro.core.manager import EasyCrashManager, FlushPolicy
from repro.kernels.delta_snapshot.ops import dirty_block_mask
from repro.kernels.delta_snapshot.ref import dirty_block_mask_reference
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_reference

pytestmark = pytest.mark.kernel


# ------------------------------------------------------------- delta snapshot
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("n", [1, 7, 255, 256, 257, 1000, 4097])
def test_dirty_block_mask_differential(n, dtype):
    """Kernel == jnp oracle for every dtype at odd (non-multiple-of-block)
    lengths; the zero-padding of the tail block must never read as dirty."""
    be = 256
    rng = np.random.default_rng(n)
    if dtype == jnp.int32:
        x = rng.integers(-1000, 1000, size=n).astype(np.int32)
    else:
        x = rng.standard_normal(n).astype(np.float32)
    p = x.copy()
    idx = rng.choice(n, size=min(5, n), replace=False)
    p[idx] += 1
    xj = jnp.asarray(x, dtype)
    pj = jnp.asarray(p, dtype)
    got = np.asarray(dirty_block_mask(xj, pj, block_elems=be))
    nb = -(-n // be)
    assert got.shape == (nb,) and got.dtype == np.int32
    xpad = jnp.zeros(nb * be, dtype).at[:n].set(xj)
    ppad = jnp.zeros(nb * be, dtype).at[:n].set(pj)
    ref = np.asarray(
        dirty_block_mask_reference(xpad.reshape(nb, be), ppad.reshape(nb, be))
    )
    np.testing.assert_array_equal(got, ref)
    changed = np.flatnonzero(np.asarray(xj) != np.asarray(pj))
    assert set(np.flatnonzero(got)) == set(changed // be)
    # identical inputs: padding contributes no phantom dirt
    clean = np.asarray(dirty_block_mask(xj, xj, block_elems=be))
    assert not clean.any()


@pytest.mark.parametrize("n,block_bytes", [(300, 64), (1024, 64), (65, 32)])
def test_dirty_block_mask_agrees_with_cpu_block_diff(n, block_bytes):
    """The TPU flush-block mask and the campaign engine's byte-level
    block_diff_mask must flag the same blocks when block sizes align
    (block_elems * itemsize == block_bytes)."""
    elems = block_bytes // 4
    rng = np.random.default_rng(7)
    x = rng.standard_normal(n).astype(np.float32)
    p = x.copy()
    p[rng.choice(n, size=4, replace=False)] *= -1.0
    kernel_mask = np.asarray(
        dirty_block_mask(jnp.asarray(x), jnp.asarray(p), block_elems=elems)
    ).astype(bool)
    cpu_mask = block_diff_mask(x, p, block_bytes=block_bytes)
    np.testing.assert_array_equal(kernel_mask, cpu_mask)


# ------------------------------------------------------- delta persistence
def _persist_series(n, dtype, rng):
    """A value trajectory that touches one block per step plus the tail."""
    if np.dtype(dtype).kind == "i":
        x = rng.integers(-1000, 1000, size=n).astype(dtype)
    else:
        x = rng.standard_normal(n).astype(np.float32).astype(dtype)
    series = [x]
    for step in range(1, 5):
        x = x.copy()
        x[(step * 17) % n] += np.asarray(1, dtype)
        x[n - 1] += np.asarray(1, dtype)  # partial tail block goes dirty too
        series.append(x)
    return series


@pytest.mark.parametrize("dtype", [np.float32, np.int32, "bfloat16"])
@pytest.mark.parametrize("n", [1, 7, 255, 256, 257, 1000, 4097])
def test_delta_persist_image_matches_full(n, dtype):
    """persist_mode='delta' must leave a byte-identical NVM image to a
    whole-object persist across dtypes and non-multiple-of-block shapes,
    while writing no more blocks."""
    if dtype == "bfloat16":
        dtype = jnp.bfloat16.dtype
    assert kernel_available()
    rng = np.random.default_rng(n)
    series = _persist_series(n, dtype, rng)

    def run(mode):
        arena = NVMArena(block_bytes=64)
        mgr = EasyCrashManager(
            arena, FlushPolicy(leaves=("x",), async_flush=False, persist_mode=mode)
        )
        for step, x in enumerate(series, start=1):
            mgr.maybe_flush(step, {"x": x})
        mgr.close()
        return arena.get("x"), mgr.stats.blocks_written

    img_delta, blocks_delta = run("delta")
    img_full, blocks_full = run("full")
    img_auto, blocks_auto = run("auto")
    assert img_delta.tobytes() == img_full.tobytes() == img_auto.tobytes()
    assert img_delta.dtype == np.dtype(dtype)
    assert blocks_delta <= blocks_full
    # delta and the arena's own byte diff agree on what moved
    assert blocks_delta == blocks_auto
    if n > 256:  # multi-block object: the savings must be real
        assert blocks_delta < blocks_full


@pytest.mark.parametrize("dtype", [np.float32, np.int32, "bfloat16"])
@pytest.mark.parametrize("n", [1, 7, 255, 257, 1000, 4097])
def test_delta_block_mask_matches_cpu_reference(n, dtype):
    """The kernel-backed byte-view mask is the CPU block_diff_mask, exactly."""
    if dtype == "bfloat16":
        dtype = jnp.bfloat16.dtype
    rng = np.random.default_rng(n + 1)
    series = _persist_series(n, dtype, rng)
    for cur, live in zip(series, series[1:]):
        got = delta_block_mask(cur, live, block_bytes=64)
        ref = block_diff_mask(cur, live, block_bytes=64)
        np.testing.assert_array_equal(got, ref)
        clean = delta_block_mask(live, live, block_bytes=64)
        assert not clean.any()


# ------------------------------------------------------------------ rwkv6
def _rwkv_inputs(b, t, h, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (b, t, h, d), dtype) * 0.5
    k = jax.random.normal(ks[1], (b, t, h, d), dtype) * 0.5
    v = jax.random.normal(ks[2], (b, t, h, d), dtype) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, d), jnp.float32)).astype(dtype)
    u = jax.random.normal(ks[4], (h, d), jnp.float32) * 0.3
    return r, k, v, w, u


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,d", [(1, 40, 2, 16), (2, 50, 1, 32), (1, 97, 2, 16)])
def test_rwkv6_scan_differential_odd_t(b, t, h, d, dtype):
    """Sequence lengths that are not a multiple of the default time block:
    the kernel must clamp its chunk to T and still match the reference."""
    r, k, v, w, u = _rwkv_inputs(b, t, h, d, dtype)
    out = rwkv6_scan(r, k, v, w, u)  # default block_t=256 > t
    ref = rwkv6_reference(
        jnp.swapaxes(r, 1, 2), jnp.swapaxes(k, 1, 2),
        jnp.swapaxes(v, 1, 2), jnp.swapaxes(w, 1, 2), u,
    )
    ref = jnp.swapaxes(ref, 1, 2)
    assert out.shape == (b, t, h, d)
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("t,bt", [(96, 24), (60, 20), (144, 48)])
def test_rwkv6_scan_differential_odd_chunks(t, bt):
    """Non-power-of-two chunk sizes tile T exactly and match both the
    reference and the single-chunk evaluation."""
    r, k, v, w, u = _rwkv_inputs(1, t, 2, 16, jnp.float32, seed=3)
    chunked = rwkv6_scan(r, k, v, w, u, block_t=bt)
    whole = rwkv6_scan(r, k, v, w, u, block_t=t)
    ref = rwkv6_reference(
        jnp.swapaxes(r, 1, 2), jnp.swapaxes(k, 1, 2),
        jnp.swapaxes(v, 1, 2), jnp.swapaxes(w, 1, 2), u,
    )
    ref = jnp.swapaxes(ref, 1, 2)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(whole), atol=1e-5)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(ref), atol=1e-4, rtol=1e-4)
