"""End-to-end behaviour: the paper's full pipeline + production recovery."""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_full_paper_pipeline_improves_recomputability():
    """Steps 1-4 on MG: workflow must find u critical and the validated plan
    must improve recomputability at <= t_s overhead."""
    from repro.core import CrashTester
    from repro.core.workflow import run_workflow
    from repro.hpc.suite import ci_app, default_cache

    app = ci_app("mg")
    cache = default_cache(app)
    wf = run_workflow(app, n_tests=50, cache=cache, seed=0)
    assert "u" in wf.critical
    assert wf.region_selection.total_overhead <= wf.t_s + 1e-9
    val = CrashTester(app, wf.plan, cache, seed=123).run_campaign(50)
    assert val.recomputability >= wf.baseline_campaign.recomputability + 0.1


def test_train_driver_recovers_from_injected_failures(tmp_path):
    """The production trainer survives injected failures via EasyCrash."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--steps", "30", "--inject-failure-every", "14",
         "--workdir", str(tmp_path), "--width", "64", "--seq", "32",
         "--batch", "4", "--log-every", "10"],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(SRC),
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "source=easycrash" in out.stdout
    assert "'final_step': 30" in out.stdout


def test_dryrun_tiny_mesh_compiles(tmp_path):
    """Multi-pod dry-run machinery on the CI-sized mesh (8 host devices)."""
    env = dict(os.environ, PYTHONPATH=SRC, DRYRUN_DEVICES="8")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "stablelm-1.6b", "--shape", "train_4k",
         "--mesh", "tiny,tiny-multi", "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(SRC),
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.count("[ok]") == 2, out.stdout
    import json
    d = json.load(open(tmp_path / "stablelm-1.6b_train_4k_tiny.json"))
    assert d["status"] == "ok"
    assert d["roofline"]["flops_per_device"] > 0
    assert d["roofline"]["collective_bytes"] > 0


def test_data_pipeline_determinism_and_seek():
    from repro.data import DataConfig, SyntheticLMStream

    cfg = DataConfig(seq_len=32, global_batch=8, vocab=100)
    s1 = SyntheticLMStream(cfg, 0, 1)
    step0, b0 = next(s1)
    step1, b1 = next(s1)
    s1.seek(0)
    step0b, b0b = next(s1)
    s1.close()
    assert step0 == 0 and step1 == 1 and step0b == 0
    assert np.array_equal(b0["tokens"], b0b["tokens"])
    # host sharding partitions the global batch
    s2 = SyntheticLMStream(cfg, 1, 2)
    _, half = next(s2)
    s2.close()
    assert half["tokens"].shape[0] == 4
    assert np.array_equal(half["tokens"], b0["tokens"][4:])
