import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core.blocks import (
    block_diff_mask,
    inconsistent_rate,
    mix_blocks,
    num_blocks,
)


def test_num_blocks():
    assert num_blocks(0) == 0
    assert num_blocks(1) == 1
    assert num_blocks(64) == 1
    assert num_blocks(65) == 2
    assert num_blocks(128, block_bytes=32) == 4


def test_mix_blocks_basic():
    old = np.zeros(32, np.float32)   # 128 B = 2 blocks
    new = np.ones(32, np.float32)
    out = mix_blocks(old, new, np.array([True, False]))
    assert (out[:16] == 1).all() and (out[16:] == 0).all()


def test_mix_blocks_partial_tail():
    old = np.zeros(20, np.float32)   # 80 B = 2 blocks (2nd partial)
    new = np.ones(20, np.float32)
    out = mix_blocks(old, new, np.array([False, True]))
    assert (out[:16] == 0).all() and (out[16:] == 1).all()


def test_inconsistent_rate():
    a = np.zeros(16, np.float32)
    b = a.copy()
    assert inconsistent_rate(a, b) == 0.0
    b[0] = 1.0
    assert 0 < inconsistent_rate(a, b) <= 4 / 64


@given(
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
    block_bytes=st.sampled_from([16, 64, 128]),
)
@settings(max_examples=50, deadline=None)
def test_mix_blocks_roundtrip(n, seed, block_bytes):
    rng = np.random.default_rng(seed)
    old = rng.standard_normal(n).astype(np.float32)
    new = rng.standard_normal(n).astype(np.float32)
    nb = num_blocks(old.nbytes, block_bytes)
    # all-new mask reproduces new; all-old reproduces old
    assert np.array_equal(mix_blocks(old, new, np.ones(nb, bool), block_bytes), new)
    assert np.array_equal(mix_blocks(old, new, np.zeros(nb, bool), block_bytes), old)
    # a random mask only ever takes bytes from old or new
    mask = rng.random(nb) < 0.5
    out = mix_blocks(old, new, mask, block_bytes)
    ob = out.view(np.uint8)
    for src in (old, new):
        pass
    takes = (ob == old.view(np.uint8)) | (ob == new.view(np.uint8))
    assert takes.all()


@given(n=st.integers(1, 100), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_block_diff_mask_matches_mix(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal(n).astype(np.float32)
    b = a.copy()
    nb = num_blocks(a.nbytes)
    flip = rng.integers(0, n)
    b[flip] += 1.0
    mask = block_diff_mask(a, b)
    assert mask.shape == (nb,)
    assert mask.sum() == 1
    assert mask[(flip * 4) // 64]
    # mixing b into a along the diff mask reproduces b
    assert np.array_equal(mix_blocks(a, b, mask), b)
