"""Differential + property suite for the vectorized campaign hot path.

The ``"vec"`` engine (SoA window simulator + batched lane recompute + shared
trace cache) must be bit-for-bit the ``"ref"`` oracle: identical
:class:`WindowTrace` output, identical resolved NVM images under tearing,
identical S1–S4 classification — per fault model, per worker count, and
through the cross-campaign trace cache.
"""
import numpy as np
import pytest

from repro.core import CrashTester, PersistPlan
from repro.core.cache_sim import (
    CacheConfig,
    Flush,
    RegionEvents,
    Sweep,
    resolve_window_images,
    simulate_window,
    simulate_window_vec,
)
from repro.core.faults import FAULT_MODELS, get_fault_model
from repro.core.trace_cache import WindowTraceCache
from repro.hpc.suite import ci_app, default_cache


def _small_app(name="sor"):
    if name == "sor":
        return ci_app("sor", grid=16, n_iters=60)
    return ci_app("pagerank", n_nodes=96, n_iters=60)


#: sub-CI sizes for the fast per-app differentials — every suite app that
#: opted into batched recompute + the jit-resident lane driver
TINY_SIZES = {
    "cg": dict(grid=12, n_iters=60),
    "mg": dict(grid=16, n_iters=8),
    "kmeans": dict(n_points=200, n_iters=6),
    "montecarlo": dict(batch=256, n_iters=8),
    "heat": dict(grid=16, n_iters=60),
    "pagerank": dict(n_nodes=96, n_iters=60),
}

#: the field advance_lanes carries that a perturbation meaningfully reaches
DRIVER_NOISE_FIELD = {
    "cg": "x", "mg": "u", "kmeans": "centroids",
    "montecarlo": "sums", "heat": "u", "pagerank": "rank",
}


def _tiny_app(name):
    return ci_app(name, **TINY_SIZES[name])


def _campaign(app, engine, fault=None, n_tests=8, workers=1, plan=None, tc=None):
    tester = CrashTester(
        app, plan if plan is not None else PersistPlan.none(),
        default_cache(app), seed=123, fault=fault, engine=engine,
        trace_cache=tc if tc is not None else WindowTraceCache(0, 0),
    )
    return tester.run_campaign(n_tests, n_workers=workers)


def _records_equal(a, b):
    """CrashRecord equality with NaN == NaN (S3 metrics are NaN)."""
    if len(a) != len(b):
        return False
    for ra, rb in zip(a, b):
        if (ra.iter_idx, ra.region_idx, ra.frac, ra.inconsistency,
                ra.outcome, ra.extra_iters) != (
                rb.iter_idx, rb.region_idx, rb.frac, rb.inconsistency,
                rb.outcome, rb.extra_iters):
            return False
        ma, mb = ra.verify_metric, rb.verify_metric
        if not (ma == mb or (np.isnan(ma) and np.isnan(mb))):
            return False
    return True


def _assert_traces_equal(a, b):
    assert a.obj_blocks == b.obj_blocks
    assert a.t_end == b.t_end
    assert a.eviction_writes == b.eviction_writes
    assert a.flush_writes == b.flush_writes
    assert a.flushed_clean_blocks == b.flushed_clean_blocks
    assert a.flush_ops == b.flush_ops
    assert a.spans == b.spans
    assert [(s.t_start, s.obj, s.seq, s.n_blocks) for s in a.sweeps] == [
        (s.t_start, s.obj, s.seq, s.n_blocks) for s in b.sweeps
    ]
    for o in a.obj_blocks:
        np.testing.assert_array_equal(a.wb_t[o], b.wb_t[o], err_msg=f"wb_t[{o}]")
        np.testing.assert_array_equal(a.wb_block[o], b.wb_block[o], err_msg=f"wb_block[{o}]")
        np.testing.assert_array_equal(a.wb_seq[o], b.wb_seq[o], err_msg=f"wb_seq[{o}]")


# ------------------------------------------------------ engine differentials
@pytest.mark.parametrize("fault_name", sorted(FAULT_MODELS))
def test_engines_identical_per_fault_model(fault_name):
    """Full-campaign record equality, ref vs vec, under every fault model
    (tearing, SDC, recovery crashes, biased crash points)."""
    results = {}
    for engine in ("ref", "vec"):
        app = _small_app("sor")
        fault = get_fault_model(fault_name, app=app)
        results[engine] = _campaign(app, engine, fault=fault, n_tests=8)
    assert _records_equal(results["ref"].records, results["vec"].records)
    assert results["ref"].class_fractions() == results["vec"].class_fractions()


def test_engines_identical_pagerank():
    """pagerank exercises hot-sweep windows and the lax.map batched spmv."""
    ref = _campaign(_small_app("pagerank"), "ref", n_tests=8)
    vec = _campaign(_small_app("pagerank"), "vec", n_tests=8)
    assert _records_equal(ref.records, vec.records)


@pytest.mark.parametrize("name", sorted(set(TINY_SIZES) - {"pagerank"}))
def test_engines_identical_newly_batched(name):
    """Full-campaign record equality, ref vs vec, on every app that gained
    batched recompute + the lane driver in this round (kmeans was the
    anti-case; cg/mg are the FMA-sensitive recurrences; montecarlo mixes
    eager and jit rounding in one serial app)."""
    ref = _campaign(_tiny_app(name), "ref", n_tests=6)
    vec = _campaign(_tiny_app(name), "vec", n_tests=6)
    assert _records_equal(ref.records, vec.records)
    assert ref.class_fractions() == vec.class_fractions()


@pytest.mark.parametrize("name", ["heat", "cg"])
def test_engines_identical_under_bitflip(name):
    """Silent bit flips can push restart lanes into blow-up territory, so
    this exercises the driver's suspect-lane path (non-finite residual →
    serial reclassification → S3) against the oracle."""
    results = {}
    for engine in ("ref", "vec"):
        app = _tiny_app(name)
        fault = get_fault_model("bit-flip", app=app)
        results[engine] = _campaign(app, engine, fault=fault, n_tests=6)
    assert _records_equal(results["ref"].records, results["vec"].records)


def _serial_advance(app, s0, it, stop):
    """The campaign's phase-A loop: step, then converged(), to the budget."""
    s = {k: np.array(v, copy=True) for k, v in s0.items()}
    while it < stop:
        s = app.run_iteration(s)
        it += 1
        try:
            if app.converged(s, it):
                break
        except FloatingPointError:
            return s, it, False
    return s, it, True


@pytest.mark.parametrize("name", sorted(TINY_SIZES))
def test_lane_driver_matches_serial_bitwise(name):
    """advance_lanes == the serial phase-A loop, full state bitwise, for
    lanes entering at scattered iterations (including at and near the
    stop bound) with small per-lane perturbations."""
    app = _tiny_app(name)
    noise_field = DRIVER_NOISE_FIELD[name]
    s = app.init(0)
    traj = [s]
    golden_iters = app.n_iters
    it = 0
    while it < app.n_iters:
        s = app.run_iteration(s)
        it += 1
        traj.append(s)
        if app.converged(s, it):
            golden_iters = it
            break
    rng = np.random.default_rng(7)
    entry_its = sorted({1, golden_iters // 2, max(golden_iters - 1, 1), golden_iters})
    lanes = []
    for ei in entry_its:
        lane = {k: np.array(v, copy=True) for k, v in traj[ei].items()}
        lane[noise_field] = (
            lane[noise_field]
            + rng.standard_normal(lane[noise_field].shape) * 1e-5
        ).astype(lane[noise_field].dtype)
        lanes.append((lane, ei))
    serial = [_serial_advance(app, s0, ei, golden_iters) for s0, ei in lanes]
    states, its, oks = app.advance_lanes(
        [s0 for s0, _ in lanes], [ei for _, ei in lanes], golden_iters
    )
    for i, ((ss, sit, sok), ds, dit, ok) in enumerate(zip(serial, states, its, oks)):
        if not sok:
            assert not ok, f"{name} lane {i}: driver missed a raising lane"
            continue
        assert bool(ok), f"{name} lane {i}: driver flagged a clean lane"
        assert int(dit) == sit, f"{name} lane {i}: stopped at {dit} != {sit}"
        for f in ss:
            a, b = np.asarray(ss[f]), np.asarray(ds[f])
            assert a.dtype == b.dtype and a.shape == b.shape, (name, i, f)
            assert np.array_equal(a.view(np.uint8), b.view(np.uint8)), (
                f"{name} lane {i}: field {f!r} not bitwise the serial value"
            )


#: poisoning this field reaches the convergence decision within one step,
#: so the serial loop raises FloatingPointError (cg decides on the carried
#: rho = r·r, not on x)
_POISON_FIELD = {"heat": "u", "cg": "r", "mg": "u", "pagerank": "rank"}


@pytest.mark.parametrize("name", ["heat", "cg", "mg", "pagerank"])
def test_lane_driver_flags_nan_lanes(name):
    """A NaN-poisoned lane (where serial converged() raises) must come back
    ok=False and untouched, while its healthy neighbours advance normally."""
    app = _tiny_app(name)
    noise_field = _POISON_FIELD[name]
    clean = app.init(0)
    clean = app.run_iteration(clean)
    poisoned = {k: np.array(v, copy=True) for k, v in clean.items()}
    poisoned[noise_field] = np.full_like(poisoned[noise_field], np.nan)
    stop = min(app.n_iters, 6)
    states, its, oks = app.advance_lanes([clean, poisoned], [1, 1], stop)
    assert bool(oks[0]) and not bool(oks[1])
    want, wit, wok = _serial_advance(app, clean, 1, stop)
    assert wok and int(its[0]) == wit
    for f in want:
        np.testing.assert_array_equal(
            np.asarray(want[f]).view(np.uint8),
            np.asarray(states[0][f]).view(np.uint8), err_msg=f,
        )


def test_lane_batch_invariance():
    """Campaign results are identical at any lane-batch setting — it is an
    execution-strategy knob, not a semantic one."""
    base = None
    for lb in (None, 1, 3):
        app = _tiny_app("kmeans")
        tester = CrashTester(
            app, PersistPlan.none(), default_cache(app), seed=123,
            engine="vec", trace_cache=WindowTraceCache(0, 0), lane_batch=lb,
        )
        camp = tester.run_campaign(6)
        if base is None:
            base = camp
        else:
            assert _records_equal(base.records, camp.records), lb


def test_engines_identical_with_flush_plan():
    """Flush events (plan-driven CLWB) through both engines."""
    results = {}
    for engine in ("ref", "vec"):
        app = _small_app("sor")
        plan = PersistPlan.at_loop_end(("u",), app)
        results[engine] = _campaign(app, engine, plan=plan, n_tests=8)
    assert _records_equal(results["ref"].records, results["vec"].records)


def test_window_traces_and_images_identical_on_app_windows():
    """WindowTrace fields and resolved NVM images (with torn blocks) are
    identical between engines on real application windows."""
    testers = {}
    for engine in ("ref", "vec"):
        app = _small_app("pagerank")
        testers[engine] = CrashTester(
            app, PersistPlan.at_loop_end(("rank",), app), default_cache(app),
            seed=7, engine=engine, trace_cache=WindowTraceCache(0, 0),
        )
        testers[engine]._ensure_golden()
    for crash_iter in (0, 3):
        tr_ref, sv_ref, ss_ref = testers["ref"]._simulate_crash_window(crash_iter)
        tr_vec, sv_vec, ss_vec = testers["vec"]._simulate_crash_window(crash_iter)
        _assert_traces_equal(tr_ref, tr_vec)
        assert ss_ref == ss_vec
        start = {
            o: testers["ref"]._golden_states[max(0, crash_iter - 1)][o]
            for o in ("rank", "y")
        }
        crash_ts = [ss_ref, ss_ref + 3, tr_ref.t_end - 1]
        fault = get_fault_model("torn-write", app=testers["ref"].app)
        for engine, tr, sv in (("ref", tr_ref, sv_ref), ("vec", tr_vec, sv_vec)):
            from repro.core.crash_tester import PlannedTest

            tearing = [
                fault.torn_blocks(PlannedTest(0, crash_iter, ct, fault_seed=99), tr, 64)
                for ct in crash_ts
            ]
            nvms, lives = resolve_window_images(
                tr, crash_ts, start, sv, 64, tearing=tearing
            )
            if engine == "ref":
                want_nvms, want_lives = nvms, lives
            else:
                for a, b in zip(want_nvms, nvms):
                    for o in a:
                        np.testing.assert_array_equal(a[o], b[o])
                for a, b in zip(want_lives, lives):
                    for o in a:
                        np.testing.assert_array_equal(a[o], b[o])


@pytest.mark.slow
@pytest.mark.parametrize("workers", [2, 4])
@pytest.mark.parametrize("name", ["sor", "kmeans"])
def test_vec_engine_worker_parity(name, workers):
    """vec-engine campaigns are identical at every worker count — and to the
    single-process ref engine.  kmeans rides the jit-resident lane driver,
    so this also proves the driver cache rebuilds identically in workers."""
    app = _tiny_app("kmeans") if name == "kmeans" else _small_app("sor")
    baseline = _campaign(app, "ref", n_tests=10, workers=1)
    app2 = _tiny_app("kmeans") if name == "kmeans" else _small_app("sor")
    fanned = _campaign(app2, "vec", n_tests=10, workers=workers)
    assert _records_equal(baseline.records, fanned.records)


def test_run_shards_matches_per_window(monkeypatch):
    """Cross-window chunked batching (run_shards) == per-shard execution,
    even when the chunk size forces mid-campaign flushes."""
    monkeypatch.setenv("REPRO_LANE_BATCH", "3")
    app = _small_app("sor")
    tester = CrashTester(
        app, PersistPlan.none(), default_cache(app), seed=123,
        engine="vec", trace_cache=WindowTraceCache(0, 0),
    )
    tests, shards = tester.plan_shards(10)
    seen = []
    chunked = tester.run_shards(shards, on_shard=lambda ci, recs: seen.append(ci))
    assert sorted(seen) == sorted(shards)
    per_window = {ci: tester.run_window_tests(ci, ts) for ci, ts in shards.items()}
    assert set(chunked) == set(per_window)
    for ci in per_window:
        assert [i for i, _ in chunked[ci]] == [i for i, _ in per_window[ci]]
        assert _records_equal(
            [r for _, r in chunked[ci]], [r for _, r in per_window[ci]]
        )


# ---------------------------------------------------------- trace-cache reuse
def test_trace_cache_cross_campaign_reuse():
    """A second campaign over the same app/plan hits the shared cache and
    still produces identical records (replay / robustness-matrix case)."""
    app = _small_app("sor")
    tc = WindowTraceCache()
    cold = _campaign(app, "vec", n_tests=8, tc=tc)
    assert tc.stats()["misses"] > 0
    before = tc.stats()["hits"]
    warm = _campaign(app, "vec", n_tests=8, tc=tc)
    assert _records_equal(cold.records, warm.records)
    assert tc.stats()["hits"] > before
    assert tc.stats()["misses"] == tc.stats()["traces"]  # no new simulations


def test_trace_cache_payloads_shared_across_plans():
    """Campaigns with different persist plans share window *payloads* (the
    app-side region re-execution) while keeping distinct traces."""
    app = _small_app("sor")
    tc = WindowTraceCache()
    base = _campaign(app, "vec", n_tests=8, tc=tc)
    stats0 = tc.stats()
    flush = _campaign(
        app, "vec", n_tests=8, tc=tc, plan=PersistPlan.at_loop_end(("u",), app)
    )
    stats1 = tc.stats()
    # same seed => same windows => every payload re-used, no payload misses
    assert stats1["payload_misses"] == stats0["payload_misses"]
    assert stats1["payload_hits"] > stats0["payload_hits"]
    # ...but the flush schedule differs, so traces were simulated anew
    assert stats1["traces"] > stats0["traces"]
    assert base.records != flush.records  # flushing u actually changes outcomes


def test_trace_cache_isolated_between_engines():
    """ref and vec testers sharing one cache never exchange traces (the
    engine is part of the trace key), so differential tests stay honest."""
    app = _small_app("sor")
    tc = WindowTraceCache()
    ref = _campaign(app, "ref", n_tests=6, tc=tc)
    hits_after_ref = tc.stats()["hits"]
    vec = _campaign(app, "vec", n_tests=6, tc=tc)
    assert _records_equal(ref.records, vec.records)
    # vec may reuse payloads but must not reuse ref's traces
    assert tc.stats()["hits"] == hits_after_ref


# ------------------------------------------------------- hypothesis property
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def _random_window(rng):
    sizes = [int(rng.integers(1, 20)) for _ in range(int(rng.integers(1, 5)))]
    objs = {f"o{i}": s for i, s in enumerate(sizes)}
    names = list(objs)
    hot_obj = (
        min(names, key=lambda o: objs[o])
        if len(names) > 1 and rng.random() < 0.7 else None
    )
    regions = []
    seq_values = {}
    seq = 0
    for it in range(2):
        for ridx in range(int(rng.integers(1, 4))):
            events = []
            writes = []
            for _ in range(int(rng.integers(1, 5))):
                o = names[int(rng.integers(0, len(names)))]
                kind = int(rng.integers(0, 3))
                if kind == 2:
                    events.append(Flush(o))
                else:
                    hot = (
                        (hot_obj,)
                        if kind and hot_obj and o != hot_obj and rng.random() < 0.6
                        else ()
                    )
                    events.append(
                        Sweep(o, write=bool(kind), hot=hot,
                              hot_every=int(rng.integers(2, 8)))
                    )
                    if kind:
                        writes.append(o)
            regions.append(
                RegionEvents(seq=seq, iter_idx=it, region_idx=ridx, events=tuple(events))
            )
            seq_values[seq] = {
                o: rng.standard_normal(objs[o] * 16).astype(np.float32)
                for o in set(writes)
            }
            seq += 1
    start = {
        o: rng.standard_normal(objs[o] * 16).astype(np.float32) for o in names
    }
    capacity = int(rng.integers(1, sum(sizes) + 5))
    return CacheConfig(capacity, 64), objs, regions, start, seq_values


if HAVE_HYPOTHESIS:

    @given(
        tol=st.floats(1e-8, 1e-1, allow_nan=False, allow_infinity=False),
        raw=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=300, deadline=None)
    def test_f32_monotone_cutoff_property(tol, raw):
        """The lane driver replaces each app's host-side float64 threshold
        predicate with an exact float32 compare against a bisected cutoff:
        for every finite non-negative f32 value v, ``v <= cutoff`` must equal
        the original predicate ``pred(float(v))`` — otherwise an in-jit
        convergence decision could diverge from the serial loop by one
        iteration and break bit-for-bit equality."""
        from repro.core.lane_driver import f32_monotone_cutoff

        v = np.int32(raw).view(np.float32)
        if not np.isfinite(v) or v < 0:
            return
        pred = lambda x: x < tol * 0.5  # noqa: E731 - the serial decision shape
        cutoff = f32_monotone_cutoff(pred)
        assert bool(v <= cutoff) == bool(pred(float(v)))

    @given(seed=st.integers(0, 100_000))
    @settings(max_examples=60, deadline=None)
    def test_vec_simulator_matches_oracle_property(seed):
        """simulate_window_vec == simulate_window on arbitrary event windows
        (sweeps, flushes, hot re-reads, adversarial capacities), including
        the images the batch resolver derives from the trace."""
        rng = np.random.default_rng(seed)
        cfg, objs, regions, start, seq_values = _random_window(rng)
        ref = simulate_window(cfg, objs, regions)
        vec = simulate_window_vec(cfg, objs, regions)
        _assert_traces_equal(ref, vec)
        if ref.t_end == 0:
            return
        crash_ts = rng.integers(0, ref.t_end + 1, size=4).tolist()
        # block_bytes=64 but values are 16 floats per block: pass the
        # geometry the generator used
        ref_imgs = resolve_window_images(ref, crash_ts, start, seq_values, 64)
        vec_imgs = resolve_window_images(vec, crash_ts, start, seq_values, 64)
        for side in (0, 1):
            for a, b in zip(ref_imgs[side], vec_imgs[side]):
                for o in a:
                    np.testing.assert_array_equal(a[o], b[o])
