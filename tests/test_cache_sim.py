"""Cache-model correctness: event simulation vs a brute-force reference."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core.cache_sim import (
    CacheConfig,
    Flush,
    RegionEvents,
    Sweep,
    resolve_live_values,
    resolve_nvm_image,
    simulate_window,
)


def brute_force(capacity, obj_blocks, regions):
    """Reference write-back LRU; returns list of (t, obj, blk, seq) records."""
    from collections import OrderedDict

    lines = OrderedDict()  # (obj, blk) -> writer seq or -1
    records = []
    t = 0
    for reg in regions:
        for ev in reg.events:
            if isinstance(ev, Sweep):
                for b in range(obj_blocks[ev.obj]):
                    key = (ev.obj, b)
                    prev = lines.pop(key, None)
                    if prev is None and len(lines) >= capacity:
                        (eo, eb), eseq = lines.popitem(last=False)
                        if eseq >= 0:
                            records.append((t, eo, eb, eseq))
                    if ev.write:
                        lines[key] = reg.seq
                    else:
                        lines[key] = prev if (prev is not None and prev >= 0) else -1
                    t += 1
            elif isinstance(ev, Flush):
                for (o, b), seq in list(lines.items()):
                    if o == ev.obj and seq >= 0:
                        records.append((t, o, b, seq))
                        lines[(o, b)] = -1
    return records


@given(
    capacity=st.integers(2, 40),
    sizes=st.lists(st.integers(1, 20), min_size=1, max_size=3),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=40, deadline=None)
def test_simulation_matches_bruteforce(capacity, sizes, seed):
    rng = np.random.default_rng(seed)
    objs = {f"o{i}": s for i, s in enumerate(sizes)}
    names = list(objs)
    regions = []
    seq = 0
    for it in range(2):
        for ridx in range(rng.integers(1, 4)):
            events = []
            for _ in range(rng.integers(1, 4)):
                o = names[rng.integers(0, len(names))]
                kind = rng.integers(0, 3)
                if kind == 2:
                    events.append(Flush(o))
                else:
                    events.append(Sweep(o, write=bool(kind)))
            regions.append(RegionEvents(seq=seq, iter_idx=it, region_idx=ridx, events=tuple(events)))
            seq += 1
    trace = simulate_window(CacheConfig(capacity, 64), objs, regions)
    expected = brute_force(capacity, objs, regions)
    got = []
    for o in objs:
        for t, b, s in zip(trace.wb_t[o], trace.wb_block[o], trace.wb_seq[o]):
            got.append((int(t), o, int(b), int(s)))
    assert sorted(got) == sorted(expected)


def _mk_regions(events_per_region):
    return [
        RegionEvents(seq=i, iter_idx=0, region_idx=i, events=tuple(evs))
        for i, evs in enumerate(events_per_region)
    ]


def test_flush_makes_object_consistent():
    """Crash right after a flush: the flushed object's NVM image equals the
    live value (zero inconsistency) — the paper's consistency guarantee."""
    objs = {"a": 8}
    regions = _mk_regions([[Sweep("a", True), Flush("a")]])
    trace = simulate_window(CacheConfig(4, 64), objs, regions)
    start = {"a": np.zeros(8 * 16, np.float32)}
    after = {"a": np.ones(8 * 16, np.float32)}
    img = resolve_nvm_image(trace, trace.t_end, start, {0: after}, 64)
    assert np.array_equal(img["a"], after["a"])


def test_unflushed_small_object_is_stale():
    """A dirty object that fits in cache and is never flushed: crash loses
    everything — NVM retains the start value."""
    objs = {"a": 4}
    regions = _mk_regions([[Sweep("a", True)]])
    trace = simulate_window(CacheConfig(16, 64), objs, regions)
    start = {"a": np.zeros(4 * 16, np.float32)}
    after = {"a": np.ones(4 * 16, np.float32)}
    img = resolve_nvm_image(trace, trace.t_end, start, {0: after}, 64)
    assert np.array_equal(img["a"], start["a"])


def test_eviction_writes_back():
    """An object larger than the cache leaks its head blocks to NVM."""
    objs = {"a": 10}
    regions = _mk_regions([[Sweep("a", True)]])
    trace = simulate_window(CacheConfig(4, 64), objs, regions)
    assert trace.eviction_writes == 6  # blocks 0..5 evicted by 4-block LRU
    start = {"a": np.zeros(10 * 16, np.float32)}
    after = {"a": np.ones(10 * 16, np.float32)}
    img = resolve_nvm_image(trace, trace.t_end, start, {0: after}, 64)
    flat = img["a"].reshape(10, 16)
    assert (flat[:6] == 1).all() and (flat[6:] == 0).all()


def test_live_values_partial_sweep():
    objs = {"a": 10}
    regions = _mk_regions([[Sweep("a", True)]])
    trace = simulate_window(CacheConfig(4, 64), objs, regions)
    start = {"a": np.zeros(10 * 16, np.float32)}
    after = {"a": np.ones(10 * 16, np.float32)}
    live = resolve_live_values(trace, 3, start, {0: after}, 64)
    flat = live["a"].reshape(10, 16)
    assert (flat[:3] == 1).all() and (flat[3:] == 0).all()


def test_write_accounting_flush_clean_is_free():
    objs = {"a": 8}
    regions = _mk_regions([[Sweep("a", True), Flush("a"), Flush("a")]])
    trace = simulate_window(CacheConfig(16, 64), objs, regions)
    assert trace.flush_writes == 8          # first flush writes all dirty
    assert trace.flushed_clean_blocks == 8  # second flush: all clean, free
    assert trace.flush_ops == 2
