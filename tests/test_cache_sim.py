"""Cache-model correctness: event simulation vs a brute-force reference."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core.cache_sim import (
    CacheConfig,
    Flush,
    RegionEvents,
    Sweep,
    _LRU,
    resolve_live_values,
    resolve_nvm_image,
    resolve_window_images,
    simulate_window,
)


def brute_force(capacity, obj_blocks, regions):
    """Reference write-back LRU; returns list of (t, obj, blk, seq) records."""
    from collections import OrderedDict

    lines = OrderedDict()  # (obj, blk) -> writer seq or -1
    records = []
    t = 0
    for reg in regions:
        for ev in reg.events:
            if isinstance(ev, Sweep):
                for b in range(obj_blocks[ev.obj]):
                    key = (ev.obj, b)
                    prev = lines.pop(key, None)
                    if prev is None and len(lines) >= capacity:
                        (eo, eb), eseq = lines.popitem(last=False)
                        if eseq >= 0:
                            records.append((t, eo, eb, eseq))
                    if ev.write:
                        lines[key] = reg.seq
                    else:
                        lines[key] = prev if (prev is not None and prev >= 0) else -1
                    t += 1
            elif isinstance(ev, Flush):
                for (o, b), seq in list(lines.items()):
                    if o == ev.obj and seq >= 0:
                        records.append((t, o, b, seq))
                        lines[(o, b)] = -1
    return records


@given(
    capacity=st.integers(2, 40),
    sizes=st.lists(st.integers(1, 20), min_size=1, max_size=3),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=40, deadline=None)
def test_simulation_matches_bruteforce(capacity, sizes, seed):
    rng = np.random.default_rng(seed)
    objs = {f"o{i}": s for i, s in enumerate(sizes)}
    names = list(objs)
    regions = []
    seq = 0
    for it in range(2):
        for ridx in range(rng.integers(1, 4)):
            events = []
            for _ in range(rng.integers(1, 4)):
                o = names[rng.integers(0, len(names))]
                kind = rng.integers(0, 3)
                if kind == 2:
                    events.append(Flush(o))
                else:
                    events.append(Sweep(o, write=bool(kind)))
            regions.append(RegionEvents(seq=seq, iter_idx=it, region_idx=ridx, events=tuple(events)))
            seq += 1
    trace = simulate_window(CacheConfig(capacity, 64), objs, regions)
    expected = brute_force(capacity, objs, regions)
    got = []
    for o in objs:
        for t, b, s in zip(trace.wb_t[o], trace.wb_block[o], trace.wb_seq[o]):
            got.append((int(t), o, int(b), int(s)))
    assert sorted(got) == sorted(expected)


def _mk_regions(events_per_region):
    return [
        RegionEvents(seq=i, iter_idx=0, region_idx=i, events=tuple(evs))
        for i, evs in enumerate(events_per_region)
    ]


def test_flush_makes_object_consistent():
    """Crash right after a flush: the flushed object's NVM image equals the
    live value (zero inconsistency) — the paper's consistency guarantee."""
    objs = {"a": 8}
    regions = _mk_regions([[Sweep("a", True), Flush("a")]])
    trace = simulate_window(CacheConfig(4, 64), objs, regions)
    start = {"a": np.zeros(8 * 16, np.float32)}
    after = {"a": np.ones(8 * 16, np.float32)}
    img = resolve_nvm_image(trace, trace.t_end, start, {0: after}, 64)
    assert np.array_equal(img["a"], after["a"])


def test_unflushed_small_object_is_stale():
    """A dirty object that fits in cache and is never flushed: crash loses
    everything — NVM retains the start value."""
    objs = {"a": 4}
    regions = _mk_regions([[Sweep("a", True)]])
    trace = simulate_window(CacheConfig(16, 64), objs, regions)
    start = {"a": np.zeros(4 * 16, np.float32)}
    after = {"a": np.ones(4 * 16, np.float32)}
    img = resolve_nvm_image(trace, trace.t_end, start, {0: after}, 64)
    assert np.array_equal(img["a"], start["a"])


def test_eviction_writes_back():
    """An object larger than the cache leaks its head blocks to NVM."""
    objs = {"a": 10}
    regions = _mk_regions([[Sweep("a", True)]])
    trace = simulate_window(CacheConfig(4, 64), objs, regions)
    assert trace.eviction_writes == 6  # blocks 0..5 evicted by 4-block LRU
    start = {"a": np.zeros(10 * 16, np.float32)}
    after = {"a": np.ones(10 * 16, np.float32)}
    img = resolve_nvm_image(trace, trace.t_end, start, {0: after}, 64)
    flat = img["a"].reshape(10, 16)
    assert (flat[:6] == 1).all() and (flat[6:] == 0).all()


def test_live_values_partial_sweep():
    objs = {"a": 10}
    regions = _mk_regions([[Sweep("a", True)]])
    trace = simulate_window(CacheConfig(4, 64), objs, regions)
    start = {"a": np.zeros(10 * 16, np.float32)}
    after = {"a": np.ones(10 * 16, np.float32)}
    live = resolve_live_values(trace, 3, start, {0: after}, 64)
    flat = live["a"].reshape(10, 16)
    assert (flat[:3] == 1).all() and (flat[3:] == 0).all()


def test_write_accounting_flush_clean_is_free():
    objs = {"a": 8}
    regions = _mk_regions([[Sweep("a", True), Flush("a"), Flush("a")]])
    trace = simulate_window(CacheConfig(16, 64), objs, regions)
    assert trace.flush_writes == 8          # first flush writes all dirty
    assert trace.flushed_clean_blocks == 8  # second flush: all clean, free
    assert trace.flush_ops == 2


# --------------------------------------------------- batch resolver properties
def _random_event_window(rng, with_hot=True):
    """Arbitrary region/flush event window (the generator behind the
    ``resolve_window_images`` equivalence properties)."""
    block_bytes = 16
    sizes = [int(rng.integers(1, 14)) for _ in range(int(rng.integers(1, 4)))]
    objs = {f"o{i}": s for i, s in enumerate(sizes)}
    names = list(objs)
    hot_obj = min(names, key=lambda o: objs[o]) if with_hot and len(names) > 1 else None
    regions = []
    seq_values = {}
    seq = 0
    for it in range(2):
        for ridx in range(int(rng.integers(1, 4))):
            events = []
            writes = []
            for _ in range(int(rng.integers(1, 4))):
                o = names[int(rng.integers(0, len(names)))]
                kind = int(rng.integers(0, 3))
                if kind == 2:
                    events.append(Flush(o))
                elif kind == 1:
                    hot = (
                        (hot_obj,)
                        if hot_obj and o != hot_obj and rng.random() < 0.5
                        else ()
                    )
                    events.append(Sweep(o, write=True, hot=hot, hot_every=4))
                    writes.append(o)
                else:
                    events.append(Sweep(o, write=False))
            regions.append(RegionEvents(seq=seq, iter_idx=it, region_idx=ridx,
                                        events=tuple(events)))
            seq_values[seq] = {
                o: rng.standard_normal(objs[o] * block_bytes // 4).astype(np.float32)
                for o in set(writes)
            }
            seq += 1
    start = {
        o: rng.standard_normal(objs[o] * block_bytes // 4).astype(np.float32)
        for o in names
    }
    capacity = int(rng.integers(1, sum(sizes) + 4))
    return CacheConfig(capacity, block_bytes), objs, regions, start, seq_values


def _replay_reference(cfg, obj_blocks, regions, start_values, seq_values, crash_ts):
    """Fully independent step-by-step replay of the cache semantics.

    Walks the event stream one block access at a time with its own LRU dict,
    collecting timestamped write-back records and live-value snapshots, then
    builds each crash time's NVM image by applying records with t <= crash_t
    in order.  Shares no code with simulate_window/resolve_window_images.
    """
    from collections import OrderedDict

    bb = cfg.block_bytes
    as_bytes = lambda a: np.ascontiguousarray(a).view(np.uint8).reshape(-1)  # noqa: E731
    live = {o: as_bytes(v).copy() for o, v in start_values.items()}
    want = sorted(set(int(c) for c in crash_ts))
    live_snaps = {}
    records = []  # (t, obj, blk, seq) in emission order
    lines = OrderedDict()
    t = 0

    def access(o, blk, writer_seq, at_t):
        prev = lines.pop((o, blk), None)
        if prev is None and len(lines) >= cfg.capacity_blocks:
            (eo, eb), eseq = lines.popitem(last=False)
            if eseq >= 0:
                records.append((at_t, eo, eb, eseq))
        if writer_seq >= 0:
            lines[(o, blk)] = writer_seq
        else:
            lines[(o, blk)] = prev if (prev is not None and prev >= 0) else -1

    def snap_live_if_due():
        if t in want and t not in live_snaps:
            live_snaps[t] = {o: v.copy() for o, v in live.items()}

    for reg in regions:
        for ev in reg.events:
            if isinstance(ev, Sweep):
                for b in range(obj_blocks[ev.obj]):
                    snap_live_if_due()
                    access(ev.obj, b, reg.seq if ev.write else -1, t)
                    if ev.write and ev.obj in live:
                        src = as_bytes(seq_values[reg.seq][ev.obj])
                        lo, hi = b * bb, min((b + 1) * bb, live[ev.obj].size)
                        live[ev.obj][lo:hi] = src[lo:hi]
                    t += 1
                    if ev.hot and b % ev.hot_every == ev.hot_every - 1:
                        for h in ev.hot:
                            for hb in range(obj_blocks[h]):
                                access(h, hb, -1, t)
            else:  # Flush
                for (o, blk), seq in list(lines.items()):
                    if o == ev.obj and seq >= 0:
                        records.append((t, o, blk, seq))
                        lines[(o, blk)] = -1
    snap_live_if_due()
    for ct in want:
        live_snaps.setdefault(ct, {o: v.copy() for o, v in live.items()})

    nvm_snaps = {}
    for ct in want:
        nvm = {o: as_bytes(v).copy() for o, v in start_values.items()}
        for rt, o, blk, seq in records:
            if rt > ct or o not in nvm:
                continue
            src = as_bytes(seq_values[seq][o])
            lo, hi = blk * bb, min((blk + 1) * bb, nvm[o].size)
            nvm[o][lo:hi] = src[lo:hi]
        nvm_snaps[ct] = nvm
    return nvm_snaps, live_snaps


@given(seed=st.integers(0, 10_000), n_crashes=st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_batch_resolution_matches_step_by_step_replay(seed, n_crashes):
    """resolve_window_images == an independent one-access-at-a-time replay,
    for arbitrary region/flush/hot event sequences and crash times."""
    rng = np.random.default_rng(seed)
    cfg, objs, regions, start, seq_values = _random_event_window(rng)
    trace = simulate_window(cfg, objs, regions)
    if trace.t_end == 0:
        return
    crash_ts = rng.integers(0, trace.t_end + 1, size=n_crashes).tolist()
    nvms, lives = resolve_window_images(
        trace, crash_ts, start, seq_values, cfg.block_bytes
    )
    ref_nvm, ref_live = _replay_reference(cfg, objs, regions, start, seq_values, crash_ts)
    for ct, nvm, live in zip(crash_ts, nvms, lives):
        for o in start:
            np.testing.assert_array_equal(
                nvm[o].view(np.uint8).reshape(-1), ref_nvm[ct][o],
                err_msg=f"nvm {o} t={ct} seed={seed}")
            np.testing.assert_array_equal(
                live[o].view(np.uint8).reshape(-1), ref_live[ct][o],
                err_msg=f"live {o} t={ct} seed={seed}")


@given(seed=st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_batch_resolution_matches_single_shot_property(seed):
    """Property form of the batch==single-shot equivalence, including hot
    sweeps and a chronic base image."""
    rng = np.random.default_rng(seed)
    cfg, objs, regions, start, seq_values = _random_event_window(rng)
    trace = simulate_window(cfg, objs, regions)
    if trace.t_end == 0:
        return
    crash_ts = rng.integers(0, trace.t_end + 1, size=5).tolist()
    chronic = None
    if seed % 2:
        chronic = {o: np.full_like(v, 7.5) for o, v in start.items()}
    nvms, lives = resolve_window_images(
        trace, crash_ts, start, seq_values, cfg.block_bytes, chronic_base=chronic
    )
    for ct, nvm, live in zip(crash_ts, nvms, lives):
        ref_nvm = resolve_nvm_image(trace, ct, start, seq_values, cfg.block_bytes,
                                    chronic_base=chronic)
        ref_live = resolve_live_values(trace, ct, start, seq_values, cfg.block_bytes)
        for o in start:
            np.testing.assert_array_equal(nvm[o], ref_nvm[o])
            np.testing.assert_array_equal(live[o], ref_live[o])


# ------------------------------------------------------------- LRU invariants
@given(
    capacity=st.integers(1, 8),
    ops=st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 2), st.integers(0, 15)),
        min_size=1, max_size=300,
    ),
)
@settings(max_examples=60, deadline=None)
def test_lru_capacity_and_dirty_invariants(capacity, ops):
    """Model-based check of the exact LRU: capacity is never exceeded, dirty
    lines are always resident, evictions hit the least-recently-used line,
    and flushes clean without evicting."""
    lru = _LRU(capacity)
    order = []          # our own recency list, oldest first
    dirty = {}          # key -> writer seq
    for i, (kind, objid, blk) in enumerate(ops):
        key = (f"o{objid}", blk)
        if kind == 3:  # flush one object
            obj = f"o{objid}"
            lru.clean_obj(obj)
            for k in list(dirty):
                if k[0] == obj:
                    del dirty[k]
            assert lru.dirty_lines_of(obj) == []
        else:
            write = kind in (1, 2)
            miss = key not in order
            evicted = lru.access(key, i if write else -1)
            if evicted is not None:
                evk = (evicted[0], evicted[1])
                assert evk == order[0], "eviction must be the LRU line"
                assert evicted[2] == dirty[evk], "evicted seq is the writer's"
                assert len(order) == capacity
                order.pop(0)
                dirty.pop(evk, None)
            elif miss and len(order) >= capacity:
                # the LRU line was clean: dropped silently, no write-back
                assert order[0] not in dirty
                order.pop(0)
            if key in order:
                order.remove(key)
            order.append(key)
            if write:
                dirty[key] = i
        # invariants after every op
        assert len(lru._lines) <= capacity
        resident = set(lru._lines)
        all_dirty = {k for k, seq in lru._lines.items() if seq >= 0}
        assert all_dirty <= resident
        assert all_dirty == set(dirty), f"op {i}"
        assert list(lru._lines) == order
        # the per-object dirty index must equal a full-cache scan, in the
        # cache's recency order — flush emission order depends on it
        for obj in {k[0] for k in lru._lines} | set(lru._dirty):
            scan = [
                (blk, seq) for (o, blk), seq in lru._lines.items()
                if o == obj and seq >= 0
            ]
            assert lru.dirty_lines_of(obj) == scan, f"op {i} obj {obj}"
            mask = lru.dirty_resident_mask(obj, 16)
            assert set(np.flatnonzero(mask)) == {blk for blk, _ in scan}
