"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes and no NaNs.  Full configs are exercised only by
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, arch_names, get_arch
from repro.launch.steps import init_train_state, make_train_step
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_and_aux,
    scaled_down,
)

ALL = sorted(ARCHS)


def _batch(cfg, key, b=2, s=16):
    batch = {"tokens": jax.random.randint(key, (b, s + 1), 0, cfg.vocab)}
    if cfg.frontend_tokens:
        batch["patches"] = jax.random.normal(
            key, (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_and_finite(name):
    cfg = scaled_down(get_arch(name))
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    patches = None
    if cfg.frontend_tokens:
        patches = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
        )
    logits, aux = forward(cfg, params, tokens, patches)
    s_total = s + cfg.frontend_tokens
    assert logits.shape == (b, s_total, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("name", ALL)
def test_train_step_no_nans(name):
    cfg = scaled_down(get_arch(name))
    step = make_train_step(cfg, peak_lr=1e-3, total_steps=10)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(3))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # parameters actually moved
    before = jax.tree.leaves(state["params"])[1]
    after = jax.tree.leaves(new_state["params"])[1]
    assert not np.array_equal(np.asarray(before), np.asarray(after))
    for leaf in jax.tree.leaves(new_state["params"]):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("name", ALL)
def test_decode_step_shapes(name):
    cfg = scaled_down(get_arch(name))
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = 2
    cache = init_cache(cfg, b, max_len=32)
    token = jax.random.randint(jax.random.PRNGKey(4), (b, 1), 0, cfg.vocab)
    logits, new_cache = decode_step(cfg, params, token, cache)
    assert logits.shape == (b, 1, cfg.vocab)
    assert int(new_cache["t"]) == 1
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


def test_all_archs_registered():
    assert len(ALL) == 10
    assert set(ALL) == {
        "musicgen-medium", "minitron-8b", "granite-8b", "stablelm-1.6b",
        "nemotron-4-340b", "recurrentgemma-9b", "rwkv6-3b",
        "llama4-scout-17b-a16e", "qwen2-moe-a2.7b", "internvl2-76b",
    }


def test_exact_assigned_configs():
    """The full configs carry the exact assigned hyper-parameters."""
    expect = {
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
    }
    for name, (L, d, hq, hkv, ff, V) in expect.items():
        cfg = get_arch(name)
        assert cfg.n_layers == L, name
        assert cfg.d_model == d, name
        assert cfg.n_heads == hq, name
        assert cfg.n_kv_heads == hkv, name
        assert cfg.d_ff == ff, name
        assert cfg.vocab == V, name
    q = get_arch("qwen2-moe-a2.7b").moe
    assert q.num_experts == 60 and q.top_k == 4 and q.d_ff_shared == 5632
    l4 = get_arch("llama4-scout-17b-a16e").moe
    assert l4.num_experts == 16 and l4.top_k == 1
    rg = get_arch("recurrentgemma-9b")
    assert rg.total_layers() == 38 and rg.attn_window == 2048
