"""Adaptive crash-campaign scheduler vs the brute-force W+2 workflow.

The differential contract: ``plan_source="adaptive"`` with the uniform
sampler (``sampler_bias=0``) draws the *identical* planned tests as the
brute-force workflow, so early stopping — which only ever fires when the
knapsack decision is provably invariant to the remaining uncertainty —
must land the byte-identical final plan on every suite app while
executing strictly fewer crash tests.  With the importance sampler on
(the default ``sampler_bias``), draws differ; the estimator is unbiased
for the same rates, and the per-app agreement is pinned in the golden
(cg's knife-edge knapsack decision is the one documented divergence).

Oracle: ``tests/golden/adaptive_goldens.json`` — regenerate with

    PYTHONPATH=src python tests/test_adaptive.py --regen

which re-runs the brute-force workflow live (cross-checked against
``tests/golden/static_agreement.json``) and re-pins the adaptive
tests-executed counts for both sampler settings.
"""
import dataclasses
import json
import os

import pytest

from repro.core import (
    CrashTester,
    SequentialConfig,
    WorkflowConfig,
    load_workflow,
    run_workflow,
    save_workflow,
)
from repro.hpc.suite import ci_app, default_cache

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "adaptive_goldens.json")
BRUTE_GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                            "static_agreement.json")
SUITE = ("sor", "pagerank", "kmeans", "heat", "mg", "cg", "montecarlo")
N_TESTS = 40          # the golden oracle size (matches static_agreement.json)

#: the provable configuration: uniform draws (bit-identical to brute force)
#: + sequential stopping.  round_tests matches SequentialConfig's default so
#: the exact and default-IS runs stop on the same round geometry.
EXACT_STOPPING = SequentialConfig(sampler_bias=0.0)


def _cfg(cache, stopping=None, **kw):
    return WorkflowConfig(
        n_tests=N_TESTS, seed=0, cache=cache, plan_source="adaptive",
        stopping=stopping, **kw,
    )


def _plan_key(wf):
    return {
        "critical": list(wf.plan.objects),
        "region_freq": {str(k): v for k, v in sorted(wf.plan.region_freq.items())},
    }


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def brute_golden():
    with open(BRUTE_GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def exact_runs():
    """One uniform-sampler adaptive workflow per suite app (the cheap side
    of the differential: early stopping makes these cost less than brute)."""
    out = {}
    for name in SUITE:
        app = ci_app(name)
        out[name] = run_workflow(app, _cfg(default_cache(app),
                                           stopping=EXACT_STOPPING))
    return out


# ------------------------------------------------------------- differential
def test_exact_adaptive_plan_equals_brute_force(exact_runs, golden, brute_golden):
    """Uniform-sampler adaptive == brute force on EVERY suite app."""
    for name in SUITE:
        wf = exact_runs[name]
        brute = brute_golden[name]
        assert list(wf.plan.objects) == brute["critical"], name
        assert {str(k): v for k, v in wf.plan.region_freq.items()} \
            == brute["region_freq"], name
        # strictly fewer tests than the brute-force total, never more
        assert wf.tests_executed < brute["n_tests_total"], name


def test_exact_adaptive_goldens_pinned(exact_runs, golden):
    """Tests-executed counts and stop rounds are deterministic — pinned."""
    for name in SUITE:
        wf, g = exact_runs[name], golden[name]["exact"]
        rep = wf.adaptive
        assert wf.tests_executed == g["tests_executed"], name
        assert rep.stopped_early == g["stopped_early"], name
        assert rep.rounds_executed == g["rounds_executed"], name
        assert rep.rounds_total == g["rounds_total"], name
        assert _plan_key(wf) == golden[name]["plan"], name


def test_exact_adaptive_savings_bar(exact_runs, golden, brute_golden):
    """>= 40% fewer executed crash tests on at least 3 suite apps."""
    cleared = [
        name for name in SUITE
        if 1 - exact_runs[name].tests_executed
        / brute_golden[name]["n_tests_total"] >= 0.40
    ]
    assert len(cleared) >= 3, cleared


def test_adaptive_report_evidence(exact_runs):
    """The report carries per-region evidence consistent with the run."""
    for name in SUITE:
        rep = exact_runs[name].adaptive
        assert rep.tests_skipped == rep.tests_planned - rep.tests_executed
        assert rep.tests_skipped >= 0
        # uniform sampler: no IS spec, unit weights, n_eff == n
        assert rep.sampler is None
        for ev in rep.regions:
            assert 0 <= ev.executed <= ev.planned
            lo, hi = ev.interval
            assert 0.0 <= lo <= ev.rate <= hi <= 1.0
            assert ev.n_eff == pytest.approx(ev.executed)
        # pure adaptive mode: the persist-everything reference rode the
        # rounds and carries its own evidence
        assert rep.reference is not None
        assert rep.reference.region == -1
        assert rep.reference.executed == exact_runs[name].best_campaign.n


# ------------------------------------------------------- default (IS) config
def test_default_is_agreement_pinned(golden, brute_golden):
    """Default sampler_bias: per-app plan agreement as pinned (cg is the
    documented knife-edge divergence), savings counts pinned."""
    for name in ("pagerank", "kmeans", "cg"):
        app = ci_app(name)
        wf = run_workflow(app, _cfg(default_cache(app)))
        g = golden[name]["default_is"]
        agrees = {str(k): v for k, v in wf.plan.region_freq.items()} \
            == brute_golden[name]["region_freq"]
        assert agrees == g["plan_matches"], name
        assert wf.tests_executed == g["tests_executed"], name
        assert wf.adaptive.sampler is not None
        assert wf.adaptive.sampler["kind"] == "static-prior"


@pytest.mark.slow
def test_default_is_agreement_all_apps(golden, brute_golden):
    """Full-suite default-IS sweep: >= 6/7 plans match brute force."""
    matches = 0
    for name in SUITE:
        app = ci_app(name)
        wf = run_workflow(app, _cfg(default_cache(app)))
        g = golden[name]["default_is"]
        agrees = {str(k): v for k, v in wf.plan.region_freq.items()} \
            == brute_golden[name]["region_freq"]
        assert agrees == g["plan_matches"], name
        assert wf.tests_executed == g["tests_executed"], name
        matches += agrees
    assert matches >= 6


# ------------------------------------------------- determinism + kill/resume
def _wf_dicts(wf):
    return {
        "baseline": [dataclasses.asdict(r) for r in wf.baseline_campaign.records],
        "best": [dataclasses.asdict(r) for r in wf.best_campaign.records],
        "plan": (wf.plan.objects, tuple(sorted(wf.plan.region_freq.items()))),
        "adaptive": wf.adaptive.to_payload(),
        "summary": wf.summary(),
    }


@pytest.mark.slow
@pytest.mark.parametrize("n_workers", [2, 4])
def test_adaptive_worker_parity(n_workers):
    """Stopping is a pure function of the completed-round prefix: every
    worker count produces the bit-identical workflow."""
    app = ci_app("kmeans")
    cache = default_cache(app)
    one = run_workflow(app, _cfg(cache, n_workers=1))
    par = run_workflow(app, _cfg(cache, n_workers=n_workers))
    assert _wf_dicts(one) == _wf_dicts(par), n_workers


def test_adaptive_resume_after_kill(tmp_path):
    """An adaptive workflow killed mid-run (torn trailing store line)
    resumes bit-identically, re-executing only the missing shards, and
    stops on the same round."""
    app = ci_app("kmeans")
    cache = default_cache(app)
    path = str(tmp_path / "wf.jsonl")
    kw = dict(store_path=path)
    full = run_workflow(app, _cfg(cache, **kw))
    assert full.adaptive.stopped_early

    lines = open(path).read().splitlines()
    n_shard_lines = sum(1 for ln in lines if '"type": "shard"' in ln)
    assert n_shard_lines >= 4
    keep = len(lines) // 2
    with open(path, "w") as f:
        f.write("\n".join(lines[:keep]) + "\n"
                + lines[keep][: len(lines[keep]) // 2])

    # every executed shard prepares its window exactly once, on both the
    # per-shard and the chunked (lane-batched) vec paths
    executed = []
    orig = CrashTester._prepare_window_items

    def counting(self, crash_iter, tests):
        executed.append(crash_iter)
        return orig(self, crash_iter, tests)

    CrashTester._prepare_window_items = counting
    try:
        resumed = run_workflow(app, _cfg(cache, **kw))
    finally:
        CrashTester._prepare_window_items = orig
    assert _wf_dicts(resumed) == _wf_dicts(full)
    kept_shards = sum(1 for ln in lines[:keep] if '"type": "shard"' in ln)
    assert len(executed) == n_shard_lines - kept_shards

    # a completed store resumes executing nothing, same stop round
    executed.clear()
    CrashTester._prepare_window_items = counting
    try:
        again = run_workflow(app, _cfg(cache, **kw))
    finally:
        CrashTester._prepare_window_items = orig
    assert _wf_dicts(again) == _wf_dicts(full)
    assert executed == []


# ------------------------------------------------------ composition + config
def test_static_verify_composes_with_stopping():
    """static+verify + stopping: only the uncertain regions get (sequential)
    campaigns; the persist-everything reference runs in full because the
    confident regions' fixed gains consume it."""
    app = ci_app("heat")          # uncertain regions [1, 2]
    cache = default_cache(app)
    wf = run_workflow(app, WorkflowConfig(
        n_tests=N_TESTS, seed=0, cache=cache, plan_source="static+verify",
        stopping=SequentialConfig()))
    assert dict(wf.plan.region_freq) == {}        # matches measured golden
    rep = wf.adaptive
    assert rep is not None
    assert rep.reference is None                  # best ran in full
    assert wf.best_campaign.n == N_TESTS
    assert {ev.region for ev in rep.regions} == {1, 2}
    assert wf.tests_executed < 170                # brute-force total on heat


def test_adaptive_artifact_roundtrip(exact_runs, tmp_path):
    wf = exact_runs["pagerank"]
    path = str(tmp_path / "pagerank_adaptive.json")
    save_workflow(path, wf)
    art = load_workflow(path)
    rep = art.adaptive_report()
    assert rep.to_payload() == wf.adaptive.to_payload()
    assert rep.stopped_early == wf.adaptive.stopped_early
    assert rep.reference is not None


def test_config_validation():
    with pytest.raises(ValueError, match="isolated"):
        WorkflowConfig(plan_source="adaptive", region_measure="paper")
    with pytest.raises(ValueError, match="shared"):
        WorkflowConfig(plan_source="adaptive", scheduler="serial")
    with pytest.raises(ValueError, match="stopping"):
        WorkflowConfig(plan_source="measured", stopping=SequentialConfig())
    with pytest.raises(ValueError, match="round_tests"):
        SequentialConfig(round_tests=0)
    with pytest.raises(ValueError, match="min_rounds"):
        SequentialConfig(min_rounds=0)
    with pytest.raises(ValueError, match="z"):
        SequentialConfig(z=-1.0)
    with pytest.raises(ValueError, match="sampler_bias"):
        SequentialConfig(sampler_bias=-0.5)


def test_adaptive_spec_identity():
    """Adaptive configs carry their stopping knobs in spec(); measured
    configs stay byte-identical to historical fingerprints."""
    cfg = WorkflowConfig(n_tests=8, plan_source="adaptive")
    d = json.loads(json.dumps(cfg_spec_dict(cfg)))
    assert d["stopping"] == SequentialConfig().spec()
    measured = WorkflowConfig(n_tests=8)
    assert "stopping" not in cfg_spec_dict(measured)


def cfg_spec_dict(cfg):
    from repro.core import CacheConfig, PersistPlan

    app = ci_app("kmeans")
    tester = CrashTester(app, PersistPlan.none(), CacheConfig(), seed=0)
    return cfg.spec(app, tester)


# ------------------------------------------------------------------- regen
def _regen():
    out = {}
    for name in SUITE:
        app = ci_app(name)
        cache = default_cache(app)
        brute = run_workflow(app, WorkflowConfig(
            n_tests=N_TESTS, seed=0, cache=cache))
        with open(BRUTE_GOLDEN) as f:
            pinned = json.load(f)[name]
        if brute.tests_executed != pinned["n_tests_total"]:
            raise SystemExit(
                f"{name}: live brute force disagrees with "
                f"static_agreement.json — regenerate that golden first")
        exact = run_workflow(app, _cfg(cache, stopping=EXACT_STOPPING))
        default = run_workflow(app, _cfg(cache))
        brute_freq = {str(k): v for k, v in sorted(brute.plan.region_freq.items())}
        if _plan_key(exact) != {"critical": list(brute.plan.objects),
                                "region_freq": brute_freq}:
            raise SystemExit(f"{name}: exact adaptive plan != brute force")
        out[name] = {
            "plan": _plan_key(exact),
            "brute_tests": brute.tests_executed,
            "exact": {
                "tests_executed": exact.tests_executed,
                "stopped_early": exact.adaptive.stopped_early,
                "rounds_executed": exact.adaptive.rounds_executed,
                "rounds_total": exact.adaptive.rounds_total,
            },
            "default_is": {
                "tests_executed": default.tests_executed,
                "plan_matches": _plan_key(default)["region_freq"] == brute_freq,
                "stopped_early": default.adaptive.stopped_early,
            },
        }
        print(f"{name}: exact {exact.tests_executed}/{brute.tests_executed} "
              f"default {default.tests_executed} "
              f"(match={out[name]['default_is']['plan_matches']})")
    with open(GOLDEN, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"wrote {GOLDEN}")


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        sys.exit("usage: python tests/test_adaptive.py --regen")
