"""Property tests for the adaptive scheduler's statistical machinery.

Three families, matching the soundness claims in
:mod:`repro.core.adaptive`:

* Wilson intervals — coverage on synthetic Bernoulli streams, width
  monotonicity in ``n`` and ``z``, containment of the point estimate;
* the self-normalized importance-sampling estimator — exact agreement
  with the plain mean under uniform weights, convergence to the
  uniform-draw rates under a tilted proposal, Kish ``n_eff <= n``;
* the stopping rule — :func:`selection_invariant` NEVER returns a
  decision while any point inside the gain box would change the
  knapsack's plan (stopping cannot fire while the decision is
  interval-ambiguous).

Hypothesis is a dev-only dependency; the file skips cleanly where it is
not installed (the pinned differential suite in tests/test_adaptive.py
does not depend on it).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.adaptive import (  # noqa: E402
    effective_sample_size,
    final_rate_interval,
    selection_invariant,
    weighted_outcome_stats,
    wilson_interval,
)
from repro.core.selection import select_regions_from_gains  # noqa: E402


# ------------------------------------------------------------------- Wilson
@given(
    n=st.integers(min_value=1, max_value=500),
    frac=st.floats(min_value=0.0, max_value=1.0),
    z=st.floats(min_value=0.1, max_value=4.0),
)
def test_wilson_contains_point_and_stays_in_unit_interval(n, frac, z):
    s = frac * n
    lo, hi = wilson_interval(s, n, z)
    assert 0.0 <= lo <= hi <= 1.0
    assert lo <= s / n + 1e-12 and s / n - 1e-12 <= hi


@given(
    n=st.integers(min_value=2, max_value=400),
    frac=st.floats(min_value=0.0, max_value=1.0),
)
def test_wilson_width_shrinks_with_n(n, frac):
    """Same success fraction, more samples -> never a wider interval."""
    lo1, hi1 = wilson_interval(frac * n, n)
    lo2, hi2 = wilson_interval(frac * 2 * n, 2 * n)
    assert (hi2 - lo2) <= (hi1 - lo1) + 1e-12


@given(
    n=st.integers(min_value=1, max_value=400),
    frac=st.floats(min_value=0.0, max_value=1.0),
    z=st.floats(min_value=0.2, max_value=2.0),
)
def test_wilson_width_grows_with_z(n, frac, z):
    lo1, hi1 = wilson_interval(frac * n, n, z)
    lo2, hi2 = wilson_interval(frac * n, n, z * 1.5)
    assert (hi2 - lo2) >= (hi1 - lo1) - 1e-12


def test_wilson_coverage_on_bernoulli_streams():
    """Empirical coverage within slack of nominal on synthetic streams."""
    rng = np.random.default_rng(7)
    for p in (0.1, 0.5, 0.9):
        for n in (20, 60):
            hits = 0
            trials = 1500
            for _ in range(trials):
                s = rng.binomial(n, p)
                lo, hi = wilson_interval(s, n, z=1.96)
                hits += lo <= p <= hi
            # nominal 95%; Wilson is near-nominal for all p, n
            assert hits / trials >= 0.92, (p, n, hits / trials)


# --------------------------------------------------------- IS estimator
@given(
    vals=st.lists(st.sampled_from([0.0, 1.0]), min_size=1, max_size=60),
    w=st.floats(min_value=0.05, max_value=20.0),
)
def test_uniform_weights_recover_plain_mean(vals, w):
    rate, n_eff = weighted_outcome_stats(vals, [w] * len(vals))
    assert rate == pytest.approx(float(np.mean(vals)))
    assert n_eff == pytest.approx(len(vals))


@given(
    weights=st.lists(st.floats(min_value=0.01, max_value=50.0),
                     min_size=1, max_size=60),
)
def test_kish_effective_sample_size_bounds(weights):
    n_eff = effective_sample_size(weights)
    assert 1.0 - 1e-9 <= n_eff <= len(weights) + 1e-9


def test_self_normalized_is_converges_to_uniform_rates():
    """Tilted proposal + p/q weights recover the uniform-draw S1 rate."""
    rng = np.random.default_rng(11)
    p = np.array([0.5, 0.3, 0.2])          # uniform (span-proportional) mass
    q = np.array([0.2, 0.3, 0.5])          # tilted proposal
    rates = np.array([1.0, 0.4, 0.1])      # per-region S1 probability
    true_rate = float(p @ rates)
    n = 6000
    ks = rng.choice(3, size=n, p=q)
    vals = (rng.random(n) < rates[ks]).astype(float)
    ws = (p / q)[ks]
    est, n_eff = weighted_outcome_stats(vals.tolist(), ws.tolist())
    assert est == pytest.approx(true_rate, abs=0.03)
    assert n_eff < n                       # non-uniform weights cost ESS


# ----------------------------------------------------------- stopping rule
@st.composite
def knapsack_instances(draw):
    n_regions = draw(st.integers(min_value=1, max_value=4))
    point, boxes, overheads = {}, {}, {}
    for k in range(n_regions):
        lo = draw(st.floats(min_value=-0.5, max_value=0.9))
        width = draw(st.floats(min_value=0.0, max_value=0.4))
        point[k] = lo + width * draw(st.floats(min_value=0.0, max_value=1.0))
        boxes[k] = (lo, lo + width)
        overheads[k] = draw(st.floats(min_value=1e-4, max_value=0.05))
    y_base = draw(st.floats(min_value=0.0, max_value=1.0))
    t_s = draw(st.floats(min_value=0.005, max_value=0.1))
    tau = draw(st.floats(min_value=0.1, max_value=0.9))
    return point, boxes, overheads, y_base, t_s, tau


@settings(max_examples=60, deadline=None)
@given(inst=knapsack_instances(), data=st.data())
def test_stopping_never_fires_while_decision_ambiguous(inst, data):
    """If selection_invariant claims a decision, every point inside the
    gain box (not just the corners) yields that same plan."""
    point, boxes, overheads, y_base, t_s, tau = inst
    decision = selection_invariant(point, boxes, overheads, y_base,
                                   t_s=t_s, tau=tau)
    if decision is None:
        return
    # the point estimate itself must produce the claimed plan
    assert select_regions_from_gains(
        point, overheads, y_base, t_s=t_s, tau=tau).plan_freqs() == decision
    # and so must arbitrary interior points of the box
    for _ in range(5):
        gains = {
            k: lo + (hi - lo) * data.draw(
                st.floats(min_value=0.0, max_value=1.0))
            for k, (lo, hi) in boxes.items()
        }
        assert select_regions_from_gains(
            gains, overheads, y_base, t_s=t_s, tau=tau,
        ).plan_freqs() == decision, gains


def test_max_corners_guard_never_claims_invariance():
    point = {k: 0.5 for k in range(3)}
    boxes = {k: (0.1, 0.9) for k in range(3)}
    overheads = {k: 0.001 for k in range(3)}
    assert selection_invariant(point, boxes, overheads, 0.2,
                               t_s=0.03, tau=0.4, max_corners=4) is None


# ------------------------------------------------------ final_rate_interval
@given(
    vals=st.lists(st.sampled_from([0.0, 1.0]), min_size=1, max_size=40),
    data=st.data(),
)
def test_final_rate_interval_invariants(vals, data):
    ws = [data.draw(st.floats(min_value=0.1, max_value=5.0))
          for _ in vals]
    rem = [data.draw(st.floats(min_value=0.1, max_value=5.0))
           for _ in range(data.draw(st.integers(min_value=0, max_value=20)))]
    lo, hi, rate, n_eff = final_rate_interval(vals, ws, rem, z=1.645)
    assert 0.0 <= lo <= rate <= hi <= 1.0
    # hard reachable bound is never violated
    s = float(np.dot(vals, ws))
    w_tot = float(np.sum(ws) + np.sum(rem))
    assert lo >= s / w_tot - 1e-9
    assert hi <= (s + float(np.sum(rem))) / w_tot + 1e-9
    if not rem:
        # no remaining mass: Wilson may stay wide but the hard bound (and
        # therefore the intersection) collapses onto the exact final rate
        assert lo == pytest.approx(rate) and hi == pytest.approx(rate)
