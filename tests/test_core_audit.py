"""Static API audits: every ``*Config``/``*Result`` exported from
``repro.core`` is a frozen dataclass exposing ``spec()``, and the shared
window-trace cache behaves at its cap edge cases."""
import dataclasses

import numpy as np
import pytest

import repro.core as core
import repro.core.trace_cache as tc
from repro.core import CacheConfig, CrashTester, PersistPlan
from repro.core.trace_cache import WindowTraceCache, shared_trace_cache
from repro.hpc.suite import ci_app


AUDITED = sorted(
    n for n in core.__all__ if n.endswith("Config") or n.endswith("Result")
)


def test_audit_covers_the_expected_surface():
    # additions are welcome; silent removals from the audit are not
    assert {"CacheConfig", "CampaignResult", "SystemConfig", "SimResult",
            "FleetConfig", "FleetResult", "WorkflowConfig", "WorkflowResult",
            "VerifyResult"} <= set(AUDITED)


@pytest.mark.parametrize("name", AUDITED)
def test_config_result_frozen_with_spec(name):
    cls = getattr(core, name)
    assert dataclasses.is_dataclass(cls), f"{name} is not a dataclass"
    assert cls.__dataclass_params__.frozen, f"{name} is not frozen"
    assert callable(getattr(cls, "spec", None)), f"{name} has no spec()"


def test_campaign_result_spec_is_json_and_frozen():
    import json

    app = ci_app("kmeans")
    camp = CrashTester(app, PersistPlan.none(), CacheConfig(), seed=0
                       ).run_campaign(6)
    d = json.loads(json.dumps(camp.spec()))
    assert d["app"] == "kmeans" and d["n_tests"] == 6
    assert set(d["class_fractions"]) == {"S1", "S2", "S3", "S4"}
    with pytest.raises(dataclasses.FrozenInstanceError):
        camp.golden_iters = 99


# ------------------------------------------------------------- trace cache
@pytest.fixture
def fresh_shared():
    """Snapshot/restore the process-shared cache around env manipulation."""
    old = tc._SHARED
    tc._SHARED = None
    yield
    tc._SHARED = old


def test_trace_cache_env_zero_disables(fresh_shared, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_CACHE", "0")
    cache = shared_trace_cache()
    assert cache.max_traces == 0 and cache.max_payloads == 0
    cache.put_trace(("k",), ("t", {}, 0))
    cache.put_payload(("k",), tc.WindowPayload({}, {}, ()))
    s = cache.stats()
    assert s["traces"] == 0 and s["payloads"] == 0
    # a campaign through the disabled cache is still bit-identical
    app = ci_app("kmeans")
    disabled = CrashTester(app, PersistPlan.none(), CacheConfig(), seed=0,
                           trace_cache=cache).run_campaign(5)
    normal = CrashTester(ci_app("kmeans"), PersistPlan.none(), CacheConfig(),
                         seed=0, trace_cache=WindowTraceCache()).run_campaign(5)
    assert [r.outcome for r in disabled.records] == \
           [r.outcome for r in normal.records]
    assert cache.stats()["traces"] == 0  # still nothing retained


def test_trace_cache_env_garbage_falls_back(fresh_shared, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_CACHE", "not-a-number")
    cache = shared_trace_cache()
    assert cache.max_traces == 128 and cache.max_payloads == 32


def test_trace_cache_cap_one_is_lru(fresh_shared, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_CACHE", "1")
    cache = shared_trace_cache()
    assert cache.max_traces == 1
    cache.put_trace(("a",), ("ta", {}, 0))
    cache.put_trace(("b",), ("tb", {}, 0))
    assert cache.get_trace(("a",)) is None        # evicted by cap=1
    assert cache.get_trace(("b",)) == ("tb", {}, 0)
    # re-put of the survivor refreshes, not duplicates
    cache.put_trace(("b",), ("tb", {}, 0))
    assert cache.stats()["traces"] == 1
    assert cache.stats()["hits"] == 1 and cache.stats()["misses"] == 1


def test_trace_cache_payload_cap_independent():
    cache = WindowTraceCache(max_traces=8, max_payloads=1)
    p1 = tc.WindowPayload({0: {"u": np.zeros(2)}}, {"u": 1}, ((0, 0, 0),))
    p2 = tc.WindowPayload({1: {"u": np.ones(2)}}, {"u": 1}, ((1, 1, 0),))
    cache.put_payload(("p1",), p1)
    cache.put_trace(("t1",), ("x", {}, 0))
    cache.put_payload(("p2",), p2)                # evicts p1, not t1
    assert cache.get_payload(("p1",)) is None
    assert cache.get_payload(("p2",)) is p2
    assert cache.get_trace(("t1",)) == ("x", {}, 0)
    s = cache.stats()
    assert s["payloads"] == 1 and s["traces"] == 1
    assert s["payload_hits"] == 1 and s["payload_misses"] == 1


def test_trace_cache_app_tokens_never_reused():
    cache = WindowTraceCache()
    a1, a2 = ci_app("kmeans"), ci_app("kmeans")
    t1, t2 = cache.app_token(a1), cache.app_token(a2)
    assert t1 != t2
    assert cache.app_token(a1) == t1              # stable per live object
    del a1
    a3 = ci_app("kmeans")
    assert cache.app_token(a3) not in (t1,)       # ids are monotonic
