"""Hypothesis property tests for the fleet simulator.

Randomized generalizations of the fixed-seed invariants in
``tests/test_fleetsim.py``: request conservation and the replica-seconds
time partition must hold for *every* policy and fleet geometry, and
identical seeds must reproduce byte-identical results.
"""
import dataclasses
import json

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e .[dev])"
)
from hypothesis import given, settings, strategies as st

from repro.core.efficiency import SystemConfig
from repro.core.fleetsim import ArrivalProcess, FleetConfig, ServiceModel, simulate_fleet
from repro.core.sysim import POLICIES, PoissonTrace, RecomputeProfile

PROFILE = RecomputeProfile.from_fractions(
    "decode", {"S1": 0.75, "S2": 0.15, "S3": 0.05, "S4": 0.05},
    extra_iters_hist=((2, 4), (9, 1)),
)

SERVE_SYS = SystemConfig(mtbf=1800.0, t_chk=20.0, nvm_restore_time=2.0)


def _prof_for(policy):
    return PROFILE if policy in ("easycrash", "hybrid") else None


@settings(max_examples=25, deadline=None)
@given(
    policy=st.sampled_from(POLICIES),
    seed=st.integers(0, 2**31 - 1),
    rate=st.floats(0.0, 5.0),
    amplitude=st.floats(0.0, 0.9),
    mtbf=st.floats(120.0, 1e6),
    sigma=st.floats(0.0, 1.2),
    n_replicas=st.integers(1, 5),
    queue_cap=st.integers(1, 40),
    t_s=st.floats(0.0, 0.3),
)
def test_request_conservation_and_time_partition(
    policy, seed, rate, amplitude, mtbf, sigma, n_replicas, queue_cap, t_s
):
    """arrived == served + dropped + in-flight, exactly, for every policy and
    geometry; and replica-seconds partition into up/checkpoint/down."""
    cfg = FleetConfig(
        n_replicas=n_replicas,
        arrival=ArrivalProcess(rate=rate, amplitude=amplitude),
        service=ServiceModel(mean_s=0.4, sigma=sigma, prefill_s=0.8),
        trace=PoissonTrace(mtbf=mtbf),
        system=SERVE_SYS,
        slo_latency=1.5,
        queue_cap=queue_cap,
        horizon=900.0,
        t_s=t_s,
        seed=seed,
    )
    r = simulate_fleet(policy, cfg, _prof_for(policy))
    assert r.arrived == r.served + r.dropped + r.in_flight
    assert r.dropped_down <= r.dropped
    assert sum(r.breakdown.values()) == pytest.approx(
        cfg.n_replicas * cfg.horizon, abs=1e-6
    )
    assert 0.0 <= r.availability <= 1.0
    assert 0.0 <= r.slo_violation_frac <= 1.0
    if r.served:
        assert r.latency_p50 <= r.latency_p95 <= r.latency_p99 <= r.latency_max


@settings(max_examples=15, deadline=None)
@given(policy=st.sampled_from(POLICIES), seed=st.integers(0, 2**31 - 1))
def test_identical_seeds_are_byte_identical(policy, seed):
    cfg = FleetConfig(
        n_replicas=3,
        arrival=ArrivalProcess(rate=3.0, amplitude=0.25),
        service=ServiceModel(mean_s=0.4, sigma=0.5, prefill_s=0.8),
        trace=PoissonTrace(mtbf=600.0),
        system=SERVE_SYS,
        slo_latency=1.5,
        queue_cap=32,
        horizon=600.0,
        seed=seed,
    )
    a = simulate_fleet(policy, cfg, _prof_for(policy))
    b = simulate_fleet(policy, cfg, _prof_for(policy))
    assert a == b
    assert json.dumps(a.payload(), sort_keys=True) == \
        json.dumps(b.payload(), sort_keys=True)
    assert dataclasses.asdict(a) == dataclasses.asdict(b)
