"""Optimizer substrate: AdamW math, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.optim import (
    adamw_init,
    adamw_update,
    compress_topk,
    cosine_schedule,
    decompress_topk,
    dequantize_int8,
    quantize_int8,
)
from repro.optim.adamw import AdamWConfig


def test_adamw_descends_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, stats = adamw_update(params, grads, state, lr=0.1, cfg=cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1
    assert int(state["count"]) == 200


def test_adamw_clips_gradients():
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    _, _, stats = adamw_update(params, {"w": jnp.full(4, 1e6)}, state, lr=0.0)
    assert float(stats["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_adamw_bf16_moments():
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    state = adamw_init(params, moment_dtype="bfloat16")
    assert state["mu"]["w"].dtype == jnp.bfloat16
    new_p, new_s, _ = adamw_update(params, {"w": jnp.ones(4, jnp.bfloat16)}, state, lr=1e-3)
    assert new_s["mu"]["w"].dtype == jnp.bfloat16
    assert new_p["w"].dtype == jnp.bfloat16


def test_cosine_schedule_shape():
    warm = float(cosine_schedule(jnp.asarray(0), 100, 1000, 1.0))
    peak = float(cosine_schedule(jnp.asarray(100), 100, 1000, 1.0))
    end = float(cosine_schedule(jnp.asarray(1000), 100, 1000, 1.0))
    assert warm < 0.05 and peak == pytest.approx(1.0, abs=0.02)
    assert end == pytest.approx(0.1, abs=0.02)  # floor_frac


@given(seed=st.integers(0, 1000), n=st.integers(8, 512))
@settings(max_examples=25, deadline=None)
def test_int8_quantization_bounded_error(seed, n):
    g = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s, g.dtype)
    max_err = float(jnp.max(jnp.abs(back - g)))
    assert max_err <= float(s) * 0.5 + 1e-7  # half-ULP of the quant grid


def test_topk_keeps_largest():
    g = jnp.array([0.1, -5.0, 0.2, 3.0, -0.05])
    vals, idx, residual = compress_topk(g, frac=0.4)  # k = 2
    back = decompress_topk(vals, idx, g.shape, g.dtype)
    np.testing.assert_allclose(np.asarray(back), [0, -5.0, 0, 3.0, 0], atol=1e-6)
    np.testing.assert_allclose(np.asarray(residual), [0.1, 0, 0.2, 0, -0.05], atol=1e-6)
    # decomposition is lossless: back + residual == g
    np.testing.assert_allclose(np.asarray(back + residual), np.asarray(g), atol=1e-6)


@pytest.mark.parametrize("compression", [None, "int8", "topk:0.1"])
def test_train_step_with_compression(compression):
    from repro.configs import get_arch
    from repro.launch.steps import init_train_state, make_train_step
    from repro.models import scaled_down

    cfg = scaled_down(get_arch("stablelm-1.6b"))
    step = make_train_step(cfg, grad_compression=compression, total_steps=5)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab)}
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    for leaf in jax.tree.leaves(new_state["params"]):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))
