import math

import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core.efficiency import (
    SystemConfig,
    efficiency_with,
    efficiency_without,
    expected_overhead,
    scale_mtbf,
    tau_threshold,
    young_interval,
)


def test_young_interval():
    assert young_interval(320.0, 12 * 3600) == pytest.approx(math.sqrt(2 * 320 * 43200))


def test_efficiency_baseline_sane():
    cfg = SystemConfig(mtbf=12 * 3600, t_chk=320.0)
    r = efficiency_without(cfg)
    assert 0.5 < r.efficiency < 1.0
    assert r.interval == young_interval(320.0, cfg.mtbf)


def test_easycrash_beats_cr_at_high_recomputability():
    """The paper's headline: at the measured 82 % recomputability EasyCrash
    improves system efficiency, most at large checkpoint cost."""
    for t_chk, min_gain in [(32.0, 0.0), (320.0, 0.005), (3200.0, 0.05)]:
        cfg = SystemConfig(mtbf=12 * 3600, t_chk=t_chk)
        base = efficiency_without(cfg).efficiency
        ec = efficiency_with(cfg, recomputability=0.82, t_s=0.015).efficiency
        assert ec - base >= min_gain, (t_chk, base, ec)


def test_zero_recomputability_is_worse():
    """R = 0: EasyCrash adds flush overhead and saves nothing."""
    cfg = SystemConfig(mtbf=12 * 3600, t_chk=320.0)
    assert (
        efficiency_with(cfg, recomputability=0.0, t_s=0.03).efficiency
        < efficiency_without(cfg).efficiency
    )


def test_gain_grows_with_scale():
    """Paper Fig 11: the EasyCrash advantage grows as MTBF shrinks."""
    gains = []
    for nodes in (100_000, 200_000, 400_000):
        mtbf = scale_mtbf(12 * 3600, 100_000, nodes)
        cfg = SystemConfig(mtbf=mtbf, t_chk=3200.0)
        gains.append(
            efficiency_with(cfg, 0.82, t_s=0.015).efficiency
            - efficiency_without(cfg).efficiency
        )
    assert gains[0] < gains[1] < gains[2]


def test_tau_threshold_is_crossing_point():
    cfg = SystemConfig(mtbf=12 * 3600, t_chk=320.0)
    tau = tau_threshold(cfg, t_s=0.03)
    assert 0.0 < tau < 1.0
    base = efficiency_without(cfg).efficiency
    assert efficiency_with(cfg, tau + 0.02, 0.03).efficiency > base
    assert efficiency_with(cfg, max(tau - 0.02, 0.0), 0.03).efficiency < base


@given(
    mtbf_h=st.floats(1.0, 100.0),
    t_chk=st.floats(10.0, 5000.0),
    r=st.floats(0.0, 0.99),
)
@settings(max_examples=50, deadline=None)
def test_efficiency_bounded_and_monotone_in_r(mtbf_h, t_chk, r):
    cfg = SystemConfig(mtbf=mtbf_h * 3600, t_chk=t_chk)
    e1 = efficiency_with(cfg, r, t_s=0.02)
    e2 = efficiency_with(cfg, min(r + 0.05, 0.995), t_s=0.02)
    assert 0.0 <= e1.efficiency <= 1.0
    assert e2.efficiency >= e1.efficiency - 1e-9  # higher R never hurts


@given(t_chk=st.floats(1.0, 5000.0), mtbf_h=st.floats(0.5, 1000.0))
@settings(max_examples=80, deadline=None)
def test_young_interval_minimizes_expected_overhead(t_chk, mtbf_h):
    """Young's interval is the exact argmin of the first-order overhead rate
    it is derived from — no neighboring interval does better."""
    mtbf = mtbf_h * 3600.0
    T = young_interval(t_chk, mtbf)
    best = expected_overhead(T, t_chk, mtbf)
    for f in (0.5, 0.8, 0.95, 1.05, 1.25, 2.0):
        assert best <= expected_overhead(T * f, t_chk, mtbf) + 1e-12, f


@given(mtbf_h=st.floats(0.5, 1000.0), t_chk=st.floats(1.0, 5000.0))
@settings(max_examples=60, deadline=None)
def test_efficiency_without_bounded(mtbf_h, t_chk):
    """Plain C/R efficiency is a fraction of wall time — always in [0, 1) —
    and its breakdown accounts for the useful share exactly."""
    cfg = SystemConfig(mtbf=mtbf_h * 3600.0, t_chk=t_chk)
    r = efficiency_without(cfg)
    assert 0.0 <= r.efficiency < 1.0
    assert r.breakdown["useful"] == pytest.approx(r.efficiency * cfg.total_time)
    assert r.n_checkpoints >= 0.0


@given(
    mtbf_h=st.floats(0.5, 200.0),
    t_chk=st.floats(1.0, 5000.0),
    r=st.floats(0.0, 0.99),
)
@settings(max_examples=60, deadline=None)
def test_efficiency_monotone_in_mtbf(mtbf_h, t_chk, r):
    """A more reliable machine is never less efficient, with or without
    EasyCrash (paper Fig 11 read backwards)."""
    a = SystemConfig(mtbf=mtbf_h * 3600.0, t_chk=t_chk)
    b = SystemConfig(mtbf=1.5 * mtbf_h * 3600.0, t_chk=t_chk)
    assert efficiency_without(b).efficiency >= \
        efficiency_without(a).efficiency - 1e-9
    assert efficiency_with(b, r, t_s=0.02).efficiency >= \
        efficiency_with(a, r, t_s=0.02).efficiency - 1e-9


@given(
    mtbf_h=st.floats(2.0, 48.0),
    t_chk=st.floats(30.0, 2000.0),
    t_s=st.floats(0.005, 0.08),
)
@settings(max_examples=60, deadline=None)
def test_tau_threshold_brackets_the_crossing(mtbf_h, t_chk, t_s):
    """tau_threshold returns the minimum recomputability at which EasyCrash
    wins: just above it EasyCrash beats plain C/R, just below it doesn't
    (and inf means it never wins, not even at R -> 1)."""
    cfg = SystemConfig(mtbf=mtbf_h * 3600.0, t_chk=t_chk)
    base = efficiency_without(cfg).efficiency
    tau = tau_threshold(cfg, t_s=t_s)
    if math.isinf(tau):
        assert efficiency_with(cfg, 0.999999, t_s).efficiency <= base
        return
    assert 0.0 <= tau <= 1.0
    assert efficiency_with(cfg, min(tau + 1e-3, 0.999999), t_s).efficiency \
        > base - 1e-12
    if tau > 1e-3:
        assert efficiency_with(cfg, tau - 1e-3, t_s).efficiency <= base + 1e-12


def test_explicit_interval_overrides_young():
    """The interval parameter feeds interval sweeps: Young is the default,
    and a checkpoint-dominated interval is measurably worse."""
    cfg = SystemConfig(mtbf=12 * 3600.0, t_chk=320.0)
    T = young_interval(cfg.t_chk, cfg.mtbf)
    assert efficiency_without(cfg, interval=T) == efficiency_without(cfg)
    assert efficiency_without(cfg, interval=cfg.t_chk).efficiency \
        < efficiency_without(cfg).efficiency
    assert efficiency_with(cfg, 0.8, 0.02, interval=T * 2).interval == T * 2
