"""Static persist-plan analyzer: agreement with measured plans, the
static / static+verify workflow modes, and the static-plan artifact.

The measured oracle is ``tests/golden/static_agreement.json`` — the region
decisions of the full W+2 workflow at n_tests=40 / seed=0 on the CI-sized
suite apps (regenerate with ``python -m benchmarks.bench_static_plan
--full``).  The analyzer is judged on *region decision sets*: which regions
end up in the persist plan.
"""
import json
import math
import os

import pytest

from repro.analysis import CONFIDENCE_THRESHOLD, analyze_app
from repro.core import load_static_plan, save_static_plan
from repro.core.artifacts import ArtifactError
from repro.core.workflow import WorkflowConfig, run_workflow
from repro.hpc.suite import ci_app, default_cache

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "static_agreement.json")
SUITE = ("sor", "pagerank", "kmeans", "heat", "mg", "cg", "montecarlo")

#: apps whose static region decisions exactly match the measured workflow.
#: mg and cg are the two designed-in misses; everything else must agree —
#: acceptance bar is >= 5 of 7.
EXPECTED_AGREE = {"sor", "pagerank", "kmeans", "heat", "montecarlo"}

#: per-app expected-disagreement annotations for the two misses, asserted
#: exactly (region sets, not just "disagrees") so drift on either side
#: surfaces here.  Investigated and confirmed not to be classifier bugs:
EXPECTED_DISAGREEMENT = {
    "mg": {
        # static persists {2, 3}; measured selects {1, 3}.  R2_coarse
        # carries its value through untracked coarse-grid temporaries —
        # invisible to the candidate-object dataflow walk, yet its measured
        # gain is real.  R3_correct's write to u is immediately rewritten
        # by R4_smooth, so its measured marginal gain is too small for the
        # knapsack even though the walk sees "writes persist-decided u".
        # Both misses are *confident* (mg has no uncertain regions), so
        # static+verify cannot repair this app: the honest cost of the
        # static path, priced into the >= 5/7 agreement bar.
        "static_only": [2],
        "measured_only": [1],
        "verify_repairable": False,
    },
    "cg": {
        # static persists {1, 2, 3}; measured selects {2, 3}.  x_update
        # writes persist-decided x, but x is cheaply rebuilt from the p/r
        # recurrences, so its measured gain misses the knapsack.  Every cg
        # region decision is self-flagged (confidence 0.35 < threshold ->
        # uncertain_regions [1, 2, 3]), so static+verify re-measures the
        # lot and lands the measured plan.
        "static_only": [1],
        "measured_only": [],
        "verify_repairable": True,
    },
}


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def plans():
    out = {}
    for name in SUITE:
        app = ci_app(name)
        out[name] = analyze_app(app, cache=default_cache(app))
    return out


def test_agreement_with_measured_plans(golden, plans):
    agree = set()
    for name in SUITE:
        static = {r.index for r in plans[name].regions
                  if r.decision == "persist"}
        measured = set(golden[name]["persist_regions"])
        if static == measured:
            agree.add(name)
    assert len(agree) >= 5, f"static agreement below bar: {sorted(agree)}"
    assert agree == EXPECTED_AGREE


def test_expected_disagreement_annotations(golden, plans):
    """The two designed-in misses disagree in exactly the annotated way."""
    for name, note in EXPECTED_DISAGREEMENT.items():
        static = {r.index for r in plans[name].regions
                  if r.decision == "persist"}
        measured = set(golden[name]["persist_regions"])
        assert sorted(static - measured) == note["static_only"], name
        assert sorted(measured - static) == note["measured_only"], name
        flagged = set(plans[name].uncertain_regions())
        disagreeing = (static ^ measured)
        assert note["verify_repairable"] == (disagreeing <= flagged), name
    assert set(EXPECTED_DISAGREEMENT) == set(SUITE) - EXPECTED_AGREE


def test_classification_pins(plans):
    # montecarlo: the exact-accumulator hint wins with high confidence
    for obj in ("counts", "sums"):
        rep = plans["montecarlo"].object_report(obj)
        assert rep.klass == "crash-critical"
        assert rep.confidence == pytest.approx(0.9)
        assert "exact accumulator" in rep.rationale
    # cg: q is overwritten before it is read -> dead across the crash
    q = plans["cg"].object_report("q")
    assert q.klass == "dead"
    assert q.confidence == pytest.approx(0.95)
    # heat: the stencil contracts (damping < threshold) -> self-correcting
    u = plans["heat"].object_report("u")
    assert u.klass == "accumulator"
    assert u.decision == "skip"
    assert u.damping is not None and u.damping < plans["heat"].damping_threshold
    # sor: over-relaxation does not contract -> the accumulator must persist
    s = plans["sor"].object_report("u")
    assert s.klass == "accumulator" and s.decision == "persist"
    assert s.damping is not None and s.damping > plans["sor"].damping_threshold


def test_uncertain_regions_confidence(plans):
    # confident apps prune every region campaign under static+verify
    for name in ("sor", "pagerank", "kmeans", "montecarlo", "mg"):
        assert plans[name].uncertain_regions() == []
    # heat/cg carry low-confidence decisions that verify mode re-measures
    assert plans["heat"].uncertain_regions() == [1, 2]
    assert plans["cg"].uncertain_regions() == [1, 2, 3]
    for name in SUITE:
        for r in plans[name].regions:
            uncertain = r.index in plans[name].uncertain_regions()
            assert uncertain == (r.confidence < CONFIDENCE_THRESHOLD)


def test_write_traffic_positive(plans):
    for name in SUITE:
        assert plans[name].write_traffic_bytes() > 0


def test_pure_static_workflow_runs_no_campaigns():
    app = ci_app("sor")
    wf = run_workflow(app, WorkflowConfig(
        n_tests=40, seed=0, cache=default_cache(app), plan_source="static"))
    assert wf.plan_source == "static"
    assert wf.tests_executed == 0
    assert wf.baseline_campaign is None and wf.best_campaign is None
    assert wf.critical == ("u",)
    assert dict(wf.plan.region_freq) == {1: 4, 2: 1}
    assert wf.static_plan is not None
    # spec() must stay strict-JSON even with no measured campaigns
    d = json.loads(json.dumps(wf.spec()))
    assert d["plan_source"] == "static"
    assert d["summary"]["baseline_recomputability"] is None
    assert math.isnan(wf.summary()["baseline_recomputability"])
    with pytest.raises(ValueError, match="static"):
        wf.recompute_profile("best")


def test_static_verify_matches_measured_plan_with_fewer_tests():
    cache = default_cache(ci_app("sor"))
    measured = run_workflow(ci_app("sor"), WorkflowConfig(
        n_tests=40, seed=0, cache=cache))
    verified = run_workflow(ci_app("sor"), WorkflowConfig(
        n_tests=40, seed=0, cache=cache, plan_source="static+verify"))
    assert measured.tests_executed == 170
    assert verified.tests_executed == 80   # baseline + best, 0 region campaigns
    assert verified.plan.objects == measured.plan.objects == ("u",)
    assert dict(verified.plan.region_freq) == dict(measured.plan.region_freq)
    saved = 1 - verified.tests_executed / measured.tests_executed
    assert saved >= 0.40
    # verify mode keeps the measured evidence it did collect
    assert verified.baseline_campaign is not None
    assert verified.best_campaign is not None
    assert verified.plan_source == "static+verify"
    # measured workflows are unchanged by the feature (provenance default)
    assert measured.plan_source == "measured"


def test_static_plan_artifact_roundtrip(tmp_path):
    app = ci_app("pagerank")
    sp = analyze_app(app, cache=default_cache(app))
    path = str(tmp_path / "pagerank_static.json")
    fp = save_static_plan(path, sp, meta={"note": "test"})
    art = load_static_plan(path)
    assert art.fingerprint == fp
    assert art.app_name == "pagerank"
    assert art.meta == {"note": "test"}
    rt = art.static_plan()
    assert rt.persist_objects() == sp.persist_objects()
    assert rt.region_decisions() == sp.region_decisions()
    assert rt.uncertain_regions() == sp.uncertain_regions()
    assert [o.klass for o in rt.objects] == [o.klass for o in sp.objects]

    # fingerprint rejection on tamper
    with open(path) as f:
        doc = json.load(f)
    doc["payload"]["app"] = "sor"
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(ArtifactError):
        load_static_plan(path)


def test_config_validation():
    with pytest.raises(ValueError, match="plan_source"):
        WorkflowConfig(plan_source="psychic")
    with pytest.raises(ValueError, match="store_path"):
        WorkflowConfig(plan_source="static", store_path="x.jsonl")
    with pytest.raises(ValueError, match="isolated"):
        WorkflowConfig(plan_source="static+verify", region_measure="paper")


def test_measured_config_spec_fingerprint_unchanged():
    """Historical (measured) workflow identities must not grow a
    plan_source field — resume stores and artifact fingerprints from
    before this feature stay valid."""
    from repro.core import CacheConfig, CrashTester, PersistPlan

    app = ci_app("kmeans")
    tester = CrashTester(app, PersistPlan.none(), CacheConfig(), seed=0)
    spec = WorkflowConfig(n_tests=7).spec(app, tester)
    assert "plan_source" not in spec
    spec2 = WorkflowConfig(n_tests=7, plan_source="static+verify").spec(app, tester)
    assert spec2["plan_source"] == "static+verify"
