"""Golden regression: pinned fleet-serving metrics for a small seeded fleet.

Any change that shifts the fleet DES — event ordering, RNG stream layout,
queueing/dispatch, recovery accounting, latency bookkeeping — fails here
loudly, per policy.  Integer counters (arrived/served/dropped, failures,
recoveries) are pinned exactly; float metrics (goodput, SLO fraction,
latency percentiles) are pinned rounded to 6 decimals so the pins survive
last-ulp libm differences across platforms (within-platform byte-identity
is asserted separately in tests/test_fleetsim.py).  The pins live in
``tests/golden/fleet_goldens.json``; when a shift is *intended*, regenerate

    PYTHONPATH=src python tests/test_fleet_goldens.py --regen

and say so in the commit message.
"""
import json
import os

import pytest

from repro.core.efficiency import SystemConfig
from repro.core.fleetsim import ArrivalProcess, FleetConfig, ServiceModel, simulate_fleet
from repro.core.sysim import POLICIES, PoissonTrace, RecomputeProfile

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden", "fleet_goldens.json")

#: synthetic profile — fixed fractions, not a campaign run, so the fleet
#: pins only move when the *fleet* simulator moves
GOLDEN_PROFILE = RecomputeProfile.from_fractions(
    "golden", {"S1": 0.7, "S2": 0.2, "S3": 0.05, "S4": 0.05},
    extra_iters_hist=((2, 3), (8, 1)),
)

ROUND = 6

_INT_KEYS = (
    "arrived", "served", "dropped", "dropped_down", "in_flight",
    "slo_violations", "n_failures", "n_checkpoints", "n_nvm_recoveries",
    "n_fallbacks", "n_cold_restarts",
)
_FLOAT_KEYS = (
    "goodput", "slo_violation_frac", "availability",
    "latency_p50", "latency_p95", "latency_p99", "latency_mean", "latency_max",
)


def golden_config() -> FleetConfig:
    return FleetConfig(
        n_replicas=3,
        arrival=ArrivalProcess(rate=2.5, amplitude=0.3),
        service=ServiceModel(mean_s=0.4, sigma=0.5, prefill_s=0.8),
        trace=PoissonTrace(mtbf=400.0),
        system=SystemConfig(mtbf=400.0, t_chk=15.0, nvm_restore_time=2.0),
        slo_latency=1.5,
        queue_cap=24,
        horizon=1200.0,
        t_s=0.02,
        seed=321,
    )


def _entry(policy: str) -> dict:
    cfg = golden_config()
    prof = GOLDEN_PROFILE if policy in ("easycrash", "hybrid") else None
    r = simulate_fleet(policy, cfg, prof)
    p = r.payload()
    out = {k: p[k] for k in _INT_KEYS}
    out.update({k: round(p[k], ROUND) for k in _FLOAT_KEYS})
    return out


def _load_goldens():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


def test_fleet_golden_smoke():
    """Fast-gate leg: the single hybrid pin — the policy exercising every
    recovery path (NVM warm starts, fallback checkpoints, cold restarts)."""
    goldens = _load_goldens()
    assert _entry("hybrid") == goldens["policies"]["hybrid"]


@pytest.mark.slow
@pytest.mark.parametrize("policy", POLICIES)
def test_fleet_metrics_match_golden(policy):
    goldens = _load_goldens()
    assert goldens["fingerprint"] == golden_config().fingerprint(), (
        "golden fleet config drifted; regenerate tests/golden/fleet_goldens.json"
    )
    assert policy in goldens["policies"], f"no golden pinned for {policy}; --regen"
    got = _entry(policy)
    want = goldens["policies"][policy]
    assert got == want, (
        f"{policy}: fleet metrics drifted:\n got {got}\nwant {want}"
    )


def _regen():
    cfg = golden_config()
    doc = {
        "fingerprint": cfg.fingerprint(),
        "config": cfg.spec(),
        "policies": {policy: _entry(policy) for policy in POLICIES},
    }
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {GOLDEN_PATH}")
    for policy, e in doc["policies"].items():
        print(f"  {policy:10s} goodput={e['goodput']} p99={e['latency_p99']} "
              f"served={e['served']}/{e['arrived']}")


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
