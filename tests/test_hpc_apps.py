"""Every HPC app: golden run passes its own acceptance verification."""
import numpy as np
import pytest

from repro.hpc import app_names, get_app
from repro.hpc.suite import CI_SIZES, ci_app


@pytest.mark.parametrize("name", sorted(CI_SIZES))
def test_golden_verifies(name):
    app = ci_app(name)
    state, iters = app.run_golden()
    res = app.verify(state)
    assert res.passed, (name, res)
    assert iters > 0


@pytest.mark.parametrize("name", sorted(CI_SIZES))
def test_regions_declare_their_writes(name):
    """Region metadata must match behaviour: a region only mutates objects it
    declares in ``writes`` (the cache model depends on this)."""
    app = ci_app(name)
    state = app.init(0)
    # run one warm-up iteration so temporals are populated
    state = app.run_iteration(state)
    for region in app.regions():
        before = {k: np.array(v, copy=True) for k, v in state.items()}
        state = region.fn(state)
        for k in state:
            if k in region.writes:
                continue
            assert np.array_equal(before[k], state[k]), (
                f"{name}: region {region.name} mutated undeclared object {k}"
            )


@pytest.mark.parametrize("name", sorted(CI_SIZES))
def test_restart_init_installs_persisted(name):
    app = ci_app(name)
    state = app.init(0)
    state = app.run_iteration(state)
    persisted = {c: state[c] for c in app.candidates if c in state}
    restored = app.restart_init(0, persisted)
    for c, v in persisted.items():
        assert np.allclose(restored[c].astype(np.float64), np.asarray(v, np.float64)), (name, c)


@pytest.mark.parametrize("name", sorted(CI_SIZES))
def test_deterministic_iterations(name):
    """Redo of the same iteration from the same state must be bit-identical
    (the basis for trajectory-match acceptance)."""
    app = ci_app(name)
    s0 = app.init(0)
    s0 = app.run_iteration(s0)
    snap = {k: np.array(v, copy=True) for k, v in s0.items()}
    a = app.run_iteration({k: np.array(v, copy=True) for k, v in snap.items()})
    b = app.run_iteration({k: np.array(v, copy=True) for k, v in snap.items()})
    for k in a:
        assert np.array_equal(a[k], b[k]), (name, k)


def test_registry():
    assert set(app_names()) == set(CI_SIZES)
    with pytest.raises(KeyError):
        get_app("nope")
