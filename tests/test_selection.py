import math

import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core.selection import (
    interpolate_ckx,
    select_regions,
    select_regions_from_gains,
    spearman,
    t_sf,
)


# ---------------------------------------------------------------- spearman
def _spearman_reference(x, y):
    """Naive Spearman: Pearson on average ranks."""
    def rank(v):
        v = np.asarray(v, float)
        order = np.argsort(v, kind="stable")
        r = np.empty(len(v))
        i = 0
        sv = v[order]
        while i < len(v):
            j = i
            while j + 1 < len(v) and sv[j + 1] == sv[i]:
                j += 1
            r[order[i:j + 1]] = (i + j) / 2 + 1
            i = j + 1
        return r

    rx, ry = rank(x), rank(y)
    rx -= rx.mean()
    ry -= ry.mean()
    return float(rx @ ry / np.sqrt((rx @ rx) * (ry @ ry)))


def test_spearman_perfect_monotone():
    rs, p = spearman([1, 2, 3, 4, 5], [10, 20, 30, 40, 50])
    assert rs == pytest.approx(1.0)
    assert p < 0.05


def test_spearman_anticorrelation():
    x = np.linspace(0, 1, 30)
    rs, p = spearman(x, -x + 0.001 * np.sin(x * 50))
    assert rs < -0.9
    assert p < 1e-6


def test_spearman_degenerate():
    rs, p = spearman([1.0] * 10, list(range(10)))
    assert math.isnan(rs) and p == 1.0


def test_t_sf_known_values():
    # P(T > 0) = 0.5 for any df
    assert t_sf(0.0, 10) == pytest.approx(0.5, abs=1e-9)
    # df=1 (Cauchy): P(T > 1) = 0.25
    assert t_sf(1.0, 1) == pytest.approx(0.25, abs=1e-6)
    # large df ~ normal: P(T > 1.96) ~ 0.025
    assert t_sf(1.96, 10_000) == pytest.approx(0.025, abs=1e-3)


@given(
    n=st.integers(5, 60),
    seed=st.integers(0, 2**31 - 1),
    ties=st.booleans(),
)
@settings(max_examples=60, deadline=None)
def test_spearman_matches_reference(n, seed, ties):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n)
    y = rng.standard_normal(n)
    if ties:
        x = np.round(x)          # heavy ties
        y = (y > 0).astype(float)  # binary, like recompute outcomes
    rs, p = spearman(x, y)
    if math.isnan(rs):
        return
    assert rs == pytest.approx(_spearman_reference(x, y), abs=1e-9)
    assert 0.0 <= p <= 1.0


# ---------------------------------------------------------------- knapsack
def test_interpolation_eq5():
    assert interpolate_ckx(0.9, 0.3, 1) == pytest.approx(0.9)
    assert interpolate_ckx(0.9, 0.3, 2) == pytest.approx(0.6)
    assert interpolate_ckx(0.9, 0.3, 6) == pytest.approx(0.4)


def test_select_regions_respects_budget():
    a = [0.25, 0.25, 0.25, 0.25]
    c_base = [0.2, 0.2, 0.2, 0.2]
    c_max = [0.9, 0.9, 0.9, 0.9]
    l = [0.02, 0.02, 0.02, 0.02]
    sel = select_regions(a, c_base, c_max, l, t_s=0.03, tau=0.1)
    assert sel.total_overhead <= 0.03 + 1e-9
    assert len(sel.choices) >= 1


def test_select_regions_prefers_high_gain():
    # region 1 has far higher gain at the same cost: must be selected
    sel = select_regions(
        a=[0.5, 0.5], c_base=[0.1, 0.1], c_max=[0.15, 0.95],
        l=[0.02, 0.02], t_s=0.025, tau=0.0,
    )
    assert any(c.region_idx == 1 for c in sel.choices)
    assert all(c.region_idx != 0 or c.freq > 1 for c in sel.choices)


def test_select_regions_skips_negative_gain():
    sel = select_regions_from_gains(
        gains={0: -0.1, 1: 0.0}, overheads={0: 0.001, 1: 0.001},
        y_base=0.5, t_s=0.03, tau=0.0,
    )
    assert sel.choices == []
    assert sel.expected_recomputability == pytest.approx(0.5)


@given(
    w=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
    ts=st.floats(0.005, 0.1),
)
@settings(max_examples=40, deadline=None)
def test_knapsack_budget_invariant(w, seed, ts):
    rng = np.random.default_rng(seed)
    gains = {k: float(rng.uniform(-0.2, 0.5)) for k in range(w)}
    overheads = {k: float(rng.uniform(0.001, 0.08)) for k in range(w)}
    sel = select_regions_from_gains(gains, overheads, 0.3, t_s=ts, tau=0.0)
    assert sel.total_overhead <= ts + 1e-9
    # at most one choice per region; only positive gains chosen
    regions = [c.region_idx for c in sel.choices]
    assert len(regions) == len(set(regions))
    assert all(c.gain > 0 for c in sel.choices)
