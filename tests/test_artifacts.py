"""Plan/workflow/profile artifacts: round-trip, fingerprint rejection,
replay."""
import dataclasses
import json

import pytest

from repro.core import CrashTester, PersistPlan, RecomputeProfile
from repro.core.artifacts import (
    ArtifactError,
    load_plan,
    load_profile,
    load_workflow,
    plan_from_payload,
    plan_to_payload,
    profile_from_payload,
    profile_from_workflow,
    profile_to_payload,
    replay_plan,
    save_plan,
    save_profile,
    save_workflow,
)
from repro.core.faults import PowerFail, TornWrite
from repro.core.workflow import run_workflow
from repro.hpc.suite import ci_app, default_cache


@pytest.fixture(scope="module")
def km_setup():
    app = ci_app("kmeans")
    return app, default_cache(app)


@pytest.fixture(scope="module")
def km_workflow(km_setup):
    app, cache = km_setup
    return run_workflow(app, n_tests=14, cache=cache, seed=0,
                        region_measure="paper")


def test_plan_payload_round_trip():
    plan = PersistPlan(objects=("u", "r"), region_freq={2: 4, 0: 1})
    assert plan_from_payload(plan_to_payload(plan)) == plan
    assert plan_from_payload(json.loads(json.dumps(plan_to_payload(plan)))) == plan
    assert plan_from_payload(plan_to_payload(PersistPlan.none())) == PersistPlan.none()


def test_plan_artifact_round_trip(km_setup, km_workflow, tmp_path):
    app, _ = km_setup
    wf = km_workflow
    path = str(tmp_path / "plan.json")
    fp = save_plan(path, wf.plan, app_name=app.name, fault=TornWrite(depth=3),
                   meta={"tau": wf.tau})
    art = load_plan(path)
    assert art.plan == wf.plan
    assert art.app_name == app.name
    assert art.fingerprint == fp
    assert art.fault == TornWrite(depth=3)
    assert art.meta["tau"] == wf.tau
    # saving the identical payload is deterministic
    assert save_plan(str(tmp_path / "p2.json"), wf.plan, app_name=app.name,
                     fault=TornWrite(depth=3), meta={"tau": wf.tau}) == fp


def test_artifact_rejects_tampering(km_setup, km_workflow, tmp_path):
    app, _ = km_setup
    path = str(tmp_path / "plan.json")
    save_plan(path, km_workflow.plan, app_name=app.name)
    doc = json.load(open(path))
    doc["payload"]["plan"]["objects"] = ["weights"]  # the hand-edited plan
    json.dump(doc, open(path, "w"))
    with pytest.raises(ArtifactError, match="fingerprint mismatch"):
        load_plan(path)
    # truncation / non-JSON
    with open(path, "w") as f:
        f.write(json.dumps(doc)[: 40])
    with pytest.raises(ArtifactError, match="unreadable"):
        load_plan(path)
    # binary garbage (invalid UTF-8) is ArtifactError too, not UnicodeDecodeError
    with open(path, "wb") as f:
        f.write(b"\xff\xfe\x00garbage")
    with pytest.raises(ArtifactError, match="unreadable"):
        load_plan(path)
    # wrong kind
    path2 = str(tmp_path / "wf.json")
    save_workflow(path2, km_workflow)
    with pytest.raises(ArtifactError, match="not a"):
        load_plan(path2)
    # mangled version field must raise ArtifactError, not TypeError
    save_plan(path, km_workflow.plan, app_name=app.name)
    doc = json.load(open(path))
    doc["version"] = None
    json.dump(doc, open(path, "w"))
    with pytest.raises(ArtifactError, match="version"):
        load_plan(path)


def test_workflow_artifact_round_trip(km_setup, km_workflow, tmp_path):
    app, _ = km_setup
    wf = km_workflow
    path = str(tmp_path / "wf.json")
    save_workflow(path, wf, fault=PowerFail())
    art = load_workflow(path)
    assert art.plan == wf.plan
    assert art.critical == wf.critical
    assert art.summary == wf.summary()
    assert art.tau == wf.tau and art.t_s == wf.t_s
    assert art.campaign_fractions["baseline"] == \
           wf.baseline_campaign.class_fractions()
    assert [s["name"] for s in art.object_scores] == \
           [s.name for s in wf.object_scores]
    assert art.fault == PowerFail()


def test_replay_plan_reproduces_direct_campaign(km_setup, km_workflow, tmp_path):
    """Replaying a loaded artifact == running CrashTester with the plan."""
    app, cache = km_setup
    wf = km_workflow
    path = str(tmp_path / "plan.json")
    save_plan(path, wf.plan, app_name=app.name)
    replayed = replay_plan(path, app, cache=cache, n_tests=10, seed=5)
    direct = CrashTester(app, wf.plan, cache, seed=5).run_campaign(10)
    assert [dataclasses.asdict(r) for r in replayed.records] == \
           [dataclasses.asdict(r) for r in direct.records]


def test_replay_plan_under_other_fault(km_setup, km_workflow, tmp_path):
    """The cross-fault experiment: fault=None replays the characterization
    model; an explicit model overrides it."""
    app, cache = km_setup
    path = str(tmp_path / "plan.json")
    save_plan(path, km_workflow.plan, app_name=app.name, fault=TornWrite())
    under_torn = replay_plan(path, app, cache=cache, n_tests=8, seed=5)
    direct = CrashTester(app, km_workflow.plan, cache, seed=5,
                         fault=TornWrite()).run_campaign(8)
    assert [dataclasses.asdict(r) for r in under_torn.records] == \
           [dataclasses.asdict(r) for r in direct.records]
    under_power = replay_plan(path, app, cache=cache, n_tests=8, seed=5,
                              fault=PowerFail())
    assert [dataclasses.asdict(r) for r in under_power.records] != \
           [dataclasses.asdict(r) for r in under_torn.records]


def test_artifact_records_cache_and_replay_defaults_to_it(km_setup, km_workflow, tmp_path):
    """The characterization cache geometry travels with the plan; replaying
    without an explicit cache uses it (not the generic CacheConfig())."""
    app, cache = km_setup
    path = str(tmp_path / "plan.json")
    save_plan(path, km_workflow.plan, app_name=app.name, cache=cache)
    art = load_plan(path)
    assert art.cache == cache
    implicit = replay_plan(path, app, n_tests=8, seed=5)
    explicit = CrashTester(app, km_workflow.plan, cache, seed=5).run_campaign(8)
    assert [dataclasses.asdict(r) for r in implicit.records] == \
           [dataclasses.asdict(r) for r in explicit.records]
    # a plan saved without cache context still replays (generic default)
    path2 = str(tmp_path / "nocache.json")
    save_plan(path2, km_workflow.plan, app_name=app.name)
    assert load_plan(path2).cache is None
    replay_plan(path2, app, n_tests=2, seed=5)


def test_workflow_artifact_is_strict_json_even_with_nan_scores(km_setup, km_workflow, tmp_path):
    """NaN Spearman scores (constant inconsistency vectors) must serialize
    as null, not the non-portable NaN token."""
    from repro.core.selection import ObjectScore

    app, _ = km_setup
    wf = dataclasses.replace(
        km_workflow,
        object_scores=[ObjectScore("ghost", float("nan"), 1.0, False)],
    )
    path = str(tmp_path / "wf.json")
    save_workflow(path, wf)

    def no_constants(s):
        raise AssertionError(f"non-strict JSON token {s!r} in artifact")

    doc = json.loads(open(path).read(), parse_constant=no_constants)
    assert doc["payload"]["object_scores"][0]["rs"] is None
    art = load_workflow(path)
    assert art.object_scores[0]["rs"] is None


def test_artifacts_survive_nonfinite_tau(km_setup, km_workflow, tmp_path):
    """tau_threshold returns inf when EasyCrash can never win (documented);
    a finished workflow must still serialize — non-finite floats map to
    null, and the strict encoder never raises after the campaigns ran."""
    import math

    app, _ = km_setup
    wf = dataclasses.replace(km_workflow, tau=float("inf"))
    path = str(tmp_path / "wf.json")
    save_workflow(path, wf)
    art = load_workflow(path)
    assert math.isnan(art.tau)  # null round-trips as nan
    plan_path = str(tmp_path / "plan.json")
    save_plan(plan_path, wf.plan, app_name=app.name,
              meta={"tau": float("inf"), "note": "kept"})
    loaded = load_plan(plan_path)
    assert loaded.meta == {"tau": None, "note": "kept"}


def _demo_profile():
    return RecomputeProfile.from_fractions(
        "kmeans", {"S1": 0.6, "S2": 0.25, "S3": 0.05, "S4": 0.1},
        fault_spec=PowerFail().spec(),
        extra_iters_hist=((1, 3), (4, 2)), golden_iters=8, n_records=20,
    )


def test_profile_payload_round_trip():
    prof = _demo_profile()
    assert profile_from_payload(profile_to_payload(prof)) == prof
    assert profile_from_payload(
        json.loads(json.dumps(profile_to_payload(prof)))
    ) == prof


def test_profile_artifact_round_trip(tmp_path):
    prof = _demo_profile()
    path = str(tmp_path / "profile.json")
    fp = save_profile(path, prof, meta={"campaign": "best", "n_tests": 20})
    art = load_profile(path)
    assert art.profile == prof
    assert art.app_name == "kmeans"
    assert art.meta == {"campaign": "best", "n_tests": 20}
    assert art.fingerprint == fp
    assert art.fault == PowerFail()
    # deterministic fingerprint for the identical payload
    assert save_profile(str(tmp_path / "p2.json"), prof,
                        meta={"campaign": "best", "n_tests": 20}) == fp


def test_profile_artifact_rejects_tampering(tmp_path):
    path = str(tmp_path / "profile.json")
    save_profile(path, _demo_profile())
    doc = json.load(open(path))
    doc["payload"]["fractions"]["S1"] = 0.99  # the hand-tuned success rate
    json.dump(doc, open(path, "w"))
    with pytest.raises(ArtifactError, match="fingerprint mismatch"):
        load_profile(path)
    # a plan artifact is not a profile artifact
    plan_path = str(tmp_path / "plan.json")
    save_plan(plan_path, PersistPlan.none(), app_name="kmeans")
    with pytest.raises(ArtifactError, match="not a"):
        load_profile(plan_path)


def test_workflow_recompute_profile_and_from_workflow(km_setup, km_workflow, tmp_path):
    """The workflow's measured profile round-trips two ways: directly from
    the campaigns (with the recompute-cost histogram) and from a stored
    workflow artifact (rates only, histogram empty)."""
    wf = km_workflow
    prof = wf.recompute_profile()
    assert prof.app_name == wf.app_name
    assert prof.fractions == wf.best_campaign.class_fractions()
    assert prof.n_records == wf.best_campaign.n
    s2 = [r.extra_iters for r in wf.best_campaign.records if r.outcome == "S2"]
    assert sum(c for _, c in prof.extra_iters_hist) == len(s2)
    base = wf.recompute_profile(which="baseline")
    assert base.fractions == wf.baseline_campaign.class_fractions()
    with pytest.raises(ValueError, match="which"):
        wf.recompute_profile(which="plan")

    path = str(tmp_path / "wf.json")
    save_workflow(path, wf, fault=PowerFail())
    art = load_workflow(path)
    from_art = profile_from_workflow(art)
    assert from_art.fractions == pytest.approx(prof.fractions)
    assert from_art.extra_iters_hist == ()
    assert from_art.fault_spec == dict(PowerFail().spec())
    with pytest.raises(ArtifactError, match="no 'plan' campaign"):
        profile_from_workflow(art, which="plan")


def test_replay_refuses_foreign_app(km_setup, km_workflow, tmp_path):
    app, cache = km_setup
    path = str(tmp_path / "plan.json")
    save_plan(path, km_workflow.plan, app_name=app.name)
    other = ci_app("mg")
    with pytest.raises(ArtifactError, match="cannot replay"):
        replay_plan(path, other, cache=cache, n_tests=4)
