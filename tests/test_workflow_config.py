"""WorkflowConfig: the consolidated run_workflow API and its kwargs shim."""
import dataclasses as dc
import warnings

import pytest

from repro.core import (
    CacheConfig,
    CampaignSpec,
    CrashTester,
    PersistPlan,
    SystemConfig,
    WorkflowConfig,
    run_workflow,
)
from repro.hpc.suite import ci_app, default_cache


@pytest.fixture(scope="module")
def mg_setup():
    app = ci_app("mg")
    return app, default_cache(app)


def _wf_dicts(wf):
    return [dc.asdict(r) for r in wf.baseline_campaign.records]


# -------------------------------------------------------------- construction
def test_defaults_and_freeze():
    cfg = WorkflowConfig()
    assert cfg.n_tests == 200 and cfg.seed == 0
    assert cfg.freq_options == (1, 2, 4, 8)
    with pytest.raises(dc.FrozenInstanceError):
        cfg.n_tests = 5


def test_validation():
    with pytest.raises(ValueError, match="n_tests"):
        WorkflowConfig(n_tests=0)
    with pytest.raises(ValueError, match="region_measure"):
        WorkflowConfig(region_measure="bogus")
    with pytest.raises(ValueError, match="scheduler"):
        WorkflowConfig(scheduler="bogus")
    with pytest.raises(ValueError, match="shared"):
        WorkflowConfig(scheduler="serial", store_path="/tmp/x.jsonl")


def test_replace_revalidates():
    cfg = WorkflowConfig(n_tests=10)
    assert cfg.replace(seed=3).seed == 3
    assert cfg.replace(seed=3).n_tests == 10
    with pytest.raises(ValueError):
        cfg.replace(n_tests=0)
    # freq_options coerce to int tuples however they arrive
    assert cfg.replace(freq_options=[1.0, 2]).freq_options == (1, 2)


def test_spec_is_workflow_identity(mg_setup):
    """spec() carries exactly the result-changing fields; execution plumbing
    (workers, scheduler, callbacks) must not perturb it."""
    app, cache = mg_setup
    cfg = WorkflowConfig(n_tests=12, cache=cache)
    tester = CrashTester(app, PersistPlan.none(), cache, seed=0)
    base = cfg.spec(app, tester)
    assert base["app"] == app.name and base["n_tests"] == 12
    same = cfg.replace(n_workers=4, engine="ref",
                       shard_callback=lambda k, i: None).spec(app, tester)
    assert same == base
    assert cfg.replace(seed=1).spec(app, tester) != base
    assert cfg.replace(t_s=0.05).spec(app, tester) != base
    import json

    json.dumps(base)  # JSON-round-trip safe by contract


# ---------------------------------------------------------------------- shim
def test_kwargs_shim_warns_and_matches_config(mg_setup):
    """Old-style keyword calls go through a deprecation shim and produce
    results identical to the explicit WorkflowConfig call."""
    app, cache = mg_setup
    new = run_workflow(app, WorkflowConfig(n_tests=14, cache=cache, seed=0))
    with pytest.warns(DeprecationWarning, match="WorkflowConfig"):
        old = run_workflow(app, n_tests=14, cache=cache, seed=0)
    assert _wf_dicts(old) == _wf_dicts(new)
    assert old.plan == new.plan
    assert old.t_s == new.t_s


def test_kwargs_shim_warning_points_at_caller(mg_setup):
    """The DeprecationWarning must be attributed to the *calling* site (the
    code that has to migrate to WorkflowConfig), not to workflow.py's shim —
    stacklevel drift here turns every deprecation report into a dead end."""
    app, cache = mg_setup
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always", DeprecationWarning)
        run_workflow(app, n_tests=14, cache=cache, seed=0)
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert dep, "kwargs shim did not warn"
    assert dep[0].filename == __file__, (
        f"warning blamed {dep[0].filename}, not the caller"
    )


def test_positional_shim_warning_points_at_caller(mg_setup):
    """Same contract for the legacy positional form run_workflow(app, n_tests)."""
    app, cache = mg_setup
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always", DeprecationWarning)
        run_workflow(app, 14, cache=cache, seed=0)
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert dep, "positional shim did not warn"
    assert dep[0].filename == __file__, (
        f"warning blamed {dep[0].filename}, not the caller"
    )


def test_config_with_override_kwargs(mg_setup):
    """run_workflow(app, cfg, seed=...) applies kwargs as replace() overrides
    without a deprecation warning."""
    app, cache = mg_setup
    cfg = WorkflowConfig(n_tests=12, cache=cache, seed=0)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        a = run_workflow(app, cfg, seed=1)
    b = run_workflow(app, cfg.replace(seed=1))
    assert _wf_dicts(a) == _wf_dicts(b)


def test_rejects_non_config_positional(mg_setup):
    app, _ = mg_setup
    with pytest.raises(TypeError, match="WorkflowConfig"):
        run_workflow(app, "nonsense")


def test_campaign_spec_seeds_follow_contract():
    """The W+2 seed layout (baseline=seed, best=seed+1, region k=seed+2+k)
    is workflow identity — spelled out here so a refactor cannot silently
    reshuffle it and orphan every resume store."""
    spec = CampaignSpec("baseline", PersistPlan.none(), 7, 10)
    assert spec.key == "baseline" and spec.seed == 7 and spec.n_tests == 10
